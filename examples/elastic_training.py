"""End-to-end driver: train a ~100M-parameter LM under the full Kotta stack.

The paper's execution model applied to training: the job runs on
*preemptible* capacity — we inject spot revocations from the market model —
and survives via tiered checkpoints + the deterministic step-indexed loader
(bitwise-identical resume). Defaults are sized for a CPU container
(~25M params, 60 steps); ``--full`` selects the ~100M/300-step configuration
from the assignment.

    PYTHONPATH=src python examples/elastic_training.py [--full]
"""
import argparse
import time

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.core import (DEFAULT_ZONES, ObjectStore, PolicyEngine, SpotMarket,
                        install_standard_roles)
from repro.data import SyntheticCorpus, TokenLoader
from repro.models import count_params
from repro.train import AdamWConfig, ElasticTrainer


def build_cfg(full: bool):
    base = get_config("internlm2-1.8b")
    if full:  # ~100M-parameter configuration
        return base.replace(num_layers=10, d_model=640, num_heads=10,
                            num_kv_heads=5, head_dim=64, d_ff=2560,
                            vocab_size=8192, remat="none"), 300, 16, 128
    return base.replace(num_layers=4, d_model=256, num_heads=4,
                        num_kv_heads=2, head_dim=64, d_ff=1024,
                        vocab_size=2048, remat="none"), 30, 4, 64


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    cfg, steps, batch, seq = build_cfg(args.full)
    print(f"model: {count_params(cfg) / 1e6:.1f}M params, {steps} steps")

    engine = PolicyEngine()
    install_standard_roles(engine)
    store = ObjectStore(clock=engine.clock)
    keys = SyntheticCorpus.build(store, "corpus", num_shards=4,
                                 tokens_per_shard=max(batch * (seq + 1) * 8,
                                                      65_536),
                                 vocab_size=cfg.vocab_size)
    loader = TokenLoader(store.get, keys, batch_size=batch, seq_len=seq)

    # Preemptible capacity: revoke whenever the us-east-1a spot price spikes
    # above a stingy bid (each revocation costs us the steps since the last
    # checkpoint — exactly the paper's §V-B trade-off).
    market = SpotMarket(seed=4)
    zone, itype, bid = DEFAULT_ZONES[0], "m4.xlarge", 0.08
    revoked_steps = []

    def revoke_at(step):
        hour = step / 10.0  # pretend 10 steps/hour for the price trace
        if market.price(zone, itype, hour) > bid and \
                (not revoked_steps or step - revoked_steps[-1] > 15):
            revoked_steps.append(step)
            return True
        return False

    opt = AdamWConfig(learning_rate=3e-3, warmup_steps=10, decay_steps=steps)
    trainer = ElasticTrainer(cfg, opt, Checkpointer(store, "elastic-demo"),
                             seed=0, async_checkpoint=True)
    t0 = time.time()
    report = trainer.train(loader, steps, checkpoint_every=10,
                           revoke_at=revoke_at)
    dt = time.time() - t0
    print(f"done in {dt:.1f}s: {report.steps_run} steps executed for "
          f"{report.final_step} global steps "
          f"({report.restarts} revocations at {revoked_steps})")
    first, last = min(report.losses), max(report.losses)
    print(f"loss {report.losses[first]:.3f} -> {report.losses[last]:.3f}")
    print(f"checkpoints: {trainer.ckpt.steps()[-3:]} "
          f"(tiered store, ${store.monthly_cost():.6f}/mo)")


if __name__ == "__main__":
    main()
