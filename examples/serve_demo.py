"""Batched serving near the data (paper: 'analytics close to the data').

Continuous-batching greedy decode over a shared *paged* KV cache for a batch
of ragged prompts, with the model weights restored from a tiered-store
checkpoint. Finished sequences free their cache pages for queued prompts —
the serving analogue of the paper's elastic provisioning.

    PYTHONPATH=src python examples/serve_demo.py
"""
import time

import jax

from repro.checkpoint import Checkpointer
from repro.configs import get_reduced_config
from repro.core import ObjectStore, VirtualClock
from repro.models import get_family
from repro.models.params import init_params
from repro.serve import ContinuousBatchingEngine


def main():
    cfg = get_reduced_config("mistral-nemo-12b").replace(vocab_size=1024)
    fam = get_family(cfg)
    params = init_params(fam.layout(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)

    # round-trip the weights through the tiered store (deploy-from-checkpoint)
    store = ObjectStore(clock=VirtualClock())
    ck = Checkpointer(store, "serve-model")
    ck.save(0, params)
    _, params = ck.restore(params)
    print(f"restored {len(jax.tree.leaves(params))} weight tensors "
          f"from the object store")

    # 2 slots for 4 prompts: the last two queue and are admitted the moment
    # the first finishers evict and free their pages (continuous batching).
    engine = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2)
    prompts = [[1, 2, 3], [10, 11], [42, 43, 44, 45], [7]]
    t0 = time.time()
    out = engine.generate(prompts, max_new=12)
    dt = time.time() - t0
    for p, toks in zip(prompts, out.tokens.tolist()):
        print(f"prompt {p} -> {toks}")
    n_tok = out.tokens.size
    print(f"{n_tok} tokens in {dt:.2f}s ({n_tok / dt:.1f} tok/s batched on CPU)")


if __name__ == "__main__":
    main()
