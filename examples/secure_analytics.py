"""The Cloud Kotta story (paper §II + §VI): secure multi-tenant analytics.

Three tenants, one enclave:
- admin registers the private "wos" corpus (non-downloadable) + public wiki;
- alice (researcher, WOS access) submits an LDA-ish topic-count job — the
  worker assumes her role to stage data, computes near the data, and her
  results are private;
- bob (public-only) can analyze wikipedia but is denied WOS — at submit time,
  with the denial in the audit log;
- a cold shard ages to ARCHIVE; a job needing it parks in the restore queue
  (fast-forwarded here) and then completes — the paper's Glacier path.

    PYTHONPATH=src python examples/secure_analytics.py
"""
import collections
import time

import numpy as np

from repro.core import (ExecutableRegistry, JobSpec, JobStatus, KottaService,
                        ObjectStore, PolicyEngine, Principal, Role, Tier,
                        allow, install_standard_roles, make_dataset_role)


def main():
    engine = PolicyEngine()
    install_standard_roles(engine)
    store = ObjectStore(clock=engine.clock)
    registry = ExecutableRegistry()

    @registry.register("topic_count")
    def topic_count(ctx):
        """Toy LDA stand-in: top tokens across staged shards."""
        counts = collections.Counter()
        for data in ctx.staged_inputs.values():
            counts.update(np.frombuffer(data, dtype=np.int32) % 97)
        top = counts.most_common(5)
        ctx.outputs[f"results/{ctx.job_id}/topics.txt"] = repr(top).encode()
        return top[0]

    svc = KottaService(engine, store, registry,
                       watcher_kwargs={"heartbeat_timeout_s": 2.0,
                                       "interval_s": 0.05})

    # --- datasets -----------------------------------------------------------
    rng = np.random.default_rng(0)
    for name, public in [("wos", False), ("wikipedia", True)]:
        prefix = "public/" if public else ""
        for i in range(2):
            store.put(f"dataset/{prefix}{name}/shard-{i}",
                      rng.integers(0, 50_000, 4096, dtype=np.int32).tobytes(),
                      owner="admin")
    make_dataset_role(engine, "wos", downloadable=False)

    # --- tenants ---------------------------------------------------------------
    researcher = Role("researcher", policies=[
        allow(["data:Get", "data:List"], ["dataset/wos/*", "dataset/public/*"]),
        allow(["data:*"], ["results/*"]),
        allow(["jobs:*"], ["queue/*"])], trusted_assumers={"task-executor"})
    publicuser = Role("public-user", policies=[
        allow(["data:Get", "data:List"], ["dataset/public/*"]),
        allow(["data:*"], ["results/*"]),
        allow(["jobs:*"], ["queue/*"])], trusted_assumers={"task-executor"})
    engine.register_role(researcher)
    engine.register_role(publicuser)
    for uid, role in [("alice", "researcher"), ("bob", "public-user")]:
        p = Principal(uid)
        engine.authenticator.register_identity(p, "pw")
        engine.bind(p, role)

    svc.start(dev_workers=1)
    alice = engine.login("alice", "pw")
    bob = engine.login("bob", "pw")

    # --- alice analyzes the private corpus --------------------------------------
    job = svc.submit(alice, JobSpec(
        "topic_count", inputs=tuple(store.keys("dataset/wos/")), queue="dev"))
    rec = svc.wait(job, timeout_s=30)
    print(f"[alice] WOS job {rec['status']}: "
          f"{store.get(f'results/{job}/topics.txt').decode()}")

    # --- bob is denied the private corpus ----------------------------------------
    try:
        svc.submit(bob, JobSpec("topic_count",
                                inputs=("dataset/wos/shard-0",), queue="dev"))
        raise AssertionError("bob should have been denied")
    except Exception as e:
        print(f"[bob]   denied WOS as expected: {type(e).__name__}")
    job = svc.submit(bob, JobSpec(
        "topic_count", inputs=tuple(store.keys("dataset/public/wikipedia/")),
        queue="dev"))
    print(f"[bob]   wikipedia job {svc.wait(job, timeout_s=30)['status']}")

    # --- the Glacier path ------------------------------------------------------------
    cold = store.head("dataset/wos/shard-1")
    cold.tier = Tier.ARCHIVE
    job = svc.submit(alice, JobSpec(
        "topic_count", inputs=("dataset/wos/shard-1",), queue="dev"))
    time.sleep(0.4)
    print(f"[alice] cold-data job parked: {svc.status(job)['status']}")
    cold.restore_ready_at = engine.clock.now() - 1  # fast-forward 4h restore
    print(f"[alice] after restore: {svc.wait(job, timeout_s=30)['status']}")

    # --- audit ------------------------------------------------------------------------
    denials = engine.audit.records(decision="deny")
    print(f"audit: {len(engine.audit)} records, {len(denials)} denials "
          f"(e.g. {denials[-1].principal_id} -> {denials[-1].resource})")
    svc.shutdown()


if __name__ == "__main__":
    main()
