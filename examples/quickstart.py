"""Quickstart: the Kotta stack in one file.

Registers a corpus in the secure tiered store, trains a small LM for a few
steps with checkpointing, then serves greedy completions — all through the
public API. Runs in ~a minute on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.checkpoint import Checkpointer
from repro.configs import get_reduced_config
from repro.core import ObjectStore, PolicyEngine, install_standard_roles
from repro.data import SyntheticCorpus, TokenLoader
from repro.models import get_family
from repro.serve import ServeEngine
from repro.train import AdamWConfig, ElasticTrainer


def main():
    # 1. infrastructure: security engine + tiered object store
    engine = PolicyEngine()
    install_standard_roles(engine)
    store = ObjectStore(clock=engine.clock)

    # 2. data: deterministic synthetic corpus under dataset/quickstart/*
    cfg = get_reduced_config("yi-6b").replace(vocab_size=512)
    keys = SyntheticCorpus.build(store, "quickstart", num_shards=2,
                                 tokens_per_shard=16_384,
                                 vocab_size=cfg.vocab_size)
    loader = TokenLoader(store.get, keys, batch_size=8, seq_len=64)

    # 3. train with tiered checkpointing
    opt = AdamWConfig(learning_rate=3e-3, warmup_steps=5, decay_steps=100)
    trainer = ElasticTrainer(cfg, opt, Checkpointer(store, "quickstart"),
                             seed=0)
    report = trainer.train(loader, num_steps=20, checkpoint_every=10)
    print(f"loss: step1={report.losses[1]:.3f} "
          f"step20={report.losses[20]:.3f}")

    # 4. serve the trained model
    params, _ = trainer.final_state
    engine_srv = ServeEngine(cfg, params, max_len=96)
    result = engine_srv.generate([[1, 2, 3, 4], [9, 8, 7]], max_new=8)
    print("completions:", result.tokens.tolist())
    print(f"checkpoints in store: {trainer.ckpt.steps()} "
          f"(monthly storage cost ${store.monthly_cost():.6f})")


if __name__ == "__main__":
    main()
