"""MoE dispatch: sort-vs-einsum equivalence + capacity/balance properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.configs import get_reduced_config
from repro.models import get_family
from repro.models.moe import expert_capacity, moe_block, moe_param_specs
from repro.models.params import init_params


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced_config("olmoe-1b-7b").replace(dtype="float32")
    fam = get_family(cfg)
    params = init_params(fam.layout(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    return cfg, fam, params


def _batch(cfg, seed, b=2, s=64):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    return {"tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size),
            "labels": jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size)}


def test_sort_equals_einsum_forward_and_grads(setup):
    cfg, fam, params = setup
    batch = _batch(cfg, 1)
    cfg_s = cfg.replace(moe_impl="sort")
    l_e, m_e = fam.train_loss(cfg, params, batch)
    l_s, m_s = fam.train_loss(cfg_s, params, batch)
    assert float(m_e["moe_drop_frac"]) == float(m_s["moe_drop_frac"])
    np.testing.assert_allclose(float(l_e), float(l_s), rtol=2e-5)
    g_e = jax.grad(lambda p: fam.train_loss(cfg, p, batch)[0])(params)
    g_s = jax.grad(lambda p: fam.train_loss(cfg_s, p, batch)[0])(params)
    for a, b_ in zip(jax.tree.leaves(g_e), jax.tree.leaves(g_s)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-4, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50))
def test_property_sort_equals_einsum_many_routings(setup, seed):
    cfg, fam, params = setup
    batch = _batch(cfg, seed)
    l_e, _ = fam.train_loss(cfg, params, batch)
    l_s, _ = fam.train_loss(cfg.replace(moe_impl="sort"), params, batch)
    np.testing.assert_allclose(float(l_e), float(l_s), rtol=5e-5)


@settings(max_examples=20, deadline=None)
@given(gs=st.sampled_from([64, 128, 256]), k=st.integers(1, 4),
       e=st.sampled_from([4, 8, 16]), cf=st.floats(0.5, 2.0))
def test_property_capacity_bounds(gs, k, e, cf):
    """0 < capacity <= group tokens · k; monotone in capacity_factor."""
    cfg = get_reduced_config("olmoe-1b-7b").replace(
        num_experts=e, experts_per_token=min(k, e), capacity_factor=cf)
    cap = expert_capacity(cfg, gs)
    assert 1 <= cap
    assert cap * e >= gs * min(k, e) * min(cf, 1.0) * 0.99  # no artificial drop
    cap_hi = expert_capacity(cfg.replace(capacity_factor=cf + 0.5), gs)
    assert cap_hi >= cap


def test_uniform_routing_drops_nothing(setup):
    """With capacity_factor >= 1 and perfectly balanced router logits,
    nothing is dropped."""
    cfg, fam, params = setup
    # zero router -> uniform probs -> top-k ties broken deterministically,
    # all tokens pick the same experts; use generous capacity instead
    cfg2 = cfg.replace(capacity_factor=float(cfg.num_experts))
    _, m = fam.train_loss(cfg2, params, _batch(cfg, 3))
    assert float(m["moe_drop_frac"]) == 0.0


def test_aux_losses_positive_and_finite(setup):
    cfg, fam, params = setup
    _, m = fam.train_loss(cfg, params, _batch(cfg, 4))
    assert np.isfinite(float(m["moe_lb_loss"])) and float(m["moe_lb_loss"]) >= 1.0
    assert np.isfinite(float(m["moe_z_loss"])) and float(m["moe_z_loss"]) >= 0.0
