"""Fleet routing + disaggregated prefill/decode: radix fingerprints,
prefix-affinity dispatch (with least-loaded fallback and the load-imbalance
cap), the page-shipping handoff (greedy token identity across
prefill -> ship -> decode, f32 and int8; radix re-registration on the
destination), role-typed engine validation, and the gateway's per-replica
routing observability."""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.clock import VirtualClock
from repro.core.elastic import ProvisioningModel, ScalingPolicy
from repro.core.security import PolicyEngine, provision_tenant
from repro.models import get_family
from repro.models.params import init_params
from repro.serve import (ContinuousBatchingEngine, EngineRequest,
                         FingerprintTracker, FleetRouter, JobState,
                         KottaServeGateway, PrefixCache, ReplicaView,
                         ServeEngine, ServiceModel, chain_hashes)

MAX_LEN = 48
SLOTS = 2


@pytest.fixture(scope="module")
def model():
    cfg = get_reduced_config("yi-6b").replace(dtype="float32", page_size=8)
    fam = get_family(cfg)
    params = init_params(fam.layout(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    return cfg, params


@pytest.fixture(scope="module")
def gold_engine(model):
    cfg, params = model
    return ServeEngine(cfg, params, max_len=MAX_LEN)


def _engine(model, **kw):
    cfg, params = model
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("max_slots", SLOTS)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("decode_chunk", 4)
    return ContinuousBatchingEngine(cfg, params, **kw)


def _factory(model, **kw):
    return lambda: _engine(model, **kw)


def _security(*tenants):
    sec = PolicyEngine(clock=VirtualClock())
    tokens = {t: provision_tenant(sec, t, f"pw-{t}",
                                  data_zones=("public", t))
              for t in tenants}
    return sec, tokens


def _gateway(model, sec, *, scaling=None, engine_kw=None, **kw):
    kw.setdefault("provisioning",
                  ProvisioningModel(base_delay_s=5.0, jitter_s=0.0,
                                    volatility_prob=0.0))
    kw.setdefault("service_model", ServiceModel(decode_step_s=0.05))
    return KottaServeGateway(_factory(model, **(engine_kw or {})), sec,
                             scaling=scaling or ScalingPolicy.none(
                                 1, market="on_demand"),
                             **kw)


def _prompt(cfg, n, seed=0):
    return np.random.RandomState(seed).randint(
        0, cfg.vocab_size, size=n).tolist()


# ---------------------------------------------------------------------------
# Fingerprints
# ---------------------------------------------------------------------------

def test_fingerprint_advertises_registered_prefixes():
    """fingerprint() holds exactly the chain hashes of every fully cached
    page-granular prefix — scoring a prompt against it by consecutive hits
    reproduces the radix walk's full-page match count."""
    pc = PrefixCache(4)
    prompt = list(range(10))                    # 2 full pages + 2-token tail
    pc.register(prompt, [3, 4, 5], namespace="a")
    fp = pc.fingerprint()
    hashes = chain_hashes(prompt, 4, namespace="a")
    assert len(hashes) == 2                     # one per FULL page only
    assert set(hashes) <= fp
    assert len(fp) == 2                         # the partial tail never ships
    # Consecutive-hit scoring == the cache's own full-page match count.
    hits = 0
    for h in chain_hashes(prompt + [99], 4, namespace="a"):
        if h not in fp:
            break
        hits += 1
    assert hits == 2
    # A diverging second page scores exactly the shared first page.
    other = prompt[:4] + [55, 56, 57, 58]
    assert chain_hashes(other, 4, "a")[0] in fp
    assert chain_hashes(other, 4, "a")[1] not in fp
    # Per-namespace view matches the union for a single-tenant cache.
    assert pc.fingerprint(namespace="a") == fp
    assert pc.fingerprint(namespace="b") == frozenset()


def test_fingerprint_namespace_salting_and_eviction():
    """Identical token content under two namespaces never produces matching
    hashes, and eviction shrinks the advertisement (prefix-closed: a shallow
    eviction takes its whole subtree)."""
    pc = PrefixCache(4)
    prompt = list(range(8))
    pc.register(prompt, [3, 4], namespace="tenant-a")
    pc.register(prompt, [5, 6], namespace="tenant-b")
    fp = pc.fingerprint()
    assert len(fp) == 4                         # 2 depths x 2 namespaces
    ha = chain_hashes(prompt, 4, "tenant-a")
    hb = chain_hashes(prompt, 4, "tenant-b")
    assert not set(ha) & set(hb)                # salt keeps tenants apart
    pc.evict(3)                                 # tenant-a's root page
    fp2 = pc.fingerprint()
    assert fp2 == frozenset(hb)                 # a's whole chain gone
    assert pc.fingerprint(namespace="tenant-a") == frozenset()


# ---------------------------------------------------------------------------
# FleetRouter units
# ---------------------------------------------------------------------------

def _view(rid, prompt, ns=None, ps=4, load=0, open_slots=2):
    fp = frozenset(chain_hashes(prompt, ps, ns)) if prompt else frozenset()
    return ReplicaView(rid, open_slots, load, ps, fp)


def test_router_affinity_picks_matching_replica():
    warm = list(range(12))                      # 3 pages cached on replica 1
    router = FleetRouter("affinity")
    views = [_view(0, None, load=0), _view(1, warm, load=1)]
    d = router.route(warm + [99], None, views)
    assert (d.replica_id, d.matched_tokens, d.reason) == (1, 12, "affinity")
    assert router.stats["affinity"] == 1
    assert router.stats["matched_tokens"] == 12
    # Zero match anywhere: least-loaded fallback (replica 0 is idler).
    d = router.route([777] * 8, None, views)
    assert (d.replica_id, d.reason) == (0, "least_loaded")
    # Namespace mismatch scores zero even on identical tokens.
    d = router.route(warm + [99], "other-tenant", views)
    assert d.reason == "least_loaded"
    # No open slots anywhere -> None.
    assert router.route(warm, None,
                        [_view(0, warm, open_slots=0)]) is None


def test_router_imbalance_cap_spills_hot_prefix():
    """When the affinity winner is already imbalance_cap ahead of the
    idlest replica, the request spills to the best match within the cap."""
    warm = list(range(8))
    router = FleetRouter("affinity", imbalance_cap=2)
    views = [_view(0, None, load=0), _view(1, warm, load=3)]
    d = router.route(warm, None, views)
    assert (d.replica_id, d.reason) == (0, "imbalance_cap")
    assert d.matched_tokens == 0
    assert router.stats["imbalance_cap"] == 1
    # Within the cap the warm replica keeps winning.
    views = [_view(0, None, load=0), _view(1, warm, load=2)]
    assert router.route(warm, None, views).replica_id == 1


def test_router_blind_round_robins_and_validates():
    router = FleetRouter("blind")
    views = [_view(0, None), _view(1, None), _view(2, None)]
    picks = [router.route([1, 2], None, views).replica_id for _ in range(6)]
    assert picks == [0, 1, 2, 0, 1, 2]
    assert router.stats["blind"] == 6
    with pytest.raises(ValueError, match="routing mode"):
        FleetRouter("random")
    with pytest.raises(ValueError, match="imbalance_cap"):
        FleetRouter("affinity", imbalance_cap=0)


def test_router_best_match_tokens_for_admission():
    warm = list(range(12))
    router = FleetRouter("affinity")
    views = [_view(0, None), _view(1, warm)]
    assert router.best_match_tokens(warm + [5], None, views) == 12
    assert router.best_match_tokens([9] * 8, None, views) == 0
    assert router.best_match_tokens(warm, None, []) == 0


# ---------------------------------------------------------------------------
# Engine roles + page shipping
# ---------------------------------------------------------------------------

def test_role_validation(model):
    with pytest.raises(ValueError, match="role"):
        _engine(model, role="router")
    with pytest.raises(ValueError, match="never decode"):
        _engine(model, role="prefill", enable_spec_decode=True)
    pre = _engine(model, role="prefill")
    assert not pre.spec_decode                  # forced off, even via cfg
    with pytest.raises(RuntimeError, match="prefill-role"):
        pre.decode_step()


@pytest.mark.parametrize("kv_dtype", ["f32", "int8"])
def test_ship_token_identity(model, gold_engine, kv_dtype):
    """prefill-role admit -> export -> import into a decode-role engine ->
    decode: greedy tokens identical to a never-shipped run, both pool
    layouts (int8 ships its scale pages alongside the data pages)."""
    cfg, params = model
    prompts = [_prompt(cfg, 13, seed=21), _prompt(cfg, 8, seed=22)]
    max_new = 12
    gold = _engine(model, kv_cache_dtype=kv_dtype).generate(
        prompts, max_new=max_new).tokens

    pre = _engine(model, role="prefill", kv_cache_dtype=kv_dtype,
                  prefill_chunk=16)
    dec = _engine(model, role="decode", kv_cache_dtype=kv_dtype)
    for i, p in enumerate(prompts):
        pre.enqueue(EngineRequest(i, p, max_new))
    assert pre.admit() == 2
    payloads = [pre.export_pages(s) for s in sorted(pre._live)]
    assert pre.live == 0 and pre.stats["page_exports"] == 2
    assert pre.alloc.available() == pre.num_pages - 1   # all pages released
    pre._debug_check_refcounts()
    for pl, p in zip(payloads, prompts):
        assert pl.emitted == 0 and pl.pos == len(p)
        assert pl.n_content == -(-len(p) // cfg.page_size)
        assert pl.nbytes == sum(a.nbytes for a in pl.content.values()) > 0
        if kv_dtype == "int8":
            assert {"k", "v", "k_scale", "v_scale"} == set(pl.content)
        else:
            assert {"k", "v"} == set(pl.content)
        dec.import_pages(pl)
    assert dec.live == 2 and dec.stats["page_imports"] == 2
    assert dec.stats["prefill_tokens"] == 0     # decode side never prefills
    dec._debug_check_refcounts()
    done = {}
    while dec.live:
        for req, toks in dec.decode_step():
            done[req.rid] = toks
    for i in range(len(prompts)):
        np.testing.assert_array_equal(gold[i],
                                      np.asarray(done[i], np.int32))
    assert pre._n_decode_traces == 0


def test_ship_into_spec_decode_engine(model):
    """A payload from a (non-speculative) prefill engine lands in a
    speculative decode engine: the reconstructed drafting history yields
    the same greedy tokens as a unified speculative run."""
    cfg, params = model
    # A repetitive prompt so speculation genuinely accepts drafts.
    prompt = ([5, 6, 7, 8] * 5)[:18]
    max_new = 14
    gold = _engine(model, enable_spec_decode=True, spec_tokens=4).generate(
        [prompt], max_new=max_new).tokens[0]
    pre = _engine(model, role="prefill")
    dec = _engine(model, role="decode", enable_spec_decode=True,
                  spec_tokens=4)
    pre.enqueue(EngineRequest(0, prompt, max_new))
    pre.admit()
    dec.import_pages(pre.export_pages(list(pre._live)[0]))
    done = {}
    while dec.live:
        for req, toks in dec.decode_step():
            done[req.rid] = toks
    np.testing.assert_array_equal(gold, np.asarray(done[0], np.int32))
    assert dec.stats["spec_steps"] > 0


def test_import_reregisters_prefix_in_destination_cache(model):
    """Shipped pages re-enter the destination's radix cache: the NEXT
    request with the same prefix aliases them instead of re-prefilling."""
    cfg, params = model
    prompt = _prompt(cfg, 16, seed=30)          # 2 full pages
    pre = _engine(model, role="prefill")
    dec = _engine(model, role="decode")
    pre.enqueue(EngineRequest(0, prompt, 8))
    pre.admit()
    payload = pre.export_pages(list(pre._live)[0])
    dec.import_pages(payload)
    chain, match = dec.prefix_cache.lookup(prompt)
    assert match == 16 and len(chain) == 2
    # Source cache survives the export too (prefill replica stays warm).
    assert pre.prefix_cache.lookup(prompt)[1] == 16
    # A second request for the same prompt on the destination: admission
    # serves the prefix from the imported pages, zero fresh prefill pages.
    dec.enqueue(EngineRequest(1, prompt, 8))
    dec.admit()
    assert dec.stats["cached_tokens"] == 15     # plen-1 cap: last tok redone
    dec._debug_check_refcounts()


def test_import_validates_layout_and_capacity(model):
    cfg, params = model
    prompt = _prompt(cfg, 9, seed=31)
    pre = _engine(model, role="prefill")
    pre.enqueue(EngineRequest(0, prompt, 4))
    pre.admit()
    payload = pre.export_pages(list(pre._live)[0])
    with pytest.raises(ValueError, match="int8"):
        _engine(model, kv_cache_dtype="int8").import_pages(payload)
    # A destination pool too small for the request fails loudly.
    tiny = _engine(model, max_slots=1, num_pages=1)
    with pytest.raises(ValueError, match="pages"):
        tiny.import_pages(payload)
    # No free pages right now (transient): RuntimeError, payload reusable.
    dec = _engine(model, max_slots=2, num_pages=3)
    dec.enqueue(EngineRequest(7, _prompt(cfg, 9, seed=32), 4))
    dec.admit()                                 # 2 of 3 pages now occupied
    with pytest.raises(RuntimeError, match="insufficient free pages"):
        dec.import_pages(payload)
    ok = _engine(model)
    ok.import_pages(payload)
    assert ok.live == 1


# ---------------------------------------------------------------------------
# Gateway: affinity routing end to end
# ---------------------------------------------------------------------------

def _placement(gw, rid):
    """Step until job rid is dispatched; return its replica id."""
    for _ in range(200):
        if gw.jobs[rid].replica is not None:
            return gw.jobs[rid].replica
        if gw.jobs[rid].status is JobState.DONE:
            pytest.fail("job finished before placement was observed")
        gw.step()
    pytest.fail("job never dispatched")


def test_affinity_routes_repeat_prefix_to_warm_replica(model):
    """Two static replicas, two tenants with hot 16-token prefixes: after
    the cold first round, every repeat lands on the tenant's warm replica
    and admission serves the prefix from cache."""
    cfg, _ = model
    sec, tok = _security("a", "b")
    gw = _gateway(model, sec, routing="affinity",
                  scaling=ScalingPolicy.none(2, market="on_demand"))
    hot = {t: _prompt(cfg, 16, seed=40 + i)
           for i, t in enumerate(("a", "b"))}

    def job(tenant, tail_seed):
        # max_new=8 spans two decode chunks, so the job is still live (and
        # its placement observable) after the step that dispatched it.
        tail = _prompt(cfg, 4, seed=900 + tail_seed)
        return gw.submit(tok[tenant], hot[tenant] + tail, max_new=8,
                         data_zone="public")

    first = {t: _placement(gw, job(t, i)) for i, t in enumerate(("a", "b"))}
    gw.drain()
    # Cold start spread the two tenants across the two replicas.
    assert first["a"] != first["b"]
    for i in range(3):
        for t in ("a", "b"):
            assert _placement(gw, job(t, 10 + 2 * i + (t == "b"))) == first[t]
            gw.drain()
    m = gw.metrics()
    assert m["routing_mode"] == "affinity"
    assert m["routing"]["affinity"] >= 6
    assert m["routing"]["matched_tokens"] >= 6 * 16
    per = {e["replica"]: e for e in m["per_replica"]}
    assert len(per) == 2
    # Both replicas served warm repeats: prefix hits on each, and the
    # dispatch counters account for every placement.
    assert all(e["prefix_hit_rate"] > 0 for e in per.values())
    assert sum(e["dispatched"] for e in per.values()) == 8
    # The accessor satellite: per-replica engines are addressable by id.
    for rid_, e in per.items():
        eng = gw.replica_engine(rid_)
        assert eng.prefix_hit_rate == e["prefix_hit_rate"]
    with pytest.raises(KeyError):
        gw.replica_engine(10_000)


def test_blind_routing_ignores_affinity(model):
    """Same trace under routing='blind': round-robin placement alternates
    replicas, so the hot tenant's repeats re-prefill from scratch roughly
    half the time — strictly more fresh prefill than affinity pays."""
    cfg, _ = model
    sec, tok = _security("a")
    hot = _prompt(cfg, 16, seed=44)

    def run(mode):
        gw = _gateway(model, sec, routing=mode,
                      scaling=ScalingPolicy.none(2, market="on_demand"))
        for i in range(6):
            gw.submit(tok["a"], hot + _prompt(cfg, 4, seed=700 + i),
                      max_new=4, data_zone="public")
            gw.drain()
        m = gw.metrics()
        fresh = sum(gw.replica_engine(e["replica"]).stats["prefill_tokens"]
                    for e in m["per_replica"])
        return m, fresh

    m_blind, fresh_blind = run("blind")
    m_aff, fresh_aff = run("affinity")
    assert m_blind["routing"]["blind"] == 6
    assert m_blind["routing"]["matched_tokens"] == 0
    assert fresh_aff < fresh_blind


# ---------------------------------------------------------------------------
# Gateway: disaggregated prefill/decode
# ---------------------------------------------------------------------------

def test_disaggregated_gateway_token_identity(model, gold_engine):
    """1 prefill + 2 decode replicas: every request flows admission ->
    export -> ship -> import -> decode, tokens oracle-identical; the
    prefill engine never decodes and the decode engines never prefill."""
    cfg, _ = model
    sec, tok = _security("alice")
    gw = _gateway(
        model, sec, routing="affinity",
        scaling=ScalingPolicy.none(2, market="on_demand"),
        engine_kw={"role": "decode"},
        prefill_replicas=1,
        prefill_engine_factory=_factory(model, role="prefill",
                                        prefill_chunk=16))
    rng = np.random.RandomState(60)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).tolist()
               for n in (6, 13, 16, 9)]
    rids = [gw.submit(tok["alice"], p, max_new=10, data_zone="public")
            for p in prompts]
    gw.drain()
    for rid, p in zip(rids, prompts):
        gold = gold_engine.generate([p], max_new=10).tokens[0]
        np.testing.assert_array_equal(gold,
                                      np.asarray(gw.result(rid), np.int32))
    m = gw.metrics()
    assert m["completed"] == 4 and m["shed"] == 0
    assert m["page_ships"] == 4
    assert m["page_ship_bytes"] > 0 and m["page_ship_bytes_per_ship"] > 0
    assert m["handoffs_in_flight"] == 0
    roles = {e["replica"]: e["role"] for e in m["per_replica"]}
    assert sorted(roles.values()) == ["decode", "decode", "prefill"]
    for rid_, role in roles.items():
        eng = gw.replica_engine(rid_)
        if role == "prefill":
            assert eng._n_decode_traces == 0
            assert eng.stats["prefill_tokens"] > 0
        else:
            assert eng.stats["prefill_tokens"] == 0
            assert eng.stats["page_imports"] > 0
    # New work was dispatched exclusively to the prefill front end.
    pre_id = next(r for r, ro in roles.items() if ro == "prefill")
    assert {e["replica"]: e["dispatched"]
            for e in m["per_replica"]}[pre_id] == 4


def test_disaggregated_shipped_prefix_stays_shareable(model):
    """Two same-tenant requests sharing a 16-token prefix through the
    disaggregated path: the prefill replica prefills the shared prefix
    once, and the shipped pages re-register on the decode side."""
    cfg, _ = model
    sec, tok = _security("alice")
    gw = _gateway(
        model, sec, routing="affinity",
        scaling=ScalingPolicy.none(1, market="on_demand"),
        engine_kw={"role": "decode"},
        prefill_replicas=1,
        prefill_engine_factory=_factory(model, role="prefill"))
    hot = _prompt(cfg, 16, seed=70)
    r1 = gw.submit(tok["alice"], hot + _prompt(cfg, 3, seed=71),
                   max_new=6, data_zone="public")
    gw.drain()
    r2 = gw.submit(tok["alice"], hot + _prompt(cfg, 5, seed=72),
                   max_new=6, data_zone="public")
    gw.drain()
    assert gw.jobs[r1].status is JobState.DONE
    assert gw.jobs[r2].status is JobState.DONE
    m = gw.metrics()
    pre = next(e for e in m["per_replica"] if e["role"] == "prefill")
    dec = next(e for e in m["per_replica"] if e["role"] == "decode")
    # Second request's 16-token prefix came from the prefill replica's
    # cache (hit rate > 0 there); the decode replica's cache holds the
    # imported prefix for future COW sharing.
    assert pre["prefix_hit_rate"] > 0
    eng = gw.replica_engine(dec["replica"])
    assert eng.prefix_cache.lookup(hot, ("alice", "public"))[1] == 16

    # Gateway-level validation of factory roles.
    with pytest.raises(ValueError, match="prefill_engine_factory"):
        _gateway(model, sec, prefill_replicas=1)
    with pytest.raises(ValueError, match="role='prefill'"):
        _gateway(model, sec, prefill_replicas=1,
                 prefill_engine_factory=_factory(model))
    with pytest.raises(ValueError, match="decode-capable"):
        _gateway(model, sec, engine_kw={"role": "prefill"})


# ---------------------------------------------------------------------------
# Fingerprint deltas: epoch journal + router-side mirrors
# ---------------------------------------------------------------------------

def test_fingerprint_delta_replays_to_exact_snapshot():
    """The epoch journal is exact: replaying fingerprint_delta() onto any
    snapshot reproduces fingerprint() after every mutation, and the tracker
    only pays a full walk on first contact or journal overrun."""
    pc = PrefixCache(16)
    tr = FingerprintTracker()

    def check():
        assert tr.refresh(0, pc) == pc.fingerprint()

    check()                                     # first contact: snapshot
    assert tr.stats["snapshots"] == 1
    pc.register(list(range(12)), [1, 2, 3], namespace="a")
    check()
    pc.register(list(range(8)), [4, 5], namespace="b")
    check()
    pc.evict(1)                                 # drops a's whole chain
    check()
    assert tr.stats["snapshots"] == 1           # all follow-ups were deltas
    assert tr.stats["deltas"] >= 3

    # No mutation since the mirror's epoch -> empty delta.
    ep, added, removed = pc.fingerprint_delta(pc.epoch)
    assert ep == pc.epoch and added == frozenset() == removed
    # An epoch from the future is a protocol error -> full resync.
    assert pc.fingerprint_delta(pc.epoch + 1) is None


def test_fingerprint_delta_journal_overrun_falls_back():
    """A mirror that fell more than JOURNAL_DEPTH mutations behind gets
    None (take a snapshot) rather than a wrong partial delta."""
    from collections import deque
    pc = PrefixCache(16)
    tr = FingerprintTracker()
    assert tr.refresh(0, pc) == pc.fingerprint()
    pc._journal = deque(maxlen=2)               # tiny journal for the test
    for i in range(3):                          # 3 mutations > depth 2
        pc.register(list(range(100 + 20 * i, 116 + 20 * i)), [i + 1],
                    namespace="x")
    assert pc.fingerprint_delta(0) is None
    assert tr.refresh(0, pc) == pc.fingerprint()
    assert tr.stats["snapshots"] == 2           # overrun forced a resync


def test_delta_fed_router_matches_snapshot_fed():
    """Routing decisions from tracker-mirrored fingerprints are identical
    to full-snapshot routing across registration and eviction churn —
    the mirror is exact, not approximate."""
    caches = {0: PrefixCache(16), 1: PrefixCache(16)}
    tr = FingerprintTracker()
    rt_delta = FleetRouter("affinity")
    rt_snap = FleetRouter("affinity")
    hot_a, hot_b = list(range(16)), list(range(50, 66))
    probes = [hot_a, hot_b, hot_a[:8] + [9] * 8]

    def views(fp_of):
        return [ReplicaView(replica_id=i, open_slots=2, load=0, page_size=4,
                            fingerprint=fp_of(i)) for i in caches]

    def assert_same_decisions():
        for p in probes:
            d = rt_delta.route(p, "t", views(lambda i: tr.refresh(i, caches[i])))
            s = rt_snap.route(p, "t", views(lambda i: caches[i].fingerprint()))
            assert d == s

    assert_same_decisions()                     # both caches cold
    caches[0].register(hot_a, [1, 2, 3, 4], namespace="t")
    assert_same_decisions()
    caches[1].register(hot_b, [1, 2, 3, 4], namespace="t")
    assert_same_decisions()
    caches[0].evict(1)                          # hot_a chain gone from 0
    assert_same_decisions()
    caches[1].register(hot_a, [5, 6, 7, 8], namespace="t")
    assert_same_decisions()
    assert tr.stats["deltas"] > 0               # the mirror really was fed
    assert rt_delta.stats == rt_snap.stats      # byte-identical outcomes
