"""CI regression gate: the committed smoke baselines checked against
themselves pass; synthetic regressions (a 20% decode-tok/s drop, a deadline
hit-rate drop, a missing metric, a recorded scenario failure) exit nonzero.

Runs the real CLI in a subprocess — exactly what the CI workflow invokes —
against candidate JSONs derived from the committed baselines, so the gate's
metric extractors are validated against the real file schema.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
SCRIPT = REPO / "benchmarks" / "check_regression.py"
SERVE_BASE = REPO / "BENCH_serve.smoke.json"
GATEWAY_BASE = REPO / "BENCH_gateway.smoke.json"


def _run(args):
    return subprocess.run([sys.executable, str(SCRIPT), *args],
                          capture_output=True, text=True, timeout=60)


def _candidates(tmp_path, serve_edit=None, gateway_edit=None):
    serve = json.loads(SERVE_BASE.read_text())
    gateway = json.loads(GATEWAY_BASE.read_text())
    if serve_edit:
        serve_edit(serve)
    if gateway_edit:
        gateway_edit(gateway)
    sp = tmp_path / "serve.json"
    gp = tmp_path / "gateway.json"
    sp.write_text(json.dumps(serve))
    gp.write_text(json.dumps(gateway))
    return ["--serve", str(sp), "--gateway", str(gp)]


@pytest.fixture(autouse=True)
def _needs_baselines():
    if not (SERVE_BASE.exists() and GATEWAY_BASE.exists()):
        pytest.skip("committed smoke baselines missing")


def test_baseline_vs_itself_passes(tmp_path):
    res = _run(_candidates(tmp_path))
    assert res.returncode == 0, res.stdout + res.stderr
    assert "all metrics within tolerance" in res.stdout


def test_synthetic_20pct_decode_drop_fails(tmp_path):
    """The acceptance bar: a 20% decode-tok/s drop must fail the gate (the
    checker recomputes the speedup from the raw tok/s fields, so editing
    only the raw field is caught)."""
    def drop(serve):
        serve["decode"][0]["continuous_tok_s"] *= 0.8
    res = _run(_candidates(tmp_path, serve_edit=drop))
    assert res.returncode != 0, res.stdout
    assert "decode.continuous_vs_static_speedup" in res.stdout
    assert "REGRESSION GATE FAILED" in res.stdout


def test_deadline_hit_rate_drop_fails(tmp_path):
    def drop(gateway):
        gateway["trace"]["elastic"]["deadline_hit_rate"] *= 0.8
    res = _run(_candidates(tmp_path, gateway_edit=drop))
    assert res.returncode != 0
    assert "deadline_hit_rate" in res.stdout


def test_preempt_ttft_inflation_fails(tmp_path):
    """Losing the preemption win (interactive TTFT back to the wait
    baseline) fails the gate."""
    def slow(gateway):
        ib = gateway["interactive_burst"]
        ib["preempt"]["interactive_p99_ttft_s"] = \
            ib["no_preempt_wait"]["interactive_p99_ttft_s"]
        ib["ttft_reduction_s"] = 0.0
    res = _run(_candidates(tmp_path, gateway_edit=slow))
    assert res.returncode != 0
    assert "interactive_burst" in res.stdout


def test_kv_capacity_ratio_drop_fails(tmp_path):
    """The int8 capacity ratio gates at ZERO tolerance — any layout drift
    (widened scale dtype, dropped scale page changing the byte math) must
    fail, and the checker recomputes the ratio from the raw byte fields."""
    def widen(serve):
        serve["quantized_kv"]["int8_bytes_per_slot_token"] *= 1.5
    res = _run(_candidates(tmp_path, serve_edit=widen))
    assert res.returncode != 0
    assert "capacity_ratio" in res.stdout


def test_adaptive_low_accept_collapse_fails(tmp_path):
    """Losing the adaptive-K recovery on the adversarial workload (adaptive
    tok/s back to half the fixed-K rate) fails the gate."""
    def collapse(serve):
        serve["spec_low_accept"]["adaptive_decode_tok_s"] *= 0.5
    res = _run(_candidates(tmp_path, serve_edit=collapse))
    assert res.returncode != 0
    assert "spec_low_accept.adaptive_vs_spec" in res.stdout


def test_missing_metric_fails(tmp_path):
    """A half-run bench (scenario JSON section absent) must not pass."""
    def strip(serve):
        del serve["spec_decode"]
    res = _run(_candidates(tmp_path, serve_edit=strip))
    assert res.returncode != 0
    assert "spec_decode" in res.stdout


def test_recorded_scenario_failure_fails(tmp_path):
    def taint(serve):
        serve["failures"] = ["decode: RuntimeError: boom"]
    res = _run(_candidates(tmp_path, serve_edit=taint))
    assert res.returncode != 0
    assert "scenario failures" in res.stdout


def test_fleet_affinity_advantage_collapse_fails(tmp_path):
    """Losing the affinity win (affinity fleet tok/s down to blind's rate)
    fails the gate — the checker recomputes the ratio from the raw per-mode
    tok_per_sim_s fields, so editing only the stored convenience ratio is
    not enough to sneak past."""
    def collapse(gateway):
        f = gateway["fleet_routing"]
        f["affinity"]["tok_per_sim_s"] = f["blind"]["tok_per_sim_s"]
    res = _run(_candidates(tmp_path, gateway_edit=collapse))
    assert res.returncode != 0
    assert "fleet_routing.tok_ratio_affinity_over_blind" in res.stdout


def test_fleet_ttft_advantage_collapse_fails(tmp_path):
    """Affinity's p99 TTFT inflating back to blind's fails the gate."""
    def inflate(gateway):
        f = gateway["fleet_routing"]
        f["affinity"]["interactive_p99_ttft_s"] = \
            f["blind"]["interactive_p99_ttft_s"]
    res = _run(_candidates(tmp_path, gateway_edit=inflate))
    assert res.returncode != 0
    assert "fleet_routing.ttft_p99_ratio_blind_over_affinity" in res.stdout


def test_fleet_ship_bytes_inflation_fails(tmp_path):
    """Page-ship bytes/request gate at ZERO tolerance — shipping even one
    extra page per request (layout drift in the KV handoff payload) must
    fail."""
    def inflate(gateway):
        gateway["fleet_routing"]["page_ship_bytes_per_request"] *= 1.1
    res = _run(_candidates(tmp_path, gateway_edit=inflate))
    assert res.returncode != 0
    assert "page_ship_bytes_per_request" in res.stdout


def test_fault_recovery_ttft_advantage_collapse_fails(tmp_path):
    """Losing the evacuation win (evacuate-mode recovered TTFT inflating to
    requeue's) fails the gate — the ratio is recomputed from the raw
    per-mode fields, so editing only the stored headline is not enough."""
    def collapse(gateway):
        f = gateway["fault_recovery"]
        f["evacuate"]["recovered_ttft_mean_s"] = \
            f["requeue"]["recovered_ttft_mean_s"]
    res = _run(_candidates(tmp_path, gateway_edit=collapse))
    assert res.returncode != 0
    assert "fault_recovery.recovered_ttft_ratio_requeue_over_evacuate" \
        in res.stdout


def test_fault_recovery_goodput_collapse_fails(tmp_path):
    def collapse(gateway):
        f = gateway["fault_recovery"]
        f["evacuate"]["tok_per_sim_s"] = 0.8 * f["requeue"]["tok_per_sim_s"]
    res = _run(_candidates(tmp_path, gateway_edit=collapse))
    assert res.returncode != 0
    assert "fault_recovery.goodput_ratio_evacuate_over_requeue" in res.stdout


def test_fault_recovery_token_divergence_fails(tmp_path):
    """Token identity across recovery modes gates at ZERO tolerance — any
    divergence is a correctness bug, not a perf wobble."""
    def diverge(gateway):
        gateway["fault_recovery"]["token_identity"] = False
    res = _run(_candidates(tmp_path, gateway_edit=diverge))
    assert res.returncode != 0
    assert "fault_recovery.token_identity" in res.stdout


def test_fault_recovery_no_evacuations_fails(tmp_path):
    """Zero evacuations means the graceful path never ran in evacuate mode
    (a silently-dead notice window) — gated exactly."""
    def zero(gateway):
        gateway["fault_recovery"]["evacuate"]["evacuations"] = 0
    res = _run(_candidates(tmp_path, gateway_edit=zero))
    assert res.returncode != 0
    assert "fault_recovery.evacuate.evacuations" in res.stdout


def test_saturation_max_sustained_drop_fails(tmp_path):
    """The saturation wall is deterministic on the virtual clock, so the
    max sustained req/s at the 99% bar gates exactly — an admission or
    scheduling slip that drops it a load point must fail."""
    def drop(gateway):
        gateway["saturation"]["max_sustained_req_s"] *= 0.5
    res = _run(_candidates(tmp_path, gateway_edit=drop))
    assert res.returncode != 0
    assert "saturation.max_sustained_req_s" in res.stdout


def test_saturation_sharding_win_loss_fails(tmp_path):
    """Sharded throttles climbing back to the single-table count means the
    write wall silently returned — gated as a binary."""
    def regress(gateway):
        s = gateway["saturation"]["statestore"]
        s["throttled_sharded"] = s["throttled_single"]
    res = _run(_candidates(tmp_path, gateway_edit=regress))
    assert res.returncode != 0
    assert "saturation.sharding_cuts_throttles" in res.stdout


def test_missing_metric_family_fails_schema_gate(tmp_path):
    """An instrumentation refactor that drops a registry family breaks
    every dashboard scraping it: the schema gate names the family."""
    def drop_family(gateway):
        fams = gateway["saturation"]["metric_families"]
        fams.remove("kotta_tenant_cost_usd_total")
    res = _run(_candidates(tmp_path, gateway_edit=drop_family))
    assert res.returncode != 0
    assert "kotta_tenant_cost_usd_total" in res.stdout
    assert "metric_families" in res.stdout


def test_absent_saturation_section_fails(tmp_path):
    def strip(gateway):
        del gateway["saturation"]
    res = _run(_candidates(tmp_path, gateway_edit=strip))
    assert res.returncode != 0
    assert "saturation" in res.stdout


def test_session_resume_advantage_collapse_fails(tmp_path):
    """Losing the tiered-restore win (tiered resumed TTFT inflating to the
    re-prefill baseline's) fails the gate — the ratio is recomputed from
    the raw per-mode fields."""
    def collapse(gateway):
        s = gateway["session_resume"]
        s["tiered"]["resumed_ttft_mean_s"] = \
            s["reprefill"]["resumed_ttft_mean_s"]
    res = _run(_candidates(tmp_path, gateway_edit=collapse))
    assert res.returncode != 0
    assert "session_resume.resumed_ttft_ratio" in res.stdout


def test_session_resume_cost_inflation_fails(tmp_path):
    """$/1k resumed tokens is recomputed from the raw compute + storage
    cost fields — a storage-pricing slip or an accounting leak fails."""
    def inflate(gateway):
        gateway["session_resume"]["tiered"]["cost_usd"] *= 1.5
    res = _run(_candidates(tmp_path, gateway_edit=inflate))
    assert res.returncode != 0
    assert "session_resume.tiered.usd_per_1k_resumed_tokens" in res.stdout


@pytest.mark.parametrize("delta", [-1, +1], ids=["fewer", "more"])
def test_session_resume_restore_count_gates_exactly(tmp_path, delta):
    """The restore count is structural (trace + demotion state, no
    numerics): a drop means resumes stopped coming back through the store,
    a rise means the device radix or the affinity skip broke — both fail."""
    def shift(gateway):
        gateway["session_resume"]["tiered"]["kv_restores"] += delta
    res = _run(_candidates(tmp_path, gateway_edit=shift))
    assert res.returncode != 0
    assert "session_resume.tiered.kv_restores" in res.stdout


def test_session_resume_token_divergence_fails(tmp_path):
    """Token identity across demote/restore gates at ZERO tolerance for
    the f32 run AND the int8 scale-page leg."""
    for field in ("token_identity", "int8_token_identity"):
        def diverge(gateway, f=field):
            gateway["session_resume"][f] = False
        res = _run(_candidates(tmp_path, gateway_edit=diverge))
        assert res.returncode != 0
        assert f"session_resume.{field}" in res.stdout


def test_within_tolerance_noise_passes(tmp_path):
    """Small same-direction noise (5%) stays green — the gate is a
    regression check, not an exact-match check."""
    def jitter(serve):
        serve["decode"][0]["continuous_tok_s"] *= 0.95
        serve["spec_decode"]["spec_decode_tok_s"] *= 1.05
    res = _run(_candidates(tmp_path, serve_edit=jitter))
    assert res.returncode == 0, res.stdout + res.stderr
