"""Tiered checkpointing: roundtrip, async, corruption, lifecycle aging."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.core import (LifecyclePolicy, ObjectArchivedError, ObjectStore,
                        Tier, VirtualClock, days)
from repro.train import adamw


def make_tree(key):
    ks = jax.random.split(key, 3)
    return {"layers": {"w": jax.random.normal(ks[0], (8, 16)),
                       "b": jax.random.normal(ks[1], (16,))},
            "embed": jax.random.normal(ks[2], (32, 8)).astype(jnp.bfloat16)}


def test_roundtrip_bitwise():
    store = ObjectStore(clock=VirtualClock())
    ck = Checkpointer(store, "runA")
    tree = make_tree(jax.random.PRNGKey(0))
    ck.save(3, tree)
    step, back = ck.restore(jax.tree.map(jnp.zeros_like, tree))
    assert step == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        assert bool(jnp.array_equal(a, b))


def test_roundtrip_with_qtensor_state():
    store = ObjectStore(clock=VirtualClock())
    ck = Checkpointer(store, "runQ")
    cfg = adamw.AdamWConfig(state_dtype="int8")
    params = {"w": jnp.ones((64, 128))}
    state = adamw.init(cfg, params)
    ck.save(1, (params, state))
    like = (params, adamw.init(cfg, params))
    _, (p2, s2) = ck.restore(like)
    assert isinstance(s2.m["w"], adamw.QTensor)
    assert bool(jnp.array_equal(s2.m["w"].q, state.m["w"].q))


def test_async_save_then_restore():
    store = ObjectStore(clock=VirtualClock())
    ck = Checkpointer(store, "runB")
    tree = make_tree(jax.random.PRNGKey(1))
    ck.save(1, tree, blocking=False)
    ck.wait()
    _, back = ck.restore(jax.tree.map(jnp.zeros_like, tree))
    assert bool(jnp.array_equal(back["layers"]["w"], tree["layers"]["w"]))


def test_latest_and_gc():
    store = ObjectStore(clock=VirtualClock())
    ck = Checkpointer(store, "runC", keep_last=2)
    tree = {"w": jnp.zeros((4,))}
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    assert ck.steps() == [3, 4]
    assert ck.latest_step() == 4


def test_corruption_detected_on_restore():
    store = ObjectStore(clock=VirtualClock())
    ck = Checkpointer(store, "runD")
    tree = {"w": jnp.ones((4, 4))}
    ck.save(1, tree)
    key = [k for k in store.keys() if k.endswith(".npy")][0]
    blob = store.get(key)
    store.put(key, blob[:-4] + b"\x00\x00\x00\x01", owner="evil")
    with pytest.raises(IOError, match="checksum"):
        ck.restore(tree)


def test_checkpoints_age_into_archive_and_restore_queue():
    """Kotta dogfood: old checkpoints migrate to ARCHIVE under the lifecycle
    policy; restoring one raises ObjectArchivedError (the restore queue)."""
    clock = VirtualClock()
    store = ObjectStore(clock=clock,
                        policy=LifecyclePolicy.parse("STD30-IA60-ARCHIVE"))
    ck = Checkpointer(store, "runE")
    tree = {"w": jnp.ones((4,))}
    ck.save(1, tree)
    clock.advance(days(120))
    store.tick()
    assert store.head(ck._manifest_key(1)).tier is Tier.ARCHIVE
    with pytest.raises(ObjectArchivedError):
        ck.restore(tree)
    # request restore of all objects, wait 4h, then it loads
    for k in store.keys("checkpoints/runE/"):
        store.restore(k)
    clock.advance(4 * 3600 + 1)
    _, back = ck.restore(tree)
    assert bool(jnp.array_equal(back["w"], tree["w"]))
