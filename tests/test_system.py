"""End-to-end system test: the full Cloud Kotta story on a real (tiny) model.

A research group registers a private corpus; an authorized user submits a
*training job* through the secure scheduler; the worker assumes the user's
role to stage data, trains with checkpointing through the tiered store,
survives a revocation, and the outputs land as private objects — with the
whole trail in the audit log.
"""
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_reduced_config
from repro.core import (ExecutableRegistry, JobSpec, JobStatus, KottaService,
                        ObjectStore, PolicyEngine, Principal, Role, allow,
                        install_standard_roles, make_dataset_role)
from repro.data import SyntheticCorpus, TokenLoader
from repro.train import AdamWConfig, ElasticTrainer


@pytest.fixture(scope="module")
def kotta():
    engine = PolicyEngine()
    install_standard_roles(engine)
    store = ObjectStore(clock=engine.clock)
    registry = ExecutableRegistry()
    svc = KottaService(engine, store, registry,
                       watcher_kwargs={"heartbeat_timeout_s": 2.0,
                                       "interval_s": 0.05})

    cfg = get_reduced_config("internlm2-1.8b").replace(vocab_size=128)
    SyntheticCorpus.build(store, "wos", num_shards=2, tokens_per_shard=8192,
                          vocab_size=cfg.vocab_size)

    @registry.register("train_lm")
    def train_lm(ctx):
        keys = sorted(ctx.staged_inputs)
        loader = TokenLoader(lambda k: ctx.staged_inputs[k], keys,
                             batch_size=4, seq_len=32)
        opt = AdamWConfig(learning_rate=1e-3, warmup_steps=2, decay_steps=50)
        trainer = ElasticTrainer(cfg, opt,
                                 Checkpointer(store, f"job-{ctx.job_id}"),
                                 seed=0)
        fired = []

        def revoke(step):  # one simulated spot reclaim mid-job
            if step == 4 and not fired:
                fired.append(step)
                return True
            return False

        rep = trainer.train(loader, 6, checkpoint_every=2, revoke_at=revoke)
        ctx.report(loss=rep.losses[6])
        ctx.outputs[f"results/{ctx.job_id}/losses.npy"] = np.asarray(
            [rep.losses[s] for s in sorted(rep.losses)]).tobytes()
        return {"final_loss": rep.losses[6], "restarts": rep.restarts}

    make_dataset_role(engine, "wos")
    user_role = Role("researcher", policies=[
        allow(["data:Get", "data:List"], ["dataset/wos/*"]),
        allow(["data:*"], ["results/*"]),
        allow(["jobs:*"], ["queue/*"]),
    ], trusted_assumers={"task-executor"})
    engine.register_role(user_role)
    alice = Principal("alice")
    engine.authenticator.register_identity(alice, "pw")
    engine.bind(alice, "researcher")

    svc.start(dev_workers=1)
    yield svc, engine
    svc.shutdown()


def test_training_job_end_to_end(kotta):
    svc, engine = kotta
    tok = engine.login("alice", "pw")
    shards = tuple(svc.store.keys("dataset/wos/"))
    job = svc.submit(tok, JobSpec("train_lm", inputs=shards, queue="dev"))
    rec = svc.wait(job, timeout_s=300, poll_s=0.1)
    assert rec["status"] == JobStatus.COMPLETED, rec
    assert "'restarts': 1" in rec["result"]
    # outputs staged back as the user's private results
    losses = np.frombuffer(
        svc.store.get(f"results/{job}/losses.npy"), dtype=np.float64)
    assert losses[-1] < losses[0]          # it actually learned
    # checkpoints were written through the tiered store
    assert svc.store.keys(f"checkpoints/job-{job}/")
    # audit trail covers staging under the assumed user role
    reads = [r for r in engine.audit.records(principal_id="alice")
             if r.action == "data:Get" and r.resource.startswith("dataset/wos")]
    assert len(reads) >= 2


def test_unauthorized_user_cannot_touch_corpus(kotta):
    svc, engine = kotta
    mallory = Principal("mallory")
    engine.authenticator.register_identity(mallory, "pw")
    engine.register_role(Role("outsider", policies=[
        allow(["jobs:*"], ["queue/*"])]))
    engine.bind(mallory, "outsider")
    tok = engine.login("mallory", "pw")
    with pytest.raises(Exception):
        svc.submit(tok, JobSpec("train_lm",
                                inputs=("dataset/wos/shard-000",),
                                queue="dev"))
