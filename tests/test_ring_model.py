"""Ring attention integrated in the model: logits and grads must match the
chunked implementation on a real multi-device mesh (8 host devices)."""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_ring_model_matches_chunked_subprocess():
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {ROOT + "/src"!r})
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced_config
from repro.distributed.sharding import ShardingRules, activate_rules
from repro.models import get_family
from repro.models.params import init_params

# starcoder2 reduced: 4 heads on a 4-way model axis would divide; force the
# interesting case with 6 heads (6 % 4 != 0 -> replicated without ring).
cfg = get_reduced_config("starcoder2-7b").replace(
    dtype="float32", num_heads=6, num_kv_heads=3, head_dim=16)
fam = get_family(cfg)
params = init_params(fam.layout(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
B, S = 2, 64
batch = {{"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                       cfg.vocab_size),
          "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                       cfg.vocab_size)}}
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
rules = ShardingRules(mesh, {{}})

def loss(c):
    def f(p, b):
        return fam.train_loss(c, p, b)[0]
    return f

with jax.set_mesh(mesh), activate_rules(rules):
    l_chunked = jax.jit(loss(cfg))(params, batch)
    l_ring = jax.jit(loss(cfg.replace(attn_impl="ring")))(params, batch)
    g_c = jax.jit(jax.grad(loss(cfg)))(params, batch)
    g_r = jax.jit(jax.grad(loss(cfg.replace(attn_impl="ring"))))(params, batch)

np.testing.assert_allclose(float(l_chunked), float(l_ring), rtol=1e-5)
errs = [float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(g_c), jax.tree.leaves(g_r))]
assert max(errs) < 1e-4, max(errs)
print("RING-MODEL-OK", float(l_chunked), max(errs))
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "RING-MODEL-OK" in out.stdout
