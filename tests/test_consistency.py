"""Cross-implementation consistency: chunked vs dense attention, chunked vs
recurrent mLSTM/SSD, and decode-vs-prefill equivalence per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import get_family
from repro.models.layers import chunked_attention, dense_attention
from repro.models.params import init_params


def test_chunked_attention_matches_dense():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 128, 8, 16))
    k = jax.random.normal(ks[1], (2, 128, 4, 16))
    v = jax.random.normal(ks[2], (2, 128, 4, 16))
    for causal in (True, False):
        a = chunked_attention(q, k, v, causal=causal, q_chunk=32, kv_chunk=64)
        b = dense_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)


def test_block_triangular_matches_rectangular():
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 16))
    k = jax.random.normal(ks[1], (1, 256, 4, 16))
    v = jax.random.normal(ks[2], (1, 256, 4, 16))
    a = chunked_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64,
                          block_triangular=True)
    b = chunked_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("arch", [
    "yi-6b",             # dense GQA
    "olmoe-1b-7b",       # MoE
    "xlstm-350m",        # recurrent
    "zamba2-1.2b",       # hybrid
    "paligemma-3b",      # vlm (prefix + MQA)
])
def test_decode_matches_prefill(arch):
    """Greedy decode over a cache must reproduce teacher-forced prefill
    logits position by position."""
    cfg = get_reduced_config(arch).replace(dtype="float32")  # numeric stability
    fam = get_family(cfg)
    key = jax.random.PRNGKey(2)
    params = init_params(fam.layout(cfg), key, cfg.param_dtype)
    b, s_total = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s_total), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.frontend == "patch":
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(4), (b, cfg.frontend_len, cfg.frontend_dim))

    # full prefill logits for the final position
    full_logits, _ = fam.prefill(cfg, params, batch)

    # prefill on the prefix, then decode the remaining tokens one by one
    split = s_total - 3
    prefix = dict(batch, tokens=toks[:, :split])
    logits, cache = fam.prefill(cfg, params, prefix)
    offset = cfg.frontend_len if cfg.frontend == "patch" else 0

    # grow attention caches to the full length
    def grow(x):
        if x.ndim >= 3 and x.shape[2] == split + offset:
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, s_total + offset - x.shape[2])
            return jnp.pad(x, pad)
        return x
    cache = jax.tree.map(grow, cache)

    for i in range(split, s_total):
        pos = jnp.full((b,), i + offset, jnp.int32)
        step = {"tokens": toks[:, i:i + 1], "pos": pos}
        logits, cache = fam.decode(cfg, params, step, cache)

    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=0.05, atol=0.05)
