"""Tiered KV-cache hierarchy: the page-residency API (unified export with
reason tags, deprecated aliases, ``page_nbytes`` as the single sizing
truth), demote -> OBJECT-spill -> restore -> resume token identity (plain
and speculative decode, f32 and int8 pools), per-tenant storage budgets
(typed refusal), restore racing eviction (graceful re-prefill fallback),
and the PrefixCache EvictionEvent contract."""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.clock import VirtualClock
from repro.core.elastic import ProvisioningModel, ScalingPolicy
from repro.core.security import PolicyEngine, provision_tenant
from repro.models import get_family
from repro.models.params import init_params
from repro.serve import (ContinuousBatchingEngine, EngineRequest,
                         ExportReason, JobState, KottaServeGateway,
                         PageResidency, ServiceModel, StorageBudgetExceeded,
                         Tier, TieredKVStore)

MAX_LEN = 48
SLOTS = 2
NS = ("alice", "public")


@pytest.fixture(scope="module")
def model():
    cfg = get_reduced_config("yi-6b").replace(dtype="float32", page_size=8)
    fam = get_family(cfg)
    params = init_params(fam.layout(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    return cfg, params


def _engine(model, **kw):
    cfg, params = model
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("max_slots", SLOTS)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("decode_chunk", 4)
    return ContinuousBatchingEngine(cfg, params, **kw)


def _security(*tenants):
    sec = PolicyEngine(clock=VirtualClock())
    tokens = {t: provision_tenant(sec, t, f"pw-{t}",
                                  data_zones=("public", t))
              for t in tenants}
    return sec, tokens


def _gateway(model, sec, *, engine_kw=None, **kw):
    kw.setdefault("provisioning",
                  ProvisioningModel(base_delay_s=5.0, jitter_s=0.0,
                                    volatility_prob=0.0))
    kw.setdefault("service_model", ServiceModel(decode_step_s=0.05))

    def factory(m=model, ekw=engine_kw):
        return _engine(m, **(ekw or {}))
    return KottaServeGateway(factory, sec,
                             scaling=ScalingPolicy.none(
                                 1, market="on_demand"), **kw)


def _prompt(cfg, n, seed=0):
    return np.random.RandomState(seed).randint(
        0, cfg.vocab_size, size=n).tolist()


def _run_to_done(eng, max_steps=200):
    """Admit + decode until idle; returns {rid: emitted tokens}."""
    out = {}
    for _ in range(max_steps):
        if not eng.has_work:
            return out
        eng.admit()
        for req, toks in eng.decode_step():
            out[req.rid] = list(toks)
    raise RuntimeError("engine did not drain")


# ---------------------------------------------------------------------------
# Residency API: protocol shape, unified export, sizing truth
# ---------------------------------------------------------------------------

def test_engine_satisfies_page_residency_protocol(model):
    eng = _engine(model)
    assert isinstance(eng, PageResidency)
    assert [r.value for r in ExportReason] == ["handoff", "evacuate",
                                               "demote"]


def test_export_requires_exactly_one_handle(model):
    cfg, _ = model
    eng = _engine(model)
    eng.enqueue(EngineRequest(rid="a", prompt=_prompt(cfg, 12), max_new=8,
                              namespace=NS))
    eng.admit()
    with pytest.raises(ValueError, match="exactly one"):
        eng.export()
    with pytest.raises(ValueError, match="exactly one"):
        eng.export(slot=0, rid="a")
    slot = next(iter(eng._live))
    payload = eng.export(slot=slot, reason=ExportReason.DEMOTE)
    assert payload.reason is ExportReason.DEMOTE


@pytest.mark.parametrize("dtype", [None, "int8"])
def test_page_nbytes_is_the_sizing_truth(model, dtype):
    """``ShippedKV.nbytes`` must equal the actual content-array bytes AND
    ``page_nbytes() * n_content`` — one sizing truth for ship budgets and
    tier capacities, scale pages included on int8 pools."""
    cfg, _ = model
    eng = _engine(model, kv_cache_dtype=dtype)
    eng.enqueue(EngineRequest(rid="a", prompt=_prompt(cfg, 20), max_new=4,
                              namespace=NS))
    eng.admit()
    slot = next(iter(eng._live))
    payload = eng.export(slot=slot)
    n_content = next(iter(payload.content.values())).shape[2]
    manual = sum(a.nbytes for a in payload.content.values())
    assert payload.nbytes == manual == eng.page_nbytes() * n_content
    if dtype == "int8":
        f32 = _engine(model).page_nbytes()
        assert eng.page_nbytes() < f32     # int8 data + f32 scale < f32 data


# ---------------------------------------------------------------------------
# Token identity across pause -> demote -> OBJECT spill -> restore -> resume
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine_kw", [
    {},
    {"enable_spec_decode": True},
    {"kv_cache_dtype": "int8"},
    {"kv_cache_dtype": "int8", "enable_spec_decode": True},
], ids=["f32", "f32-spec", "int8", "int8-spec"])
def test_demote_restore_token_identity(model, engine_kw):
    """A request paused mid-decode, exported with reason=DEMOTE, parked in
    the store with zero HOST capacity (straight to the OBJECT tier, i.e.
    through full serialize/deserialize), restored, and re-imported must
    finish with greedy tokens identical to an undisturbed run."""
    cfg, _ = model
    prompt = _prompt(cfg, 14, seed=7)
    oracle = _run_to_done(_deferred_engine(model, engine_kw, prompt))["s"]

    eng = _engine(model, **engine_kw)
    eng.enqueue(EngineRequest(rid="s", prompt=prompt, max_new=12,
                              namespace=NS))
    eng.admit()
    eng.decode_step()                       # emit a few tokens mid-stream
    slot = next(iter(eng._live))
    eng.preempt(slot)
    # Deprecated alias must still reach the unified entry point.
    payload = eng.export_paused("s", reason=ExportReason.DEMOTE)
    assert not eng.has_work                 # pages fully off the engine

    store = TieredKVStore(host_capacity_bytes=0)    # everything spills
    assert store.demote(payload, "alice", now=0.0) is Tier.OBJECT
    stream = tuple(prompt) + tuple(payload.tokens)
    key, matched, tier = store.match(NS, stream)
    assert tier is Tier.OBJECT and matched == len(stream)
    ticket = store.request_restore(key, "s", now=1.0)
    assert ticket.ready_at > 1.0            # OBJECT restores are not free
    restored = store.complete_restore(ticket, ticket.ready_at)
    assert restored is not None

    eng.import_pages(restored)
    final = _run_to_done(eng)["s"]
    assert final == oracle
    assert store.stats["restores_object"] == 1


def _deferred_engine(model, engine_kw, prompt):
    eng = _engine(model, **engine_kw)
    eng.enqueue(EngineRequest(rid="s", prompt=prompt, max_new=12,
                              namespace=NS))
    return eng


# ---------------------------------------------------------------------------
# Per-tenant storage budgets
# ---------------------------------------------------------------------------

def test_tenant_storage_budget_typed_refusal(model):
    cfg, _ = model
    eng = _engine(model)
    payloads = []
    for i, seed in enumerate((1, 2, 3)):
        eng.enqueue(EngineRequest(rid=i, prompt=_prompt(cfg, 16, seed),
                                  max_new=4, namespace=NS))
        eng.admit()
        slot = next(iter(eng._live))
        payloads.append(eng.export(slot=slot, reason=ExportReason.DEMOTE))

    budget = payloads[0].nbytes + payloads[1].nbytes
    store = TieredKVStore(host_capacity_bytes=1 << 30,
                          tenant_budget_bytes=budget)
    store.demote(payloads[0], "alice", now=0.0)
    store.demote(payloads[1], "alice", now=0.0)
    with pytest.raises(StorageBudgetExceeded) as ei:
        store.demote(payloads[2], "alice", now=0.0)
    assert ei.value.reason == "storage_budget_exceeded"
    assert store.stats["budget_refusals"] == 1
    # Budgets are per tenant: another tenant's demotion still lands.
    assert store.demote(payloads[2], "bob", now=0.0) is Tier.HOST


# ---------------------------------------------------------------------------
# Restore racing eviction
# ---------------------------------------------------------------------------

def test_restore_racing_eviction_returns_none(model):
    """An entry evicted while its restore is in flight: ``complete_restore``
    reports the loss as None (a restore miss), never a crash."""
    cfg, _ = model
    eng = _engine(model)
    payloads = []
    for i, seed in enumerate((4, 5)):
        eng.enqueue(EngineRequest(rid=i, prompt=_prompt(cfg, 16, seed),
                                  max_new=4, namespace=NS))
        eng.admit()
        slot = next(iter(eng._live))
        payloads.append(eng.export(slot=slot, reason=ExportReason.DEMOTE))

    store = TieredKVStore(host_capacity_bytes=0,
                          object_capacity_bytes=payloads[0].nbytes)
    store.demote(payloads[0], "alice", now=0.0)
    key, _, _ = store.match(NS, tuple(payloads[0].req.prompt)
                            + tuple(payloads[0].tokens))
    ticket = store.request_restore(key, 0, now=1.0)
    # Capacity pressure while the restore is in flight evicts the entry.
    store.demote(payloads[1], "alice", now=2.0)
    assert store.tier_of(key) is None
    assert store.complete_restore(ticket, ticket.ready_at) is None
    assert store.stats["restore_misses"] == 1


def test_gateway_restore_fallback_reprefills(model):
    """Gateway-level race: a parked RESTORE_PENDING job whose store entry
    vanishes mid-flight falls back to plain re-prefill — same tokens as a
    store-less gateway, no crash, and the miss is counted."""
    cfg, _ = model
    prompt = _prompt(cfg, 16, seed=9)

    def run(store):
        sec, tok = _security("alice")
        gw = _gateway(model, sec, kv_store=store)
        r1 = gw.submit(tok["alice"], prompt, max_new=4, data_zone="public")
        gw.drain()
        reply = gw.result(r1)
        r2 = gw.submit(tok["alice"], prompt + reply + _prompt(cfg, 4, 10),
                       max_new=4, data_zone="public")
        if store is not None:
            gw.step()           # parks r2 RESTORE_PENDING on the ticket
            assert gw.jobs[r2].status is JobState.RESTORE_PENDING
            # The entry vanishes while the restore is in flight (capacity
            # eviction seen from the gateway's side).
            store._entries.clear()
        gw.drain()
        assert gw.jobs[r2].status is JobState.DONE
        return gw.result(r2), gw

    # Slow restores guarantee the park window outlives one step.
    store = TieredKVStore(host_capacity_bytes=1 << 30,
                          host_restore_bytes_per_s=64.0)
    got, gw = run(store)
    want, _ = run(None)
    assert got == want
    assert gw.stats["kv_restore_fallbacks"] == 1
    assert gw.stats["kv_restores"] == 0


# ---------------------------------------------------------------------------
# PrefixCache eviction contract
# ---------------------------------------------------------------------------

def test_eviction_events_cover_only_free_pages(model):
    """Every page in an EvictionEvent is refcount-zero at event time (the
    only pages the allocator may recycle), namespaces are preserved, and
    epochs advance monotonically."""
    cfg, _ = model
    eng = _engine(model, num_pages=12)
    events = []

    def on_evict(ev):
        for p in ev.pages:
            assert eng.alloc.refs[p] == 0, \
                f"page {p} evicted while still referenced"
        events.append(ev)

    eng.prefix_cache.on_evict = on_evict
    for seed in range(6):                   # churn the 12-page pool
        eng.enqueue(EngineRequest(rid=seed,
                                  prompt=_prompt(cfg, 16, seed + 20),
                                  max_new=4, namespace=NS))
        _run_to_done(eng)
    assert events, "pool churn produced no eviction events"
    epochs = [e.epoch for e in events]
    assert epochs == sorted(epochs) and len(set(epochs)) == len(epochs)
    assert all(e.namespace == NS for e in events)
    assert all(0 < p < 12 for e in events for p in e.pages)


def test_gateway_demotes_before_device_eviction(model):
    """With a store attached, a finished session's stream is demoted at
    retirement — so later device-index evictions can never lose content:
    the resumed request restores and extends the stream token-identically.
    """
    cfg, _ = model
    prompt = _prompt(cfg, 16, seed=30)
    tail = _prompt(cfg, 4, seed=31)

    def run(store):
        sec, tok = _security("alice")
        gw = _gateway(model, sec, kv_store=store,
                      engine_kw={"num_pages": 12})
        r1 = gw.submit(tok["alice"], prompt, max_new=4, data_zone="public")
        gw.drain()
        reply = gw.result(r1)
        # Churn the 12-page pool so the finished stream's device copy is
        # recycled before the resume arrives.
        for s in range(3):
            gw.submit(tok["alice"], _prompt(cfg, 16, seed=40 + s),
                      max_new=4, data_zone="public")
        gw.drain()
        r2 = gw.submit(tok["alice"], prompt + reply + tail, max_new=4,
                       data_zone="public")
        gw.drain()
        return gw.result(r2), gw

    store = TieredKVStore(host_capacity_bytes=1 << 30)
    got, gw = run(store)
    want, _ = run(None)
    assert got == want
    assert gw.stats["kv_demotions"] >= 4        # every retirement demoted
    assert gw.stats["kv_restores"] == 1         # the resume came back
    assert store.stats["eviction_events"] > 0   # device index did churn
    assert store.stats["device_evicted_pages"] > 0


# ---------------------------------------------------------------------------
# Storage accounting
# ---------------------------------------------------------------------------

def test_gb_hours_accrue_per_tier_and_tenant(model):
    cfg, _ = model
    eng = _engine(model)
    eng.enqueue(EngineRequest(rid="a", prompt=_prompt(cfg, 16, 50),
                              max_new=4, namespace=NS))
    eng.admit()
    payload = eng.export(slot=next(iter(eng._live)),
                         reason=ExportReason.DEMOTE)
    store = TieredKVStore(host_capacity_bytes=1 << 30)
    store.demote(payload, "alice", now=0.0)
    store.accrue(now=0.0)                   # open the accrual interval
    usd = store.accrue(now=3600.0)          # one GB-hour later
    gb = payload.nbytes / 1e9
    assert store.gb_hours[Tier.HOST] == pytest.approx(gb)
    assert usd == pytest.approx(gb * store.rate_per_gb_hour[Tier.HOST])
    assert store.cost_by_tenant["alice"] == pytest.approx(usd)
    assert store.gb_hours_by_tenant["alice"][Tier.HOST] == \
        pytest.approx(gb)
