"""StateStore overload behavior: token-bucket refill math, non-blocking
try_* throttle accounting, and the sharding move — a ShardedStateStore
with N partitions of the same per-shard capacity sustains ~N x the write
rate of a single table (the paper's Fig-6 scaling fix)."""
import threading
import time

import pytest

from repro.core.clock import VirtualClock
from repro.core.scheduler import ShardedStateStore, StateStore, _TokenBucket


# ---------------------------------------------------------------------------
# Token bucket: refill math
# ---------------------------------------------------------------------------

def test_token_bucket_starts_full_and_refills_at_rate():
    clock = VirtualClock()
    tb = _TokenBucket(10.0, clock)
    # Burst = rate: exactly 10 immediate acquires, the 11th refuses.
    assert all(tb.try_acquire() for _ in range(10))
    assert not tb.try_acquire()
    # 0.5s refills 5 tokens — not 6.
    clock.advance(0.5)
    assert all(tb.try_acquire() for _ in range(5))
    assert not tb.try_acquire()


def test_token_bucket_refill_caps_at_burst():
    clock = VirtualClock()
    tb = _TokenBucket(4.0, clock)
    clock.advance(100.0)               # idle forever != unbounded credit
    assert all(tb.try_acquire() for _ in range(4))
    assert not tb.try_acquire()


def test_token_bucket_blocking_acquire_waits_out_shortfall():
    # acquire() parks on VirtualClock.sleep until a DRIVER advances the
    # clock — which is why the single-threaded gateway (which IS the
    # driver) must use try_acquire instead (it would deadlock here).
    clock = VirtualClock()
    tb = _TokenBucket(2.0, clock)
    for _ in range(2):
        tb.acquire()
    woke = []
    worker = threading.Thread(
        target=lambda: (tb.acquire(), woke.append(clock.now())))
    worker.start()
    deadline = time.monotonic() + 5.0
    while clock.pending_wakeups() == 0:       # worker parked on the clock
        assert time.monotonic() < deadline
        time.sleep(0.001)
    clock.advance(0.25)                       # half the 0.5s shortfall
    time.sleep(0.01)
    assert not woke                           # still short 0.5 tokens
    clock.advance(0.25)
    worker.join(timeout=5.0)
    assert woke == [pytest.approx(0.5)]


# ---------------------------------------------------------------------------
# StateStore: try_* throttle accounting
# ---------------------------------------------------------------------------

def test_try_put_counts_throttles_and_drops_nothing_silently():
    clock = VirtualClock()
    store = StateStore(clock=clock, write_capacity=5.0)
    ok = [store.try_put_item(f"k{i}", {"i": i}) for i in range(8)]
    assert ok == [True] * 5 + [False] * 3
    assert store.write_count == 5
    assert store.throttled_writes == 3
    assert len(store.scan()) == 5       # refused writes left no item
    clock.advance(1.0)                  # 5 tokens back
    assert store.try_put_item("late", {})
    assert store.throttled_writes == 3  # success does not touch the counter


def test_try_get_distinguishes_throttle_from_absent():
    clock = VirtualClock()
    store = StateStore(clock=clock, read_capacity=1.0)
    store.put_item("k", {"v": 1})
    ok, item = store.try_get_item("k")
    assert ok and item == {"v": 1}
    ok, item = store.try_get_item("k")          # bucket empty
    assert (ok, item) == (False, None)
    assert store.throttled_reads == 1
    clock.advance(1.0)
    ok, item = store.try_get_item("missing")    # absent but NOT throttled
    assert (ok, item) == (True, None)


def test_try_update_creates_and_merges():
    clock = VirtualClock()
    store = StateStore(clock=clock, write_capacity=2.0)
    assert store.try_update_item("job", status="queued")
    assert store.try_update_item("job", status="done", tokens=7)
    assert not store.try_update_item("job", lost=True)
    assert store.get_item("job") == {"status": "done", "tokens": 7}


# ---------------------------------------------------------------------------
# ShardedStateStore: N shards sustain ~N x the write rate
# ---------------------------------------------------------------------------

def _offered_writes(store, rate_per_s: float, duration_s: float, clock):
    """Open-loop write stream at ``rate_per_s`` against ``store``;
    returns (accepted, throttled)."""
    n = int(rate_per_s * duration_s)
    accepted = 0
    for i in range(n):
        clock.advance(duration_s / n)
        if store.try_put_item(f"metrics/{i:06d}", {"i": i}):
            accepted += 1
    return accepted, store.throttled_writes


def test_sharded_store_sustains_4x_single_table_write_rate():
    # Offered 80 w/s against 20 w/s tables: a single table throttles ~3/4
    # of the stream; 4 shards of the same per-shard capacity absorb it.
    rate, dur, cap = 80.0, 10.0, 20.0
    clock1 = VirtualClock()
    single = StateStore(clock=clock1, write_capacity=cap)
    acc1, thr1 = _offered_writes(single, rate, dur, clock1)

    clock4 = VirtualClock()
    sharded = ShardedStateStore(4, clock=clock4, write_capacity=cap)
    acc4, thr4 = _offered_writes(sharded, rate, dur, clock4)

    assert thr1 > 0                      # the single table genuinely walls
    # Sustained rates: ~cap for the single table, ~4x cap for the shards
    # (crc32 spreads sequential keys unevenly, so allow a 25% haircut).
    assert acc1 <= cap * dur * 1.2
    assert acc4 >= 3.0 * acc1
    assert thr4 < thr1
    assert len(sharded.scan("metrics/")) == acc4
    assert sharded.write_count == acc4   # aggregate property sums shards


def test_sharded_store_routes_keys_stably_and_merges_scans():
    clock = VirtualClock()
    store = ShardedStateStore(4, clock=clock, write_capacity=1000.0)
    keys = [f"servejob/{i}" for i in range(32)]
    for k in keys:
        store.put_item(k, {"k": k})
    # Every key reads back from the shard that holds it, and at least two
    # shards got traffic (crc32 actually spreads the space).
    for k in keys:
        assert store.get_item(k) == {"k": k}
    assert sum(1 for s in store.shards if s.write_count) >= 2
    assert set(store.scan("servejob/")) == set(keys)


def test_sharded_store_validates_shard_count():
    with pytest.raises(ValueError, match="shards"):
        ShardedStateStore(0)
