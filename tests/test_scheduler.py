"""Job management (paper §IV-D): queues, workers, watcher, restore parking."""
import time

import pytest

from repro.core import (ExecutableRegistry, JobSpec, JobStatus, KottaService,
                        ObjectStore, PolicyEngine, Principal, Role, Tier,
                        allow, days, install_standard_roles)


def make_service(**watcher_kwargs):
    engine = PolicyEngine()
    install_standard_roles(engine)
    store = ObjectStore(clock=engine.clock)
    registry = ExecutableRegistry()

    @registry.register("wordcount")
    def wordcount(ctx):
        total = sum(len(v.split()) for v in ctx.staged_inputs.values())
        ctx.outputs[f"results/{ctx.job_id}/count.txt"] = str(total).encode()
        return total

    @registry.register("sleepy")
    def sleepy(ctx):
        for _ in range(50):
            ctx.checkpoint()
            time.sleep(0.01)
        return "done"

    @registry.register("boom")
    def boom(ctx):
        raise RuntimeError("analysis exploded")

    svc = KottaService(engine, store, registry,
                       watcher_kwargs=watcher_kwargs or
                       {"heartbeat_timeout_s": 0.5, "interval_s": 0.05})
    return svc


def make_user(svc, uid="alice", dataset="corpus"):
    role = Role(f"user-{uid}", policies=[
        allow(["data:Get", "data:List"], [f"dataset/{dataset}/*"]),
        allow(["data:*"], [f"results/*"]),
        allow(["jobs:*"], ["queue/*"]),
    ], trusted_assumers={"task-executor"})
    svc.engine.register_role(role)
    p = Principal(uid)
    svc.engine.authenticator.register_identity(p, "pw")
    svc.engine.bind(p, role.name)
    return svc.engine.login(uid, "pw")


@pytest.fixture
def svc():
    s = make_service()
    yield s
    s.shutdown()


def test_end_to_end_job(svc):
    svc.store.put("dataset/corpus/a.txt", b"the quick brown fox", owner="sys")
    tok = make_user(svc)
    svc.start(dev_workers=1)
    job = svc.submit(tok, JobSpec("wordcount", inputs=("dataset/corpus/a.txt",),
                                  queue="dev"))
    rec = svc.wait(job, timeout_s=10)
    assert rec["status"] == JobStatus.COMPLETED
    assert svc.store.get(f"results/{job}/count.txt") == b"4"


def test_unauthorized_submit_rejected(svc):
    svc.store.put("dataset/secret/a", b"x", owner="sys")
    tok = make_user(svc, dataset="corpus")
    svc.start()
    with pytest.raises(Exception):
        svc.submit(tok, JobSpec("wordcount", inputs=("dataset/secret/a",)))


def test_failed_job_reports_error(svc):
    tok = make_user(svc)
    svc.start()
    job = svc.submit(tok, JobSpec("boom", queue="dev"))
    rec = svc.wait(job, timeout_s=10)
    assert rec["status"] == JobStatus.FAILED
    assert "exploded" in rec["error"]


def test_archived_input_parks_then_runs(svc):
    svc.store.put("dataset/corpus/cold.txt", b"one two", owner="sys")
    # age it into ARCHIVE
    meta = svc.store.head("dataset/corpus/cold.txt")
    meta.tier = Tier.ARCHIVE
    tok = make_user(svc)
    svc.start(dev_workers=1)
    job = svc.submit(tok, JobSpec("wordcount",
                                  inputs=("dataset/corpus/cold.txt",),
                                  queue="dev"))
    time.sleep(0.3)
    assert svc.status(job)["status"] == JobStatus.WAITING_DATA
    # fast-forward the restore (real latency is 4h)
    meta.restore_ready_at = svc.clock.now() - 1
    rec = svc.wait(job, timeout_s=10)
    assert rec["status"] == JobStatus.COMPLETED


def test_revocation_resubmits_and_completes(svc):
    tok = make_user(svc)
    svc.start(dev_workers=1)
    w_spot = svc.add_worker("prod", preemptible=True)
    job = svc.submit(tok, JobSpec("sleepy", queue="prod"))
    deadline = time.time() + 5
    while (svc.status(job)["status"] != JobStatus.RUNNING
           and time.time() < deadline):
        time.sleep(0.02)
    w_spot.revoke()                      # spot reclaim mid-run
    svc.add_worker("prod", preemptible=True)
    rec = svc.wait(job, timeout_s=20)
    assert rec["status"] == JobStatus.COMPLETED
    assert svc.watcher.resubmissions >= 1 or rec.get("attempt", 0) >= 0


def test_worker_assumes_user_role_for_staging(svc):
    svc.store.put("dataset/corpus/a.txt", b"hello world", owner="sys")
    tok = make_user(svc)
    svc.start(dev_workers=1)
    job = svc.submit(tok, JobSpec("wordcount", inputs=("dataset/corpus/a.txt",),
                                  queue="dev"))
    svc.wait(job, timeout_s=10)
    assumes = [r for r in svc.engine.audit.records(decision="allow")
               if r.action == "sts:AssumeRole" and "user-alice" in r.resource]
    assert assumes, "worker must assume the submitting user's role to stage"


def test_throughput_multiple_jobs(svc):
    tok = make_user(svc)
    svc.start(dev_workers=2)

    @svc.registry.register("quick")
    def quick(ctx):
        return "ok"

    jobs = [svc.submit(tok, JobSpec("quick", queue="dev")) for _ in range(12)]
    for j in jobs:
        assert svc.wait(j, timeout_s=15)["status"] == JobStatus.COMPLETED
