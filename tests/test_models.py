"""Per-arch smoke tests: REDUCED configs, one forward/train step on CPU,
output shapes + finite values (assignment requirement f)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config, get_reduced_config
from repro.models import count_params, get_family
from repro.models.params import abstract_params, init_params

B, S = 2, 32


def make_batch(cfg, key):
    ks = jax.random.split(key, 4)
    if cfg.frontend == "frame":
        return {"frames": jax.random.normal(ks[0], (B, S, cfg.frontend_dim)),
                "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
                "loss_mask": (jax.random.uniform(ks[2], (B, S)) < 0.3)
                .astype(jnp.float32)}
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)}
    if cfg.frontend == "patch":
        batch["patches"] = jax.random.normal(
            ks[2], (B, cfg.frontend_len, cfg.frontend_dim))
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_train_step(arch):
    cfg = get_reduced_config(arch)
    fam = get_family(cfg)
    key = jax.random.PRNGKey(0)
    params = init_params(fam.layout(cfg), key, cfg.param_dtype)
    batch = make_batch(cfg, jax.random.PRNGKey(7))

    loss, metrics = jax.jit(lambda p, b: fam.train_loss(cfg, p, b))(
        params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    assert float(loss) > 0.0
    # gradients exist and are finite for every leaf
    grads = jax.grad(lambda p: fam.train_loss(cfg, p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gnorm) and gnorm > 0.0, f"{arch}: bad grads"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_serve_step(arch):
    cfg = get_reduced_config(arch)
    fam = get_family(cfg)
    key = jax.random.PRNGKey(0)
    params = init_params(fam.layout(cfg), key, cfg.param_dtype)
    batch = make_batch(cfg, jax.random.PRNGKey(7))
    batch.pop("labels", None)
    batch.pop("loss_mask", None)
    logits, cache = jax.jit(lambda p, b: fam.prefill(cfg, p, b))(params, batch)
    if cfg.encoder_only:
        assert logits.shape == (B, S, cfg.vocab_size)
    else:
        assert logits.shape == (B, cfg.vocab_size)
        assert cache, f"{arch}: prefill must emit a cache"
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_full_config_abstract_layout(arch):
    """FULL configs are exercised abstractly (no allocation): layout builds,
    parameter count matches the published scale."""
    import math
    cfg = get_config(arch)
    fam = get_family(cfg)
    abs_p = abstract_params(fam.layout(cfg), cfg.param_dtype)
    total = sum(math.prod(l.shape) for l in jax.tree.leaves(abs_p))
    assert total == count_params(cfg)


EXPECTED_SCALE_B = {
    "arctic-480b": (450, 520), "olmoe-1b-7b": (6, 8),
    "mistral-nemo-12b": (11, 13.5), "starcoder2-7b": (6.5, 8),
    "yi-6b": (5.5, 6.5), "internlm2-1.8b": (1.6, 2.1),
    "hubert-xlarge": (0.8, 1.1), "xlstm-350m": (0.3, 0.55),
    "paligemma-3b": (2.2, 3.2), "zamba2-1.2b": (1.0, 1.4),
}


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_param_count_matches_published(arch):
    lo, hi = EXPECTED_SCALE_B[arch]
    n = count_params(get_config(arch)) / 1e9
    assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo},{hi}]"


def test_moe_active_params():
    cfg = get_config("olmoe-1b-7b")
    active = cfg.active_param_count() / 1e9
    assert 0.9 <= active <= 1.6  # the "1B" in OLMoE-1B-7B
