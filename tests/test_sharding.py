"""Sharding rule engine + a reduced-mesh dry-run in a subprocess."""
import json
import os
import subprocess
import sys

import pytest
from jax.sharding import PartitionSpec as P

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def make_rules(mesh_shape=(2, 2), axes=("data", "model"), overrides=None):
    import jax
    from repro.distributed.sharding import ShardingRules
    # AbstractMesh: rule resolution needs only the mesh *shape*, so the unit
    # tests run on a 1-device container.
    mesh = jax.sharding.AbstractMesh(
        mesh_shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return ShardingRules(mesh, overrides or {})


def test_spec_basic_mapping():
    rules = make_rules()
    spec = rules.spec_for((64, 128), ("vocab", "embed"))
    assert spec == P("model", "data")


def test_divisibility_fallback():
    rules = make_rules()
    # 7 heads do not divide the 2-way model axis -> unsharded (Arctic case)
    spec = rules.spec_for((64, 7, 16), ("embed", "heads", "head_dim"))
    assert spec == P("data", None, None)


def test_mesh_axis_used_once():
    rules = make_rules()
    # both logical axes map to "model"; only the first dim gets it
    spec = rules.spec_for((64, 64), ("vocab", "mlp"))
    assert spec == P("model", None)


def test_missing_mesh_axes_dropped():
    rules = make_rules(mesh_shape=(4,), axes=("data",))
    spec = rules.spec_for((8, 64), ("batch", "mlp"))
    assert spec == P("data", None)  # "pod"/"model" absent from mesh


def test_tuple_rule_batch_over_pod_and_data():
    rules = make_rules(mesh_shape=(2, 2, 2), axes=("pod", "data", "model"))
    spec = rules.spec_for((8, 64), ("batch", None))
    assert spec == P(("pod", "data"), None)


def test_shard_noop_outside_context():
    import jax.numpy as jnp
    from repro.distributed.sharding import shard
    x = jnp.ones((4, 4))
    assert shard(x, ("batch", None)) is x


@pytest.mark.slow
def test_reduced_dryrun_subprocess(tmp_path):
    """End-to-end dry-run on 16 placeholder devices with a reduced config:
    proves lower+compile+analysis machinery without the full 512-dev cost."""
    code = f"""
import os
os.environ["KOTTA_XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
os.environ["XLA_FLAGS"] = os.environ["KOTTA_XLA_FLAGS"]
import sys
sys.path.insert(0, {ROOT + "/src"!r})
import jax
from repro.configs import get_reduced_config, ShapeConfig
from repro.distributed.sharding import ShardingRules, activate_rules
from repro.launch.input_specs import build_cell
cfg = get_reduced_config("yi-6b")
shape = ShapeConfig("mini_train", "train", 64, 8)
mesh = jax.make_mesh((4, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
rules = ShardingRules(mesh, {{}})
step, args, sh = build_cell(cfg, shape, rules)
with jax.set_mesh(mesh), activate_rules(rules):
    compiled = jax.jit(step, in_shardings=sh).lower(*args).compile()
print("MEM", compiled.memory_analysis().temp_size_in_bytes)
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "MEM" in out.stdout
