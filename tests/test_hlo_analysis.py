"""HLO parser: trip counts, dot FLOPs vs analytic, collective conventions."""
import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.launch.hlo_analysis import analyze_hlo, parse_computations


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_trip_count_scales_flops():
    d, L = 128, 7

    def f(w, x):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = lax.scan(body, x, w)
        return h.sum()

    w = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((4, d), jnp.float32)
    rep = analyze_hlo(_compile_text(f, w, x))
    expected = 2 * 4 * d * d * L
    assert rep.dot_flops == pytest.approx(expected, rel=0.05)
    assert L in [int(t) for t in rep.while_trips.values()]


def test_plain_matmul_flops():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 16), jnp.float32)
    rep = analyze_hlo(_compile_text(f, a, b))
    assert rep.dot_flops == pytest.approx(2 * 64 * 32 * 16, rel=0.01)


def test_nested_scan_multiplies():
    d, outer, inner = 32, 3, 5

    def f(w, x):
        def obody(h, _):
            def ibody(hh, _):
                return jnp.tanh(hh @ w), None
            h2, _ = lax.scan(ibody, h, None, length=inner)
            return h2, None
        h, _ = lax.scan(obody, x, None, length=outer)
        return h.sum()

    w = jax.ShapeDtypeStruct((d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((2, d), jnp.float32)
    rep = analyze_hlo(_compile_text(f, w, x))
    expected = 2 * 2 * d * d * outer * inner
    assert rep.dot_flops == pytest.approx(expected, rel=0.05)


def test_computation_parsing_smoke():
    def f(x):
        return jnp.sum(x * 2.0)

    comps = parse_computations(_compile_text(
        f, jax.ShapeDtypeStruct((8,), jnp.float32)))
    assert any(c.is_entry for c in comps.values())


def test_collective_conventions():
    """Hand-written SPMD-style HLO exercises the ring formulas."""
    hlo = """
ENTRY %main (p: f32[16,64]) -> f32[16,64] {
  %p = f32[16,64]{1,0} parameter(0)
  %ag = f32[16,256]{1,0} all-gather(%p), replica_groups=[4,4]<=[16], dimensions={1}
  %ar = f32[16,64]{1,0} all-reduce(%p), replica_groups=[2,8]<=[16], to_apply=%add
  ROOT %cp = f32[16,64]{1,0} collective-permute(%p), source_target_pairs={{0,1}}
}
"""
    rep = analyze_hlo(hlo)
    ag = 16 * 256 * 4 * (4 - 1) / 4
    ar = 2 * 16 * 64 * 4 * (8 - 1) / 8
    cp = 16 * 64 * 4
    assert rep.collective_by_op["all-gather"] == pytest.approx(ag)
    assert rep.collective_by_op["all-reduce"] == pytest.approx(ar)
    assert rep.collective_by_op["collective-permute"] == pytest.approx(cp)
