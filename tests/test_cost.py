"""Cost models: Table III exact reproduction + placement (Fig 7) relations."""
import pytest

from repro.core import lifecycle_annual_cost, placement_cost
from repro.core.cost import StoragePricing, glacier_retrieval_monthly, s3_std_monthly

TEN_TB = 10_000.0  # decimal GB, as the paper uses


@pytest.mark.parametrize("policy,active,expected", [
    ("STD", 0.0, 3546.0),
    ("IA", 0.0, 1500.0),
    ("GLACIER", 0.03, 840.0),
    ("STD30-IA", 0.0, 1670.5),
    ("STD30-IA60-GLACIER", 0.03, 880.259),
    ("STD30-IA60-GLACIER", 0.10, 974.20),
])
def test_table3_storage_column_exact(policy, active, expected):
    got = lifecycle_annual_cost(policy, TEN_TB, active).storage_annual
    assert got == pytest.approx(expected, abs=0.01)


def test_table3_lifecycle_access_cost_close_to_paper():
    # Paper: $169.73/yr (their spreadsheet mixes binary/decimal GB; the same
    # Eq (1)-(2) burst with decimal GB gives $165.0 — within 3%).
    got = lifecycle_annual_cost("STD30-IA60-GLACIER", TEN_TB, 0.03).access_annual
    assert got == pytest.approx(169.73, rel=0.04)


def test_glacier_free_quota_means_zero_fee():
    # retrieving under 5%/month pro-rated daily is free
    assert glacier_retrieval_monthly(10.0, 10_000.0) == 0.0
    assert glacier_retrieval_monthly(300.0, 10_000.0) > 0.0


def test_std_tiered_pricing():
    assert s3_std_monthly(1_000.0) == pytest.approx(30.0)
    assert s3_std_monthly(10_000.0) == pytest.approx(295.5)


def test_placement_egress_tradeoff():
    """Fig 7: remote-cheap wins at low data volume, local wins at high."""
    local = placement_cost(1.675, 1.0, 0, 0, same_region_as_data=True)
    # remote instance 40% cheaper
    for gb, expect_remote_cheaper in [(5.0, True), (200.0, False)]:
        remote = placement_cost(1.0, 1.0, gb, gb, same_region_as_data=False)
        assert (remote < local) == expect_remote_cheaper


def test_pricing_is_frozen_dataclass():
    p = StoragePricing()
    with pytest.raises(Exception):
        p.s3_ia_per_gb_month = 0.0
