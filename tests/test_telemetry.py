"""The unified observability plane: MetricsRegistry (counters / gauges /
fixed-bucket histograms, labels, collectors), lossless Prometheus text
exposition (parse_exposition round-trips to snapshot equality),
RegistryDict write-through compatibility views (positive-delta counter
semantics across engine stat resets), the open-loop traffic generator,
and gateway integration: one registry serves gateway + engines + router
with per-tenant labels while telemetry streams into a StateStore."""
from dataclasses import replace

import jax
import pytest

from repro.configs import get_reduced_config
from repro.core.clock import VirtualClock
from repro.core.elastic import ScalingPolicy
from repro.core.scheduler import ShardedStateStore, StateStore
from repro.core.security import PolicyEngine, provision_tenant
from repro.models import get_family
from repro.models.params import init_params
from repro.serve import (ContinuousBatchingEngine, DeadlineCostPolicy,
                         KottaServeGateway, MetricsRegistry, RegistryDict,
                         ServiceModel, TrafficConfig, generate_trace,
                         parse_exposition, run_open_loop)
from repro.serve.loadgen import offered_load

MAX_LEN = 48
SLOTS = 4


@pytest.fixture(scope="module")
def model():
    cfg = get_reduced_config("yi-6b").replace(dtype="float32", page_size=8)
    fam = get_family(cfg)
    params = init_params(fam.layout(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    return cfg, params


# ---------------------------------------------------------------------------
# MetricsRegistry: families, labels, validation
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("jobs_total", "jobs", ("tenant",))
    c.inc(tenant="alice")
    c.inc(2, tenant="alice")
    c.inc(tenant="bob")
    assert reg.value("jobs_total", tenant="alice") == 3
    assert reg.value("jobs_total", tenant="bob") == 1
    assert reg.value("jobs_total", tenant="nobody") == 0.0

    g = reg.gauge("depth")
    g.set(7)
    g.set(3)
    assert reg.value("depth") == 3

    h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    snap = reg.snapshot()["families"]["lat_seconds"]["samples"][0]
    # Integral bounds render bare ("1", not "1.0") in le= keys.
    assert snap["buckets"] == {"0.1": 1.0, "1": 2.0, "+Inf": 3.0}
    assert snap["sum"] == pytest.approx(5.55)
    assert snap["count"] == 3


def test_registration_is_idempotent_but_conflicts_raise():
    reg = MetricsRegistry()
    a = reg.counter("x_total", "x", ("t",))
    assert reg.counter("x_total", "x", ("t",)) is a
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x_total")
    with pytest.raises(ValueError, match="already registered"):
        reg.counter("x_total", "x", ("other",))


def test_counters_reject_negative_and_histograms_reject_value_read():
    reg = MetricsRegistry()
    c = reg.counter("n_total")
    with pytest.raises(ValueError, match="must be >= 0"):
        c.inc(-1)
    h = reg.histogram("h_seconds", buckets=(1.0,))
    with pytest.raises(TypeError):
        h.value()


def test_label_validation():
    reg = MetricsRegistry()
    c = reg.counter("l_total", "", ("tenant",))
    with pytest.raises(ValueError):
        c.inc(region="us")          # wrong label name
    with pytest.raises(ValueError):
        c.inc()                     # missing label


def test_collector_refreshes_and_retires_gauge_series():
    reg = MetricsRegistry()
    g = reg.gauge("occ", "", ("replica",))
    live = {"r0": 0.5, "r1": 1.0}

    def collect():
        g.clear()
        for r, v in live.items():
            g.set(v, replica=r)

    reg.register_collector(collect)
    assert reg.value("occ", replica="r1") == 0  # not collected yet
    snap = reg.snapshot()                       # snapshot() collects
    assert len(snap["families"]["occ"]["samples"]) == 2
    del live["r1"]                              # replica retires
    snap = reg.snapshot()
    assert [s["labels"] for s in snap["families"]["occ"]["samples"]] == [
        {"replica": "r0"}]


# ---------------------------------------------------------------------------
# Exposition: valid Prometheus text, lossless round-trip
# ---------------------------------------------------------------------------

def _populated_registry():
    clock = VirtualClock()
    clock.advance(12.5)
    reg = MetricsRegistry(clock=clock)
    c = reg.counter("kotta_requests_total", "Requests", ("tenant", "class"))
    c.inc(3, tenant="alice", **{"class": "interactive"})
    c.inc(1, tenant='quo"te\\back\nline', **{"class": "batch"})
    reg.gauge("kotta_burn", "Burn").set(1.75)
    h = reg.histogram("kotta_ttft_seconds", "TTFT", buckets=(0.5, 2.0),
                      labelnames=("tenant",))
    for v in (0.1, 1.0, 9.0):
        h.observe(v, tenant="alice")
    return reg


def test_exposition_format():
    text = _populated_registry().expose()
    assert "# TYPE kotta_requests_total counter" in text
    assert ('kotta_requests_total{tenant="alice",class="interactive"} 3'
            in text)
    # Label escaping: backslash, quote, newline.
    assert r'tenant="quo\"te\\back\nline"' in text
    assert "# TYPE kotta_ttft_seconds histogram" in text
    assert 'kotta_ttft_seconds_bucket{tenant="alice",le="+Inf"} 3' in text
    assert 'kotta_ttft_seconds_count{tenant="alice"} 3' in text


def test_parse_exposition_round_trips_snapshot_exactly():
    reg = _populated_registry()
    assert parse_exposition(reg.expose())["families"] == \
        reg.snapshot()["families"]


def test_round_trip_is_lossless_for_awkward_floats():
    reg = MetricsRegistry()
    g = reg.gauge("g", "", ("k",))
    for i, v in enumerate((0.1, 1e-12, 1e300, 123456789.000001,
                           float("inf"))):
        g.set(v, k=str(i))
    assert parse_exposition(reg.expose())["families"] == \
        reg.snapshot()["families"]


# ---------------------------------------------------------------------------
# RegistryDict: the dict-compat layer over registry series
# ---------------------------------------------------------------------------

def test_registry_dict_counter_delta_semantics():
    reg = MetricsRegistry()
    c = reg.counter("evt_total", "", ("engine",))
    rd = RegistryDict()
    rd.bind("evt", c, initial=5, engine="e0")
    assert rd["evt"] == 5
    assert reg.value("evt_total", engine="e0") == 5
    rd["evt"] += 3
    assert reg.value("evt_total", engine="e0") == 8
    # A stat reset zeroes the dict view; the Prometheus counter is
    # monotonic and keeps its value (counter-reset semantics).
    rd["evt"] = 0
    assert rd["evt"] == 0
    assert reg.value("evt_total", engine="e0") == 8
    rd["evt"] += 2
    assert reg.value("evt_total", engine="e0") == 10


def test_registry_dict_gauge_and_unbound_keys():
    reg = MetricsRegistry()
    g = reg.gauge("level")
    rd = RegistryDict()
    rd.bind("level", g, initial=4)
    rd.bind("scratch", None, initial=0)      # local-only key
    rd["level"] = 2                          # gauges set outright
    assert reg.value("level") == 2
    rd["scratch"] = 99
    assert rd["scratch"] == 99
    assert dict(rd) == {"level": 2, "scratch": 99}
    assert len(rd) == 2


# ---------------------------------------------------------------------------
# Open-loop traffic generation
# ---------------------------------------------------------------------------

def test_trace_is_deterministic_and_shaped():
    cfg = TrafficConfig(duration_s=20.0, base_rate_rps=10.0, tenants=3,
                        diurnal_amplitude=0.5, diurnal_period_s=20.0,
                        seed=5)
    a, b = generate_trace(cfg), generate_trace(cfg)
    assert a == b                            # byte-identical across runs
    assert generate_trace(replace(cfg, seed=6)) != a
    assert all(0 <= x.tenant_idx < 3 for x in a)
    assert all(a[i].at_s <= a[i + 1].at_s for i in range(len(a) - 1))
    assert 5.0 < offered_load(a, cfg) < 20.0
    # Shared prefix: same-tenant arrivals share their first 16 tokens.
    by_tenant = {}
    for x in a:
        by_tenant.setdefault(x.tenant_idx, []).append(x.prompt[:16])
    for prompts in by_tenant.values():
        assert len(set(prompts)) == 1
    # Zipf skew: the heaviest user outweighs the median user.
    users = [x.user for x in a]
    assert users.count(0) > 1
    # Both classes present, deadlines matched to class.
    assert {x.priority for x in a} == {0, 1}
    for x in a:
        assert x.deadline_s == (cfg.interactive_deadline_s if x.priority == 0
                                else cfg.batch_deadline_s)


def test_trace_config_validation():
    with pytest.raises(ValueError, match="amplitude"):
        generate_trace(TrafficConfig(diurnal_amplitude=1.5))
    with pytest.raises(ValueError, match="zipf"):
        generate_trace(TrafficConfig(zipf_alpha=1.0))


# ---------------------------------------------------------------------------
# ServiceModel calibration
# ---------------------------------------------------------------------------

def test_service_model_calibration_math():
    svc = ServiceModel(prefill_tok_per_s=2048.0, decode_step_s=0.05)
    assumed = svc.assumed_req_per_s(20, 8, 4)
    assert assumed == pytest.approx(4 / (20 / 2048.0 + 8 * 0.05))
    cal = svc.calibrated(assumed / 2, prompt_len=20, max_new=8, slots=4)
    assert cal.overhead == pytest.approx(2.0)
    assert cal.service_s(20, 8) == pytest.approx(2 * svc.service_s(20, 8))
    # Billing inputs are untouched; overhead never "speeds up" the model.
    assert cal.decode_step_s == svc.decode_step_s
    fast = svc.calibrated(assumed * 10, prompt_len=20, max_new=8, slots=4)
    assert fast.overhead == 1.0
    with pytest.raises(ValueError):
        svc.calibrated(0.0, prompt_len=20, max_new=8, slots=4)


# ---------------------------------------------------------------------------
# Gateway integration: one registry, per-tenant labels, telemetry stream
# ---------------------------------------------------------------------------

def _security(n):
    sec = PolicyEngine(clock=VirtualClock())
    tokens = [provision_tenant(sec, f"tenant{i}", f"pw-{i}",
                               data_zones=("public",))
              for i in range(n)]
    return sec, tokens


def _gateway(model, sec, **kw):
    cfg, params = model
    svc = ServiceModel(decode_step_s=0.05)
    kw.setdefault("admission", DeadlineCostPolicy(model=svc))
    kw.setdefault("scaling", ScalingPolicy.none(1, market="on_demand"))
    kw.setdefault("service_model", svc)
    kw.setdefault("idle_tick_s", 0.05)
    return KottaServeGateway(
        lambda: ContinuousBatchingEngine(cfg, params, max_len=MAX_LEN,
                                         max_slots=SLOTS, prefill_chunk=8,
                                         decode_chunk=4),
        sec, **kw)


def _small_trace(cfg, tenants, **kw):
    kw.setdefault("duration_s", 6.0)
    kw.setdefault("base_rate_rps", 4.0)
    return TrafficConfig(tenants=tenants, vocab_size=cfg.vocab_size,
                         prefix_tokens=16, interactive_max_new=4,
                         batch_max_new=4, seed=3, **kw)


@pytest.fixture(scope="module")
def served(model):
    """One open-loop run shared by the integration assertions below."""
    cfg, _ = model
    sec, tokens = _security(3)
    store = StateStore(clock=sec.clock, write_capacity=200.0)
    gw = _gateway(model, sec, telemetry_store=store, telemetry_flush_s=1.0)
    trace = generate_trace(_small_trace(cfg, 3))
    rounds = run_open_loop(gw, tokens, trace)
    gw.flush_telemetry()
    return gw, store, trace, rounds


def test_one_registry_serves_gateway_engine_and_router(served):
    gw, _, trace, rounds = served
    reg = gw.registry
    fams = set(reg.families())
    assert {"kotta_requests_total", "kotta_request_ttft_seconds",
            "kotta_engine_admitted_total", "kotta_routing_decisions_total",
            "kotta_gateway_rounds_total", "kotta_slo_burn_rate"} <= fams
    # Per-tenant labels: every tenant that submitted has its own series.
    seen = {t for t in ("tenant0", "tenant1", "tenant2")
            if reg.value("kotta_requests_total", tenant=t,
                         **{"class": "interactive"})
            + reg.value("kotta_requests_total", tenant=t,
                        **{"class": "batch"}) > 0}
    assert seen == {f"tenant{a.tenant_idx}" for a in trace}
    # The registry's counters agree with the legacy dict views.
    assert reg.value("kotta_gateway_rounds_total") == gw.stats["rounds"] \
        == rounds
    assert reg.value("kotta_engine_admitted_total", engine="e0") \
        == gw.metrics()["completed"] == len(trace)


def test_gateway_exposition_round_trips(served):
    gw, _, _, _ = served
    reg = gw.registry
    assert parse_exposition(reg.expose())["families"] == \
        reg.snapshot()["families"]


def test_latency_histograms_observe_every_completion(served):
    gw, _, trace, _ = served
    snap = gw.registry.snapshot()["families"]
    for fam in ("kotta_request_ttft_seconds", "kotta_request_tpot_seconds",
                "kotta_request_queue_wait_seconds"):
        assert sum(s["count"] for s in snap[fam]["samples"]) == len(trace)
    cost = sum(s["value"]
               for s in snap["kotta_tenant_cost_usd_total"]["samples"])
    assert cost > 0


def test_telemetry_stream_lands_in_statestore(served):
    gw, store, trace, _ = served
    jobs = store.scan("servejob/")
    assert len(jobs) == len(trace)
    assert all(j["status"] == "done" for j in jobs.values())
    assert {j["tenant"] for j in jobs.values()} == \
        {f"tenant{a.tenant_idx}" for a in trace}
    audits = store.scan("audit/")
    assert len(audits) == len(gw.security.audit.records())
    snaps = store.scan("metrics/")
    assert len(snaps) == gw.stats["telemetry_flushes"] + 1  # + end drain
    # Snapshots are full registry states, orderable by key.
    last = snaps[max(snaps)]
    assert "kotta_gateway_rounds_total" in last["families"]
    assert gw.stats["telemetry_writes"] == store.write_count


def test_throttled_store_counts_and_sharding_recovers(model):
    cfg, _ = model

    def run(store_factory):
        sec, tokens = _security(2)
        store = store_factory(sec.clock)
        gw = _gateway(model, sec, telemetry_store=store,
                      telemetry_flush_s=0.5)
        trace = generate_trace(_small_trace(cfg, 2, base_rate_rps=8.0))
        run_open_loop(gw, tokens, trace)
        gw.flush_telemetry()
        assert len(store.scan("servejob/")) == len(trace)  # drained anyway
        return gw.stats["statestore_throttled"], store.throttled_writes

    gw_thr, st_thr = run(lambda c: StateStore(clock=c, write_capacity=4.0))
    assert st_thr > 0 and gw_thr == st_thr
    gw_thr4, st_thr4 = run(
        lambda c: ShardedStateStore(4, clock=c, write_capacity=4.0))
    assert st_thr4 < st_thr


def test_metrics_dict_compat_keys_survive(served):
    gw, _, _, _ = served
    m = gw.metrics()
    for key in ("completed", "shed", "sla_rate", "deadline_hit_rate",
                "slo_burn_rate", "telemetry_flushes", "telemetry_writes",
                "telemetry_dropped", "statestore_throttled", "routing",
                "per_replica"):
        assert key in m
    assert m["slo_burn_rate"] == 0.0         # nothing missed in this run
