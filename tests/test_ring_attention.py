"""Ring attention vs dense oracle (single-device ring degenerates to R=1;
the multi-device path is exercised in a subprocess with 8 host devices)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_ring_r1_matches_dense():
    from repro.distributed.ring_attention import ring_attention_sharded
    from repro.models.layers import dense_attention
    mesh = jax.make_mesh((1,), ("model",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 16))
    k = jax.random.normal(ks[1], (2, 64, 2, 16))
    v = jax.random.normal(ks[2], (2, 64, 2, 16))
    for causal in (True, False):
        out = ring_attention_sharded(q, k, v, mesh, causal=causal,
                                     batch_axes=())
        ref = dense_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


def test_ring_multi_device_subprocess():
    code = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {ROOT + "/src"!r})
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.ring_attention import ring_attention_sharded
from repro.models.layers import dense_attention
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
ks = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(ks[0], (2, 128, 6, 16))   # 6 heads: !%4 -> ring shines
k = jax.random.normal(ks[1], (2, 128, 3, 16))
v = jax.random.normal(ks[2], (2, 128, 3, 16))
for causal in (True, False):
    out = ring_attention_sharded(q, k, v, mesh, causal=causal,
                                 batch_axes=("data",))
    ref = dense_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
print("RING-OK")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "RING-OK" in out.stdout
