"""Pallas kernels vs pure-jnp oracles, interpret mode, shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (attention_reference, flash_attention,
                           mamba_chunk_scan, rmsnorm, rmsnorm_reference,
                           ssd_reference)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,s,h,kv,hd", [
    (1, 128, 4, 4, 32),    # MHA
    (2, 256, 8, 2, 32),    # GQA group=4
    (1, 128, 4, 1, 64),    # MQA
    (2, 192, 6, 3, 16),    # non-power-of-two seq/heads
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, h, kv, hd, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                          interpret=True)
    ref = attention_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_cross_lengths():
    """Sq != Skv (chunked prefill / cross-attention shape)."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32))
    k = jax.random.normal(ks[1], (1, 256, 4, 32))
    v = jax.random.normal(ks[2], (1, 256, 4, 32))
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                          interpret=True)
    ref = attention_reference(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,s,h,p,n,chunk,hb", [
    (1, 64, 4, 16, 8, 16, 2),
    (2, 128, 8, 32, 16, 32, 4),
    (1, 96, 2, 8, 4, 32, 1),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba_chunk_scan_sweep(b, s, h, p, n, chunk, hb, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h))).astype(dtype)
    a_log = jnp.linspace(0.0, 1.5, h)
    bm = jax.random.normal(ks[2], (b, s, n), dtype)
    cm = jax.random.normal(ks[3], (b, s, n), dtype)
    y = mamba_chunk_scan(x, dt, a_log, bm, cm, chunk=chunk, head_block=hb,
                         interpret=True)
    yref, _ = ssd_reference(x, dt, a_log, bm, cm)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yref, np.float32), **tol)


@pytest.mark.parametrize("rows,d,block", [(64, 128, 16), (256, 512, 64),
                                          (32, 1024, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(rows, d, block, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    x = (jax.random.normal(ks[0], (4, rows // 4, d)) * 3.0).astype(dtype)
    w = jax.random.normal(ks[1], (d,), jnp.float32)
    out = rmsnorm(x, w, block_rows=block, interpret=True)
    ref = rmsnorm_reference(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))
