"""Paged flash-decode kernel vs oracle (interpret mode) + engine equivalence:
continuous batching must reproduce the static-batch engine token-for-token."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.kernels import flash_decode, paged_decode_reference, quantize_pool
from repro.models import get_family
from repro.models.params import init_params
from repro.serve import ContinuousBatchingEngine, ServeEngine

# CI runs the kernels lane under both KV-pool layouts (see ci.yml): the
# engine-level fixtures below build their pool from this, so the int8 lane
# exercises quantize-on-scatter + in-kernel dequant through the whole engine.
KV_DTYPE = os.environ.get("REPRO_KV_CACHE_DTYPE", "f32")


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-3, atol=1e-3)


def _paged_case(key, b, h, kv, hd, ps, npages, num_pool_pages, dtype):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, h, hd), dtype)
    kp = jax.random.normal(ks[1], (kv, num_pool_pages, ps, hd), dtype)
    vp = jax.random.normal(ks[2], (kv, num_pool_pages, ps, hd), dtype)
    # each request gets distinct physical pages, shuffled (paging is real)
    perm = jax.random.permutation(ks[3], num_pool_pages)[:b * npages]
    pt = perm.reshape(b, npages).astype(jnp.int32)
    lengths = jax.random.randint(ks[4], (b,), 1, npages * ps + 1)
    return q, kp, vp, pt, lengths.astype(jnp.int32)


@pytest.mark.parametrize("b,h,kv,hd", [
    (2, 4, 4, 32),     # MHA
    (3, 8, 2, 32),     # GQA group=4
    (2, 4, 1, 64),     # MQA
    (1, 6, 3, 16),     # odd head group
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_sweep(b, h, kv, hd, dtype):
    ps, npages = 8, 4
    q, kp, vp, pt, lengths = _paged_case(
        jax.random.PRNGKey(0), b, h, kv, hd, ps, npages, 32, dtype)
    out = flash_decode(q, kp, vp, pt, lengths, num_splits=2, interpret=True)
    ref = paged_decode_reference(q, kp, vp, pt, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("num_splits", [1, 2, 4])
def test_flash_decode_split_kv(num_splits):
    """Split-KV partial combine is exact for any split factor."""
    q, kp, vp, pt, lengths = _paged_case(
        jax.random.PRNGKey(1), 2, 8, 2, 32, 8, 4, 16, jnp.float32)
    out = flash_decode(q, kp, vp, pt, lengths, num_splits=num_splits,
                       interpret=True)
    ref = paged_decode_reference(q, kp, vp, pt, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_flash_decode_ragged_lengths():
    """Per-request masking: very short next to pool-filling sequences."""
    b, h, kv, hd, ps, npages = 4, 4, 2, 16, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (b, h, hd))
    kp = jax.random.normal(ks[1], (kv, b * npages, ps, hd))
    vp = jax.random.normal(ks[2], (kv, b * npages, ps, hd))
    pt = jnp.arange(b * npages, dtype=jnp.int32).reshape(b, npages)
    lengths = jnp.array([1, 5, 17, npages * ps], jnp.int32)
    out = flash_decode(q, kp, vp, pt, lengths, num_splits=2, interpret=True)
    ref = paged_decode_reference(q, kp, vp, pt, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("b,h,kv,hd", [
    (2, 4, 4, 32),     # MHA
    (3, 8, 2, 32),     # GQA group=4
    (2, 4, 1, 64),     # MQA
])
def test_flash_decode_int8_parity(b, h, kv, hd):
    """Tiered int8 parity. Tier 1 (tight): the kernel's in-tile dequant is
    the same arithmetic as the int8 oracle, so they agree at f32-path
    tolerance. Tier 2 (loose): both sit inside the quantization error band
    of exact f32 attention — per-row symmetric int8 bounds each element's
    pre-softmax error by amax(row)/254."""
    ps, npages = 8, 4
    q, kp, vp, pt, lengths = _paged_case(
        jax.random.PRNGKey(5), b, h, kv, hd, ps, npages, 32, jnp.float32)
    qp = quantize_pool({"k": kp, "v": vp})
    scales = dict(k_scale=qp["k_scale"], v_scale=qp["v_scale"])
    out = flash_decode(q, qp["k"], qp["v"], pt, lengths, num_splits=2,
                       interpret=True, **scales)
    ref = paged_decode_reference(q, qp["k"], qp["v"], pt, lengths, **scales)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)
    exact = paged_decode_reference(q, kp, vp, pt, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exact),
                               rtol=5e-2, atol=5e-2)


def test_default_num_splits_occupancy_adaptive():
    """Split-KV fills idle cores at low occupancy; at high occupancy the
    batch axis already covers the chip (batch * splits ~ budget)."""
    from repro.kernels.decode_attention.ops import default_num_splits
    assert default_num_splits(8, batch=1, split_budget=32) == 8
    assert default_num_splits(8, batch=8, split_budget=32) == 4
    assert default_num_splits(8, batch=32, split_budget=32) == 1
    assert default_num_splits(6, batch=4, split_budget=32) == 6  # divisor rule
    assert default_num_splits(8) == 4           # legacy default unchanged


# ---------------------------------------------------------------------------
# Engine-level equivalence
# ---------------------------------------------------------------------------

def _make(arch="yi-6b", **kw):
    kw.setdefault("kv_cache_dtype", KV_DTYPE)
    cfg = get_reduced_config(arch).replace(dtype="float32", page_size=8, **kw)
    fam = get_family(cfg)
    params = init_params(fam.layout(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    return cfg, params


def _prompts(vocab, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, vocab, size=n).tolist() for n in lens]


def test_continuous_matches_static_equal_lengths():
    """No padding in play: both engines must emit identical tokens."""
    cfg, params = _make()
    prompts = _prompts(cfg.vocab_size, [8, 8, 8])
    a = ServeEngine(cfg, params, max_len=48).generate(prompts, max_new=8)
    b = ContinuousBatchingEngine(cfg, params, max_len=48, max_slots=3) \
        .generate(prompts, max_new=8)
    np.testing.assert_array_equal(a.tokens, b.tokens)
    assert a.prompt_lens == b.prompt_lens


def test_continuous_matches_per_request_gold_mixed_lengths():
    """Ragged batch: continuous batching must match the exact (unpadded,
    single-request) decode — the static engine's left-padding perturbs RoPE
    positions for shorter prompts, so per-request runs are the oracle."""
    cfg, params = _make()
    prompts = _prompts(cfg.vocab_size, [3, 7, 12, 5], seed=1)
    legacy = ServeEngine(cfg, params, max_len=48)
    gold = np.concatenate(
        [legacy.generate([p], max_new=8).tokens for p in prompts])
    out = ContinuousBatchingEngine(cfg, params, max_len=48, max_slots=4) \
        .generate(prompts, max_new=8)
    np.testing.assert_array_equal(gold, out.tokens)


def test_continuous_batching_queues_and_reuses_pages():
    """More requests than slots: eviction frees pages, waiters are admitted,
    and tokens are unchanged vs the all-slots run."""
    cfg, params = _make()
    prompts = _prompts(cfg.vocab_size, [4, 9, 6, 11, 5, 8], seed=2)
    wide = ContinuousBatchingEngine(cfg, params, max_len=32, max_slots=6) \
        .generate(prompts, max_new=6)
    narrow = ContinuousBatchingEngine(cfg, params, max_len=32, max_slots=2,
                                      decode_chunk=4) \
        .generate(prompts, max_new=6)
    np.testing.assert_array_equal(wide.tokens, narrow.tokens)


def test_decode_writes_cross_page_boundaries():
    """Decoded KV rows spill from the prompt page into fresh pages."""
    cfg, params = _make()
    prompts = _prompts(cfg.vocab_size, [6], seed=3)     # page_size=8: crosses
    legacy = ServeEngine(cfg, params, max_len=32)
    gold = legacy.generate(prompts, max_new=12).tokens
    out = ContinuousBatchingEngine(cfg, params, max_len=32, max_slots=1) \
        .generate(prompts, max_new=12)
    np.testing.assert_array_equal(gold, out.tokens)


def test_engine_validates_before_reserving():
    """Bad requests are rejected up front: no slot/page leak, engine reusable."""
    cfg, params = _make()
    eng = ContinuousBatchingEngine(cfg, params, max_len=32, max_slots=2)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.generate([[1, 2, 3], []], max_new=4)
    with pytest.raises(ValueError, match="exceed max_len"):
        eng.generate([[1, 2, 3], list(range(40))], max_new=4)
    assert not eng._active.any()
    assert eng.alloc.available() == eng.num_pages - 1
    out = eng.generate(_prompts(cfg.vocab_size, [4, 6], seed=4), max_new=4)
    assert out.tokens.shape == (2, 4)


def test_paged_decode_rejects_recurrent_families():
    from repro.train.train_step import build_paged_decode_step
    cfg = get_reduced_config("xlstm-350m")
    with pytest.raises(ValueError, match="paged"):
        build_paged_decode_step(cfg)
