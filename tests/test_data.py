"""Data pipeline: determinism, DP-shard disjointness, step purity."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import ObjectStore, VirtualClock
from repro.data import PrefetchLoader, SyntheticCorpus, TokenLoader


def build(store=None, **kw):
    store = store or ObjectStore(clock=VirtualClock())
    keys = SyntheticCorpus.build(store, "c", num_shards=2,
                                 tokens_per_shard=8192, vocab_size=101,
                                 seed=5)
    return store, keys


def test_corpus_deterministic():
    s1, k1 = build()
    s2, k2 = build()
    assert [s1.get(k) for k in k1] == [s2.get(k) for k in k2]


def test_labels_are_shifted_tokens():
    store, keys = build()
    loader = TokenLoader(store.get, keys, batch_size=4, seq_len=16)
    b = loader.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_batch_at_is_pure():
    store, keys = build()
    loader = TokenLoader(store.get, keys, batch_size=4, seq_len=16, seed=3)
    a = loader.batch_at(7)
    b = loader.batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


@settings(max_examples=10, deadline=None)
@given(dp=st.sampled_from([1, 2, 4]), step=st.integers(0, 20))
def test_property_dp_shards_partition_global_batch(dp, step):
    """The dp ranks' shards are disjoint and union to the dp=1 batch."""
    store, keys = build()
    global_rows = TokenLoader(store.get, keys, batch_size=8, seq_len=16,
                              seed=1).batch_at(step)["tokens"]
    got = [TokenLoader(store.get, keys, batch_size=8, seq_len=16, seed=1,
                       dp_rank=r, dp_size=dp).batch_at(step)["tokens"]
           for r in range(dp)]
    stacked = np.concatenate(got, axis=0)
    assert stacked.shape == global_rows.shape
    assert sorted(map(tuple, stacked)) == sorted(map(tuple, global_rows))


def test_epoch_shuffle_changes_order():
    store, keys = build()
    loader = TokenLoader(store.get, keys, batch_size=4, seq_len=16, seed=0)
    steps_per_epoch = loader.windows_per_epoch // loader.batch_size
    a = loader.batch_at(0)["tokens"]
    b = loader.batch_at(steps_per_epoch)["tokens"]  # same slot, next epoch
    assert not np.array_equal(a, b)


def test_prefetch_matches_direct():
    store, keys = build()
    loader = TokenLoader(store.get, keys, batch_size=4, seq_len=16)
    pf = PrefetchLoader(loader, start_step=0, depth=2)
    try:
        for step in range(3):
            np.testing.assert_array_equal(next(pf)["tokens"],
                                          loader.batch_at(step)["tokens"])
    finally:
        pf.close()
