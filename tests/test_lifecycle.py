"""Storage lifecycle (paper §V-A): LRU tiering, restore queue, encryption."""
import pytest

hypothesis = pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import (LifecyclePolicy, ObjectArchivedError, ObjectStore,
                        Tier, VirtualClock, days, hours)
from repro.core.lifecycle import RESTORE_LATENCY_S, TIER_ORDER


@pytest.fixture
def store():
    return ObjectStore(clock=VirtualClock(),
                       policy=LifecyclePolicy.parse("STD30-IA60-ARCHIVE"))


def test_policy_parse():
    pol = LifecyclePolicy.parse("STD30-IA60-GLACIER")
    assert [s.tier for s in pol.stages] == [Tier.STD, Tier.IA, Tier.ARCHIVE]
    assert pol.stages[0].staleness_s == days(30)
    assert pol.stages[2].staleness_s is None


def test_roundtrip_and_encryption_at_rest(store):
    store.put("dataset/x/a", b"hello kotta", owner="alice")
    assert store.get("dataset/x/a") == b"hello kotta"
    # at-rest representation is not the plaintext
    assert store._blobs["dataset/x/a"] != b"hello kotta"


def test_corruption_detected(store):
    store.put("k", b"payload")
    store._blobs["k"] = store._blobs["k"][:-1] + b"\x00"
    with pytest.raises(Exception, match="checksum"):
        store.get("k")


def test_lru_aging_std_ia_archive(store):
    store.put("obj", b"x" * 100)
    store.clock.advance(days(31))
    store.tick()
    assert store.head("obj").tier is Tier.IA
    store.clock.advance(days(61))
    store.tick()
    assert store.head("obj").tier is Tier.ARCHIVE


def test_access_resets_staleness(store):
    store.put("obj", b"x")
    store.clock.advance(days(29))
    store.get("obj")                      # touch
    store.clock.advance(days(29))
    store.tick()
    assert store.head("obj").tier is Tier.STD


def test_skip_level_demotion_when_very_stale(store):
    store.put("obj", b"x")
    store.clock.advance(days(100))        # > 30 + 60: straight to ARCHIVE
    store.tick()
    assert store.head("obj").tier is Tier.ARCHIVE


def test_archive_read_blocks_until_restore(store):
    store.put("obj", b"data")
    store.clock.advance(days(100))
    store.tick()
    with pytest.raises(ObjectArchivedError):
        store.get("obj")
    eta = store.restore("obj")
    assert eta == pytest.approx(store.clock.now() + RESTORE_LATENCY_S)
    store.clock.advance(hours(3.9))
    assert not store.is_available("obj")
    store.clock.advance(hours(0.2))
    assert store.is_available("obj")
    assert store.get("obj") == b"data"
    assert store.head("obj").tier is Tier.STD


def test_pinned_objects_never_age(store):
    store.put("hot", b"x", pinned=True)
    store.clock.advance(days(365))
    store.tick()
    assert store.head("hot").tier is Tier.STD


def test_monthly_cost_decreases_with_aging(store):
    store.put("obj", b"x" * 10_000_000)
    c_std = store.monthly_cost()
    store.clock.advance(days(31))
    store.tick()
    c_ia = store.monthly_cost()
    store.clock.advance(days(61))
    store.tick()
    c_gl = store.monthly_cost()
    assert c_std > c_ia > c_gl > 0


# -- property tests ------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(idle_days=st.floats(0, 500), start=st.sampled_from(list(TIER_ORDER)[1:]))
def test_property_demotion_monotone(idle_days, start):
    """More staleness never promotes an object."""
    pol = LifecyclePolicy.parse("STD30-IA60-ARCHIVE")
    t1 = pol.next_tier(start, days(idle_days))
    t2 = pol.next_tier(start, days(idle_days + 10))
    assert TIER_ORDER.index(t2) >= TIER_ORDER.index(t1) >= TIER_ORDER.index(start)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["a", "b", "c"]),
                          st.floats(0, 40)), min_size=1, max_size=20))
def test_property_lru_only_stale_objects_move(events):
    """After any access pattern, objects touched within 30 days stay in STD."""
    clock = VirtualClock()
    store = ObjectStore(clock=clock)
    for key, _ in events:
        if not store.exists(key):
            store.put(key, b"x")
    for key, advance in events:
        clock.advance(days(advance))
        try:
            store.get(key)
        except ObjectArchivedError:
            store.restore(key)
    store.tick()
    now = clock.now()
    for key in store.keys():
        meta = store.head(key)
        if now - meta.last_access < days(30):
            assert meta.tier in (Tier.STD, Tier.ARCHIVE) or True
            if meta.tier is not Tier.ARCHIVE:  # not mid-restore
                assert meta.tier is Tier.STD
