"""Elastic trainer: revocation recovery with bitwise restart equality."""
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_reduced_config
from repro.core import ObjectStore, VirtualClock
from repro.data import SyntheticCorpus, TokenLoader
from repro.train import AdamWConfig, ElasticTrainer


@pytest.fixture(scope="module")
def env():
    cfg = get_reduced_config("internlm2-1.8b").replace(vocab_size=128)
    store = ObjectStore(clock=VirtualClock())
    keys = SyntheticCorpus.build(store, "t", num_shards=1,
                                 tokens_per_shard=8192,
                                 vocab_size=cfg.vocab_size)
    loader = TokenLoader(store.get, keys, batch_size=8, seq_len=32)
    opt = AdamWConfig(learning_rate=1e-3, warmup_steps=2, decay_steps=50)
    return cfg, store, loader, opt


def test_loss_decreases(env):
    cfg, store, loader, opt = env
    tr = ElasticTrainer(cfg, opt, Checkpointer(store, "t-base"), seed=0)
    rep = tr.train(loader, 10, checkpoint_every=10)
    assert rep.losses[10] < rep.losses[1]


def test_revocation_restart_bitwise_equal(env):
    cfg, store, loader, opt = env
    t_ref = ElasticTrainer(cfg, opt, Checkpointer(store, "t-ref"), seed=0)
    ref = t_ref.train(loader, 8, checkpoint_every=4)

    t_rev = ElasticTrainer(cfg, opt, Checkpointer(store, "t-rev"), seed=0)
    fired = []

    def revoke(step):
        if step == 6 and not fired:
            fired.append(step)
            return True
        return False

    rev = t_rev.train(loader, 8, checkpoint_every=4, revoke_at=revoke)
    assert rev.restarts == 1
    assert ref.losses[8] == rev.losses[8]
    for a, b in zip(jax.tree.leaves(t_ref.final_state[0]),
                    jax.tree.leaves(t_rev.final_state[0])):
        assert bool(jnp.array_equal(a, b))


def test_microbatched_step_close_to_plain(env):
    cfg, store, loader, opt = env
    t1 = ElasticTrainer(cfg, opt, Checkpointer(store, "t-m1"), seed=0)
    t2 = ElasticTrainer(cfg, opt, Checkpointer(store, "t-m2"), seed=0,
                        microbatches=2)
    r1 = t1.train(loader, 3, checkpoint_every=10)
    r2 = t2.train(loader, 3, checkpoint_every=10)
    # grad accumulation reorders float sums: equal to ~1e-3
    assert r1.losses[3] == pytest.approx(r2.losses[3], rel=1e-2)


def test_async_checkpoint_restartable(env):
    cfg, store, loader, opt = env
    tr = ElasticTrainer(cfg, opt, Checkpointer(store, "t-async"), seed=0,
                        async_checkpoint=True)
    tr.train(loader, 4, checkpoint_every=2)
    step, _, _ = tr.restore_or_init()
    assert step == 4
