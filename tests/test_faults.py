"""Fault injection + graceful replica failure: the market's revocation
notice, mid-decode KV export/import (plain + speculative, f32 + int8),
import rejection paths, notice-window evacuation through the gateway
(token identity vs an uninterrupted run), requeue fallback with capped
backoff, typed retry-budget exhaustion, router health states
(UP/DEGRADED/QUARANTINED), the FaultInjector schedule/seeded-random API,
and seeded chaos sweeps that must end with every job DONE or typed-SHED
and clean page refcounts."""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.clock import VirtualClock
from repro.core.elastic import ProvisioningModel, ScalingPolicy
from repro.core.market import SpotMarket
from repro.core.security import PolicyEngine, provision_tenant
from repro.models import get_family
from repro.models.params import init_params
from repro.serve import (HEALTH_DEGRADED, HEALTH_QUARANTINED, HEALTH_UP,
                         ContinuousBatchingEngine, EngineRequest, FaultEvent,
                         FaultInjector, FleetRouter, JobState,
                         KottaServeGateway, RetryBudgetExhausted, ServeEngine,
                         ServiceModel)

MAX_LEN = 48
SLOTS = 2


@pytest.fixture(scope="module")
def model():
    cfg = get_reduced_config("yi-6b").replace(dtype="float32", page_size=8)
    fam = get_family(cfg)
    params = init_params(fam.layout(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    return cfg, params


@pytest.fixture(scope="module")
def gold_engine(model):
    cfg, params = model
    return ServeEngine(cfg, params, max_len=MAX_LEN)


def _engine(model, **kw):
    cfg, params = model
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("max_slots", SLOTS)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("decode_chunk", 4)
    return ContinuousBatchingEngine(cfg, params, **kw)


def _factory(model, **kw):
    return lambda: _engine(model, **kw)


def _security(*tenants):
    sec = PolicyEngine(clock=VirtualClock())
    tokens = {t: provision_tenant(sec, t, f"pw-{t}",
                                  data_zones=("public", t))
              for t in tenants}
    return sec, tokens


def _gateway(model, sec, *, scaling=None, market=None, engine_kw=None, **kw):
    kw.setdefault("provisioning",
                  ProvisioningModel(base_delay_s=5.0, jitter_s=0.0,
                                    volatility_prob=0.0))
    kw.setdefault("service_model", ServiceModel(decode_step_s=0.05))
    return KottaServeGateway(_factory(model, **(engine_kw or {})), sec,
                             scaling=scaling or ScalingPolicy.none(
                                 1, market="on_demand"),
                             market=market, **kw)


def _prompt(cfg, n, seed=0):
    return np.random.RandomState(seed).randint(
        0, cfg.vocab_size, size=n).tolist()


def _mid_decode_replica(gw, rounds=400):
    """Step until some replica has a slot genuinely mid-decode; return it."""
    for _ in range(rounds):
        for r in gw.replicas():
            if any(0 < l.emitted < l.req.max_new
                   for l in r.engine._live.values()):
                return r
        gw.step()
    pytest.fail("never reached mid-decode state")


def _finish(eng):
    done = {}
    while eng.live:
        for req, toks in eng.decode_step():
            done[req.rid] = toks
    return done


def _audit(sec, action, decision=None):
    recs = [a for a in sec.audit.records() if a.action == action]
    if decision is not None:
        recs = [a for a in recs if a.decision == decision]
    return recs


# ---------------------------------------------------------------------------
# Market: the revocation notice precedes the revocation
# ---------------------------------------------------------------------------

def test_market_notice_fires_exactly_one_window_ahead():
    m = SpotMarket(seed=0)
    z, it = m.zones[0], "m4.xlarge"
    trace = [m.price(z, it, h) for h in range(12)]
    bid = (min(trace) + max(trace)) / 2.0       # guaranteed crossings
    ahead = m.notice_s / 3600.0
    grid = [i * 0.01 for i in range(1200)]      # 12h at 36s resolution
    for t in grid:
        assert m.notice(z, it, bid, t) == m.revoked(z, it, bid, t + ahead)
    # The warning genuinely precedes the loss somewhere on the trace:
    # notice true while the instance is still alive.
    assert any(m.notice(z, it, bid, t) and not m.revoked(z, it, bid, t)
               for t in grid)


# ---------------------------------------------------------------------------
# Engine: mid-decode export -> import token identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_dtype", ["f32", "int8"])
@pytest.mark.parametrize("spec", [False, True])
def test_mid_decode_export_import_token_identity(model, kv_dtype, spec):
    """A slot exported mid-decode and imported elsewhere finishes with the
    exact tokens of an uninterrupted run — the evacuation correctness core,
    across pool layouts and with the speculative controller riding along."""
    cfg, _ = model
    kw = dict(kv_cache_dtype=kv_dtype)
    if spec:
        kw.update(enable_spec_decode=True, spec_tokens=4)
        prompts = [([5, 6, 7, 8] * 5)[:18], ([3, 4] * 8)[:10]]
    else:
        prompts = [_prompt(cfg, 13, seed=40), _prompt(cfg, 9, seed=41)]
    max_new = 14
    gold = _engine(model, **kw).generate(prompts, max_new=max_new).tokens

    src = _engine(model, **kw)
    for i, p in enumerate(prompts):
        src.enqueue(EngineRequest(i, p, max_new))
    assert src.admit() == 2
    for _ in range(20):                         # reach genuine mid-decode
        src.decode_step()
        if all(0 < l.emitted < max_new for l in src._live.values()):
            break
    else:
        pytest.fail("never mid-decode on both slots")

    payloads = {src._live[s].req.rid: src.export_pages(s)
                for s in sorted(src._live)}
    assert src.live == 0
    src._debug_check_refcounts()

    dst = _engine(model, **kw)
    for i in range(len(prompts)):
        pl = payloads[i]
        assert 0 < pl.emitted < max_new         # really mid-stream
        assert pl.pos == len(prompts[i]) + pl.emitted
        if spec:
            assert pl.kslot >= 1                # tuned window ships along
        dst.import_pages(pl)
        assert pl.consumed
    dst._debug_check_refcounts()
    done = _finish(dst)
    for i in range(len(prompts)):
        np.testing.assert_array_equal(gold[i], np.asarray(done[i], np.int32))
    if spec:
        assert dst.stats["spec_steps"] > 0


def test_export_paused_ships_parked_request(model):
    """A PAUSED (preempted) request is exportable too: its pinned pages,
    cursor and drafting history ship, and it finishes identically on the
    destination. Unknown rids fail loudly."""
    cfg, _ = model
    prompt = _prompt(cfg, 11, seed=50)
    max_new = 12
    gold = _engine(model).generate([prompt], max_new=max_new).tokens[0]

    src = _engine(model)
    src.enqueue(EngineRequest(7, prompt, max_new))
    src.admit()
    src.decode_step()                           # a few tokens in
    slot = next(iter(src._live))
    emitted = src._live[slot].emitted
    assert 0 < emitted < max_new
    src.preempt(slot)
    with pytest.raises(KeyError, match="not paused"):
        src.export_paused(999)
    payload = src.export_paused(7)
    assert payload.emitted == emitted
    assert src.live == 0 and not src._paused
    src._debug_check_refcounts()                # parked pages released

    dst = _engine(model)
    dst.import_pages(payload)
    done = _finish(dst)
    np.testing.assert_array_equal(gold, np.asarray(done[7], np.int32))


def test_import_rejection_paths(model):
    """Tampered or stale payloads are rejected with typed errors before any
    state mutates: double-import, page_size mismatch, pool leaf-set
    mismatch, inconsistent cursor, and a destination with no free slot."""
    cfg, _ = model

    def fresh_payload(rid):
        src = _engine(model)
        src.enqueue(EngineRequest(rid, _prompt(cfg, 9, seed=60 + rid), 4))
        src.admit()
        return src.export_pages(next(iter(src._live)))

    # One-shot move: a consumed payload never imports twice.
    pl = fresh_payload(0)
    dst = _engine(model)
    dst.import_pages(pl)
    with pytest.raises(ValueError, match="one-shot"):
        _engine(model).import_pages(pl)

    # page_size mismatch (tampered in flight).
    pl = fresh_payload(1)
    pl.page_size = 16
    with pytest.raises(ValueError, match="page_size"):
        _engine(model).import_pages(pl)

    # Pool leaf-set mismatch: a leaf went missing.
    pl = fresh_payload(2)
    pl.content = {k: v for k, v in pl.content.items() if k != "v"}
    with pytest.raises(ValueError, match="leaves"):
        _engine(model).import_pages(pl)

    # Cursor/emitted inconsistency.
    pl = fresh_payload(3)
    pl.pos += 1
    with pytest.raises(ValueError, match="inconsistent"):
        _engine(model).import_pages(pl)

    # Destination with every slot occupied: transient, payload reusable.
    pl = fresh_payload(4)
    full = _engine(model)                       # SLOTS = 2
    for i in range(SLOTS):
        full.enqueue(EngineRequest(100 + i, _prompt(cfg, 9, seed=80 + i), 4))
    full.admit()
    with pytest.raises(RuntimeError, match="no free slot"):
        full.import_pages(pl)
    assert not pl.consumed                      # still deliverable elsewhere
    ok = _engine(model)
    ok.import_pages(pl)
    assert ok.live == 1 and pl.consumed


# ---------------------------------------------------------------------------
# Gateway: notice-window evacuation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv_dtype", ["f32", "int8"])
def test_notice_window_evacuation_token_identity(model, kv_dtype):
    """A replica served a revocation notice mid-decode evacuates its live
    slots to the survivor; every job completes with oracle-identical greedy
    tokens, zero retries, and the move is audited."""
    cfg, _ = model
    sec, tok = _security("alice")
    gw = _gateway(model, sec,
                  scaling=ScalingPolicy.none(2, market="on_demand"),
                  engine_kw={"kv_cache_dtype": kv_dtype, "decode_chunk": 2})
    prompts = [_prompt(cfg, 6, seed=90), _prompt(cfg, 9, seed=91)]
    rids = [gw.submit(tok["alice"], p, max_new=12) for p in prompts]

    victim = _mid_decode_replica(gw)
    moved = [l.req.rid for l in victim.engine._live.values()]
    gw.revoke_replica(victim.id, notice_s=60.0)     # operator chaos drill
    gw.drain()

    gold = _engine(model, kv_cache_dtype=kv_dtype)
    for r, p in zip(rids, prompts):
        np.testing.assert_array_equal(
            gold.generate([p], max_new=12).tokens[0],
            np.asarray(gw.result(r), np.int32))
    m = gw.metrics()
    assert m["notices"] == 1 and m["revocations"] == 1
    assert m["evacuations"] >= 1 and m["evacuated_pages_bytes"] > 0
    assert m["retries"] == 0                    # nobody paid backoff
    assert m["completed"] == 2 and m["shed"] == 0
    assert m["disturbed_jobs"] >= 1 and m["recovered_jobs"] >= 1
    for rid in moved:
        job = gw.jobs[rid]
        assert job.evacuations >= 1
        assert job.disturbed_at is not None
        assert job.recovered_at is not None
        assert job.recovered_at >= job.disturbed_at
    assert len(_audit(sec, "serve:Evacuate", "allow")) == m["evacuations"]
    assert any("notice" in a.detail
               for a in _audit(sec, "serve:Revoke", "allow"))


def test_notice_too_short_falls_back_to_requeue(model, gold_engine):
    """When the notice window cannot fit even one slot's KV shipment the
    gateway falls back to requeue + capped backoff: slower, still lossless,
    still token-identical."""
    cfg, _ = model
    sec, tok = _security("alice")
    gw = _gateway(model, sec,
                  scaling=ScalingPolicy.none(2, market="on_demand"),
                  # 1 B/s shipping: no export can beat any finite window.
                  service_model=ServiceModel(decode_step_s=0.05,
                                             kv_ship_bytes_per_s=1.0),
                  backoff_base_s=2.0,
                  engine_kw={"decode_chunk": 2})
    prompt = _prompt(cfg, 8, seed=95)
    rid = gw.submit(tok["alice"], prompt, max_new=12)

    victim = _mid_decode_replica(gw)
    gw.revoke_replica(victim.id, notice_s=1.0)
    gw.drain()

    np.testing.assert_array_equal(
        gold_engine.generate([prompt], max_new=12).tokens[0],
        np.asarray(gw.result(rid), np.int32))
    m = gw.metrics()
    job = gw.jobs[rid]
    assert m["evacuations"] == 0 and m["notices"] == 1
    assert m["retries"] >= 1 and m["backoff_wait_s"] > 0
    assert job.retries == 1
    # The backoff genuinely held the job before its second service.
    assert job.recovered_at - job.disturbed_at >= 2.0
    assert len(_audit(sec, "serve:Requeue", "allow")) >= 1


def test_notice_window_prioritizes_tightest_deadline(model):
    """When the notice window can ship only ONE of two live slots, the
    budget goes to the tighter deadline: the urgent job evacuates (zero
    retries), the slack one falls back to requeue + backoff."""
    import math

    cfg, _ = model
    sec, tok = _security("alice")
    page_b = _engine(model).page_nbytes()
    # 1 KV page ships per second: per-slot est = page-count seconds, so the
    # test can size the window in whole pages.
    svc = ServiceModel(decode_step_s=0.05, kv_ship_bytes_per_s=page_b)
    gw = _gateway(model, sec,
                  scaling=ScalingPolicy.none(2, market="on_demand"),
                  service_model=svc, backoff_base_s=1.0,
                  engine_kw={"decode_chunk": 4})
    prompt = _prompt(cfg, 16, seed=88)          # >= 2 pages before decoding
    slack = gw.submit(tok["alice"], prompt, max_new=24)     # no deadline
    victim = _mid_decode_replica(gw)
    # Same tenant + same prompt => prefix-affinity co-places the urgent job
    # on the replica already holding the slack one.
    urgent = gw.submit(tok["alice"], prompt, max_new=24, deadline_s=120.0)
    for _ in range(400):
        live = {l.req.rid for l in victim.engine._live.values()
                if 0 < l.emitted < l.req.max_new}
        if live == {slack, urgent}:
            break
        gw.step()
    else:
        pytest.fail("jobs never decoded together on one replica")

    eng = victim.engine
    est = {eng._live[s].req.rid:
           math.ceil(int(eng._pos[s]) / eng.page_size)   # seconds per slot
           for s in eng._live}
    # Window: urgent fits (plus a round of drift), urgent + slack does not.
    gw.revoke_replica(victim.id, notice_s=est[urgent] + 1.6)
    gw.drain()

    uj, sj = gw.jobs[urgent], gw.jobs[slack]
    assert uj.evacuations == 1 and uj.retries == 0
    assert sj.evacuations == 0 and sj.retries == 1
    m = gw.metrics()
    assert m["evacuations"] == 1 and m["requeues"] >= 1
    assert m["completed"] == 2 and m["shed"] == 0
    # Both still finish token-identically to an undisturbed engine.
    gold = _engine(model)
    want = gold.generate([prompt], max_new=24).tokens[0]
    for rid in (urgent, slack):
        np.testing.assert_array_equal(want,
                                      np.asarray(gw.result(rid), np.int32))
    assert f"job {urgent}" in _audit(sec, "serve:Evacuate", "allow")[0].detail


def test_retry_budget_exhaustion_sheds_typed(model):
    """A job that keeps losing its replica is shed with a typed
    RetryBudgetExhausted after the budget, never requeued hot."""
    cfg, _ = model
    sec, tok = _security("alice")
    gw = _gateway(model, sec, retry_budget=1, backoff_base_s=0.5)
    rid = gw.submit(tok["alice"], _prompt(cfg, 8, seed=97), max_new=24)

    for _ in range(2):                          # budget 1 -> second loss kills
        victim = _mid_decode_replica(gw)
        gw.revoke_replica(victim.id)            # crash, no notice
    gw.drain()

    job = gw.jobs[rid]
    assert job.status is JobState.SHED
    assert isinstance(job.error, RetryBudgetExhausted)
    with pytest.raises(RetryBudgetExhausted, match="budget"):
        gw.result(rid)
    m = gw.metrics()
    assert m["shed"] == 1 and m["completed"] == 0
    assert m["wasted_decode_tokens"] > 0
    assert len(_audit(sec, "serve:Requeue", "deny")) == 1


# ---------------------------------------------------------------------------
# Router health states
# ---------------------------------------------------------------------------

def test_router_health_transitions():
    rt = FleetRouter("least_loaded", heartbeat_timeout_s=5.0,
                     straggler_factor=3.0, health_alpha=1.0)
    for rid in (1, 2, 3):
        rt.heartbeat(rid, 0.0, 0.05)
    assert rt.healths(0.0) == {1: HEALTH_UP, 2: HEALTH_UP, 3: HEALTH_UP}
    # Straggler: latency EMA vs leave-one-out median of the others.
    rt.heartbeat(1, 1.0, 0.5)
    rt.heartbeat(2, 1.0, 0.05)
    rt.heartbeat(3, 1.0, 0.05)
    assert rt.health(1, 1.0) == HEALTH_DEGRADED
    assert rt.health(2, 1.0) == HEALTH_UP       # not dragged up by 1's EMA
    # Heartbeat silence past the timeout quarantines.
    assert rt.health(2, 7.0) == HEALTH_QUARANTINED
    # Never-heartbeat replicas owe nothing yet.
    assert rt.health(99, 7.0) == HEALTH_UP
    # Recovery: a normal report restores UP (alpha=1 -> instant here).
    rt.heartbeat(1, 2.0, 0.05)
    assert rt.health(1, 2.0) == HEALTH_UP
    rt.forget(1)
    assert 1 not in rt.healths(2.0)


def test_router_straggler_detection_in_two_replica_fleet():
    """Leave-one-out keeps working at fleet size two: the slow one is
    degraded, the fast one stays up."""
    rt = FleetRouter("affinity", health_alpha=1.0, straggler_factor=3.0)
    rt.heartbeat(1, 0.0, 0.5)
    rt.heartbeat(2, 0.0, 0.05)
    assert rt.health(1, 0.0) == HEALTH_DEGRADED
    assert rt.health(2, 0.0) == HEALTH_UP


def test_router_health_param_validation():
    with pytest.raises(ValueError, match="heartbeat_timeout_s"):
        FleetRouter(heartbeat_timeout_s=0.0)
    with pytest.raises(ValueError, match="straggler_factor"):
        FleetRouter(straggler_factor=1.0)


# ---------------------------------------------------------------------------
# Gateway x injected straggler / heartbeat loss
# ---------------------------------------------------------------------------

def test_straggler_fault_degrades_drains_and_recovers(model):
    cfg, _ = model
    sec, tok = _security("alice")
    inj = FaultInjector(schedule=(
        FaultEvent(at_s=6.0, kind="straggler", target=0,
                   duration_s=20.0, magnitude=10.0),))
    gw = _gateway(model, sec,
                  scaling=ScalingPolicy.none(2, market="on_demand"),
                  routing=FleetRouter("affinity", health_alpha=1.0),
                  fault_injector=inj)
    while not inj.fired:
        gw.step()
    gw.step()                                   # one post-fault heartbeat
    now = gw.clock.now()
    lame = [r for r in gw.replicas() if r.latency_mult > 1.0]
    assert len(lame) == 1
    assert gw.router.health(lame[0].id, now) == HEALTH_DEGRADED
    assert gw.metrics()["replica_health"].get("degraded") == 1

    # New placements avoid the straggler entirely.
    rids = [gw.submit(tok["alice"], _prompt(cfg, 6, seed=98 + i), max_new=8)
            for i in range(2)]
    gw.drain()
    assert all(gw.jobs[r].status is JobState.DONE for r in rids)
    assert all(gw.jobs[r].replica != lame[0].id for r in rids)

    # The fault expires; latency normalizes; health returns to UP.
    while gw.clock.now() < 30.0:
        gw.step()
    assert gw.router.health(lame[0].id, gw.clock.now()) == HEALTH_UP
    assert gw.metrics()["faults_injected"] == 1


def test_heartbeat_loss_quarantines_until_heartbeats_return(model):
    cfg, _ = model
    sec, tok = _security("alice")
    inj = FaultInjector(schedule=(
        FaultEvent(at_s=6.0, kind="heartbeat_loss", target=0,
                   duration_s=8.0),))
    gw = _gateway(model, sec,
                  scaling=ScalingPolicy.none(2, market="on_demand"),
                  routing=FleetRouter("affinity", heartbeat_timeout_s=2.0),
                  fault_injector=inj)
    while gw.clock.now() < 9.5:                 # silence > timeout by now
        gw.step()
    now = gw.clock.now()
    lost = [r for r in gw.replicas()
            if gw.router.health(r.id, now) == HEALTH_QUARANTINED]
    assert len(lost) == 1
    assert gw.metrics()["replica_health"].get("quarantined") == 1

    rid = gw.submit(tok["alice"], _prompt(cfg, 6, seed=99), max_new=8)
    gw.drain()
    assert gw.jobs[rid].replica != lost[0].id   # placed on the healthy one

    while gw.clock.now() < 16.0:                # loss window over; beats back
        gw.step()
    assert gw.router.health(lost[0].id, gw.clock.now()) == HEALTH_UP


# ---------------------------------------------------------------------------
# FaultInjector unit
# ---------------------------------------------------------------------------

def test_fault_injector_schedule_and_random():
    with pytest.raises(ValueError, match="fault kind"):
        FaultEvent(at_s=0.0, kind="meteor")
    inj = FaultInjector(schedule=(
        FaultEvent(at_s=5.0, kind="crash"),
        FaultEvent(at_s=1.0, kind="straggler", duration_s=3.0),))
    assert inj.pending == 2
    assert [e.kind for e in inj.pop_due(2.0)] == ["straggler"]
    assert inj.pop_due(2.0) == []               # each event fires once
    assert [e.kind for e in inj.pop_due(10.0)] == ["crash"]
    assert inj.pending == 0

    rates = dict(crash_rate_h=8.0, revoke_rate_h=8.0, straggler_rate_h=8.0,
                 heartbeat_loss_rate_h=8.0)
    a = FaultInjector.random(3, 3600.0, notice_s=0.7, **rates)
    b = FaultInjector.random(3, 3600.0, notice_s=0.7, **rates)
    c = FaultInjector.random(4, 3600.0, notice_s=0.7, **rates)
    assert a.schedule == b.schedule             # seeded: same plan
    assert a.schedule != c.schedule
    kinds = {e.kind for e in a.schedule}
    assert kinds == {"crash", "revoke_notice", "straggler", "heartbeat_loss"}
    assert all(0.0 < e.at_s < 3600.0 for e in a.schedule)
    assert all(e.at_s <= n.at_s for e, n in zip(a.schedule, a.schedule[1:]))
    assert all(e.duration_s == 0.7 for e in a.schedule
               if e.kind == "revoke_notice")
    assert all(0 <= e.target < 8 for e in a.schedule)


# ---------------------------------------------------------------------------
# Chaos: seeded random fault sweeps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_chaos_random_faults_never_lose_or_corrupt_jobs(model, gold_engine,
                                                        seed):
    """Under a dense seeded fault storm every job ends DONE (with
    oracle-identical tokens) or SHED with a typed retry-budget error;
    page refcounts stay clean and no KV payload is stranded."""
    cfg, _ = model
    sec, tok = _security("alice")
    horizon = 8.0
    inj = FaultInjector.random(
        seed, horizon, crash_rate_h=900.0, revoke_rate_h=1800.0,
        straggler_rate_h=1800.0, heartbeat_loss_rate_h=900.0,
        notice_s=0.6, duration_s=(0.5, 2.0), magnitude=(2.0, 6.0),
        max_targets=4)
    gw = _gateway(model, sec,
                  scaling=ScalingPolicy.none(2, market="on_demand"),
                  provisioning=ProvisioningModel(base_delay_s=0.5,
                                                 jitter_s=0.0,
                                                 volatility_prob=0.0),
                  retry_budget=8, backoff_base_s=0.5,
                  fault_injector=inj,
                  engine_kw={"decode_chunk": 2})
    prompts = [_prompt(cfg, 5 + (i % 5), seed=200 + i) for i in range(6)]
    rids = [gw.submit(tok["alice"], p, max_new=10) for p in prompts]
    gw.drain(max_rounds=50_000)
    while gw.clock.now() < horizon + 1.0:       # let late faults land too
        gw.step()
    assert inj.pending == 0

    for rid, p in zip(rids, prompts):
        job = gw.jobs[rid]
        assert job.status in (JobState.DONE, JobState.SHED)
        if job.status is JobState.DONE:
            np.testing.assert_array_equal(
                gold_engine.generate([p], max_new=10).tokens[0],
                np.asarray(job.tokens, np.int32))
        else:
            assert isinstance(job.error, RetryBudgetExhausted)
    for r in gw.replicas():
        r.engine._debug_check_refcounts()
    assert not gw._handoffs                     # nothing stranded in flight
    m = gw.metrics()
    assert m["faults_injected"] == len(inj.fired)
    assert m["completed"] + m["shed"] == len(rids)
