"""Paged chunked-prefill kernel vs oracle (interpret mode), and the oracle
itself vs dense causal attention on the gathered cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_prefill, paged_prefill_reference, quantize_pool
from repro.kernels.decode_attention.ref import gather_pages
from repro.models.layers import dense_attention


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-3, atol=1e-3)


def _case(key, b, c, h, kv, hd, ps, npages, num_pool_pages, dtype):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, c, h, hd), dtype)
    kp = jax.random.normal(ks[1], (kv, num_pool_pages, ps, hd), dtype)
    vp = jax.random.normal(ks[2], (kv, num_pool_pages, ps, hd), dtype)
    # each request gets distinct physical pages, shuffled (paging is real)
    perm = jax.random.permutation(ks[3], num_pool_pages)[:b * npages]
    pt = perm.reshape(b, npages).astype(jnp.int32)
    q_start = jax.random.randint(ks[4], (b,), 0, npages * ps - c + 1)
    return q, kp, vp, pt, q_start.astype(jnp.int32)


@pytest.mark.parametrize("b,h,kv,hd", [
    (2, 4, 4, 32),     # MHA
    (3, 8, 2, 32),     # GQA group=4
    (2, 4, 1, 64),     # MQA
    (1, 6, 3, 16),     # odd head group
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_sweep(b, h, kv, hd, dtype):
    c, ps, npages = 8, 8, 4
    q, kp, vp, pt, qs = _case(
        jax.random.PRNGKey(0), b, c, h, kv, hd, ps, npages, 32, dtype)
    out = flash_prefill(q, kp, vp, pt, qs, interpret=True)
    ref = paged_prefill_reference(q, kp, vp, pt, qs)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("c", [1, 4, 16])
def test_flash_prefill_chunk_sizes(c):
    """Chunk width sweep, including the degenerate decode-like C=1."""
    q, kp, vp, pt, qs = _case(
        jax.random.PRNGKey(1), 2, c, 8, 2, 32, 8, 4, 16, jnp.float32)
    out = flash_prefill(q, kp, vp, pt, qs, interpret=True)
    ref = paged_prefill_reference(q, kp, vp, pt, qs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("b,h,kv,hd", [
    (2, 4, 4, 32),     # MHA
    (3, 8, 2, 32),     # GQA group=4
    (2, 4, 1, 64),     # MQA
])
def test_flash_prefill_int8_parity(b, h, kv, hd):
    """Tiered int8 parity (see test_flash_decode_int8_parity): tier 1 pins
    the kernel's in-tile dequant to the int8 oracle at f32-path tolerance;
    tier 2 bounds both against exact f32 attention by the per-row
    quantization error band."""
    c, ps, npages = 8, 8, 4
    q, kp, vp, pt, qs = _case(
        jax.random.PRNGKey(6), b, c, h, kv, hd, ps, npages, 32, jnp.float32)
    qp = quantize_pool({"k": kp, "v": vp})
    scales = dict(k_scale=qp["k_scale"], v_scale=qp["v_scale"])
    out = flash_prefill(q, qp["k"], qp["v"], pt, qs, interpret=True, **scales)
    ref = paged_prefill_reference(q, qp["k"], qp["v"], pt, qs, **scales)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)
    exact = paged_prefill_reference(q, kp, vp, pt, qs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exact),
                               rtol=5e-2, atol=5e-2)


def test_flash_prefill_chunk_offsets():
    """q_start=0 (no history) through deep-history chunk starts."""
    b, c, h, kv, hd, ps, npages = 3, 4, 4, 2, 16, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (b, c, h, hd))
    kp = jax.random.normal(ks[1], (kv, b * npages, ps, hd))
    vp = jax.random.normal(ks[2], (kv, b * npages, ps, hd))
    pt = jnp.arange(b * npages, dtype=jnp.int32).reshape(b, npages)
    qs = jnp.array([0, 13, npages * ps - c], jnp.int32)
    out = flash_prefill(q, kp, vp, pt, qs, interpret=True)
    ref = paged_prefill_reference(q, kp, vp, pt, qs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


def test_prefill_reference_matches_dense_causal():
    """The paged oracle equals dense causal attention on the gathered KV."""
    b, c, h, kv, hd, ps, npages = 2, 8, 4, 2, 16, 4, 6
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    q = jax.random.normal(ks[0], (b, c, h, hd))
    kp = jax.random.normal(ks[1], (kv, 24, ps, hd))
    vp = jax.random.normal(ks[2], (kv, 24, ps, hd))
    perm = jax.random.permutation(ks[3], 24)[:b * npages]
    pt = perm.reshape(b, npages).astype(jnp.int32)
    qs = jnp.array([0, 9], jnp.int32)
    ref = paged_prefill_reference(q, kp, vp, pt, qs)
    kd, vd = gather_pages(kp, pt), gather_pages(vp, pt)
    t = kd.shape[1]
    for i in range(b):
        gold = dense_attention(q[i:i + 1], kd[i:i + 1], vd[i:i + 1],
                               causal=True,
                               q_positions=qs[i] + jnp.arange(c),
                               kv_positions=jnp.arange(t))
        np.testing.assert_allclose(np.asarray(ref[i]), np.asarray(gold[0]),
                                   rtol=1e-4, atol=1e-4)
