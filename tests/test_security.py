"""Security fabric (paper §VI): RBAC, assume-role, tokens, signed URLs."""
import pytest

hypothesis = pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import (AuthorizationError, Policy, PolicyEngine, Principal,
                        Role, SecurityError, TokenExpiredError, VirtualClock,
                        allow, deny, hours, install_standard_roles,
                        make_dataset_role)


@pytest.fixture
def engine():
    eng = PolicyEngine(clock=VirtualClock())
    install_standard_roles(eng)
    return eng


def _user(engine, uid="alice", roles=()):
    p = Principal(uid)
    engine.authenticator.register_identity(p, "s3cret")
    for r in roles:
        engine.bind(p, r)
    return p


def test_least_privilege_default(engine):
    _user(engine)
    # no roles -> cannot even log in to a role
    with pytest.raises(AuthorizationError):
        engine.login("alice", "s3cret")


def test_wrong_secret_rejected(engine):
    _user(engine, roles=["kotta-public-only"])
    with pytest.raises(SecurityError):
        engine.login("alice", "wrong")


def test_public_role_scoping(engine):
    _user(engine, roles=["kotta-public-only"])
    tok = engine.login("alice", "s3cret")
    assert engine.is_authorized(tok, "data:Get", "dataset/public/wiki/part0")
    assert not engine.is_authorized(tok, "data:Get", "dataset/wos/part0")
    assert not engine.is_authorized(tok, "data:Put", "dataset/public/x")


def test_private_dataset_no_download(engine):
    make_dataset_role(engine, "wos", downloadable=False)
    _user(engine, roles=["kotta-read-wos-private"])
    tok = engine.login("alice", "s3cret")
    assert engine.is_authorized(tok, "data:Get", "dataset/wos/part0")
    # explicit deny beats any allow: bytes stay in the enclave
    assert not engine.is_authorized(tok, "data:Download", "dataset/wos/part0")


def test_token_expiry(engine):
    _user(engine, roles=["kotta-public-only"])
    tok = engine.login("alice", "s3cret")
    engine.clock.advance(hours(1) + 1)
    with pytest.raises(TokenExpiredError):
        engine.check(tok, "data:Get", "dataset/public/x")


def test_web_session_lasts_six_hours(engine):
    _user(engine, roles=["kotta-public-only"])
    tok = engine.web_session("alice", "s3cret")
    engine.clock.advance(hours(5.9))
    assert engine.is_authorized(tok, "data:Get", "dataset/public/x")
    engine.clock.advance(hours(0.2))
    with pytest.raises(TokenExpiredError):
        engine.check(tok, "data:Get", "dataset/public/x")


def test_task_executor_assumes_user_role(engine):
    make_dataset_role(engine, "acm")
    worker = engine.service_session("task-executor")
    # worker itself cannot read the dataset...
    assert not engine.is_authorized(worker, "data:Get", "dataset/acm/p0")
    # ...but may assume the dataset role (trusted_assumers) to stage data
    assumed = engine.assume_role(worker, "kotta-read-acm-private")
    assert engine.is_authorized(assumed, "data:Get", "dataset/acm/p0")


def test_untrusted_role_cannot_assume(engine):
    make_dataset_role(engine, "acm")
    _user(engine, roles=["kotta-public-only"])
    tok = engine.login("alice", "s3cret")
    with pytest.raises(AuthorizationError):
        engine.assume_role(tok, "kotta-read-acm-private")


def test_assumed_session_bounded_by_parent(engine):
    make_dataset_role(engine, "acm")
    worker = engine.service_session("task-executor")
    assumed = engine.assume_role(worker, "kotta-read-acm-private")
    assert assumed.expires_at <= worker.expires_at


def test_signed_url_roundtrip_and_tamper(engine):
    make_dataset_role(engine, "pub", downloadable=True)
    _user(engine, roles=["kotta-read-pub-private"])
    tok = engine.login("alice", "s3cret")
    url = engine.sign_url(tok, "dataset/pub/obj")
    assert engine.verify_url(url) == "dataset/pub/obj"
    with pytest.raises(AuthorizationError):
        engine.verify_url(url.replace("obj", "other"))
    engine.clock.advance(hours(2))
    with pytest.raises(TokenExpiredError):
        engine.verify_url(url)


def test_audit_log_records_denials(engine):
    _user(engine, roles=["kotta-public-only"])
    tok = engine.login("alice", "s3cret")
    engine.is_authorized(tok, "data:Get", "dataset/wos/secret")
    denials = engine.audit.records(principal_id="alice", decision="deny")
    assert any(r.resource == "dataset/wos/secret" for r in denials)


# -- property tests -----------------------------------------------------------

_action = st.sampled_from(
    ["data:Get", "data:Put", "data:Download", "jobs:Submit", "db:Get"])
_resource = st.text(
    alphabet="abc/xyz", min_size=1, max_size=12).map(lambda s: "dataset/" + s)


@settings(max_examples=40, deadline=None)
@given(action=_action, resource=_resource)
def test_property_default_deny(action, resource):
    """A principal with no bindings is denied everything."""
    eng = PolicyEngine(clock=VirtualClock())
    eng.register_role(Role("empty", policies=[]))
    p = Principal("bob")
    eng.authenticator.register_identity(p, "pw")
    eng.bind(p, "empty")
    tok = eng.login("bob", "pw")
    assert not eng.is_authorized(tok, action, resource)


@settings(max_examples=40, deadline=None)
@given(action=_action, resource=_resource)
def test_property_explicit_deny_dominates(action, resource):
    """deny-all + allow-all == deny, for any (action, resource)."""
    eng = PolicyEngine(clock=VirtualClock())
    eng.register_role(Role("mixed", policies=[
        allow(["*"], ["*"]), deny([action], [resource])]))
    p = Principal("bob")
    eng.authenticator.register_identity(p, "pw")
    eng.bind(p, "mixed")
    tok = eng.login("bob", "pw")
    assert not eng.is_authorized(tok, action, resource)
    assert eng.is_authorized(tok, "other:Action", "elsewhere")


@settings(max_examples=25, deadline=None)
@given(st.lists(st.sampled_from(["u1", "u2", "u3"]), min_size=1, max_size=6))
def test_property_cross_user_isolation(users):
    """No user can read another user's results/ prefix."""
    eng = PolicyEngine(clock=VirtualClock())
    toks = {}
    for u in set(users):
        eng.register_role(Role(f"user-{u}", policies=[
            allow(["data:*"], [f"results/{u}/*"])]))
        p = Principal(u)
        eng.authenticator.register_identity(p, "pw")
        eng.bind(p, f"user-{u}")
        toks[u] = eng.login(u, "pw")
    for u in toks:
        for other in toks:
            can = eng.is_authorized(toks[u], "data:Get", f"results/{other}/out")
            assert can == (u == other)
