"""Kotta serving gateway: security (authorize + audit), tenant-scoped
prefix-cache isolation, deadline-ordered (EDF) admission across waves,
typed load-shed rejections, cost-budget rejection, spot revocation with
lossless requeue, queue-driven elastic scaling, and deadline-aware decode
preemption (pause the latest-deadline batch slot for an infeasible
interactive request; lossless resume, EDF order preserved)."""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.core.clock import VirtualClock
from repro.core.elastic import ProvisioningModel, ScalingPolicy
from repro.core.market import SpotMarket
from repro.core.security import (AuthorizationError, PolicyEngine, Principal,
                                 Role, provision_tenant)
from repro.models import get_family
from repro.models.params import init_params
from repro.serve import (ContinuousBatchingEngine, CostBudgetExceeded,
                         DeadlineCostPolicy, DeadlineInfeasible,
                         EngineRequest, JobState, KottaServeGateway,
                         PreemptCandidate, ServeEngine, ServeJob,
                         ServiceModel)

MAX_LEN = 48
SLOTS = 2


@pytest.fixture(scope="module")
def model():
    cfg = get_reduced_config("yi-6b").replace(dtype="float32", page_size=8)
    fam = get_family(cfg)
    params = init_params(fam.layout(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    return cfg, params


@pytest.fixture(scope="module")
def gold_engine(model):
    cfg, params = model
    return ServeEngine(cfg, params, max_len=MAX_LEN)


def _factory(model, **kw):
    cfg, params = model
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("max_slots", SLOTS)
    kw.setdefault("prefill_chunk", 8)
    kw.setdefault("decode_chunk", 4)
    return lambda: ContinuousBatchingEngine(cfg, params, **kw)


def _security(*tenants):
    sec = PolicyEngine(clock=VirtualClock())
    tokens = {t: provision_tenant(sec, t, f"pw-{t}",
                                  data_zones=("public", t))
              for t in tenants}
    return sec, tokens


def _gateway(model, sec, *, scaling=None, market=None, engine_kw=None,
             **kw):
    kw.setdefault("provisioning",
                  ProvisioningModel(base_delay_s=5.0, jitter_s=0.0,
                                    volatility_prob=0.0))
    kw.setdefault("service_model", ServiceModel(decode_step_s=0.05))
    return KottaServeGateway(_factory(model, **(engine_kw or {})), sec,
                             scaling=scaling or ScalingPolicy.none(
                                 1, market="on_demand"),
                             market=market, **kw)


def _prompt(cfg, n, seed=0):
    return np.random.RandomState(seed).randint(
        0, cfg.vocab_size, size=n).tolist()


# ---------------------------------------------------------------------------
# Security: authorization + audit
# ---------------------------------------------------------------------------

def test_submit_authorizes_and_audits(model):
    cfg, _ = model
    sec, tok = _security("alice")
    # mallory authenticates but holds no serving role: default deny.
    mallory = Principal("mallory")
    sec.authenticator.register_identity(mallory, "pw-m")
    sec.register_role(Role("bystander"))
    sec.bind(mallory, "bystander")
    tok_m = sec.login("mallory", "pw-m")

    gw = _gateway(model, sec)
    rid = gw.submit(tok["alice"], _prompt(cfg, 6), max_new=4,
                    data_zone="public")
    with pytest.raises(AuthorizationError):
        gw.submit(tok_m, _prompt(cfg, 6), max_new=4)
    gw.drain()
    assert gw.result(rid)

    allows = sec.audit.records(principal_id="alice", decision="allow")
    assert any(r.action == "serve:Generate" for r in allows)
    assert any(r.action == "data:Get" for r in allows)
    denies = sec.audit.records(principal_id="mallory", decision="deny")
    assert len(denies) == 1 and denies[0].action == "serve:Generate"

    # Security and scheduling share ONE clock: audit records written after
    # the drain carry the advanced sim time (token expiry is live too).
    assert gw.clock is sec.clock
    t_now = gw.clock.now()
    assert t_now > 0
    gw.submit(tok["alice"], _prompt(cfg, 6), max_new=4)
    rec = sec.audit.records(principal_id="alice", decision="allow")[-1]
    assert rec.timestamp == t_now
    gw.drain()


# ---------------------------------------------------------------------------
# Tenant-scoped prefix cache
# ---------------------------------------------------------------------------

def test_cross_tenant_prompts_share_no_pages(model):
    """Identical prompts from two tenants in the SAME wave: ZERO prefix
    hits, disjoint physical pages (every page single-referenced), while the
    same prompt within one tenant still aliases."""
    cfg, _ = model
    sec, tok = _security("alice", "bob")
    gw = _gateway(model, sec, engine_kw={"decode_chunk": 2})
    eng = gw.replica_engine(gw.replicas()[0].id)
    prompt = _prompt(cfg, 16, seed=3)        # 2 full pages

    gw.submit(tok["alice"], prompt, max_new=8, data_zone="public")
    gw.submit(tok["bob"], prompt, max_new=8, data_zone="public")
    gw.step()                                # both admitted, decode underway
    assert eng.live == 2
    # Cross-tenant: not one token served from the other's pages, and the
    # two slots' physical pages are fully disjoint (refcounts all 1).
    assert eng.stats["cached_tokens"] == 0
    pages = [set(l.pages) for l in eng._live.values()]
    assert not pages[0] & pages[1]
    assert all(eng.alloc.refs[p] == 1 for s in pages for p in s)
    eng._debug_check_refcounts()
    gw.drain()
    assert eng.stats["cached_tokens"] == 0

    # Same tenant, same prompt: pages ARE shared again (alice's cached
    # pages were not reallocated by the drain above).
    gw.submit(tok["alice"], prompt, max_new=4, data_zone="public")
    gw.drain()
    assert eng.stats["cached_tokens"] > 0
    eng._debug_check_refcounts()


def test_same_data_zone_different_tenant_isolated(model):
    """The namespace is (tenant, zone): sharing a zone does not merge
    tenants' caches, and two zones of one tenant don't merge either."""
    cfg, _ = model
    sec, tok = _security("alice")
    gw = _gateway(model, sec)
    eng = gw.replica_engine(gw.replicas()[0].id)
    prompt = _prompt(cfg, 16, seed=4)
    gw.submit(tok["alice"], prompt, max_new=4, data_zone="public")
    gw.drain()
    gw.submit(tok["alice"], prompt, max_new=4, data_zone="alice")
    gw.drain()
    assert eng.stats["cached_tokens"] == 0   # distinct zones: no aliasing


# ---------------------------------------------------------------------------
# Deadline-ordered admission + load shed
# ---------------------------------------------------------------------------

def test_edf_order_across_waves(model):
    """Jobs dispatched strictly by (priority, deadline), not submit order."""
    cfg, _ = model
    sec, tok = _security("alice")
    gw = _gateway(model, sec, engine_kw={"max_slots": 1})
    t = tok["alice"]
    p = _prompt(cfg, 6, seed=5)
    loose = gw.submit(t, p, max_new=4, deadline_s=10_000.0)
    tight = gw.submit(t, p, max_new=4, deadline_s=1_000.0)
    mid = gw.submit(t, p, max_new=4, deadline_s=5_000.0)
    urgent = gw.submit(t, p, max_new=4, deadline_s=9_000.0, priority=0)
    gw.drain()
    # priority class 0 first, then EDF within class 1.
    assert gw.completed_order == [urgent, tight, mid, loose]
    assert gw.metrics()["deadline_hit_rate"] == 1.0


def test_infeasible_deadline_is_shed_with_typed_rejection(model):
    """A request that cannot make its deadline at current occupancy is shed
    (typed error, audit-able status) instead of hanging the queue."""
    cfg, _ = model
    sec, tok = _security("alice")
    gw = _gateway(model, sec, engine_kw={"max_slots": 1},
                  service_model=ServiceModel(decode_step_s=1.0))
    t = tok["alice"]
    p = _prompt(cfg, 6, seed=6)
    ok = gw.submit(t, p, max_new=8, deadline_s=10_000.0)
    # 8 decode steps at 1 s/step can never fit a 2 s deadline.
    doomed = gw.submit(t, p, max_new=8, deadline_s=2.0)
    gw.drain()                               # returns: no hang
    assert gw.result(ok)
    assert gw.jobs[doomed].status is JobState.SHED
    with pytest.raises(DeadlineInfeasible):
        gw.result(doomed)
    m = gw.metrics()
    assert m["shed"] == 1 and m["completed"] == 1


def test_cost_budget_rejection(model):
    cfg, _ = model
    sec, tok = _security("alice")
    gw = _gateway(model, sec)
    rid = gw.submit(tok["alice"], _prompt(cfg, 6, seed=7), max_new=8,
                    cost_budget=1e-12)
    gw.drain()
    with pytest.raises(CostBudgetExceeded):
        gw.result(rid)


# ---------------------------------------------------------------------------
# Deadline-aware decode preemption
# ---------------------------------------------------------------------------

def _mid_decode(gw, n_live):
    """Step until n_live requests are genuinely mid-decode on replica 0."""
    for _ in range(200):
        gw.step()
        live = gw.replicas()
        if live and live[0].engine.live == n_live and \
                all(l.emitted > 0 for l in live[0].engine._live.values()):
            return live[0].engine
    pytest.fail("never reached mid-decode state")


def test_preemption_admits_infeasible_interactive_then_resumes(
        model, gold_engine):
    """An interactive request that is infeasible at full batch occupancy
    preempts a batch slot, completes within its deadline, and the paused
    batch job resumes losslessly (oracle tokens, zero re-prefill); every
    pause/resume is audit-logged."""
    cfg, _ = model
    sec, tok = _security("alice")
    gw = _gateway(model, sec, engine_kw={"max_slots": 2, "decode_chunk": 2})
    t = tok["alice"]
    rng = np.random.RandomState(50)
    bprompts = [rng.randint(0, cfg.vocab_size, size=6).tolist()
                for _ in range(2)]
    b_rids = [gw.submit(t, p, max_new=24, deadline_s=3600.0, priority=1)
              for p in bprompts]
    eng = _mid_decode(gw, 2)
    pf_mark = eng.stats["prefill_tokens"]

    # 24 steps at 0.05 s/step hold both slots ~1.2 s: a 0.5 s interactive
    # deadline is infeasible by waiting, feasible with an instant start.
    iprompt = rng.randint(0, cfg.vocab_size, size=5).tolist()
    i_rid = gw.submit(t, iprompt, max_new=4, deadline_s=0.5, priority=0)
    saw_paused = False
    for _ in range(2_000):
        if not gw.outstanding():
            break
        gw.step()
        saw_paused = saw_paused or any(j.status is JobState.PAUSED
                                       for j in gw.jobs.values())
    m = gw.metrics()
    assert saw_paused
    assert m["completed"] == 3 and m["shed"] == 0
    assert m["preemptions"] == 1 and m["resumes"] == 1
    assert m["preempt_wait_s"] > 0.0
    assert m["deadline_hit_rate"] == 1.0
    assert m["interactive_sla_rate"] == 1.0
    assert gw.completed_order[0] == i_rid
    # Lossless: the preempted batch job's tokens match an uninterrupted
    # run, and its pause cost no re-prefill (only the interactive admission
    # prefilled anything after the mark).
    for rid, p in zip(b_rids, bprompts):
        gold = gold_engine.generate([p], max_new=24).tokens[0]
        np.testing.assert_array_equal(gold,
                                      np.asarray(gw.result(rid), np.int32))
    assert eng.stats["prefill_tokens"] - pf_mark == len(iprompt)
    # Typed accounting in the audit stream.
    assert len([r for r in sec.audit.records()
                if r.action == "serve:Preempt"]) == 1
    assert len([r for r in sec.audit.records()
                if r.action == "serve:Resume"]) == 1


def test_edf_order_preserved_across_preempt_resume(model):
    """The LATEST-deadline batch job is the victim, and completion order
    stays EDF-consistent across the preempt/resume cycle: interactive
    first, then the earlier-deadline batch job, then the resumed victim."""
    cfg, _ = model
    sec, tok = _security("alice")
    gw = _gateway(model, sec, engine_kw={"max_slots": 2, "decode_chunk": 2})
    t = tok["alice"]
    p = _prompt(cfg, 6, seed=51)
    early = gw.submit(t, p, max_new=20, deadline_s=500.0, priority=1)
    late = gw.submit(t, p, max_new=20, deadline_s=900.0, priority=1)
    _mid_decode(gw, 2)
    i_rid = gw.submit(t, _prompt(cfg, 5, seed=52), max_new=4,
                      deadline_s=0.5, priority=0)
    paused_rid = None
    for _ in range(2_000):
        if not gw.outstanding():
            break
        gw.step()
        for j in gw.jobs.values():
            if j.status is JobState.PAUSED:
                paused_rid = j.rid
    assert paused_rid == late                # latest deadline pays the wait
    assert gw.completed_order == [i_rid, early, late]
    m = gw.metrics()
    assert m["preemptions"] == 1 and m["deadline_hit_rate"] == 1.0


def test_preemption_disabled_sheds_instead(model):
    """DeadlineCostPolicy(preempt=False): the same infeasible interactive
    request is shed with the typed rejection, and no job is ever paused."""
    cfg, _ = model
    sec, tok = _security("alice")
    svc = ServiceModel(decode_step_s=0.05)
    gw = _gateway(model, sec, engine_kw={"max_slots": 2, "decode_chunk": 2},
                  service_model=svc,
                  admission=DeadlineCostPolicy(model=svc, preempt=False))
    t = tok["alice"]
    p = _prompt(cfg, 6, seed=53)
    b_rids = [gw.submit(t, p, max_new=24, deadline_s=3600.0, priority=1)
              for _ in range(2)]
    _mid_decode(gw, 2)
    i_rid = gw.submit(t, _prompt(cfg, 5, seed=54), max_new=4,
                      deadline_s=0.5, priority=0)
    gw.drain()
    assert gw.jobs[i_rid].status is JobState.SHED
    with pytest.raises(DeadlineInfeasible):
        gw.result(i_rid)
    m = gw.metrics()
    assert m["preemptions"] == 0 and m["resumes"] == 0
    assert m["completed"] == 2 and m["shed"] == 1
    assert all(gw.jobs[r].status is JobState.DONE for r in b_rids)


def test_plan_preemption_respects_both_deadlines():
    """Unit: the policy only nominates a victim when the interactive job
    meets its deadline from an instant start AND the victim still meets its
    own after a zero-re-prefill resume; the latest-deadline victim wins."""
    policy = DeadlineCostPolicy(model=ServiceModel(prefill_tok_per_s=1e9,
                                                   decode_step_s=1.0))
    now = 100.0
    job = ServeJob(rid=9, tenant="a", prompt=[1] * 4, max_new=2,
                   submitted_at=now, deadline=now + 3.0, priority=0)

    def cand(rid, deadline, remaining, priority=1):
        return PreemptCandidate(
            ServeJob(rid=rid, tenant="a", prompt=[1], max_new=8,
                     submitted_at=0.0, deadline=deadline, priority=priority),
            remaining_tokens=remaining, replica_id=0, slot=rid)

    tight = cand(1, now + 4.0, 5)       # resume at 107 > 104: protected
    loose = cand(2, now + 100.0, 5)     # resume at 107 < 200: eligible
    loosest = cand(3, now + 200.0, 5)   # latest deadline: the pick
    peer = cand(4, None, 5, priority=0)  # same class: never preempted
    pick = policy.plan_preemption(job, [tight, loose, loosest, peer], now)
    assert pick is loosest
    # No eligible victim -> None (shed proceeds).
    assert policy.plan_preemption(job, [tight, peer], now) is None
    # Interactive job hopeless even with an instant start -> None.
    hopeless = ServeJob(rid=10, tenant="a", prompt=[1] * 4, max_new=2,
                        submitted_at=now, deadline=now + 1.0, priority=0)
    assert policy.plan_preemption(hopeless, [loosest], now) is None
    # Knob off -> None.
    off = DeadlineCostPolicy(model=policy.model, preempt=False)
    assert off.plan_preemption(job, [loosest], now) is None


# ---------------------------------------------------------------------------
# Spot revocation: lossless requeue
# ---------------------------------------------------------------------------

def test_spot_revocation_mid_decode_loses_no_request(model, gold_engine):
    """Revoking a spot replica mid-decode re-enqueues its live requests;
    they complete on the replacement with oracle-identical tokens."""
    cfg, _ = model
    sec, tok = _security("alice")
    gw = _gateway(
        model, sec,
        scaling=ScalingPolicy.limited(1, market="spot", bid_fraction=1e9),
        market=SpotMarket(seed=0),
        engine_kw={"max_slots": 2, "decode_chunk": 2})
    t = tok["alice"]
    rng = np.random.RandomState(8)
    prompts = [rng.randint(0, cfg.vocab_size, size=n).tolist()
               for n in (6, 9)]
    rids = [gw.submit(t, p, max_new=12) for p in prompts]

    # Step until decode is genuinely mid-flight, then pull the plug.
    for _ in range(200):
        gw.step()
        live = gw.replicas()
        if live and any(0 < l.emitted < l.req.max_new
                        for l in live[0].engine._live.values()):
            break
    else:
        pytest.fail("never reached mid-decode state")
    gw.revoke_replica(gw.replicas()[0].id)
    assert all(gw.jobs[r].status is JobState.QUEUED for r in rids
               if gw.jobs[r].tokens is None)
    gw.drain()

    gold = np.concatenate([gold_engine.generate([p], max_new=12).tokens
                           for p in prompts])
    got = np.stack([np.asarray(gw.result(r), np.int32) for r in rids])
    np.testing.assert_array_equal(gold, got)
    m = gw.metrics()
    assert m["revocations"] == 1 and m["requeues"] >= 1
    assert m["completed"] == 2 and m["shed"] == 0


# ---------------------------------------------------------------------------
# Elasticity
# ---------------------------------------------------------------------------

def test_queue_depth_scales_replicas_up_and_down(model):
    cfg, _ = model
    sec, tok = _security("alice")
    gw = _gateway(
        model, sec,
        scaling=ScalingPolicy.limited(3, market="spot", bid_fraction=1e9,
                                      idle_timeout_s=30.0),
        market=SpotMarket(seed=0),
        engine_kw={"max_slots": 1})
    t = tok["alice"]
    p = _prompt(cfg, 6, seed=9)
    for _ in range(6):
        gw.submit(t, p, max_new=4, deadline_s=100_000.0)
    gw.drain()
    m = gw.metrics()
    assert m["completed"] == 6
    assert m["peak_replicas"] > 1            # burst scaled out
    assert m["launches"] >= m["peak_replicas"]
    # After the burst drains plus the idle timeout, the pool shrinks to the
    # floor (min_nodes=0).
    for _ in range(80):
        if not gw.replicas():
            break
        gw.step()
    assert not gw.replicas()
    assert m["cost_usd"] > 0.0               # live spot replicas were billed


# ---------------------------------------------------------------------------
# Per-replica observability
# ---------------------------------------------------------------------------

def test_metrics_report_per_replica_counters(model):
    """metrics()['per_replica'] exposes occupancy, queue depth, prefix-hit
    rate and dispatch counts for every non-retired replica — the routing
    tier's decisions are auditable without reaching into engine internals."""
    cfg, _ = model
    sec, tok = _security("alice")
    gw = _gateway(model, sec)
    prompt = _prompt(cfg, 16, seed=11)
    gw.submit(tok["alice"], prompt, max_new=8, data_zone="public")
    gw.step()                                # admitted, decode underway
    m = gw.metrics()
    per = m["per_replica"]
    assert len(per) == 1
    e = per[0]
    assert set(e) == {"replica", "role", "state", "live", "queued",
                      "open_slots", "occupancy", "prefix_hit_rate",
                      "dispatched", "health", "noticed"}
    assert e["role"] == "unified" and e["state"] == "live"
    assert e["health"] == "up" and e["noticed"] is False
    assert e["live"] == 1 and e["dispatched"] == 1
    assert e["occupancy"] == pytest.approx(0.5)      # 1 of 2 slots
    assert e["open_slots"] == 1
    assert e["prefix_hit_rate"] == 0.0               # cold cache
    assert m["queue_depth"] == 0
    assert m["routing_mode"] == "affinity"
    # The counters move with the workload: a same-prefix repeat lands cache
    # hits and another dispatch on the same replica.
    gw.drain()
    gw.submit(tok["alice"], prompt, max_new=8, data_zone="public")
    gw.drain()
    e = gw.metrics()["per_replica"][0]
    assert e["dispatched"] == 2
    assert e["prefix_hit_rate"] > 0
    assert e["live"] == 0 and e["occupancy"] == 0.0  # drained
    # Engine reachable through the explicit accessor, and consistent.
    assert gw.replica_engine(e["replica"]).prefix_hit_rate \
        == e["prefix_hit_rate"]
