"""int8 KV-pool parity: greedy serving over the quantized pool must be
TOKEN-IDENTICAL to the f32 pool on this suite's workloads — plain continuous
decode, speculative decode (fixed and adaptive window), and pause/resume —
plus the quantization round-trip error bound, the int8 pool layout/capacity
contract, the adaptive-window controller's shrink/grow behavior, and the
constructor/config validation for the new knobs.

Token identity is a strong check but the right one: per-row symmetric int8
perturbs logits by well under typical greedy margins at these scales, and a
layout or dequant bug (wrong scale row, transposed page axis) corrupts
logits far past any margin — so the assertion is exact, not toleranced.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.kernels import dequantize_rows, quantize_rows
from repro.models import get_family
from repro.models.params import init_params
from repro.serve import ContinuousBatchingEngine, EngineRequest


@pytest.fixture(scope="module")
def model():
    cfg = get_reduced_config("yi-6b").replace(dtype="float32", page_size=8)
    fam = get_family(cfg)
    params = init_params(fam.layout(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    return cfg, params


def _prompts(vocab, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, vocab, size=n).tolist() for n in lens]


def _engine(cfg, params, dtype, **kw):
    kw.setdefault("max_len", 64)
    kw.setdefault("max_slots", 4)
    return ContinuousBatchingEngine(cfg, params, kv_cache_dtype=dtype, **kw)


# ---------------------------------------------------------------------------
# Token identity: int8 pool vs f32 pool
# ---------------------------------------------------------------------------

def test_int8_plain_decode_token_identity(model):
    cfg, params = model
    prompts = _prompts(cfg.vocab_size, [5, 9, 12, 7], seed=60)
    gold = _engine(cfg, params, "f32").generate(prompts, max_new=12).tokens
    got = _engine(cfg, params, "int8").generate(prompts, max_new=12).tokens
    np.testing.assert_array_equal(gold, got)


@pytest.mark.parametrize("adaptive", [False, True])
def test_int8_spec_decode_token_identity(model, adaptive):
    """Speculative decode over the int8 pool — fixed-K and adaptive-K —
    emits the plain f32 greedy tokens. Identity holds for ANY per-slot
    window schedule: accepted draft prefixes are exact greedy matches, so
    the adaptive controller can only change how fast tokens arrive."""
    cfg, params = model
    prompts = _prompts(cfg.vocab_size, [4, 11, 8, 6], seed=61)
    gold = _engine(cfg, params, "f32").generate(prompts, max_new=12).tokens
    got = _engine(cfg, params, "int8", decode_chunk=2,
                  enable_spec_decode=True, spec_tokens=4,
                  spec_adaptive_k=adaptive).generate(
                      prompts, max_new=12).tokens
    np.testing.assert_array_equal(gold, got)


def test_int8_preempt_resume_token_identity(model):
    """Pause/resume over the int8 pool is lossless: pinned pages keep their
    quantized rows AND scale rows, so the resumed request emits exactly the
    tokens of a never-paused f32 run."""
    cfg, params = model
    prompts = _prompts(cfg.vocab_size, [5, 9, 13], seed=62)
    gold = _engine(cfg, params, "f32", max_slots=3).generate(
        prompts, max_new=10).tokens
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                   prefill_chunk=8, decode_chunk=2,
                                   kv_cache_dtype="int8")
    for rid, p in enumerate(prompts[:2]):
        eng.enqueue(EngineRequest(rid, list(p), 10))
    eng.admit()
    done = {}
    for req, toks in eng.decode_step():
        done[req.rid] = toks
    slot0 = next(s for s, l in eng._live.items() if l.req.rid == 0)
    paused = eng.preempt(slot0)
    assert 0 < paused.emitted < 10          # genuinely mid-stream
    eng.enqueue(EngineRequest(2, list(prompts[2]), 10))
    eng.admit()
    resumed = False
    for _ in range(200):
        for req, toks in eng.decode_step():
            done[req.rid] = toks
        if not resumed and eng.free_slots > 0:
            eng.resume(paused)
            resumed = True
        if len(done) == 3 and not eng.has_work:
            break
    assert resumed and len(done) == 3
    got = np.stack([np.asarray(done[i], np.int32) for i in range(3)])
    np.testing.assert_array_equal(gold, got)


# ---------------------------------------------------------------------------
# Adaptive-window controller behavior
# ---------------------------------------------------------------------------

def test_adaptive_k_shrinks_on_low_acceptance(model):
    """Full-vocab random content: the drafter accepts ~nothing, so every
    slot's window must shrink below K (and the engine dispatch drop to a
    smaller verify bucket) within a few chunks."""
    cfg, params = model
    prompts = _prompts(cfg.vocab_size, [8, 6], seed=63)
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                   decode_chunk=2, enable_spec_decode=True,
                                   spec_tokens=4, spec_adaptive_k=True)
    for rid, p in enumerate(prompts):
        eng.enqueue(EngineRequest(rid, list(p), 16))
    eng.admit()
    assert all(st["kslot"] == 4 for st in eng.slot_spec_state().values())
    seen = set()
    while eng.has_work:
        eng.decode_step()
        seen.update(st["kslot"] for st in eng.slot_spec_state().values())
    assert min(seen) < 4                    # windows shrank
    assert len(eng._spec_chunks) > 1        # a smaller verify bucket traced


def test_adaptive_k_grows_on_high_acceptance(model):
    """Repetitive small-vocab content self-seeded with the model's own
    greedy prefix: acceptance ~1, so a window knocked down to 1 must grow
    back once the accept-rate EMA clears the threshold."""
    cfg, params = model
    scfg = cfg.replace(vocab_size=4)
    fam = get_family(scfg)
    sparams = init_params(fam.layout(scfg), jax.random.PRNGKey(0),
                          scfg.param_dtype)
    head = [0, 1, 2, 3] * 4
    seed = ContinuousBatchingEngine(
        scfg, sparams, max_len=96, max_slots=1).generate(
            [head], max_new=24).tokens[0].tolist()
    eng = ContinuousBatchingEngine(scfg, sparams, max_len=96, max_slots=1,
                                   decode_chunk=2, enable_spec_decode=True,
                                   spec_tokens=4, spec_adaptive_k=True)
    eng.enqueue(EngineRequest(0, head + seed, 24))
    eng.admit()
    slot = next(iter(eng._live))
    eng._kslot[slot] = 1                    # start from a collapsed window
    grown = 1
    while eng.has_work:
        eng.decode_step()
        for st in eng.slot_spec_state().values():
            grown = max(grown, st["kslot"])
    assert grown > 1
    assert eng.mean_accept_ema > 0.5


# ---------------------------------------------------------------------------
# Quantization numerics and pool layout
# ---------------------------------------------------------------------------

def test_quantize_round_trip_error_bound():
    """Per-row symmetric int8: |round-trip error| <= amax(row)/254 per
    element (scale = amax/127, round-to-nearest), across 3 decades of row
    magnitude; all-zero rows survive exactly (scale floor, no 0/0)."""
    rng = np.random.RandomState(0)
    x = (rng.randn(64, 32) * rng.uniform(1e-2, 10.0, size=(64, 1))) \
        .astype(np.float32)
    q, s = quantize_rows(x)
    back = np.asarray(dequantize_rows(q, s))
    amax = np.max(np.abs(x), axis=-1, keepdims=True)
    assert np.all(np.abs(back - x) <= amax / 254 + 1e-7)
    qz, sz = quantize_rows(np.zeros((4, 8), np.float32))
    assert not np.asarray(qz).any()
    assert not np.asarray(dequantize_rows(qz, sz)).any()


def test_int8_pool_layout_and_capacity(model):
    """Scale pages mirror data pages minus the head_dim axis, and the int8
    layout's bytes-per-row advantage is exactly 4*hd/(hd+4)."""
    cfg, params = model
    fam = get_family(cfg)
    pool = fam.paged_pool(cfg, 8, "int8")
    assert set(pool) == {"k", "v", "k_scale", "v_scale"}
    assert pool["k"].dtype == np.int8
    assert pool["k_scale"].dtype == np.float32
    assert pool["k_scale"].shape == pool["k"].shape[:-1]
    f32 = fam.paged_pool(cfg, 8, "f32")
    assert set(f32) == {"k", "v"}
    ratio = (sum(leaf.nbytes for leaf in f32.values())
             / sum(leaf.nbytes for leaf in pool.values()))
    hd = pool["k"].shape[-1]
    assert ratio == pytest.approx(4 * hd / (hd + 4))


# ---------------------------------------------------------------------------
# Validation
# ---------------------------------------------------------------------------

def test_kv_cache_dtype_validated():
    with pytest.raises(ValueError, match="kv_cache_dtype"):
        get_reduced_config("yi-6b").replace(kv_cache_dtype="fp8")


def test_int8_requires_paged_prefill(model):
    cfg, params = model
    with pytest.raises(ValueError, match="paged"):
        ContinuousBatchingEngine(cfg, params, max_len=32, max_slots=1,
                                 prefill_mode="dense", kv_cache_dtype="int8")


def test_adaptive_requires_spec_decode(model):
    cfg, params = model
    with pytest.raises(ValueError, match="enable_spec_decode"):
        ContinuousBatchingEngine(cfg, params, max_len=32, max_slots=1,
                                 spec_adaptive_k=True)
