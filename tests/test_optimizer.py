"""AdamW (vs numpy oracle), int8 moment quantization, grad compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.train import adamw
from repro.train.grad_compress import (compress_decompress, ef_step,
                                       init_compressor)


def numpy_adamw(params, grads, m, v, step, cfg: adamw.AdamWConfig, lr):
    g = grads
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g * g
    mhat = m / (1 - cfg.b1 ** step)
    vhat = v / (1 - cfg.b2 ** step)
    upd = mhat / (np.sqrt(vhat) + cfg.eps) + cfg.weight_decay * params
    return params - lr * upd, m, v


def test_adamw_matches_numpy_reference():
    cfg = adamw.AdamWConfig(learning_rate=1e-2, warmup_steps=0, decay_steps=10**9,
                            min_lr_ratio=1.0, max_grad_norm=1e9)
    rng = np.random.default_rng(0)
    p_np = rng.normal(size=(4, 128)).astype(np.float32)
    params = {"w": jnp.asarray(p_np)}
    state = adamw.init(cfg, params)
    m_np = np.zeros_like(p_np)
    v_np = np.zeros_like(p_np)
    for step in range(1, 4):
        g_np = rng.normal(size=p_np.shape).astype(np.float32)
        params, state, _ = adamw.update(cfg, {"w": jnp.asarray(g_np)}, state,
                                        params)
        p_np, m_np, v_np = numpy_adamw(p_np, g_np, m_np, v_np, step, cfg,
                                       lr=1e-2)
        np.testing.assert_allclose(np.asarray(params["w"]), p_np, rtol=1e-5,
                                   atol=1e-6)


def test_lr_schedule_shape():
    cfg = adamw.AdamWConfig(learning_rate=1.0, warmup_steps=10,
                            decay_steps=110, min_lr_ratio=0.1)
    assert float(adamw.lr_at(cfg, 0)) == 0.0
    assert float(adamw.lr_at(cfg, 10)) == pytest.approx(1.0)
    assert float(adamw.lr_at(cfg, 110)) == pytest.approx(0.1)
    assert float(adamw.lr_at(cfg, 60)) == pytest.approx(0.55, abs=0.02)


def test_grad_clipping():
    cfg = adamw.AdamWConfig(learning_rate=1.0, warmup_steps=0,
                            max_grad_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((16, 128))}
    state = adamw.init(cfg, params)
    huge = {"w": jnp.full((16, 128), 1e6)}
    _, _, metrics = adamw.update(cfg, huge, state, params)
    assert float(metrics["grad_norm"]) > 1e6  # reported pre-clip


@settings(max_examples=30, deadline=None)
@given(hnp.arrays(np.float32, (8, 256),
                  elements=st.floats(-1e3, 1e3, width=32)))
def test_property_quantization_error_bound(x):
    """|x - deq(q(x))| <= scale/2 per block (scale = blockmax/127)."""
    qt = adamw.quantize_blockwise(jnp.asarray(x))
    back = np.asarray(adamw.dequantize_blockwise(qt))
    blocks = x.reshape(8, 256 // adamw.QBLOCK, adamw.QBLOCK)
    scale = np.abs(blocks).max(axis=-1, keepdims=True) / 127.0
    bound = np.broadcast_to(scale / 2 + 1e-7, blocks.shape).reshape(x.shape)
    assert np.all(np.abs(x - back) <= bound + 1e-6)


def test_int8_state_memory_is_quarter():
    cfg = adamw.AdamWConfig(state_dtype="int8")
    params = {"w": jnp.zeros((1024, 1024), jnp.float32)}
    state = adamw.init(cfg, params)
    q = state.m["w"]
    assert isinstance(q, adamw.QTensor)
    bytes_q = q.q.size * 1 + q.scale.size * 4
    assert bytes_q < 0.3 * params["w"].size * 4


def test_compress_decompress_error_feedback_contracts():
    """Accumulated EF residual stays bounded; mean of compressed stream
    converges to mean of the true stream (unbiased-in-time)."""
    rng = np.random.default_rng(1)
    g_true = rng.normal(size=(4, 256)).astype(np.float32)
    state = init_compressor({"g": jnp.zeros((4, 256))})
    acc = np.zeros_like(g_true)
    steps = 24
    for _ in range(steps):
        ghat, state = ef_step({"g": jnp.asarray(g_true)}, state)
        acc += np.asarray(ghat["g"])
    # error feedback: sum of emitted ~= sum of inputs (residual bounded)
    resid = np.asarray(state.residual["g"])
    np.testing.assert_allclose(acc + resid, g_true * steps, rtol=1e-4,
                               atol=1e-4)
    assert np.abs(resid).max() <= np.abs(g_true).max() + 1e-3


def test_compressed_psum_via_shard_map():
    """Cross-'pod' int8 all-reduce inside shard_map on a 1-device mesh."""
    from jax.sharding import PartitionSpec as P
    from repro.train.grad_compress import compressed_psum
    mesh = jax.make_mesh((1,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    grads = {"w": jnp.ones((2, 256)) * 0.37}
    state = init_compressor(grads)

    def f(g, s):
        return compressed_psum(g, "pod", s)

    out, _ = jax.shard_map(
        f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))(grads, state)
    np.testing.assert_allclose(np.asarray(out["w"]), 0.37, rtol=1e-2)
