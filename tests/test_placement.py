"""Cost-aware placement policy (paper §VII-E live-runtime counterpart)."""
import pytest

from repro.core.market import SpotMarket
from repro.core.placement import PlacementPolicy


@pytest.fixture
def market():
    return SpotMarket(seed=11)


def test_global_scope_never_worse_than_region(market):
    g = PlacementPolicy(market, "c4.8xlarge", scope="global")
    r = PlacementPolicy(market, "c4.8xlarge", scope="region")
    for t in (0.0, 100.0, 500.0):
        dg = g.place(data_region="us-east-1", est_hours=1.0,
                     data_down_gb=0.0, data_up_gb=0.0, t_hours=t)
        dr = r.place(data_region="us-east-1", est_hours=1.0,
                     data_down_gb=0.0, data_up_gb=0.0, t_hours=t)
        assert dg.expected_total <= dr.expected_total + 1e-9


def test_heavy_data_pins_to_home_region(market):
    """With huge egress, the optimum co-locates with the data (paper Fig 7)."""
    g = PlacementPolicy(market, "c4.8xlarge", scope="global")
    d = g.place(data_region="us-east-1", est_hours=1.0,
                data_down_gb=500.0, data_up_gb=500.0, t_hours=7.0)
    assert not d.cross_region


def test_region_scope_respects_region(market):
    r = PlacementPolicy(market, "c4.8xlarge", scope="region")
    d = r.place(data_region="eu-west-1", est_hours=1.0,
                data_down_gb=1.0, data_up_gb=1.0)
    assert d.zone.region == "eu-west-1"
    assert not d.cross_region


def test_egress_added_only_cross_region(market):
    g = PlacementPolicy(market, "c4.8xlarge", scope="global")
    d = g.place(data_region="us-east-1", est_hours=1.0,
                data_down_gb=10.0, data_up_gb=10.0, t_hours=3.0)
    expected_egress = 0.0 if not d.cross_region else 20.0 * 0.02
    assert d.expected_total == pytest.approx(d.hourly_price + expected_egress)
