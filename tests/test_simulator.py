"""Elastic-scaling DES: Table VII-C relations + the 16x headline claim."""
import pytest

from repro.core import (ElasticSimulator, ScalingPolicy, make_paper_workload,
                        run_table7c)


@pytest.fixture(scope="module")
def reports():
    return {r.policy + str(r.max_nodes): r for r in run_table7c(seed=7)}


def test_workload_matches_paper_spec():
    jobs = make_paper_workload(seed=7)
    assert len(jobs) == 40
    hours = [j.duration_s / 3600 for j in jobs]
    assert all(0.9 <= h <= 4.3 for h in hours)
    assert {j.data_gb for j in jobs} <= {1.0, 3.0, 5.0, 7.0, 9.0}


def test_static_pool_has_zero_wait(reports):
    r = reports["none40"]
    assert r.max_wait_s == 0.0
    assert r.avg_wait_s == 0.0


def test_unlimited_matches_static_makespan(reports):
    """Paper: unlimited keeps the no-scaling makespan (idle reuse)."""
    assert reports["unlimitedNone"].makespan_s <= reports["none40"].makespan_s * 1.10


def test_unlimited_much_cheaper_than_static(reports):
    base, elastic = reports["none40"], reports["unlimitedNone"]
    savings = 1 - elastic.on_demand_cost / base.on_demand_cost
    assert savings > 0.5  # paper: 61%


def test_headline_16x_claim(reports):
    """Spot + unlimited elastic vs static on-demand: >= 10x cheaper
    (paper headline: 'up to 16x')."""
    ratio = reports["none40"].on_demand_cost / reports["unlimitedNone"].spot_cost
    assert ratio >= 10.0


def test_limited_trades_makespan_for_cost(reports):
    lim10, lim20 = reports["limited10"], reports["limited20"]
    assert lim10.makespan_s > lim20.makespan_s
    assert lim10.on_demand_cost < lim20.on_demand_cost
    assert lim10.peak_instances <= 10 and lim20.peak_instances <= 20


def test_all_jobs_complete_under_every_policy(reports):
    for r in reports.values():
        assert all(j.done_s is not None for j in r.jobs)


def test_revocation_path_requeues_jobs():
    """With an aggressively low bid, revocations happen and jobs still finish."""
    wl = make_paper_workload(seed=3)
    sim = ElasticSimulator(ScalingPolicy.unlimited(bid_fraction=0.05), wl,
                           seed=3)
    rep = sim.run()
    assert all(j.done_s is not None for j in rep.jobs)
    # a tiny bid under volatile prices must eventually revoke something
    assert rep.revocations >= 1


def test_determinism():
    a = ElasticSimulator(ScalingPolicy.unlimited(),
                         make_paper_workload(seed=7), seed=7).run()
    b = ElasticSimulator(ScalingPolicy.unlimited(),
                         make_paper_workload(seed=7), seed=7).run()
    assert a.spot_cost == b.spot_cost and a.makespan_s == b.makespan_s
