"""Serve-engine lifecycle: paged chunked prefill vs the dense-prefill oracle,
copy-on-write prefix sharing, refcount invariants, page reuse across
retire/readmit, exhaustion mid-wave, up-front capacity validation, and the
one-compile guarantees for the decode/prefill hot paths."""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import get_family
from repro.models.params import init_params
from repro.serve import (ContinuousBatchingEngine, PageAllocator, PrefixCache,
                         ServeEngine)


def _make(arch="yi-6b", **kw):
    cfg = get_reduced_config(arch).replace(dtype="float32", page_size=8, **kw)
    fam = get_family(cfg)
    params = init_params(fam.layout(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    return cfg, params


def _prompts(vocab, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, vocab, size=n).tolist() for n in lens]


@pytest.fixture(scope="module")
def model():
    return _make()


@pytest.fixture(scope="module")
def gold_engine(model):
    cfg, params = model
    return ServeEngine(cfg, params, max_len=64)


def _gold(gold_engine, prompts, max_new):
    """Per-request static-engine decode: the padding-free oracle."""
    return np.concatenate(
        [gold_engine.generate([p], max_new=max_new).tokens for p in prompts])


# ---------------------------------------------------------------------------
# Paged chunked prefill vs oracle
# ---------------------------------------------------------------------------

def test_paged_prefill_matches_oracle_mixed_lengths(model, gold_engine):
    """Chunked paged admission must emit the same tokens as the dense path,
    including prompts that straddle chunk and page boundaries."""
    cfg, params = model
    prompts = _prompts(cfg.vocab_size, [3, 7, 12, 5, 17, 8], seed=1)
    gold = _gold(gold_engine, prompts, 8)
    for chunk in (4, 8, 32):
        eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=6,
                                       prefill_chunk=chunk)
        out = eng.generate(prompts, max_new=8)
        np.testing.assert_array_equal(gold, out.tokens)


def test_paged_prefill_matches_dense_mode(model):
    """The in-engine dense baseline and the paged path agree token-for-token."""
    cfg, params = model
    prompts = _prompts(cfg.vocab_size, [4, 9, 14, 6], seed=2)
    dense = ContinuousBatchingEngine(cfg, params, max_len=48, max_slots=4,
                                     prefill_mode="dense")
    paged = ContinuousBatchingEngine(cfg, params, max_len=48, max_slots=4,
                                     prefill_chunk=8)
    np.testing.assert_array_equal(dense.generate(prompts, max_new=6).tokens,
                                  paged.generate(prompts, max_new=6).tokens)


# ---------------------------------------------------------------------------
# Prefix sharing + copy-on-write
# ---------------------------------------------------------------------------

def test_prefix_sharing_across_waves(model, gold_engine):
    """Requests admitted after a shared prefix is cached alias its pages and
    still decode the exact oracle tokens."""
    cfg, params = model
    rng = np.random.RandomState(3)
    shared = rng.randint(0, cfg.vocab_size, size=16).tolist()
    prompts = [shared + rng.randint(0, cfg.vocab_size, size=4).tolist()
               for _ in range(4)]
    gold = _gold(gold_engine, prompts, 6)
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                   prefill_chunk=8)
    out = eng.generate(prompts, max_new=6)      # 2 slots: 2 admission waves
    np.testing.assert_array_equal(gold, out.tokens)
    assert eng.stats["cached_tokens"] > 0       # later waves hit the prefix
    eng._debug_check_refcounts()

    # Warm-cache readmission: nearly all prompt tokens served from cache.
    out2 = eng.generate(prompts, max_new=6)
    np.testing.assert_array_equal(gold, out2.tokens)
    assert eng.prefix_hit_rate > 0.5
    eng._debug_check_refcounts()


def test_copy_on_write_boundary_page(model, gold_engine):
    """A prefix match ending mid-page copies the boundary page instead of
    appending into the (still referenced) donor page."""
    cfg, params = model
    rng = np.random.RandomState(4)
    donor = rng.randint(0, cfg.vocab_size, size=12).tolist()   # partial page
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                   prefill_chunk=8)
    eng.generate([donor], max_new=4)            # caches 1 full + 1 partial page
    follow = [donor + rng.randint(0, cfg.vocab_size, size=5).tolist()
              for _ in range(2)]
    gold = _gold(gold_engine, follow, 6)
    out = eng.generate(follow, max_new=6)
    np.testing.assert_array_equal(gold, out.tokens)
    assert eng.stats["cow_copies"] >= 1
    assert eng.stats["cached_tokens"] >= 2 * len(donor)
    eng._debug_check_refcounts()
    # Donor pages untouched: replaying the donor still matches its oracle.
    gold_d = _gold(gold_engine, [donor], 4)
    np.testing.assert_array_equal(gold_d, eng.generate([donor], max_new=4).tokens)


def test_budget_overshoot_cannot_corrupt_shared_prefix(model, gold_engine):
    """A spent slot decoding out its chunk must not clobber cached pages.

    prompt 61 + max_new 3 fills the page-table row exactly (max_len 64,
    page_size 8); decode_chunk 16 leaves 13 overshoot steps whose pos runs
    past max_len. Unmasked, the clamped page-table gather would redirect
    those KV writes into the request's LAST REAL page — corrupting prompt
    rows the prefix cache has already published, so a follow-up sharing the
    prefix would copy-on-write garbage."""
    cfg, params = model
    rng = np.random.RandomState(12)
    donor = rng.randint(0, cfg.vocab_size, size=61).tolist()

    def boundary_page(decode_chunk):
        eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                       prefill_chunk=8,
                                       decode_chunk=decode_chunk)
        gold_d = _gold(gold_engine, [donor], 3)
        np.testing.assert_array_equal(gold_d,
                                      eng.generate([donor], max_new=3).tokens)
        page = eng.prefix_cache.lookup(donor)[0][-1]    # partial-tail page
        return eng, np.asarray(eng.pool["k"])[:, :, page]

    # decode_chunk=1 cannot overshoot (budget 3, 3 chunks): its page bytes
    # are the uncorrupted reference for the 13-step-overshoot engine.
    _, ref_rows = boundary_page(1)
    eng, rows = boundary_page(16)
    np.testing.assert_array_equal(ref_rows, rows)

    follow = [donor + rng.randint(0, cfg.vocab_size, size=1).tolist()]
    gold_f = _gold(gold_engine, follow, 2)
    out = eng.generate(follow, max_new=2)
    assert eng.stats["cached_tokens"] >= len(donor)     # prefix was shared
    assert eng.stats["cow_copies"] >= 1                 # boundary page COW'd
    np.testing.assert_array_equal(gold_f, out.tokens)


def test_refcounts_track_rows_mid_flight(model):
    """The refcount invariant holds at every decode chunk, with sharing on."""
    cfg, params = model
    rng = np.random.RandomState(5)
    shared = rng.randint(0, cfg.vocab_size, size=8).tolist()
    prompts = [shared + rng.randint(0, cfg.vocab_size, size=n).tolist()
               for n in (2, 5, 9, 3, 7)]
    eng = ContinuousBatchingEngine(cfg, params, max_len=48, max_slots=2,
                                   prefill_chunk=8, decode_chunk=4)
    eng.generate(prompts, max_new=8,
                 on_chunk=lambda s, t: eng._debug_check_refcounts())
    eng._debug_check_refcounts()
    assert eng.alloc.available() == eng.num_pages - 1   # all pages returned


# ---------------------------------------------------------------------------
# Lifecycle: reuse, exhaustion, validation
# ---------------------------------------------------------------------------

def test_retire_then_readmit_reuses_pages(model, gold_engine):
    """Back-to-back generates recycle the same physical pool correctly."""
    cfg, params = model
    a = _prompts(cfg.vocab_size, [6, 11, 4], seed=6)
    b = _prompts(cfg.vocab_size, [9, 5, 13], seed=7)
    eng = ContinuousBatchingEngine(cfg, params, max_len=48, max_slots=3,
                                   prefill_chunk=8)
    for prompts in (a, b, a):                   # a's pages were reused by b
        gold = _gold(gold_engine, prompts, 6)
        np.testing.assert_array_equal(gold,
                                      eng.generate(prompts, max_new=6).tokens)
        eng._debug_check_refcounts()
        assert eng.alloc.available() == eng.num_pages - 1


def test_admission_when_pages_exhaust_mid_wave(model, gold_engine):
    """A pool that only fits one request at a time forces per-wave admission
    yet completes every request with oracle tokens."""
    cfg, params = model
    prompts = _prompts(cfg.vocab_size, [9, 12, 10], seed=8)
    gold = _gold(gold_engine, prompts, 6)
    # 3 pages: one request (ceil((12+6)/8)=3) exhausts the pool by itself.
    eng = ContinuousBatchingEngine(cfg, params, max_len=24, max_slots=3,
                                   num_pages=3, prefill_chunk=8,
                                   decode_chunk=2, enable_prefix_cache=False)
    out = eng.generate(prompts, max_new=6)
    np.testing.assert_array_equal(gold, out.tokens)
    assert eng.alloc.available() == eng.num_pages - 1


def test_pool_capacity_validated_up_front(model):
    """A request that can never fit fails fast, before reserving anything."""
    cfg, params = model
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                   num_pages=2)
    with pytest.raises(ValueError, match="pool only holds"):
        eng.generate([[1, 2, 3], list(range(20))], max_new=8)
    assert not eng._active.any()
    assert eng.alloc.available() == eng.num_pages - 1
    out = eng.generate(_prompts(cfg.vocab_size, [4], seed=9), max_new=4)
    assert out.tokens.shape == (1, 4)


# ---------------------------------------------------------------------------
# Compile-count guarantees
# ---------------------------------------------------------------------------

def test_decode_chunk_compiles_once(model):
    """Ragged tail lengths (max_new % decode_chunk) never retrace decode."""
    cfg, params = model
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                   decode_chunk=8, prefill_chunk=8)
    prompts = _prompts(cfg.vocab_size, [5, 9], seed=10)
    for max_new in (8, 11, 3, 13):              # tails 8, 3, 3, 5
        eng.generate(prompts, max_new=max_new)
    assert eng._n_decode_traces == 1


def test_prefill_chunk_compiles_per_bucket_not_per_length(model):
    """Prompt lengths share one jit signature per pow2 wave bucket."""
    cfg, params = model
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=4,
                                   prefill_chunk=8, enable_prefix_cache=False)
    for lens in ([3], [7], [12], [17], [23]):   # 5 lengths, bucket g=1
        eng.generate(_prompts(cfg.vocab_size, lens, seed=11), max_new=2)
    assert eng._n_prefill_traces == 1
    eng.generate(_prompts(cfg.vocab_size, [4, 9, 14], seed=12), max_new=2)
    assert eng._n_prefill_traces == 2           # one more for bucket g=4


# ---------------------------------------------------------------------------
# PageAllocator / PrefixCache units
# ---------------------------------------------------------------------------

def test_page_allocator_share_revives_free_page():
    al = PageAllocator(5)                       # pages 1..4
    p = al.alloc()
    al.release(p)
    assert al.available() == 4
    al.share(p)                                 # cache hit on a retired page
    assert al.available() == 3
    got = {al.alloc() for _ in range(3)}        # stale free-list entry skipped
    assert p not in got
    with pytest.raises(RuntimeError, match="exhausted"):
        al.alloc()
    al.release(p)
    assert al.alloc() == p


def test_prefix_cache_lookup_register_evict():
    pc = PrefixCache(4)
    prompt = list(range(10))                    # 2 full pages + 2-token tail
    pc.register(prompt, [7, 8, 9])
    chain, match = pc.lookup(prompt + [99])
    assert (chain, match) == ([7, 8, 9], 10)
    chain, match = pc.lookup(prompt[:6])        # only page 7 fully matches
    assert (chain, match) == ([7], 4)
    # Diverging second page: only the first page hits.
    other = prompt[:4] + [55, 56, 57, 58]
    assert pc.lookup(other) == ([7], 4)
    # Evicting the root page must take the whole chain (and partial) with it:
    # entries keyed under page 7 would re-anchor to its future contents.
    pc.evict(7)
    assert pc.lookup(prompt) == ([], 0)
    assert len(pc) == 0


def test_prefix_cache_existing_entries_win():
    pc = PrefixCache(4)
    pc.register(list(range(8)), [3, 4])
    pc.register(list(range(8)), [5, 6])         # same-wave private duplicate
    chain, _ = pc.lookup(list(range(8)))
    assert chain == [3, 4]
    pc.evict(5)                                 # duplicate pages never indexed
    assert pc.lookup(list(range(8)))[0] == [3, 4]
