"""Serve-engine lifecycle: paged chunked prefill vs the dense-prefill oracle,
copy-on-write prefix sharing, same-wave prefix dedup, refcount invariants,
page reuse across retire/readmit, eviction-on-realloc, exhaustion mid-wave,
up-front capacity validation, speculative decode token-identity, lossless
decode preemption (pause/resume with pinned pages and zero re-prefill), and
the one-compile guarantees for the decode/verify/prefill hot paths."""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced_config
from repro.models import get_family
from repro.models.params import init_params
from repro.serve import (ContinuousBatchingEngine, EngineRequest,
                         PageAllocator, PrefixCache, ServeEngine)


def _make(arch="yi-6b", **kw):
    cfg = get_reduced_config(arch).replace(dtype="float32", page_size=8, **kw)
    fam = get_family(cfg)
    params = init_params(fam.layout(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    return cfg, params


def _prompts(vocab, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, vocab, size=n).tolist() for n in lens]


@pytest.fixture(scope="module")
def model():
    return _make()


@pytest.fixture(scope="module")
def gold_engine(model):
    cfg, params = model
    return ServeEngine(cfg, params, max_len=64)


def _gold(gold_engine, prompts, max_new):
    """Per-request static-engine decode: the padding-free oracle."""
    return np.concatenate(
        [gold_engine.generate([p], max_new=max_new).tokens for p in prompts])


# ---------------------------------------------------------------------------
# Paged chunked prefill vs oracle
# ---------------------------------------------------------------------------

def test_paged_prefill_matches_oracle_mixed_lengths(model, gold_engine):
    """Chunked paged admission must emit the same tokens as the dense path,
    including prompts that straddle chunk and page boundaries."""
    cfg, params = model
    prompts = _prompts(cfg.vocab_size, [3, 7, 12, 5, 17, 8], seed=1)
    gold = _gold(gold_engine, prompts, 8)
    for chunk in (4, 8, 32):
        eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=6,
                                       prefill_chunk=chunk)
        out = eng.generate(prompts, max_new=8)
        np.testing.assert_array_equal(gold, out.tokens)


def test_paged_prefill_matches_dense_mode(model):
    """The in-engine dense baseline and the paged path agree token-for-token."""
    cfg, params = model
    prompts = _prompts(cfg.vocab_size, [4, 9, 14, 6], seed=2)
    dense = ContinuousBatchingEngine(cfg, params, max_len=48, max_slots=4,
                                     prefill_mode="dense")
    paged = ContinuousBatchingEngine(cfg, params, max_len=48, max_slots=4,
                                     prefill_chunk=8)
    np.testing.assert_array_equal(dense.generate(prompts, max_new=6).tokens,
                                  paged.generate(prompts, max_new=6).tokens)


# ---------------------------------------------------------------------------
# Prefix sharing + copy-on-write
# ---------------------------------------------------------------------------

def test_prefix_sharing_across_waves(model, gold_engine):
    """Requests admitted after a shared prefix is cached alias its pages and
    still decode the exact oracle tokens."""
    cfg, params = model
    rng = np.random.RandomState(3)
    shared = rng.randint(0, cfg.vocab_size, size=16).tolist()
    prompts = [shared + rng.randint(0, cfg.vocab_size, size=4).tolist()
               for _ in range(4)]
    gold = _gold(gold_engine, prompts, 6)
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                   prefill_chunk=8)
    out = eng.generate(prompts, max_new=6)      # 2 slots: 2 admission waves
    np.testing.assert_array_equal(gold, out.tokens)
    assert eng.stats["cached_tokens"] > 0       # later waves hit the prefix
    eng._debug_check_refcounts()

    # Warm-cache readmission: nearly all prompt tokens served from cache.
    out2 = eng.generate(prompts, max_new=6)
    np.testing.assert_array_equal(gold, out2.tokens)
    assert eng.prefix_hit_rate > 0.5
    eng._debug_check_refcounts()


def test_copy_on_write_boundary_page(model, gold_engine):
    """A prefix match ending mid-page copies the boundary page instead of
    appending into the (still referenced) donor page."""
    cfg, params = model
    rng = np.random.RandomState(4)
    donor = rng.randint(0, cfg.vocab_size, size=12).tolist()   # partial page
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                   prefill_chunk=8)
    eng.generate([donor], max_new=4)            # caches 1 full + 1 partial page
    follow = [donor + rng.randint(0, cfg.vocab_size, size=5).tolist()
              for _ in range(2)]
    gold = _gold(gold_engine, follow, 6)
    out = eng.generate(follow, max_new=6)
    np.testing.assert_array_equal(gold, out.tokens)
    assert eng.stats["cow_copies"] >= 1
    assert eng.stats["cached_tokens"] >= 2 * len(donor)
    eng._debug_check_refcounts()
    # Donor pages untouched: replaying the donor still matches its oracle.
    gold_d = _gold(gold_engine, [donor], 4)
    np.testing.assert_array_equal(gold_d, eng.generate([donor], max_new=4).tokens)


def test_budget_overshoot_cannot_corrupt_shared_prefix(model, gold_engine):
    """A spent slot decoding out its chunk must not clobber cached pages.

    prompt 61 + max_new 3 fills the page-table row exactly (max_len 64,
    page_size 8); decode_chunk 16 leaves 13 overshoot steps whose pos runs
    past max_len. Unmasked, the clamped page-table gather would redirect
    those KV writes into the request's LAST REAL page — corrupting prompt
    rows the prefix cache has already published, so a follow-up sharing the
    prefix would copy-on-write garbage."""
    cfg, params = model
    rng = np.random.RandomState(12)
    donor = rng.randint(0, cfg.vocab_size, size=61).tolist()

    def boundary_page(decode_chunk):
        eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                       prefill_chunk=8,
                                       decode_chunk=decode_chunk)
        gold_d = _gold(gold_engine, [donor], 3)
        np.testing.assert_array_equal(gold_d,
                                      eng.generate([donor], max_new=3).tokens)
        page = eng.prefix_cache.lookup(donor)[0][-1]    # partial-tail page
        return eng, np.asarray(eng.pool["k"])[:, :, page]

    # decode_chunk=1 cannot overshoot (budget 3, 3 chunks): its page bytes
    # are the uncorrupted reference for the 13-step-overshoot engine.
    _, ref_rows = boundary_page(1)
    eng, rows = boundary_page(16)
    np.testing.assert_array_equal(ref_rows, rows)

    follow = [donor + rng.randint(0, cfg.vocab_size, size=1).tolist()]
    gold_f = _gold(gold_engine, follow, 2)
    out = eng.generate(follow, max_new=2)
    assert eng.stats["cached_tokens"] >= len(donor)     # prefix was shared
    assert eng.stats["cow_copies"] >= 1                 # boundary page COW'd
    np.testing.assert_array_equal(gold_f, out.tokens)


def test_refcounts_track_rows_mid_flight(model):
    """The refcount invariant holds at every decode chunk, with sharing on."""
    cfg, params = model
    rng = np.random.RandomState(5)
    shared = rng.randint(0, cfg.vocab_size, size=8).tolist()
    prompts = [shared + rng.randint(0, cfg.vocab_size, size=n).tolist()
               for n in (2, 5, 9, 3, 7)]
    eng = ContinuousBatchingEngine(cfg, params, max_len=48, max_slots=2,
                                   prefill_chunk=8, decode_chunk=4)
    eng.generate(prompts, max_new=8,
                 on_chunk=lambda s, t: eng._debug_check_refcounts())
    eng._debug_check_refcounts()
    assert eng.alloc.available() == eng.num_pages - 1   # all pages returned


# ---------------------------------------------------------------------------
# Same-wave prefix dedup
# ---------------------------------------------------------------------------

def test_same_wave_identical_prompts_dedup(model, gold_engine):
    """Two identical prompts admitted in ONE wave: the second aliases the
    first's pages (grouped sequenced prefill) instead of prefilling
    privately, and both decode the exact oracle tokens."""
    cfg, params = model
    rng = np.random.RandomState(20)
    prompt = rng.randint(0, cfg.vocab_size, size=20).tolist()
    prompts = [prompt, prompt]
    gold = _gold(gold_engine, prompts, 6)
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                   prefill_chunk=8)
    out = eng.generate(prompts, max_new=6)      # ONE admission wave
    np.testing.assert_array_equal(gold, out.tokens)
    # match is capped at plen-1 = 19: 2 full pages aliased + boundary COW'd
    assert eng.stats["cached_tokens"] >= 16
    assert eng.stats["cow_copies"] >= 1
    eng._debug_check_refcounts()
    assert eng.alloc.available() == eng.num_pages - 1


def test_same_wave_dedup_chained_groups(model, gold_engine):
    """A aliases nothing, B aliases A's pages, C aliases pages B prefills:
    three dependency groups sequenced inside one admission wave."""
    cfg, params = model
    rng = np.random.RandomState(21)
    a = rng.randint(0, cfg.vocab_size, size=16).tolist()     # 2 full pages
    b = a + rng.randint(0, cfg.vocab_size, size=8).tolist()  # +1 full page
    c = b + rng.randint(0, cfg.vocab_size, size=5).tolist()
    prompts = [a, b, c]
    gold = _gold(gold_engine, prompts, 6)
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=3,
                                   prefill_chunk=8)
    out = eng.generate(prompts, max_new=6)      # ONE admission wave
    np.testing.assert_array_equal(gold, out.tokens)
    # B hits A's 2 pages (16 tokens, match capped at 15); C hits A's 2 pages
    # plus the page B's prefill fills (24 tokens, capped at 23).
    assert eng.stats["cached_tokens"] >= 15 + 23
    eng._debug_check_refcounts()


# ---------------------------------------------------------------------------
# Eviction on reallocation
# ---------------------------------------------------------------------------

def test_allocator_realloc_evicts_cache_entries():
    """The on_alloc hook scrubs a page's radix entries the moment the page
    is handed out again."""
    al = PageAllocator(4)                       # pages 1..3
    pc = PrefixCache(2)
    al.on_alloc = pc.evict
    p1, p2 = al.alloc(), al.alloc()
    pc.register([1, 2, 3, 4], [p1, p2])
    al.release(p1)
    al.release(p2)
    assert pc.lookup([1, 2, 3, 4])[1] == 4      # retired but still hittable
    got = {al.alloc(), al.alloc()}              # reallocation scrubs entries
    assert got == {p1, p2}
    assert pc.lookup([1, 2, 3, 4]) == ([], 0)


def test_realloc_rejects_stale_prefix_hit(model, gold_engine):
    """A radix hit on a retired page that has since been REALLOCATED must be
    rejected (not aliased): the readmitted donor prefills from scratch and
    still emits oracle tokens."""
    cfg, params = model
    rng = np.random.RandomState(22)
    donor = rng.randint(0, cfg.vocab_size, size=16).tolist()   # 2 full pages
    flush = rng.randint(0, cfg.vocab_size, size=24).tolist()
    # 4-page pool: donor needs 3 (16+8 tokens), flush needs all 4.
    eng = ContinuousBatchingEngine(cfg, params, max_len=32, max_slots=1,
                                   num_pages=4, prefill_chunk=8,
                                   decode_chunk=4)
    gold_d = _gold(gold_engine, [donor], 8)
    np.testing.assert_array_equal(gold_d,
                                  eng.generate([donor], max_new=8).tokens)
    assert eng.prefix_cache.lookup(donor)[1] == 16   # retired, still cached
    eng.generate([flush], max_new=8)            # reallocates every pool page
    assert eng.prefix_cache.lookup(donor) == ([], 0)
    out = eng.generate([donor], max_new=8)      # no stale alias: full prefill
    assert eng.stats["cached_tokens"] == 0
    np.testing.assert_array_equal(gold_d, out.tokens)
    eng._debug_check_refcounts()


def test_cow_boundary_refcounts_consistent(model, gold_engine):
    """Three followers COW the same boundary page in one wave: refcounts hold
    at every decode chunk, the pins drain, and tokens match the oracle."""
    cfg, params = model
    rng = np.random.RandomState(23)
    donor = rng.randint(0, cfg.vocab_size, size=12).tolist()   # partial page
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=3,
                                   prefill_chunk=8, decode_chunk=4)
    eng.generate([donor], max_new=4)            # caches 1 full + 1 partial
    followers = [donor + rng.randint(0, cfg.vocab_size, size=3).tolist()
                 for _ in range(3)]
    gold = _gold(gold_engine, followers, 6)
    out = eng.generate(followers, max_new=6,
                       on_chunk=lambda s, t: eng._debug_check_refcounts())
    np.testing.assert_array_equal(gold, out.tokens)
    assert eng.stats["cow_copies"] == 3         # each COWs its private copy
    eng._debug_check_refcounts()
    assert eng.alloc.available() == eng.num_pages - 1


# ---------------------------------------------------------------------------
# Speculative multi-token decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec_tokens", [1, 4])
def test_spec_decode_token_identical(model, gold_engine, spec_tokens):
    """Greedy speculative decode emits EXACTLY the non-speculative tokens,
    across ragged prompts, queued admission and page-boundary crossings."""
    cfg, params = model
    prompts = _prompts(cfg.vocab_size, [3, 7, 12, 5, 17], seed=24)
    gold = _gold(gold_engine, prompts, 12)
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                   prefill_chunk=8, decode_chunk=4,
                                   enable_spec_decode=True,
                                   spec_tokens=spec_tokens)
    out = eng.generate(prompts, max_new=12)     # 2 slots: queued waves
    np.testing.assert_array_equal(gold, out.tokens)
    assert eng.stats["spec_steps"] > 0
    assert eng.stats["spec_emitted"] >= eng.stats["spec_steps"]
    assert 0.0 <= eng.mean_accepted_len <= spec_tokens
    eng._debug_check_refcounts()


def test_spec_decode_with_prefix_sharing(model, gold_engine):
    """Spec decode composes with COW prefix sharing: a follower aliasing the
    donor's pages (incl. the partial page spec decode wrote into) still
    decodes oracle tokens — rejected draft tails never corrupt shared
    pages."""
    cfg, params = model
    rng = np.random.RandomState(25)
    donor = rng.randint(0, cfg.vocab_size, size=12).tolist()
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                   prefill_chunk=8, decode_chunk=4,
                                   enable_spec_decode=True, spec_tokens=4)
    gold_d = _gold(gold_engine, [donor], 6)
    np.testing.assert_array_equal(gold_d,
                                  eng.generate([donor], max_new=6).tokens)
    follow = [donor + rng.randint(0, cfg.vocab_size, size=2).tolist()
              for _ in range(2)]
    gold_f = _gold(gold_engine, follow, 8)
    out = eng.generate(follow, max_new=8)
    np.testing.assert_array_equal(gold_f, out.tokens)
    assert eng.stats["cached_tokens"] >= 2 * 8   # full prefix pages aliased
    eng._debug_check_refcounts()


def test_spec_decode_budget_overshoot_masked(model, gold_engine):
    """Draft windows running past a slot's token budget route their KV to
    the sink page: the boundary page a later request will COW keeps exactly
    the bytes a no-overshoot engine produces.

    prompt 61 + max_new 3 fills the page-table row exactly (max_len 64):
    every verify window past pos 63 would otherwise spill through the
    clamped page-table gather into the request's last real page."""
    cfg, params = model
    rng = np.random.RandomState(26)
    donor = rng.randint(0, cfg.vocab_size, size=61).tolist()
    gold_d = _gold(gold_engine, [donor], 3)

    ref_eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                       prefill_chunk=8, decode_chunk=1)
    np.testing.assert_array_equal(
        gold_d, ref_eng.generate([donor], max_new=3).tokens)
    page_ref = ref_eng.prefix_cache.lookup(donor)[0][-1]
    ref_rows = np.asarray(ref_eng.pool["k"])[:, :, page_ref]

    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                   prefill_chunk=8, decode_chunk=8,
                                   enable_spec_decode=True, spec_tokens=4)
    np.testing.assert_array_equal(gold_d,
                                  eng.generate([donor], max_new=3).tokens)
    page = eng.prefix_cache.lookup(donor)[0][-1]
    rows = np.asarray(eng.pool["k"])[:, :, page]
    # allclose, not array_equal: the verify step batches T positions through
    # one projection GEMM, which may round differently from the 1-token step.
    np.testing.assert_allclose(ref_rows, rows, rtol=1e-5, atol=1e-6)

    follow = [donor + rng.randint(0, cfg.vocab_size, size=1).tolist()]
    gold_f = _gold(gold_engine, follow, 2)
    out = eng.generate(follow, max_new=2)
    assert eng.stats["cached_tokens"] >= len(donor)     # prefix was shared
    assert eng.stats["cow_copies"] >= 1                 # boundary page COW'd
    np.testing.assert_array_equal(gold_f, out.tokens)


def test_spec_decode_chunk_compiles_once(model):
    """Data-dependent accept lengths never retrace the spec decode chunk:
    the fori_loop trip count stays static."""
    cfg, params = model
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                   decode_chunk=8, prefill_chunk=8,
                                   enable_spec_decode=True, spec_tokens=4)
    prompts = _prompts(cfg.vocab_size, [5, 9], seed=27)
    for max_new in (8, 11, 3, 13):              # ragged budgets + tails
        eng.generate(prompts, max_new=max_new)
    assert eng._n_decode_traces == 1


def test_decode_chunk_occupancy_heuristic(model):
    """decode_chunk=None picks chunk = clamp(tokens_target/slots): long
    chunks for narrow batches, short chunks at high occupancy."""
    cfg, params = model
    narrow = ContinuousBatchingEngine(cfg, params, max_len=32, max_slots=1)
    wide = ContinuousBatchingEngine(cfg, params, max_len=32, max_slots=32)
    assert narrow.decode_chunk == cfg.decode_chunk_max
    assert wide.decode_chunk == max(cfg.decode_chunk_min,
                                    cfg.decode_chunk_tokens // 32)
    assert wide.decode_chunk < narrow.decode_chunk


# ---------------------------------------------------------------------------
# Lifecycle: reuse, exhaustion, validation
# ---------------------------------------------------------------------------

def test_retire_then_readmit_reuses_pages(model, gold_engine):
    """Back-to-back generates recycle the same physical pool correctly."""
    cfg, params = model
    a = _prompts(cfg.vocab_size, [6, 11, 4], seed=6)
    b = _prompts(cfg.vocab_size, [9, 5, 13], seed=7)
    eng = ContinuousBatchingEngine(cfg, params, max_len=48, max_slots=3,
                                   prefill_chunk=8)
    for prompts in (a, b, a):                   # a's pages were reused by b
        gold = _gold(gold_engine, prompts, 6)
        np.testing.assert_array_equal(gold,
                                      eng.generate(prompts, max_new=6).tokens)
        eng._debug_check_refcounts()
        assert eng.alloc.available() == eng.num_pages - 1


def test_admission_when_pages_exhaust_mid_wave(model, gold_engine):
    """A pool that only fits one request at a time forces per-wave admission
    yet completes every request with oracle tokens."""
    cfg, params = model
    prompts = _prompts(cfg.vocab_size, [9, 12, 10], seed=8)
    gold = _gold(gold_engine, prompts, 6)
    # 3 pages: one request (ceil((12+6)/8)=3) exhausts the pool by itself.
    eng = ContinuousBatchingEngine(cfg, params, max_len=24, max_slots=3,
                                   num_pages=3, prefill_chunk=8,
                                   decode_chunk=2, enable_prefix_cache=False)
    out = eng.generate(prompts, max_new=6)
    np.testing.assert_array_equal(gold, out.tokens)
    assert eng.alloc.available() == eng.num_pages - 1


def test_pool_capacity_validated_up_front(model):
    """A request that can never fit fails fast, before reserving anything."""
    cfg, params = model
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                   num_pages=2)
    with pytest.raises(ValueError, match="pool only holds"):
        eng.generate([[1, 2, 3], list(range(20))], max_new=8)
    assert not eng._active.any()
    assert eng.alloc.available() == eng.num_pages - 1
    out = eng.generate(_prompts(cfg.vocab_size, [4], seed=9), max_new=4)
    assert out.tokens.shape == (1, 4)


# ---------------------------------------------------------------------------
# Compile-count guarantees
# ---------------------------------------------------------------------------

def test_decode_chunk_compiles_once(model):
    """Ragged tail lengths (max_new % decode_chunk) never retrace decode."""
    cfg, params = model
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                   decode_chunk=8, prefill_chunk=8)
    prompts = _prompts(cfg.vocab_size, [5, 9], seed=10)
    for max_new in (8, 11, 3, 13):              # tails 8, 3, 3, 5
        eng.generate(prompts, max_new=max_new)
    assert eng._n_decode_traces == 1


def test_prefill_chunk_compiles_per_bucket_not_per_length(model):
    """Prompt lengths share one jit signature per pow2 wave bucket."""
    cfg, params = model
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=4,
                                   prefill_chunk=8, enable_prefix_cache=False)
    for lens in ([3], [7], [12], [17], [23]):   # 5 lengths, bucket g=1
        eng.generate(_prompts(cfg.vocab_size, lens, seed=11), max_new=2)
    assert eng._n_prefill_traces == 1
    eng.generate(_prompts(cfg.vocab_size, [4, 9, 14], seed=12), max_new=2)
    assert eng._n_prefill_traces == 2           # one more for bucket g=4


# ---------------------------------------------------------------------------
# Namespaced prefix cache (tenant scoping) through the stepped API
# ---------------------------------------------------------------------------

def test_namespaced_requests_never_alias_across_namespaces(model):
    """Identical prompts under different namespaces admitted in ONE wave
    keep fully disjoint pages (no same-wave dedup across the boundary);
    the same namespace still dedups."""
    cfg, params = model
    rng = np.random.RandomState(30)
    prompt = rng.randint(0, cfg.vocab_size, size=16).tolist()
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=3,
                                   prefill_chunk=8, decode_chunk=2)
    for rid, ns in enumerate((("a", None), ("b", None), ("a", None))):
        eng.enqueue(EngineRequest(rid, list(prompt), 4, namespace=ns))
    eng.admit()
    assert eng.live == 3
    pages = {l.req.rid: set(l.pages) for l in eng._live.values()}
    assert not pages[0] & pages[1]          # cross-namespace: disjoint
    assert pages[0] & pages[2]              # same namespace: aliased
    # Only the same-namespace duplicate hit the cache.
    assert 0 < eng.stats["cached_tokens"] <= len(prompt)
    eng._debug_check_refcounts()
    while eng.has_work:
        eng.decode_step()
        eng.admit()
    eng._debug_check_refcounts()
    assert eng.alloc.available() == eng.num_pages - 1


def test_stepped_api_heterogeneous_budgets(model, gold_engine):
    """enqueue/admit/decode_step with per-request max_new matches the
    oracle for each request's own budget."""
    cfg, params = model
    prompts = _prompts(cfg.vocab_size, [5, 11, 8], seed=31)
    budgets = [3, 7, 5]
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                   prefill_chunk=8, decode_chunk=2)
    for rid, (p, m) in enumerate(zip(prompts, budgets)):
        eng.enqueue(EngineRequest(rid, p, m))
    done = {}
    eng.admit()
    while eng.has_work:
        for req, toks in eng.decode_step():
            done[req.rid] = toks
        eng.admit()
    for rid, (p, m) in enumerate(zip(prompts, budgets)):
        gold = gold_engine.generate([p], max_new=m).tokens[0]
        np.testing.assert_array_equal(gold, np.asarray(done[rid]))


def test_abort_returns_requests_and_releases_pages(model, gold_engine):
    """abort() mid-decode hands every live+queued request back and leaves
    the pool clean; re-running them from scratch matches the oracle."""
    cfg, params = model
    prompts = _prompts(cfg.vocab_size, [6, 9, 12], seed=32)
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                   prefill_chunk=8, decode_chunk=2)
    for rid, p in enumerate(prompts):
        eng.enqueue(EngineRequest(rid, p, 8))
    eng.admit()
    eng.decode_step()                       # mid-flight (2 live, 1 queued)
    dropped = eng.abort()
    assert sorted(r.rid for r in dropped) == [0, 1, 2]
    assert not eng.has_work
    assert eng.alloc.available() == eng.num_pages - 1
    eng._debug_check_refcounts()
    gold = _gold(gold_engine, prompts, 8)
    np.testing.assert_array_equal(gold,
                                  eng.generate(prompts, max_new=8).tokens)


# ---------------------------------------------------------------------------
# Trigram draft keys + construction-time validation
# ---------------------------------------------------------------------------

def test_trigram_spec_decode_token_identical(model, gold_engine):
    """spec_ngram=3 (with bigram fallback) emits exactly the greedy
    tokens, via the constructor arg and via the config field."""
    cfg, params = model
    prompts = _prompts(cfg.vocab_size, [3, 7, 12, 5], seed=33)
    gold = _gold(gold_engine, prompts, 10)
    by_arg = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                      prefill_chunk=8, decode_chunk=4,
                                      enable_spec_decode=True, spec_tokens=4,
                                      spec_ngram=3)
    np.testing.assert_array_equal(gold,
                                  by_arg.generate(prompts, max_new=10).tokens)
    assert by_arg.stats["spec_steps"] > 0
    cfg3 = cfg.replace(spec_ngram=3)
    params3 = params                        # same layout
    by_cfg = ContinuousBatchingEngine(cfg3, params3, max_len=64, max_slots=2,
                                      prefill_chunk=8, decode_chunk=4,
                                      enable_spec_decode=True, spec_tokens=4)
    assert by_cfg.spec_ngram == 3
    np.testing.assert_array_equal(gold,
                                  by_cfg.generate(prompts, max_new=10).tokens)


def test_engine_config_bounds_validated_at_construction(model):
    """Bad spec/slot configs fail at construction with named knobs, not as
    shape errors deep in the verify step / Pallas kernel."""
    cfg, params = model
    mk = lambda c=cfg, **kw: ContinuousBatchingEngine(c, params, max_len=64,
                                                      **kw)
    with pytest.raises(ValueError, match="spec_tokens >= 1"):
        mk(enable_spec_decode=True, spec_tokens=0)
    with pytest.raises(ValueError, match="spec_ngram"):
        mk(enable_spec_decode=True, spec_ngram=4)
    with pytest.raises(ValueError, match="max_slots"):
        mk(max_slots=0)
    with pytest.raises(ValueError, match="page-table window"):
        mk(enable_spec_decode=True, spec_tokens=64)
    # (K+1)*G = 5*2 = 10 rows: not an 8-sublane multiple for the TPU tile.
    with pytest.raises(ValueError, match="multiple of 8"):
        mk(cfg.replace(attn_impl="pallas"), enable_spec_decode=True,
           spec_tokens=4)
    # K=3 -> (K+1)*G = 8: tile fits, construction succeeds.
    mk(cfg.replace(attn_impl="pallas"), enable_spec_decode=True,
       spec_tokens=3)


# ---------------------------------------------------------------------------
# Decode preemption: lossless pause/resume with pinned pages
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [False, True])
def test_preempt_resume_token_identity_zero_reprefill(model, gold_engine,
                                                      spec):
    """A paused-then-resumed request emits EXACTLY the tokens of a
    never-paused run — with and without speculative decode — and resume
    re-prefills NOTHING (prefill_tokens is asserted flat across it)."""
    cfg, params = model
    prompts = _prompts(cfg.vocab_size, [5, 9, 13], seed=40)
    gold = _gold(gold_engine, prompts, 10)
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                   prefill_chunk=8, decode_chunk=2,
                                   enable_spec_decode=spec, spec_tokens=4)
    for rid, p in enumerate(prompts[:2]):
        eng.enqueue(EngineRequest(rid, list(p), 10))
    eng.admit()
    done = {}
    for req, toks in eng.decode_step():
        done[req.rid] = toks
    slot0 = next(s for s, l in eng._live.items() if l.req.rid == 0)
    paused = eng.preempt(slot0)
    assert 0 < paused.emitted < 10          # genuinely mid-stream
    assert eng.free_slots == 1 and eng.paused == 1
    eng._debug_check_refcounts()            # pinned pages are counted

    # The freed slot admits a new request while rid 0 stays parked.
    eng.enqueue(EngineRequest(2, list(prompts[2]), 10))
    eng.admit()
    assert eng.live == 2
    pf_mark = eng.stats["prefill_tokens"]
    resumed = False
    for _ in range(200):
        for req, toks in eng.decode_step():
            done[req.rid] = toks
        if not resumed and eng.free_slots > 0:
            eng.resume(paused)
            resumed = True
            # Zero re-prefill: resume re-attached pages via the page table.
            assert eng.stats["prefill_tokens"] == pf_mark
            assert eng.stats["resumed"] == 1
        eng._debug_check_refcounts()
        if len(done) == 3 and not eng.has_work:
            break
    assert resumed and len(done) == 3
    got = np.stack([np.asarray(done[i], np.int32) for i in range(3)])
    np.testing.assert_array_equal(gold, got)
    assert eng.alloc.available() == eng.num_pages - 1


def test_preempted_pages_pinned_under_eviction_pressure(model, gold_engine):
    """However hard admissions churn the pool while a request is paused,
    its pinned pages are never reallocated (refcounts >= 1 throughout) and
    its cached prefix entries survive while OTHER retired pages are
    evicted; the resumed request still emits oracle tokens."""
    cfg, params = model
    rng = np.random.RandomState(41)
    donor = rng.randint(0, cfg.vocab_size, size=10).tolist()
    gold_d = _gold(gold_engine, [donor], 6)
    # 8 usable pages: donor (10+6 tok) pins 2; each flusher (20/21+4 tok)
    # takes 3-4, so two flusher rounds must recycle every free page.
    eng = ContinuousBatchingEngine(cfg, params, max_len=32, max_slots=2,
                                   num_pages=8, prefill_chunk=8,
                                   decode_chunk=2)
    eng.enqueue(EngineRequest("donor", list(donor), 6))
    eng.admit()
    eng.decode_step()
    paused = eng.preempt(next(iter(eng._live)))
    pinned = list(paused.pages)
    assert all(eng.alloc.refs[p] == 1 for p in pinned)

    first_flush = None
    for i in range(3):                      # churn: realloc every free page
        flush = rng.randint(0, cfg.vocab_size, size=20 + i % 2).tolist()
        if first_flush is None:
            first_flush = flush
        eng.enqueue(EngineRequest(f"flush{i}", flush, 4))
        eng.admit()
        while eng.live:
            eng.decode_step()
            eng._debug_check_refcounts()
        assert all(eng.alloc.refs[p] >= 1 for p in pinned)  # still pinned
    # Eviction pressure was real: the first flusher's retired pages were
    # reallocated and its cache entries scrubbed ...
    assert eng.prefix_cache.lookup(first_flush)[1] == 0
    # ... while the paused donor's pinned pages stayed hittable.
    assert eng.prefix_cache.lookup(donor)[0] == pinned[:len(
        eng.prefix_cache.lookup(donor)[0])]

    eng.resume(paused)
    done = {}
    while eng.has_work:
        for req, toks in eng.decode_step():
            done[req.rid] = toks
        eng._debug_check_refcounts()
    np.testing.assert_array_equal(gold_d[0], np.asarray(done["donor"]))
    assert eng.alloc.available() == eng.num_pages - 1


def test_preempt_resume_errors_and_abort_releases_pins(model):
    """Bad preempt/resume calls fail typed; abort surrenders paused
    requests and releases their pinned pages."""
    cfg, params = model
    eng = ContinuousBatchingEngine(cfg, params, max_len=64, max_slots=2,
                                   prefill_chunk=8, decode_chunk=2)
    with pytest.raises(KeyError, match="no live request"):
        eng.preempt(0)
    prompts = _prompts(cfg.vocab_size, [6, 9], seed=42)
    for rid, p in enumerate(prompts):
        eng.enqueue(EngineRequest(rid, p, 8))
    eng.admit()
    eng.decode_step()
    paused = eng.preempt(0)
    eng.resume(paused)
    with pytest.raises(KeyError, match="not paused"):
        eng.resume(paused)                  # double-resume guard
    # Re-preempt and fill every slot: resume must refuse, not clobber.
    paused = eng.preempt(next(iter(eng._live)))
    eng.enqueue(EngineRequest(2, prompts[0], 8))
    eng.enqueue(EngineRequest(3, prompts[1], 8))
    eng.admit()
    assert eng.free_slots == 0
    with pytest.raises(RuntimeError, match="no free slot"):
        eng.resume(paused)
    dropped = eng.abort()                   # paused req included, pins freed
    assert any(r.rid == paused.req.rid for r in dropped)
    assert not eng.has_work and eng.paused == 0
    assert eng.alloc.available() == eng.num_pages - 1
    eng._debug_check_refcounts()


# ---------------------------------------------------------------------------
# PageAllocator / PrefixCache units
# ---------------------------------------------------------------------------

def test_page_allocator_share_revives_free_page():
    al = PageAllocator(5)                       # pages 1..4
    p = al.alloc()
    al.release(p)
    assert al.available() == 4
    al.share(p)                                 # cache hit on a retired page
    assert al.available() == 3
    got = {al.alloc() for _ in range(3)}        # stale free-list entry skipped
    assert p not in got
    with pytest.raises(RuntimeError, match="exhausted"):
        al.alloc()
    al.release(p)
    assert al.alloc() == p


def test_prefix_cache_namespaces_isolated():
    """Entries registered under one namespace are invisible to lookups from
    another; eviction under one namespace leaves the other intact."""
    pc = PrefixCache(4)
    prompt = list(range(8))
    pc.register(prompt, [3, 4], namespace="tenant-a")
    pc.register(prompt, [5, 6], namespace="tenant-b")
    assert pc.lookup(prompt, namespace="tenant-a") == ([3, 4], 8)
    assert pc.lookup(prompt, namespace="tenant-b") == ([5, 6], 8)
    assert pc.lookup(prompt) == ([], 0)         # default namespace: no hit
    pc.evict(3)                                 # scrubs only tenant-a's chain
    assert pc.lookup(prompt, namespace="tenant-a") == ([], 0)
    assert pc.lookup(prompt, namespace="tenant-b") == ([5, 6], 8)
    # Namespace roots are never scrubbed, so eviction must unlink the key
    # from the root's child list too (else it leaks one entry per evict).
    assert pc._root("tenant-a") not in pc._kids


def test_prefix_cache_lookup_register_evict():
    pc = PrefixCache(4)
    prompt = list(range(10))                    # 2 full pages + 2-token tail
    pc.register(prompt, [7, 8, 9])
    chain, match = pc.lookup(prompt + [99])
    assert (chain, match) == ([7, 8, 9], 10)
    chain, match = pc.lookup(prompt[:6])        # only page 7 fully matches
    assert (chain, match) == ([7], 4)
    # Diverging second page: only the first page hits.
    other = prompt[:4] + [55, 56, 57, 58]
    assert pc.lookup(other) == ([7], 4)
    # Evicting the root page must take the whole chain (and partial) with it:
    # entries keyed under page 7 would re-anchor to its future contents.
    pc.evict(7)
    assert pc.lookup(prompt) == ([], 0)
    assert len(pc) == 0


def test_prefix_cache_existing_entries_win():
    pc = PrefixCache(4)
    pc.register(list(range(8)), [3, 4])
    pc.register(list(range(8)), [5, 6])         # same-wave private duplicate
    chain, _ = pc.lookup(list(range(8)))
    assert chain == [3, 4]
    pc.evict(5)                                 # duplicate pages never indexed
    assert pc.lookup(list(range(8)))[0] == [3, 4]


def test_prefix_cache_lookup_at_exact_page_boundaries():
    """Longest-prefix lookup lands exactly on page edges: a prompt that is
    a whole number of pages matches fully with no partial entry, a query
    one token past the boundary gains nothing, and one token short drops a
    whole page (full pages only — no sub-page credit without a partial)."""
    pc = PrefixCache(4)
    prompt = list(range(12))                    # exactly 3 pages
    pc.register(prompt, [5, 6, 7])
    assert pc.lookup(prompt) == ([5, 6, 7], 12)
    assert len(pc) == 3                         # no partial entry created
    # One past the boundary: the extra token is uncached, match stays 12.
    assert pc.lookup(prompt + [99]) == ([5, 6, 7], 12)
    # One short of the boundary: page 3 can't fully match, and with no
    # partial registered the 3 matching tokens earn nothing.
    assert pc.lookup(prompt[:11]) == ([5, 6], 8)
    assert pc.lookup(prompt[:8]) == ([5, 6], 8)
    assert pc.lookup(prompt[:4]) == ([5], 4)
    assert pc.lookup(prompt[:3]) == ([], 0)
    # A partial tail registers only when its page exists: now an 11-token
    # register adds a partial under page 6, and the boundary query walks
    # full pages first, then the partial (copy-on-write source).
    pc.register(prompt[:11], [5, 6, 8])
    chain, match = pc.lookup(prompt[:11])
    assert (chain, match) == ([5, 6, 8], 11)
    assert pc.lookup(prompt) == ([5, 6, 7], 12)  # full chain still preferred


def test_prefix_cache_eviction_on_realloc_under_namespace_churn():
    """The allocator's on_alloc hook scrubs cache entries the moment their
    page is handed out again — churning registrations across namespaces
    never lets a stale entry alias a reused page's new contents."""
    al = PageAllocator(5)                       # pages 1..4
    pc = PrefixCache(4)
    al.on_alloc = pc.evict
    prompt = list(range(8))
    pa = [al.alloc(), al.alloc()]
    pc.register(prompt, pa, namespace="tenant-a")
    pb = [al.alloc(), al.alloc()]
    pc.register(prompt, pb, namespace="tenant-b")
    for p in reversed(pa):                      # tenant-a's request retires
        al.release(p)
    # Still hittable while free (revivable), until someone takes the pages.
    assert pc.lookup(prompt, namespace="tenant-a") == (pa, 8)
    pc2 = al.alloc()                            # tenant-a's root reused...
    assert pc2 == pa[0]                         # (LIFO free list)
    assert pc.lookup(prompt, namespace="tenant-a") == ([], 0)   # whole chain
    assert pc.lookup(prompt, namespace="tenant-b") == (pb, 8)   # b untouched
    # Churn: register the reused pages under a THIRD namespace (the evicted
    # subtree left them clean), release and realloc again — only the latest
    # owner's entry ever resolves.
    pc.register(prompt, [pc2, pa[1]], namespace="tenant-c")
    assert pc.lookup(prompt, namespace="tenant-c") == ([pc2, pa[1]], 8)
    al.release(pc2)
    assert al.alloc() == pc2
    assert pc.lookup(prompt, namespace="tenant-c") == ([], 0)
    assert pc.lookup(prompt, namespace="tenant-b") == (pb, 8)
