from .registry import count_params, get_family

__all__ = ["count_params", "get_family"]
