"""Model assembly for the assigned architecture families.

``TransformerFamily`` covers dense, MoE, VLM (stubbed patch frontend) and
audio (stubbed frame frontend, encoder-only) variants. ``XLSTMFamily``
alternates mLSTM/sLSTM blocks; ``ZambaFamily`` is the Mamba2 backbone with a
*shared* attention+FFN block applied at a fixed cadence.

All families expose the same surface:

    layout(cfg)                       -> ParamSpec tree (stacked for scan)
    train_loss(cfg, params, batch)    -> (loss, metrics)
    prefill(cfg, params, batch)       -> (logits, cache)
    decode(cfg, params, batch, cache) -> (logits, new_cache)
    cache_layout(cfg, batch, len)     -> abstract cache tree (for the dry-run)

Homogeneous layer stacks run under ``lax.scan`` with configurable remat, so
HLO size is depth-independent (Arctic-480B compiles in seconds).
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import shard
from . import layers as L
from .moe import moe_block, moe_param_specs
from .params import ParamSpec, stack_specs
from .ssm import mamba_block, mamba_cache_shapes, mamba_param_specs
from .xlstm import (mlstm_block, mlstm_cache_shapes, mlstm_param_specs,
                    slstm_block, slstm_cache_shapes, slstm_param_specs)

ZERO_AUX = {"moe_lb_loss": 0.0, "moe_z_loss": 0.0, "moe_drop_frac": 0.0}


def _remat(cfg, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def _abstract(entries):
    """(shape, dtype, axes) tree -> ShapeDtypeStruct tree (axes tree aside)."""
    structs = jax.tree.map(
        lambda e: jax.ShapeDtypeStruct(e[0], jnp.dtype(e[1])), entries,
        is_leaf=lambda e: isinstance(e, tuple) and isinstance(e[0], tuple))
    axes = jax.tree.map(lambda e: e[2], entries,
                        is_leaf=lambda e: isinstance(e, tuple) and isinstance(e[0], tuple))
    return structs, axes


# ===========================================================================
# Transformer (dense / moe / vlm / audio)
# ===========================================================================

class TransformerFamily:
    name = "transformer"

    # -- params ---------------------------------------------------------------
    def layer_specs(self, cfg) -> dict:
        specs = {"attn": L.attention_param_specs(cfg)}
        if cfg.num_experts:
            specs["ffn"] = moe_param_specs(cfg)
        else:
            specs["ffn"] = L.mlp_param_specs(cfg)
        return specs

    def layout(self, cfg) -> dict:
        layout = {
            **L.embed_param_specs(cfg),
            "layers": stack_specs(self.layer_specs(cfg), cfg.num_layers),
            "final_norm": L.norm_spec(cfg.d_model),
        }
        if cfg.frontend:
            layout["frontend_proj"] = ParamSpec(
                (cfg.frontend_dim, cfg.d_model), ("frontend", "embed"))
        return layout

    # -- embedding / frontend ---------------------------------------------------
    def _embed(self, cfg, params, batch):
        """Returns (x, positions, text_offset)."""
        offset = 0
        if cfg.frontend == "frame":
            x = jnp.einsum("bsf,fd->bsd",
                           batch["frames"].astype(cfg.cdtype),
                           params["frontend_proj"].astype(cfg.cdtype))
        else:
            x = L.embed_tokens(cfg, params, batch["tokens"])
            if cfg.frontend == "patch" and "patches" in batch:
                px = jnp.einsum("bpf,fd->bpd",
                                batch["patches"].astype(cfg.cdtype),
                                params["frontend_proj"].astype(cfg.cdtype))
                x = jnp.concatenate([px, x], axis=1)
                offset = px.shape[1]
        x = shard(x, ("batch", None, None))
        positions = jnp.arange(x.shape[1])
        return x, positions, offset

    # -- full forward (train / prefill) -------------------------------------------
    def _stack_forward(self, cfg, params, x, positions, want_cache: bool):
        moe = bool(cfg.num_experts)

        def body(carry, layer_params):
            h = carry
            h, kv = L.attention_block(cfg, layer_params["attn"], h, positions)
            if moe:
                h, aux = moe_block(cfg, layer_params["ffn"], h)
            else:
                h = L.mlp_block(cfg, layer_params["ffn"], h)
                aux = dict(ZERO_AUX)
            h = shard(h, ("batch", None, None))
            out = (kv, aux) if want_cache else (None, aux)
            return h, out

        x, (kv, aux) = lax.scan(_remat(cfg, body), x, params["layers"])
        aux = {k: jnp.mean(jnp.asarray(v)) for k, v in aux.items()}
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return x, kv, aux

    # -- losses ---------------------------------------------------------------------
    def train_loss(self, cfg, params, batch):
        x, _, aux = self._stack_forward(
            cfg, params, *self._embed(cfg, params, batch)[:2], want_cache=False)
        offset = (cfg.frontend_len if cfg.frontend == "patch" else 0)
        if offset:
            x = x[:, offset:]
        labels = batch["labels"]
        if cfg.logit_chunk:
            loss = L.chunked_xent(cfg, params, x, labels, cfg.logit_chunk)
        else:
            logits = L.logits_fn(cfg, params, x)
            if "loss_mask" in batch:
                m = batch["loss_mask"].astype(jnp.float32)
                lg = logits.astype(jnp.float32)
                lse = jax.nn.logsumexp(lg, axis=-1)
                ll = jnp.take_along_axis(lg, labels[..., None], -1)[..., 0]
                loss = jnp.sum((lse - ll) * m) / jnp.maximum(m.sum(), 1.0)
            else:
                loss = L.softmax_xent(logits, labels)
        total = (loss
                 + cfg.load_balance_loss * aux["moe_lb_loss"]
                 + cfg.router_z_loss * aux["moe_z_loss"])
        metrics = {"loss": loss, **aux}
        return total, metrics

    # -- prefill ----------------------------------------------------------------------
    def prefill(self, cfg, params, batch):
        x, positions, _ = self._embed(cfg, params, batch)
        x, kv, _ = self._stack_forward(cfg, params, x, positions,
                                       want_cache=not cfg.encoder_only)
        if cfg.encoder_only:
            return L.logits_fn(cfg, params, x), {}
        logits = L.logits_fn(cfg, params, x[:, -1:])[:, 0]
        k, v = kv                                   # stacked (L,B,S,KV,hd)
        cache = {"k": shard(k, ("layers", "batch", "cache_seq", "kv_heads", None)),
                 "v": shard(v, ("layers", "batch", "cache_seq", "kv_heads", None))}
        return logits, cache

    # -- decode -----------------------------------------------------------------------
    def decode(self, cfg, params, batch, cache):
        tokens, pos = batch["tokens"], batch["pos"]      # (B,1), (B,)
        x = L.embed_tokens(cfg, params, tokens)

        def body(carry, xs):
            h = carry
            layer_params, kc, vc = xs
            h, (kc, vc) = L.attention_block(cfg, layer_params["attn"], h,
                                            pos[:, None], cache=(kc, vc),
                                            decode_pos=pos)
            if cfg.num_experts:
                h, _ = moe_block(cfg, layer_params["ffn"], h)
            else:
                h = L.mlp_block(cfg, layer_params["ffn"], h)
            return h, (kc, vc)

        x, (k, v) = lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = L.logits_fn(cfg, params, x)[:, 0]
        return logits, {"k": k, "v": v}

    # -- ragged prefill (continuous-batching admission) -----------------------------
    def prefill_ragged(self, cfg, params, batch):
        """Prefill right-padded prompts; logits taken at ``length - 1``.

        Right padding keeps cache row i at position i (what the page scatter
        needs); causal masking makes rows < length independent of the pad, so
        one compile serves every prompt length in a pad bucket.
        """
        x, positions, _ = self._embed(cfg, params, batch)
        x, kv, _ = self._stack_forward(cfg, params, x, positions,
                                       want_cache=True)
        idx = batch["length"].astype(jnp.int32) - 1                 # (B,)
        last = jnp.take_along_axis(x, idx[:, None, None], axis=1)   # (B,1,d)
        logits = L.logits_fn(cfg, params, last)[:, 0]
        k, v = kv
        return logits, {"k": k, "v": v}

    # -- paged chunked prefill (continuous-batching admission) -----------------------
    def prefill_paged(self, cfg, params, batch, pool):
        """One chunked-prefill step: C prompt tokens written straight into
        pool pages, attended against the already-written context.

        batch: tokens (B,C), q_start (B,) global position of tokens[:,0],
        kv_len (B,) true prompt length (positions >= kv_len are pad and write
        to the sink page), page_table (B,npages) int32, logit_idx (B,)
        in-chunk index to read logits at (the engine points it at
        ``prompt_len-1`` for the chunk that contains it; clamped otherwise).
        pool: {"k": (L,KV,P,ps,hd), "v": ...} — the whole physical pool; an
        int8 pool adds (L,KV,P,ps) f32 "k_scale"/"v_scale" per-row scale
        pages and the chunk's KV rows are quantized on scatter.

        Unlike ``prefill_ragged`` there is no dense per-request cache to
        re-layout afterwards: KV lands in its final pages chunk by chunk, so
        admission cost is O(chunk) per step and O(new tokens) per request.
        """
        tokens, q_start = batch["tokens"], batch["q_start"]
        kv_len, page_table = batch["kv_len"], batch["page_table"]
        x = L.embed_tokens(cfg, params, tokens)

        def body(carry, xs):
            h = carry
            layer_params, pool_sl = xs
            h, pool_sl = L.paged_prefill_attention_block(
                cfg, layer_params["attn"], h, pool=pool_sl,
                page_table=page_table, q_start=q_start, kv_len=kv_len)
            if cfg.num_experts:
                h, _ = moe_block(cfg, layer_params["ffn"], h)
            else:
                h = L.mlp_block(cfg, layer_params["ffn"], h)
            return h, pool_sl

        x, pool = lax.scan(body, x, (params["layers"], pool))
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        idx = jnp.clip(batch["logit_idx"].astype(jnp.int32), 0,
                       x.shape[1] - 1)
        last = jnp.take_along_axis(x, idx[:, None, None], axis=1)   # (B,1,d)
        logits = L.logits_fn(cfg, params, last)[:, 0]
        return logits, pool

    # -- paged decode (continuous-batching serve path) -------------------------------
    def decode_paged(self, cfg, params, batch, pool):
        """One decode step over the shared paged KV pool.

        batch: tokens (B,1), pos (B,), page_table (B,npages) int32.
        pool: {"k": (L,KV,P,ps,hd), "v": ...} — the *whole* physical pool; a
        request touches only the pages its table row names, so finished
        sequences free pages without any cache compaction or copies. An int8
        pool adds (L,KV,P,ps) f32 "k_scale"/"v_scale" per-row scale pages
        (see ``paged_pool``) and new rows are quantized on scatter.
        """
        tokens, pos = batch["tokens"], batch["pos"]
        page_table = batch["page_table"]
        x = L.embed_tokens(cfg, params, tokens)

        def body(carry, xs):
            h = carry
            layer_params, pool_sl = xs
            h, pool_sl = L.paged_attention_block(
                cfg, layer_params["attn"], h, pool=pool_sl,
                page_table=page_table, pos=pos)
            if cfg.num_experts:
                h, _ = moe_block(cfg, layer_params["ffn"], h)
            else:
                h = L.mlp_block(cfg, layer_params["ffn"], h)
            return h, pool_sl

        x, pool = lax.scan(body, x, (params["layers"], pool))
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = L.logits_fn(cfg, params, x)[:, 0]
        return logits, pool

    # -- paged speculative verify (multi-token decode) -------------------------------
    def decode_verify(self, cfg, params, batch, pool):
        """Score a T-token draft window per slot in one pass (spec decode).

        batch: tokens (B,T) — the verified current token followed by T-1
        drafts; pos (B,) global position of tokens[:,0]; page_table
        (B,npages) int32; write_limit (B,) — KV writes at positions >=
        write_limit are routed to the sink page (budget overshoot / idle
        slots). Returns logits over ALL T positions, (B,T,V): logits[:,i]
        conditions on the window prefix tokens[:, :i+1] plus the verified
        history, which is exactly what acceptance needs. T=1 is the plain
        decode step.
        """
        tokens, pos = batch["tokens"], batch["pos"]
        page_table = batch["page_table"]
        write_limit = batch["write_limit"]
        x = L.embed_tokens(cfg, params, tokens)

        def body(carry, xs):
            h = carry
            layer_params, pool_sl = xs
            h, pool_sl = L.paged_verify_attention_block(
                cfg, layer_params["attn"], h, pool=pool_sl,
                page_table=page_table, pos=pos, write_limit=write_limit)
            if cfg.num_experts:
                h, _ = moe_block(cfg, layer_params["ffn"], h)
            else:
                h = L.mlp_block(cfg, layer_params["ffn"], h)
            return h, pool_sl

        x, pool = lax.scan(body, x, (params["layers"], pool))
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = L.logits_fn(cfg, params, x)
        return logits, pool

    def paged_pool_shape(self, cfg, num_pages: int):
        """Physical pool array shape for ``num_pages`` shared cache pages."""
        return (cfg.num_layers, cfg.num_kv_heads, num_pages, cfg.page_size,
                cfg.head_dim)

    def paged_pool(self, cfg, num_pages: int, kv_cache_dtype: str | None = None):
        """Allocate the shared paged KV pool dict.

        ``kv_cache_dtype`` (default ``cfg.kv_cache_dtype``) selects the
        layout: ``"f32"`` stores K/V rows in ``cfg.dtype``; ``"int8"`` stores
        them as int8 with per-row f32 scale pages ``k_scale``/``v_scale`` of
        shape (L,KV,P,ps) — roughly ``4*hd/(hd+4)``x the slot-token capacity
        at a fixed HBM budget (see kernels/kv_quant). All three paged model
        paths detect the layout structurally (``"k_scale" in pool``).
        """
        shape = self.paged_pool_shape(cfg, num_pages)
        dtype = kv_cache_dtype or getattr(cfg, "kv_cache_dtype", "f32")
        if dtype == "int8":
            pool = {"k": jnp.zeros(shape, jnp.int8),
                    "v": jnp.zeros(shape, jnp.int8),
                    "k_scale": jnp.zeros(shape[:-1], jnp.float32),
                    "v_scale": jnp.zeros(shape[:-1], jnp.float32)}
        elif dtype == "f32":
            pool = {"k": jnp.zeros(shape, cfg.cdtype),
                    "v": jnp.zeros(shape, cfg.cdtype)}
        else:
            raise ValueError(f"unknown kv_cache_dtype {dtype!r}")
        return pool

    # -- abstract cache (dry-run input specs) ----------------------------------------
    def cache_layout(self, cfg, batch: int, cache_len: int):
        shape = (cfg.num_layers, batch, cache_len, cfg.num_kv_heads, cfg.head_dim)
        axes = ("layers", "batch", "cache_seq", "kv_heads", None)
        entry = (shape, cfg.dtype, axes)
        return _abstract({"k": entry, "v": entry})


# ===========================================================================
# xLSTM (alternating mLSTM / sLSTM pairs)
# ===========================================================================

class XLSTMFamily:
    name = "xlstm"

    def n_pairs(self, cfg) -> int:
        return cfg.num_layers // 2

    def layout(self, cfg) -> dict:
        n = self.n_pairs(cfg)
        return {
            **L.embed_param_specs(cfg),
            "pairs": {
                "m": stack_specs(mlstm_param_specs(cfg), n),
                "s": stack_specs(slstm_param_specs(cfg), n),
            },
            "final_norm": L.norm_spec(cfg.d_model),
        }

    def _forward(self, cfg, params, x, caches=None):
        def body(carry, xs):
            h = carry
            pair, mc, sc = xs
            h, mc = mlstm_block(cfg, pair["m"], h, cache=mc)
            h, sc = slstm_block(cfg, pair["s"], h, cache=sc)
            h = shard(h, ("batch", None, None))
            return h, (mc, sc)

        n = self.n_pairs(cfg)
        if caches is None:
            mc = sc = None
            xs = (params["pairs"], [None] * n, [None] * n)
            # scan cannot carry None xs; run without cache via dummy flag
            def body_nc(carry, pair):
                h = carry
                h, mc = mlstm_block(cfg, pair["m"], h)
                h, sc = slstm_block(cfg, pair["s"], h)
                h = shard(h, ("batch", None, None))
                return h, (mc, sc)
            x, (mcs, scs) = lax.scan(_remat(cfg, body_nc), x, params["pairs"])
        else:
            x, (mcs, scs) = lax.scan(body, x,
                                     (params["pairs"], caches["m"], caches["s"]))
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return x, {"m": mcs, "s": scs}

    def train_loss(self, cfg, params, batch):
        x = L.embed_tokens(cfg, params, batch["tokens"])
        x, _ = self._forward(cfg, params, x)
        logits = L.logits_fn(cfg, params, x)
        loss = L.softmax_xent(logits, batch["labels"])
        return loss, {"loss": loss}

    def prefill(self, cfg, params, batch):
        x = L.embed_tokens(cfg, params, batch["tokens"])
        x, caches = self._forward(cfg, params, x)
        logits = L.logits_fn(cfg, params, x[:, -1:])[:, 0]
        return logits, caches

    def decode(self, cfg, params, batch, cache):
        x = L.embed_tokens(cfg, params, batch["tokens"])
        x, caches = self._forward(cfg, params, x, caches=cache)
        logits = L.logits_fn(cfg, params, x)[:, 0]
        return logits, caches

    def cache_layout(self, cfg, batch: int, cache_len: int):
        n = self.n_pairs(cfg)
        def stk(entries):
            return {k: ((n,) + s, d, ("layers",) + a) for k, (s, d, a) in entries.items()}
        return _abstract({"m": stk(mlstm_cache_shapes(cfg, batch)),
                          "s": stk(slstm_cache_shapes(cfg, batch))})


# ===========================================================================
# Zamba2 hybrid (Mamba2 backbone + shared attention block)
# ===========================================================================

class ZambaFamily:
    name = "zamba"

    def group_sizes(self, cfg) -> list[int]:
        k = cfg.shared_attn_every
        n = cfg.num_layers
        sizes = [k] * (n // k)
        if n % k:
            sizes.append(n % k)
        return sizes

    def n_shared_applications(self, cfg) -> int:
        return cfg.num_layers // cfg.shared_attn_every

    def layout(self, cfg) -> dict:
        return {
            **L.embed_param_specs(cfg),
            "mamba": stack_specs(mamba_param_specs(cfg), cfg.num_layers),
            "shared": {"attn": L.attention_param_specs(cfg),
                       "ffn": L.mlp_param_specs(cfg)},
            "final_norm": L.norm_spec(cfg.d_model),
        }

    def _forward(self, cfg, params, x, positions, caches=None,
                 decode_pos=None, want_cache=False):
        sizes = self.group_sizes(cfg)
        n_apps = self.n_shared_applications(cfg)

        def mamba_body(carry, xs):
            h = carry
            if caches is None:
                lp = xs
                h, c = mamba_block(cfg, lp, h)
            else:
                lp, c_in = xs
                h, c = mamba_block(cfg, lp, h, cache=c_in)
            h = shard(h, ("batch", None, None))
            return h, c

        new_mamba, new_kv = [], []
        start = 0
        app = 0
        for gi, size in enumerate(sizes):
            sl = jax.tree.map(lambda a: a[start:start + size], params["mamba"])
            if caches is None:
                x, mc = lax.scan(_remat(cfg, mamba_body), x, sl)
            else:
                csl = jax.tree.map(lambda a: a[start:start + size],
                                   caches["mamba"])
                x, mc = lax.scan(mamba_body, x, (sl, csl))
            new_mamba.append(mc)
            start += size
            if (gi + 1) * cfg.shared_attn_every <= cfg.num_layers and app < n_apps:
                if caches is None:
                    x, kv = L.attention_block(cfg, params["shared"]["attn"], x,
                                              positions)
                else:
                    kv_in = (caches["attn_k"][app], caches["attn_v"][app])
                    x, kv = L.attention_block(cfg, params["shared"]["attn"], x,
                                              positions, cache=kv_in,
                                              decode_pos=decode_pos)
                x = L.mlp_block(cfg, params["shared"]["ffn"], x)
                new_kv.append(kv)
                app += 1

        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        mamba_cache = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_mamba)
        cache = {"mamba": mamba_cache}
        if new_kv:
            cache["attn_k"] = jnp.stack([k for k, _ in new_kv])
            cache["attn_v"] = jnp.stack([v for _, v in new_kv])
        return x, cache

    def train_loss(self, cfg, params, batch):
        x = L.embed_tokens(cfg, params, batch["tokens"])
        positions = jnp.arange(x.shape[1])
        x, _ = self._forward(cfg, params, x, positions)
        logits = L.logits_fn(cfg, params, x)
        loss = L.softmax_xent(logits, batch["labels"])
        return loss, {"loss": loss}

    def prefill(self, cfg, params, batch):
        x = L.embed_tokens(cfg, params, batch["tokens"])
        positions = jnp.arange(x.shape[1])
        x, cache = self._forward(cfg, params, x, positions)
        logits = L.logits_fn(cfg, params, x[:, -1:])[:, 0]
        return logits, cache

    def decode(self, cfg, params, batch, cache):
        x = L.embed_tokens(cfg, params, batch["tokens"])
        pos = batch["pos"]
        x, cache = self._forward(cfg, params, x, pos[:, None], caches=cache,
                                 decode_pos=pos)
        logits = L.logits_fn(cfg, params, x)[:, 0]
        return logits, cache

    def cache_layout(self, cfg, batch: int, cache_len: int):
        n_apps = self.n_shared_applications(cfg)
        entries = {"mamba": {
            k: ((cfg.num_layers,) + s, d, ("layers",) + a)
            for k, (s, d, a) in mamba_cache_shapes(cfg, batch).items()}}
        kv_shape = (n_apps, batch, cache_len, cfg.num_kv_heads, cfg.head_dim)
        kv_axes = ("layers", "batch", "cache_seq", "kv_heads", None)
        entries["attn_k"] = (kv_shape, cfg.dtype, kv_axes)
        entries["attn_v"] = (kv_shape, cfg.dtype, kv_axes)
        return _abstract(entries)
