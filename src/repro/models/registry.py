"""Model-family registry + parameter accounting."""
from __future__ import annotations

import numpy as np

from .params import ParamSpec, count_params_in_layout, tree_map_specs
from .transformer import TransformerFamily, XLSTMFamily, ZambaFamily

_TRANSFORMER = TransformerFamily()
_XLSTM = XLSTMFamily()
_ZAMBA = ZambaFamily()


def get_family(cfg):
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        return _TRANSFORMER
    if cfg.family == "ssm" and cfg.ssm_variant == "xlstm":
        return _XLSTM
    if cfg.family in ("hybrid",) or cfg.ssm_variant == "mamba2":
        return _ZAMBA
    raise ValueError(f"no family for {cfg.name} ({cfg.family}/{cfg.ssm_variant})")


def count_params(cfg, active_only: bool = False) -> int:
    """Total (or per-token active) parameter count from the layout itself."""
    layout = get_family(cfg).layout(cfg)
    total = count_params_in_layout(layout)
    if not active_only or not cfg.num_experts:
        return total

    expert = count_params_in_layout(
        layout, predicate=lambda s: "experts" in s.axes and len(s.shape) > 2)
    frac = cfg.experts_per_token / cfg.num_experts
    return int(total - expert + expert * frac)
