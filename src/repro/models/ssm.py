"""Mamba2 (state-space duality / SSD) blocks.

Training/prefill uses the chunked SSD algorithm (Dao & Gu, arXiv:2405.21060):
intra-chunk outputs via a masked (L×L) contraction, inter-chunk via a scan
over chunk states — O(S·L) work, O(S/L) sequential steps. Decode maintains
the recurrent state h ∈ (B, H, N, P) plus a short-conv tail.

Single SSM parameter group (n_groups=1): B/C projections are shared across
heads, as in the released Mamba2 models.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import shard
from .layers import norm_spec, rmsnorm
from .params import ParamSpec

A_INIT_RANGE = (1.0, 16.0)


def mamba_param_specs(cfg) -> dict:
    d, din = cfg.d_model, cfg.d_inner
    n, h = cfg.ssm_state, cfg.ssm_heads
    conv_ch = din + 2 * n  # conv over (x, B, C) as in Mamba2
    return {
        "norm": norm_spec(d),
        "w_in": ParamSpec((d, 2 * din + 2 * n + h), ("embed", "mlp")),
        "conv_w": ParamSpec((cfg.ssm_conv, conv_ch), ("conv", "mlp")),
        "conv_b": ParamSpec((conv_ch,), ("mlp",), init="zeros"),
        "dt_bias": ParamSpec((h,), ("ssm_heads",), init="zeros"),
        "A_log": ParamSpec((h,), ("ssm_heads",), init="ones"),
        "D": ParamSpec((h,), ("ssm_heads",), init="ones"),
        "gate_norm": ParamSpec((din,), ("mlp",), init="ones", dtype="float32"),
        "w_out": ParamSpec((din, d), ("mlp", "embed")),
    }


def _split_in(cfg, proj):
    """Split the fused input projection into (z, x, B, C, dt)."""
    din, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z, x, bmat, cmat, dt = jnp.split(
        proj, [din, 2 * din, 2 * din + n, 2 * din + 2 * n], axis=-1)
    return z, x, bmat, cmat, dt


def _conv1d(seq, w, b, cache=None):
    """Causal depthwise conv. seq: (B,S,C); w: (K,C). cache: (B,K-1,C)."""
    k = w.shape[0]
    if cache is None:
        pad = jnp.zeros((seq.shape[0], k - 1, seq.shape[2]), seq.dtype)
    else:
        pad = cache.astype(seq.dtype)
    full = jnp.concatenate([pad, seq], axis=1)
    out = sum(full[:, i:i + seq.shape[1]] * w[i] for i in range(k)) + b
    new_cache = full[:, -(k - 1):] if k > 1 else full[:, :0]
    return jax.nn.silu(out), new_cache


def ssd_chunked(xh, dt, a_log, bmat, cmat, d_skip, chunk: int, h_init=None):
    """Chunked SSD: one ``lax.scan`` over chunks carrying the SSM state.

    xh:   (B,S,H,P) inputs per head
    dt:   (B,S,H)   positive step sizes
    a_log:(H,)      A = -exp(a_log)
    bmat: (B,S,N), cmat: (B,S,N)  shared across heads
    Returns (y: (B,S,H,P), h_final: (B,H,N,P)).

    The per-chunk body (the (L,L) decay-masked contraction) is rematerialized
    in the backward pass, so activation traffic is O(S·L) transient and the
    only saved residual per chunk is the carried state (B,H,N,P).
    """
    b, s, h, p_ = xh.shape
    n = bmat.shape[-1]
    nc = s // chunk
    assert nc * chunk == s, f"seq {s} % chunk {chunk} != 0"

    xc = xh.reshape(b, nc, chunk, h, p_).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32).transpose(1, 0, 2, 3)
    bc = bmat.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)
    cc = cmat.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)

    a = -jnp.exp(a_log.astype(jnp.float32))                  # (H,)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))

    @jax.checkpoint
    def body(hprev, inp):
      with jax.named_scope("ssd_tile"):  # Pallas-kernel-eligible region
        x_, dt_, b_, c_ = inp            # (B,L,H,P),(B,L,H),(B,L,N),(B,L,N)
        la = jnp.cumsum(dt_ * a, axis=1)                     # (B,L,H)
        # intra-chunk
        cb = jnp.einsum("bln,bmn->blm", c_.astype(jnp.float32),
                        b_.astype(jnp.float32))
        seg = la[:, :, None, :] - la[:, None, :, :]          # (B,L,M,H)
        decay = jnp.where(mask[None, :, :, None], jnp.exp(seg), 0.0)
        decay = shard(decay, ("batch", None, None, "ssm_heads"))
        w_in = dt_[..., None] * x_.astype(jnp.float32)       # (B,L,H,P)
        y = jnp.einsum("blm,blmh,bmhp->blhp", cb, decay, w_in)
        # contribution of the carried state
        y = y + jnp.einsum("bln,blh,bhnp->blhp", c_.astype(jnp.float32),
                           jnp.exp(la), hprev)
        # next state
        wS = jnp.exp(la[:, -1:, :] - la) * dt_               # (B,L,H)
        st = jnp.einsum("bln,blh,blhp->bhnp", b_.astype(jnp.float32),
                        wS, x_.astype(jnp.float32))
        hnew = jnp.exp(la[:, -1, :])[:, :, None, None] * hprev + st
        return hnew, y.astype(xh.dtype)

    h0 = (jnp.zeros((b, h, n, p_), jnp.float32) if h_init is None
          else h_init.astype(jnp.float32))
    h_final, yc = lax.scan(body, h0, (xc, dtc, bc, cc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p_).astype(jnp.float32)
    y = y + d_skip.astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    return y.astype(xh.dtype), h_final


def ssd_step(xh, dt, a_log, bmat, cmat, d_skip, h_prev):
    """Single decode step. xh: (B,1,H,P); h_prev: (B,H,N,P)."""
    b, _, h, p_ = xh.shape
    a = -jnp.exp(a_log.astype(jnp.float32))
    dtf = dt[:, 0].astype(jnp.float32)                       # (B,H)
    decay = jnp.exp(dtf * a)                                 # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhnp", dtf, bmat[:, 0].astype(jnp.float32),
                     xh[:, 0].astype(jnp.float32))
    h_new = decay[:, :, None, None] * h_prev.astype(jnp.float32) + upd
    y = jnp.einsum("bn,bhnp->bhp", cmat[:, 0].astype(jnp.float32), h_new)
    y = y + d_skip.astype(jnp.float32)[None, :, None] * xh[:, 0].astype(jnp.float32)
    return y[:, None].astype(xh.dtype), h_new


def mamba_block(cfg, p, x, *, cache=None):
    """Pre-norm Mamba2 residual block.

    cache: None (train/prefill from scratch) or dict(conv=(B,K-1,C),
    ssm=(B,H,N,P)) for decode; returns (y, new_cache).
    """
    dt_ = cfg.cdtype
    din, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    proj = jnp.einsum("bsd,de->bse", xn, p["w_in"].astype(dt_))
    z, xs, bmat, cmat, dtp = _split_in(cfg, proj)

    conv_in = jnp.concatenate([xs, bmat, cmat], axis=-1)
    conv_out, conv_cache = _conv1d(conv_in, p["conv_w"].astype(dt_),
                                   p["conv_b"].astype(dt_),
                                   None if cache is None else cache["conv"])
    xs, bmat, cmat = jnp.split(conv_out, [din, din + n], axis=-1)

    dtv = jax.nn.softplus(dtp.astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))  # (B,S,H)
    xh = xs.reshape(*xs.shape[:2], h, cfg.ssm_headdim)
    xh = shard(xh, ("batch", None, "ssm_heads", None))

    if cache is None:
        y, h_final = ssd_chunked(xh, dtv, p["A_log"], bmat, cmat, p["D"],
                                 min(cfg.ssm_chunk, xs.shape[1]))
    else:
        y, h_final = ssd_step(xh, dtv, p["A_log"], bmat, cmat, p["D"],
                              cache["ssm"])

    y = y.reshape(*y.shape[:2], din)
    # gated RMSNorm (Mamba2's norm-before-out with SiLU(z) gate)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(dt_))
    new_cache = {"conv": conv_cache.astype(dt_), "ssm": h_final}
    return x + out, new_cache


def mamba_cache_shapes(cfg, batch: int) -> dict:
    """Abstract decode-cache shapes for one layer (pre-stacking)."""
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "conv": ((batch, cfg.ssm_conv - 1, conv_ch), cfg.dtype,
                 ("batch", None, "mlp")),
        "ssm": ((batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim),
                "float32", ("batch", "ssm_heads", None, None)),
    }
