"""Parameter-layout system.

A model is described by a nested dict of ``ParamSpec`` leaves. From one layout
we derive (a) initialized arrays, (b) ``ShapeDtypeStruct`` trees for the
AOT dry-run, and (c) logical-axis trees that the sharding rule engine maps to
``NamedSharding``. Layer-stacked parameters carry a leading ``"layers"`` axis
and are consumed by ``lax.scan``.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | embed
    scale: Optional[float] = None  # stddev override for "normal"/"embed"
    dtype: Optional[str] = None    # override param dtype (e.g. f32 norms)

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


def _leaf_dtype(spec: ParamSpec, param_dtype) -> jnp.dtype:
    return jnp.dtype(spec.dtype) if spec.dtype else jnp.dtype(param_dtype)


def tree_map_specs(fn: Callable[[ParamSpec], object], layout):
    """Map over ParamSpec leaves of a nested-dict layout."""
    if isinstance(layout, ParamSpec):
        return fn(layout)
    if isinstance(layout, dict):
        return {k: tree_map_specs(fn, v) for k, v in layout.items()}
    raise TypeError(f"bad layout node {type(layout)}")


def abstract_params(layout, param_dtype="float32"):
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, _leaf_dtype(s, param_dtype)), layout)


def logical_axes(layout):
    return tree_map_specs(lambda s: s.axes, layout)


def _fan_in(spec: ParamSpec) -> int:
    # contract all but the last axis by convention
    if len(spec.shape) <= 1:
        return max(spec.shape[-1] if spec.shape else 1, 1)
    return int(np.prod(spec.shape[:-1])) or 1


def init_params(layout, key, param_dtype="float32"):
    """Materialize a layout deterministically (fold-in by path)."""

    def go(node, path):
        if isinstance(node, ParamSpec):
            dt = _leaf_dtype(node, param_dtype)
            # crc32, NOT hash(): str hashes are salted per process
            # (PYTHONHASHSEED), which would give every process different
            # "deterministic" weights — and turn any cross-engine
            # token-identity test into a lottery on near-tie logits.
            sub = jax.random.fold_in(key, zlib.crc32(path.encode()))
            if node.init == "zeros":
                return jnp.zeros(node.shape, dt)
            if node.init == "ones":
                return jnp.ones(node.shape, dt)
            if node.init == "embed":
                std = node.scale if node.scale is not None else 1.0
            else:
                std = node.scale if node.scale is not None else _fan_in(node) ** -0.5
            return (jax.random.truncated_normal(sub, -2.0, 2.0, node.shape,
                                                jnp.float32) * std).astype(dt)
        return {k: go(v, path + "/" + k) for k, v in node.items()}

    return go(layout, "")


def stack_specs(layer_specs, n: int):
    """Add a leading ``layers`` axis of extent ``n`` to every leaf (for scan)."""
    return tree_map_specs(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes,
                            s.init, s.scale, s.dtype), layer_specs)


def count_params_in_layout(layout, predicate=None) -> int:
    total = 0

    def add(spec: ParamSpec):
        nonlocal total
        if predicate is None or predicate(spec):
            total += int(np.prod(spec.shape))

    tree_map_specs(add, layout)
    return total
