"""Shared neural layers: RMSNorm, RoPE, GQA attention (memory-efficient
chunked online-softmax — the FlashAttention dataflow expressed in XLA),
decode attention over KV caches, and (Ge/Swi)GLU FFNs.

All matmuls run in the config's compute dtype with float32 softmax/norm
statistics. ``shard`` consults the active sharding-rule context (see
:mod:`repro.distributed.sharding`) so the same model code lowers on one CPU
device and on a (pod, data, model) production mesh.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import shard
from .params import ParamSpec

MASK_VALUE = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(dt)


def norm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), ("embed",), init="ones", dtype="float32")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(angles)[..., None, :], jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _split_gqa(q, num_kv: int):
    """(B,S,H,hd) -> (B,S,KV,G,hd)."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, num_kv, h // num_kv, hd)


def _chunk_body(q_blk, q_pos_blk, k, v, kv_pos, *, causal: bool,
                kv_chunk: int, kv_lo: int, kv_hi: int):
    """Online-softmax over KV chunks [kv_lo, kv_hi) for one Q chunk.

    q_blk: (B, qc, KV, G, hd); k/v: (B, Skv, KV, hd).
    Accumulators are float32 — the FlashAttention recurrence.
    """
    b, qc, nkv, g, hd = q_blk.shape
    scale = 1.0 / math.sqrt(hd)
    nchunks = (kv_hi - kv_lo) // kv_chunk
    m0 = jnp.full((b, nkv, g, qc), MASK_VALUE, jnp.float32)
    l0 = jnp.zeros((b, nkv, g, qc), jnp.float32)
    a0 = jnp.zeros((b, nkv, g, qc, hd), jnp.float32)

    @jax.checkpoint  # flash bwd: recompute each KV tile, save only carries
    def body(carry, idx):
        # named_scope marks this region as Pallas-kernel-eligible: the
        # roofline analysis can model its intermediates as VMEM-resident
        # (see kernels/flash_attention + launch/hlo_analysis).
        with jax.named_scope("flash_tile"):
            m, l, acc = carry
            start = kv_lo + idx * kv_chunk
            k_blk = lax.dynamic_slice_in_dim(k, start, kv_chunk, axis=1)
            v_blk = lax.dynamic_slice_in_dim(v, start, kv_chunk, axis=1)
            pos_blk = lax.dynamic_slice_in_dim(kv_pos, start, kv_chunk, axis=0)
            s = jnp.einsum("bqkgh,bskh->bkgqs", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                mask = q_pos_blk[:, None] >= pos_blk[None, :]  # (qc, kvc)
                s = jnp.where(mask[None, None, None], s, MASK_VALUE)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), jnp.arange(nchunks))
    out = acc / jnp.maximum(l, 1e-9)[..., None]               # (B,KV,G,qc,hd)
    return out.transpose(0, 3, 1, 2, 4)                       # (B,qc,KV,G,hd)


def chunked_attention(q, k, v, *, causal: bool = True,
                      q_positions=None, kv_positions=None,
                      q_chunk: int = 512, kv_chunk: int = 1024,
                      block_triangular: bool = False):
    """Memory-efficient GQA attention.

    q: (B,Sq,H,hd), k/v: (B,Skv,KV,hd) -> (B,Sq,H,hd). Never materializes the
    (Sq, Skv) score matrix beyond a (q_chunk, kv_chunk) tile.

    ``block_triangular=True`` unrolls Q chunks in Python and scans only the
    KV chunks at-or-below the diagonal — ~2x fewer attention FLOPs for causal
    self-attention (a §Perf optimization; requires q_positions==kv_positions
    aligned, which holds for self-attention).
    """
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    if sq % q_chunk or skv % kv_chunk:
        raise ValueError(f"seq {sq}/{skv} not divisible by chunks {q_chunk}/{kv_chunk}")
    nkv = k.shape[2]
    qg = _split_gqa(q, nkv)
    if q_positions is None:
        q_positions = jnp.arange(sq)
    if kv_positions is None:
        kv_positions = jnp.arange(skv)

    n_q = sq // q_chunk

    # FlashAttention semantics under autodiff: recompute the (qc, kvc) tiles
    # in the backward pass instead of saving softmax residuals per tile.
    @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable,
             static_argnums=(1,))
    def one_chunk(i, kv_hi):
        q_blk = lax.dynamic_slice_in_dim(qg, i * q_chunk, q_chunk, axis=1)
        pos_blk = lax.dynamic_slice_in_dim(q_positions, i * q_chunk, q_chunk, 0)
        return _chunk_body(q_blk, pos_blk, k, v, kv_positions, causal=causal,
                           kv_chunk=kv_chunk, kv_lo=0, kv_hi=kv_hi)

    if block_triangular and causal and n_q > 1:
        outs = []
        for i in range(n_q):
            hi = min(skv, ((i + 1) * q_chunk + kv_chunk - 1) // kv_chunk * kv_chunk)
            outs.append(one_chunk(i, hi))
        out = jnp.concatenate(outs, axis=1)
    else:
        idx = jnp.arange(n_q)
        out = lax.map(lambda i: one_chunk(i, skv), idx)       # (n_q,B,qc,KV,G,hd)
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, nkv, h // nkv, hd)
        return out.reshape(b, sq, h, hd).astype(q.dtype)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def dense_attention(q, k, v, *, causal: bool = True,
                    q_positions=None, kv_positions=None):
    """Reference O(S^2)-memory attention (oracle for tests/kernels)."""
    b, sq, h, hd = q.shape
    nkv = k.shape[2]
    qg = _split_gqa(q, nkv)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    if causal:
        qp = jnp.arange(sq) if q_positions is None else q_positions
        kp = jnp.arange(k.shape[1]) if kv_positions is None else kv_positions
        s = jnp.where((qp[:, None] >= kp[None, :])[None, None, None], s, MASK_VALUE)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos):
    """Single-step attention against a KV cache.

    q: (B,1,H,hd); caches: (B,Smax,KV,hd); pos: (B,) current index (the new
    token's position; cache slots > pos are masked).
    """
    b, _, h, hd = q.shape
    nkv = k_cache.shape[2]
    qg = _split_gqa(q, nkv)[:, 0]                             # (B,KV,G,hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    idx = jnp.arange(k_cache.shape[1])
    mask = idx[None, :] <= pos[:, None]                       # (B,Smax)
    s = jnp.where(mask[:, None, None, :], s, MASK_VALUE)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention block (projections + RoPE + attention)
# ---------------------------------------------------------------------------

def attention_param_specs(cfg) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed")),
        "norm": norm_spec(d),
    }


def _qkv_proj(cfg, p, x, positions):
    """Pre-norm q/k/v projections with RoPE — shared by the dense and paged
    attention blocks so the projection contract cannot diverge."""
    dt = cfg.cdtype
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", xn, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", xn, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", xn, p["wv"].astype(dt))
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_block(cfg, p, x, positions, *, cache=None, decode_pos=None):
    """Pre-norm attention residual block.

    Training/prefill: ``cache is None`` → returns (y, (k, v)) so prefill can
    emit the cache. Decode: ``cache=(k_cache, v_cache)``, ``decode_pos=(B,)``
    → returns (y, (k_cache', v_cache')).
    """
    dt = cfg.cdtype
    q, k, v = _qkv_proj(cfg, p, x, positions)
    q = shard(q, ("batch", "attn_seq", "heads", None))
    k = shard(k, ("batch", None, "kv_heads", None))
    if cache is None:
        causal = cfg.causal and not cfg.encoder_only
        from repro.distributed.sharding import current_rules
        rules = current_rules()
        if (cfg.attn_impl == "ring" and rules is not None
                and "model" in rules.mesh.shape):
            # sequence-sharded ring attention over the model axis: fixes the
            # head-count-not-divisible replication (EXPERIMENTS §Perf A4/R1)
            from repro.distributed.ring_attention import ring_attention_sharded
            o = ring_attention_sharded(q, k, v, rules.mesh, causal=causal)
        elif cfg.attn_impl == "dense":
            o = dense_attention(q, k, v, causal=causal)
        else:
            o = chunked_attention(
                q, k, v, causal=causal,
                q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
                block_triangular=cfg.attn_block_triangular)
        new_cache = (k, v)
    else:
        k_cache, v_cache = _scatter_cache(cache, k, v, decode_pos)
        o = decode_attention(q, k_cache, v_cache, decode_pos)
        new_cache = (k_cache, v_cache)
    o = shard(o, ("batch", "attn_seq", "heads", None))
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))
    return x + y, new_cache


def _scatter_pool(pool, name, rows, page, off):
    """Write KV rows into one pool leaf, quantizing when the pool is int8.

    pool: this layer's pool-slice dict — ``{"k","v"}`` plus, for an int8
    pool, ``{"k_scale","v_scale"}`` per-row scale pages (kernels/kv_quant).
    rows: (KV, ..., hd) new rows; page/off: matching (...,) index arrays.
    Quantize-on-scatter keeps writes O(rows): per-ROW symmetric scales mean
    a louder later row never forces requantizing earlier rows in the page.
    """
    scale_name = name + "_scale"
    if scale_name in pool:
        from repro.kernels.kv_quant import quantize_rows
        q8, s = quantize_rows(rows)
        pool[name] = pool[name].at[:, page, off].set(q8)
        pool[scale_name] = pool[scale_name].at[:, page, off].set(s)
    else:
        pool[name] = pool[name].at[:, page, off].set(
            rows.astype(pool[name].dtype))
    return pool


def _pool_scales(pool):
    return dict(k_scale=pool.get("k_scale"), v_scale=pool.get("v_scale"))


def paged_attention_block(cfg, p, x, *, pool, page_table, pos):
    """Pre-norm attention residual block over a block-paged KV cache.

    x: (B,1,d) new-token activations; pool: this layer's physical pool
    slices — ``{"k","v"}`` of shape (KV,P,ps,hd) plus ``{"k_scale",
    "v_scale"}`` (KV,P,ps) when the pool is int8-quantized; page_table:
    (B,npages) int32; pos: (B,) the new token's position per request (cache
    holds [0, pos) valid rows). Returns (y, pool') with the new KV row
    scattered (quantized, for an int8 pool) into the pool page
    ``page_table[b, pos // ps]`` at offset ``pos % ps``.

    ``attn_impl="pallas"`` dispatches the split-KV flash-decode kernel on TPU
    (see kernels/decode_attention); other impls use the fused-gather oracle.
    Both dequantize int8 tiles with identical f32 arithmetic.
    """
    from repro.kernels.decode_attention import paged_decode_attention
    dt = cfg.cdtype
    b = x.shape[0]
    ps = pool["k"].shape[2]
    q, k, v = _qkv_proj(cfg, p, x, pos[:, None])

    bidx = jnp.arange(b)
    page = page_table[bidx, pos // ps]                  # (B,) physical pages
    off = pos % ps
    pool = dict(pool)
    # (B,1,KV,hd) -> (KV,B,hd) rows written at [kv, page_b, off_b].
    pool = _scatter_pool(pool, "k", k[:, 0].transpose(1, 0, 2), page, off)
    pool = _scatter_pool(pool, "v", v[:, 0].transpose(1, 0, 2), page, off)

    o = paged_decode_attention(q[:, 0], pool["k"], pool["v"], page_table,
                               pos + 1, **_pool_scales(pool),
                               impl=cfg.attn_impl,
                               split_budget=cfg.decode_split_budget)
    y = jnp.einsum("bshk,hkd->bsd", o[:, None].astype(dt), p["wo"].astype(dt))
    return x + y, pool


def paged_verify_attention_block(cfg, p, x, *, pool, page_table,
                                 pos, write_limit):
    """Pre-norm attention residual block for one speculative-verify window.

    x: (B,T,d) activations of the draft window — the already-verified
    current token followed by T-1 drafted candidates, occupying global
    positions ``pos[b] .. pos[b] + T - 1``; pool: this layer's physical pool
    slices (``{"k","v"}`` (KV,P,ps,hd) plus int8 scale pages, see
    ``paged_attention_block``); page_table: (B,npages) int32;
    write_limit: (B,) positions >= write_limit have their KV writes routed
    to the reserved sink page 0 — the engine points it at the slot's token
    budget (prompt_len + max_new), so a draft window running past the
    budget (or a rejected tail re-drafted next step) can never clobber live
    pages through the clamped page-table gather, its own or pages aliased
    from a shared prefix.

    The window's KV rows are scattered into the pool *first* (quantized, for
    an int8 pool); the kernel's positional causal mask (key pos <= query
    pos) then covers both verified history and the in-window lower triangle.
    Rows written for drafts that verification later rejects are simply
    overwritten by the next verify step, which restarts at the first
    rejected position. Returns (y, pool').
    """
    from repro.kernels.verify_attention import paged_verify_attention
    dt = cfg.cdtype
    b, t, _ = x.shape
    ps = pool["k"].shape[2]
    positions = pos[:, None] + jnp.arange(t)[None, :]            # (B, T)
    q, k, v = _qkv_proj(cfg, p, x, positions)

    bidx = jnp.arange(b)[:, None]
    valid = positions < write_limit[:, None]                     # (B, T)
    page = jnp.where(valid, page_table[bidx, positions // ps], 0)
    off = positions % ps
    pool = dict(pool)
    # (B,T,KV,hd) -> (KV,B,T,hd) rows written at [kv, page_bt, off_bt].
    pool = _scatter_pool(pool, "k", k.transpose(2, 0, 1, 3), page, off)
    pool = _scatter_pool(pool, "v", v.transpose(2, 0, 1, 3), page, off)

    o = paged_verify_attention(q, pool["k"], pool["v"], page_table, pos,
                               **_pool_scales(pool),
                               impl=cfg.attn_impl,
                               split_budget=cfg.decode_split_budget)
    y = jnp.einsum("bshk,hkd->bsd", o.astype(dt), p["wo"].astype(dt))
    return x + y, pool


def paged_prefill_attention_block(cfg, p, x, *, pool, page_table,
                                  q_start, kv_len):
    """Pre-norm attention residual block for one paged-prefill chunk.

    x: (B,C,d) chunk activations (C consecutive prompt tokens starting at
    global position ``q_start[b]``); pool: this layer's physical pool slices
    (``{"k","v"}`` (KV,P,ps,hd) plus int8 scale pages, see
    ``paged_attention_block``); page_table: (B,npages) int32; kv_len: (B,)
    the request's true prompt length — chunk positions >= kv_len are padding
    and their KV writes are routed to the reserved sink page 0, so a partial
    tail chunk can never clobber live pages (its own, or pages aliased from a
    shared prefix).

    The chunk's KV rows are scattered into the pool *first* (quantized, for
    an int8 pool); the kernel's positional causal mask (key pos <= query
    pos) then covers both history pages and the in-chunk lower triangle.
    Returns (y, pool').
    """
    from repro.kernels.prefill_attention import paged_prefill_attention
    dt = cfg.cdtype
    b, c, _ = x.shape
    ps = pool["k"].shape[2]
    positions = q_start[:, None] + jnp.arange(c)[None, :]        # (B, C)
    q, k, v = _qkv_proj(cfg, p, x, positions)

    bidx = jnp.arange(b)[:, None]
    valid = positions < kv_len[:, None]                          # (B, C)
    page = jnp.where(valid, page_table[bidx, positions // ps], 0)
    off = positions % ps
    pool = dict(pool)
    # (B,C,KV,hd) -> (KV,B,C,hd) rows written at [kv, page_bc, off_bc].
    pool = _scatter_pool(pool, "k", k.transpose(2, 0, 1, 3), page, off)
    pool = _scatter_pool(pool, "v", v.transpose(2, 0, 1, 3), page, off)

    o = paged_prefill_attention(q, pool["k"], pool["v"], page_table, q_start,
                                **_pool_scales(pool), impl=cfg.attn_impl)
    y = jnp.einsum("bshk,hkd->bsd", o.astype(dt), p["wo"].astype(dt))
    return x + y, pool


def _scatter_cache(cache, k, v, pos):
    """Write one new (k,v) row per batch element at ``pos``."""
    k_cache, v_cache = cache
    b = k.shape[0]
    bidx = jnp.arange(b)
    k_cache = k_cache.at[bidx, pos].set(k[:, 0].astype(k_cache.dtype))
    v_cache = v_cache.at[bidx, pos].set(v[:, 0].astype(v_cache.dtype))
    return k_cache, v_cache


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------

def mlp_param_specs(cfg, d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    specs = {
        "w_up": ParamSpec((d, f), ("embed", "mlp")),
        "w_down": ParamSpec((f, d), ("mlp", "embed")),
        "norm": norm_spec(d),
    }
    if cfg.mlp_gated:
        specs["w_gate"] = ParamSpec((d, f), ("embed", "mlp"))
    return specs


def _act(name: str):
    return jax.nn.silu if name == "silu" else partial(jax.nn.gelu, approximate=True)


def mlp_block(cfg, p, x):
    dt = cfg.cdtype
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    up = jnp.einsum("bsd,df->bsf", xn, p["w_up"].astype(dt))
    if cfg.mlp_gated:
        gate = jnp.einsum("bsd,df->bsf", xn, p["w_gate"].astype(dt))
        h = _act(cfg.mlp_act)(gate) * up
    else:
        h = _act(cfg.mlp_act)(up)
    h = shard(h, ("batch", None, "mlp"))
    y = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))
    return x + y


# ---------------------------------------------------------------------------
# Embedding / logits / loss
# ---------------------------------------------------------------------------

def embed_param_specs(cfg) -> dict:
    specs = {"embedding": ParamSpec((cfg.vocab_size, cfg.d_model),
                                    ("vocab", "embed"), init="embed")}
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                     ("embed", "vocab"))
    return specs


def embed_tokens(cfg, p, tokens):
    return p["embedding"].astype(cfg.cdtype)[tokens]


def logits_fn(cfg, p, x):
    dt = cfg.cdtype
    w = (p["embedding"].T if cfg.tie_embeddings else p["unembed"]).astype(dt)
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    return shard(logits, ("batch", None, "vocab"))


def softmax_xent(logits, labels, logit_dtype=jnp.float32):
    """Mean token cross-entropy, stats in float32."""
    lg = logits.astype(logit_dtype)
    lse = jax.nn.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def chunked_xent(cfg, p, x, labels, chunk: int):
    """Loss without materializing full-seq logits (lax.map over seq chunks;
    per-chunk logits are recomputed in the backward pass)."""
    b, s, d = x.shape
    chunk = min(chunk, s)
    if s % chunk:
        return softmax_xent(logits_fn(cfg, p, x), labels)
    n = s // chunk

    @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def one(i):
        xs = lax.dynamic_slice_in_dim(x, i * chunk, chunk, axis=1)
        ys = lax.dynamic_slice_in_dim(labels, i * chunk, chunk, axis=1)
        return softmax_xent(logits_fn(cfg, p, xs), ys) * chunk

    return jnp.sum(lax.map(one, jnp.arange(n))) / s
