"""Mixture-of-Experts FFN with GShard-style capacity-bounded einsum dispatch.

Tokens are reshaped into groups aligned with the data-parallel shards; top-k
routing builds dispatch/combine tensors; expert computation is three einsums
over expert-stacked weights sharded on the ``experts``→``model`` mesh axis
(expert parallelism). Arctic's *dense residual* branch (a small dense FFN in
parallel with the routed experts) is supported via ``cfg.moe_dense_ff``.

Aux outputs: Switch-style load-balance loss and router z-loss.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import shard
from .layers import _act, norm_spec
from .params import ParamSpec


def moe_param_specs(cfg) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    specs = {
        "norm": norm_spec(d),
        "router": ParamSpec((d, e), ("embed", "experts"), dtype="float32"),
        "we_gate": ParamSpec((e, d, f), ("experts", "embed", "moe_mlp")),
        "we_up": ParamSpec((e, d, f), ("experts", "embed", "moe_mlp")),
        "we_down": ParamSpec((e, f, d), ("experts", "moe_mlp", "embed")),
    }
    if cfg.moe_dense_ff:
        fd = cfg.moe_dense_ff
        specs["dense_gate"] = ParamSpec((d, fd), ("embed", "mlp"))
        specs["dense_up"] = ParamSpec((d, fd), ("embed", "mlp"))
        specs["dense_down"] = ParamSpec((fd, d), ("mlp", "embed"))
    return specs


def expert_capacity(cfg, group_size: int) -> int:
    return max(1, math.ceil(group_size * cfg.experts_per_token
                            / cfg.num_experts * cfg.capacity_factor))


def _route(cfg, p, xg):
    """Shared routing: returns (probs, gate_vals, sel) in float32."""
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, sel = lax.top_k(probs, cfg.experts_per_token)  # (g,s,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)               # renorm top-k
    return logits, probs, gate_vals, sel


def _einsum_dispatch(cfg, xg, gate_vals, sel, cap):
    """GShard capacity-bounded one-hot dispatch/combine (paper-era baseline).

    Cost: the dispatch/combine einsums contract over the group's tokens for
    every (expert, slot) pair — 2·T·E·C·d extra MACs, which dwarfs the useful
    expert FLOPs for large E (Arctic: ~130x MODEL_FLOPS). Kept as the
    reference implementation; see `_sort_dispatch` for the optimized path.
    """
    dt = cfg.cdtype
    g, gs, d = xg.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    sel_1h = jax.nn.one_hot(sel, e, dtype=jnp.float32)       # (g,s,k,e)
    flat = sel_1h.transpose(0, 2, 1, 3).reshape(g, k * gs, e)
    pos = jnp.cumsum(flat, axis=1) - flat                    # slots before me
    keep = (pos < cap).astype(jnp.float32) * flat
    slot_1h = jax.nn.one_hot(pos.astype(jnp.int32), cap,
                             dtype=jnp.float32) * keep[..., None]  # (g,ks,e,c)
    gate_flat = gate_vals.transpose(0, 2, 1).reshape(g, k * gs)
    combine = (gate_flat[:, :, None, None] * slot_1h).reshape(
        g, k, gs, e, cap).sum(axis=1)                        # (g,s,e,c)
    combine = shard(combine, ("moe_groups", None, "experts", None))
    dispatch = (combine > 0.0).astype(dt)

    ein = jnp.einsum("gsec,gsd->egcd", dispatch, xg)         # (e,g,c,d)

    def undispatch(eo):
        return jnp.einsum("gsec,egcd->gsd", combine.astype(dt), eo)

    dropped = 1.0 - (keep.sum() / jnp.maximum(flat.sum(), 1.0))
    return ein, undispatch, dropped


def _sort_dispatch(cfg, xg, gate_vals, sel, cap):
    """Gather/scatter dispatch (beyond-paper §Perf optimization).

    Builds the (E, g, C, d) expert buffers by *indexing*, not contraction:
    per group, the (s·k) routed assignments are bucketed into per-expert
    slots with the same cumsum-capacity rule as GShard (identical drop
    semantics — property-tested), then token rows are gathered. Removes the
    2·T·E·C·d dispatch/combine MACs entirely; per-group locality keeps all
    gathers collective-free (the e→model resharding of the (e,g,c,d) buffer
    is the same all-to-all-ish transfer the einsum path pays).
    """
    dt = cfg.cdtype
    g, gs, d = xg.shape
    e, k = cfg.num_experts, cfg.experts_per_token

    sel_flat = sel.transpose(0, 2, 1).reshape(g, k * gs)      # priority (k,s)
    gate_flat = gate_vals.transpose(0, 2, 1).reshape(g, k * gs)
    tok_idx = jnp.tile(jnp.arange(gs), k)                     # (k·gs,)

    sel_1h = jax.nn.one_hot(sel_flat, e, dtype=jnp.float32)   # (g,ks,e)
    pos_in_expert = (jnp.cumsum(sel_1h, axis=1) - sel_1h)
    pos = jnp.einsum("gte,gte->gt", pos_in_expert, sel_1h)    # (g,ks)
    keep = pos < cap
    slot = sel_flat * cap + pos.astype(jnp.int32)             # (g,ks) in [0,E·C)
    slot = jnp.where(keep, slot, e * cap)                     # dropped -> sentinel

    # scatter token rows into (E·C [+1], d) buffers per group
    def scatter_group(x_g, slot_g):
        buf = jnp.zeros((e * cap + 1, d), dt)
        return buf.at[slot_g].set(x_g[tok_idx], mode="drop")
    ein = jax.vmap(scatter_group)(xg, slot)[:, :-1]           # (g, E·C, d)
    ein = ein.reshape(g, e, cap, d).transpose(1, 0, 2, 3)     # (e,g,c,d)

    def undispatch(eo):
        flat_eo = eo.transpose(1, 0, 2, 3).reshape(g, e * cap, d)
        def gather_group(eo_g, slot_g, gates_g):
            rows = jnp.where((slot_g < e * cap)[:, None],
                             eo_g.at[slot_g].get(mode="fill", fill_value=0.0),
                             0.0)
            contrib = rows * gates_g[:, None].astype(dt)      # (ks, d)
            return jax.ops.segment_sum(contrib, tok_idx, num_segments=gs)
        return jax.vmap(gather_group)(flat_eo, slot, gate_flat)

    dropped = 1.0 - keep.mean()
    return ein, undispatch, dropped


def moe_block(cfg, p, x):
    """x: (B,S,D) -> (y, aux). Residual is added inside (pre-norm block)."""
    from .layers import rmsnorm  # local to avoid cycle

    dt = cfg.cdtype
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)

    tokens = b * s
    gs = min(cfg.moe_group_size, tokens)
    g = tokens // gs
    assert g * gs == tokens, f"{tokens} tokens not divisible into groups of {gs}"
    cap = expert_capacity(cfg, gs)

    xg = shard(xn.reshape(g, gs, d), ("moe_groups", None, None))
    logits, probs, gate_vals, sel = _route(cfg, p, xg)

    dispatch_fn = (_sort_dispatch if cfg.moe_impl == "sort"
                   else _einsum_dispatch)
    ein, undispatch, dropped = dispatch_fn(cfg, xg, gate_vals, sel, cap)

    # --- expert computation (EP over "experts"→model) -----------------------
    ein = shard(ein, ("experts", "moe_groups", None, None))
    hg = jnp.einsum("egcd,edf->egcf", ein, p["we_gate"].astype(dt))
    hu = jnp.einsum("egcd,edf->egcf", ein, p["we_up"].astype(dt))
    h = _act(cfg.mlp_act)(hg) * hu
    h = shard(h, ("experts", "moe_groups", None, "moe_mlp"))
    eo = jnp.einsum("egcf,efd->egcd", h, p["we_down"].astype(dt))
    y = undispatch(eo).reshape(b, s, d)                       # (g,s,d)->(b,s,d)

    # --- Arctic dense residual branch ---------------------------------------
    if cfg.moe_dense_ff:
        gate = jnp.einsum("bsd,df->bsf", xn, p["dense_gate"].astype(dt))
        up = jnp.einsum("bsd,df->bsf", xn, p["dense_up"].astype(dt))
        hd_ = _act(cfg.mlp_act)(gate) * up
        y = y + jnp.einsum("bsf,fd->bsd", hd_, p["dense_down"].astype(dt))

    # --- aux losses -----------------------------------------------------------
    # Switch load-balance: e * Σ_e f_e · P_e (f = fraction dispatched top-1).
    top1_1h = jax.nn.one_hot(sel[:, :, 0], e, dtype=jnp.float32)  # (g,s,e)
    f_e = top1_1h.mean(axis=(0, 1))
    p_e = probs.mean(axis=(0, 1))
    lb_loss = e * jnp.sum(f_e * p_e)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = {
        "moe_lb_loss": lb_loss.astype(jnp.float32),
        "moe_z_loss": z_loss.astype(jnp.float32),
        "moe_drop_frac": dropped.astype(jnp.float32),
    }
    return x + y, aux
