"""xLSTM blocks (Beck et al., arXiv:2405.04517): alternating mLSTM (matrix
memory, covariance update) and sLSTM (scalar memory, recurrent gates) blocks.

Both use exponential gating with the paper's log-space stabilizer state m.
Training runs the recurrence with ``lax.scan`` over time (O(1) HLO size);
decode is the single-step form. The d_ff=0 convention in the assigned config
means the blocks own their projections (mLSTM: 2x up-projection, sLSTM:
4/3-factor gated FFN after the cell), as in the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.sharding import shard
from .layers import norm_spec, rmsnorm
from .params import ParamSpec


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_param_specs(cfg) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    din = 2 * d                     # paper: projection factor 2
    hd = din // h
    return {
        "norm": norm_spec(d),
        "w_up_x": ParamSpec((d, din), ("embed", "mlp")),
        "w_up_z": ParamSpec((d, din), ("embed", "mlp")),
        "conv_w": ParamSpec((cfg.ssm_conv, din), ("conv", "mlp")),
        "conv_b": ParamSpec((din,), ("mlp",), init="zeros"),
        "wq": ParamSpec((din, h, hd), ("mlp", "heads", "head_dim")),
        "wk": ParamSpec((din, h, hd), ("mlp", "heads", "head_dim")),
        "wv": ParamSpec((din, h, hd), ("mlp", "heads", "head_dim")),
        "w_i": ParamSpec((din, h), ("mlp", "heads")),
        "w_f": ParamSpec((din, h), ("mlp", "heads")),
        "b_i": ParamSpec((h,), ("heads",), init="zeros"),
        "b_f": ParamSpec((h,), ("heads",), init="ones"),
        "out_norm": ParamSpec((din,), ("mlp",), init="ones", dtype="float32"),
        "w_down": ParamSpec((din, d), ("mlp", "embed")),
    }


def mlstm_scan(q, k, v, log_i, log_f, state=None):
    """Stabilized mLSTM recurrence over time.

    q,k,v: (B,S,H,hd); log_i/log_f: (B,S,H).
    state: None or (C:(B,H,hd,hd), n:(B,H,hd), m:(B,H)).
    Returns (h: (B,S,H,hd), final state).
    """
    b, s, h, hd = q.shape
    scale = hd ** -0.5
    if state is None:
        c0 = jnp.zeros((b, h, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, h, hd), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        c0, n0, m0 = (x.astype(jnp.float32) for x in state)

    def step(carry, inp):
        c, n, m = carry
        qt, kt, vt, li, lf = inp                             # (B,H,hd)...(B,H)
        m_new = jnp.maximum(lf + m, li)
        i_ = jnp.exp(li - m_new)
        f_ = jnp.exp(lf + m - m_new)
        kt = kt.astype(jnp.float32) * scale
        c = f_[..., None, None] * c + i_[..., None, None] * (
            vt.astype(jnp.float32)[..., :, None] * kt[..., None, :])
        n = f_[..., None] * n + i_[..., None] * kt
        qf = qt.astype(jnp.float32)
        num = jnp.einsum("bhvk,bhk->bhv", c, qf)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)),
                          jnp.exp(-m_new))
        return (c, n, m_new), (num / den[..., None])

    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), log_i.transpose(1, 0, 2),
          log_f.transpose(1, 0, 2))
    (c, n, m), hs = lax.scan(step, (c0, n0, m0), xs)
    return hs.transpose(1, 0, 2, 3).astype(q.dtype), (c, n, m)


def mlstm_chunked(q, k, v, log_i, log_f, state=None, chunk: int = 64):
    """Chunkwise-parallel mLSTM (the paper's training form; GLA-style).

    Identical math to :func:`mlstm_scan` (property-tested) but O(S·L) work
    with an (L,L) decay-masked intra-chunk contraction and a scan that only
    carries (C, n, m) across chunks — the per-chunk body is rematerialized in
    the backward pass.
    """
    b, s, h, hd = q.shape
    if s % chunk:
        raise ValueError(f"seq {s} % chunk {chunk}")
    nc = s // chunk
    scale = hd ** -0.5
    if state is None:
        c0 = jnp.zeros((b, h, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, h, hd), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        c0, n0, m0 = (x.astype(jnp.float32) for x in state)

    def to_chunks(x):
        return x.reshape(b, nc, chunk, *x.shape[2:]).transpose(
            1, 0, *range(2, x.ndim + 1))

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    lic, lfc = to_chunks(log_i), to_chunks(log_f)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    @jax.checkpoint
    def body(carry, inp):
      with jax.named_scope("mlstm_tile"):  # Pallas-kernel-eligible region
        c, n, m = carry                                      # (B,H,hd,hd) ...
        q_, k_, v_, li, lf = inp                             # (B,L,H,hd)...(B,L,H)
        bcum = jnp.cumsum(lf, axis=1)                        # (B,L,H) inclusive
        # intra-chunk log weights: b_l - b_m + li_m   (l >= m)
        g = bcum[:, :, None, :] - bcum[:, None, :, :] + li[:, None, :, :]
        g = jnp.where(tri[None, :, :, None], g, -jnp.inf)    # (B,L,M,H)
        m_intra = jnp.max(g, axis=2)                         # (B,L,H)
        m_l = jnp.maximum(m[:, None, :] + bcum, m_intra)     # (B,L,H)
        d_intra = jnp.exp(g - m_l[:, :, None, :])            # (B,L,M,H)
        d_inter = jnp.exp(bcum + m[:, None, :] - m_l)        # (B,L,H)

        s_qk = jnp.einsum("blhd,bmhd->blmh", q_.astype(jnp.float32),
                          k_.astype(jnp.float32)) * scale
        w = s_qk * d_intra
        num = jnp.einsum("blmh,bmhd->blhd", w, v_.astype(jnp.float32))
        num = num + d_inter[..., None] * jnp.einsum(
            "bhvk,blhk->blhv", c, q_.astype(jnp.float32))
        den = jnp.einsum("blmh->blh", w) + d_inter * jnp.einsum(
            "bhk,blhk->blh", n, q_.astype(jnp.float32))
        hout = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_l))[..., None]

        # chunk-boundary state update
        b_last = bcum[:, -1, :]                              # (B,H)
        g_last = b_last[:, None, :] - bcum + li              # (B,L,H)
        m_next = jnp.maximum(m + b_last, jnp.max(g_last, axis=1))
        w_state = jnp.exp(g_last - m_next[:, None, :])       # (B,L,H)
        kf = k_.astype(jnp.float32) * scale
        c_new = (jnp.exp(m + b_last - m_next)[:, :, None, None] * c
                 + jnp.einsum("blh,blhv,blhk->bhvk", w_state,
                              v_.astype(jnp.float32), kf))
        n_new = (jnp.exp(m + b_last - m_next)[:, :, None] * n
                 + jnp.einsum("blh,blhk->bhk", w_state, kf))
        return (c_new, n_new, m_next), hout.astype(q.dtype)

    (c, n, m), hs = lax.scan(body, (c0, n0, m0), (qc, kc, vc, lic, lfc))
    hs = hs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)
    return hs, (c, n, m)


def mlstm_block(cfg, p, x, *, cache=None):
    from .ssm import _conv1d

    dt_ = cfg.cdtype
    h = cfg.num_heads
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    xin = jnp.einsum("bsd,de->bse", xn, p["w_up_x"].astype(dt_))
    z = jnp.einsum("bsd,de->bse", xn, p["w_up_z"].astype(dt_))
    conv_out, conv_cache = _conv1d(xin, p["conv_w"].astype(dt_),
                                   p["conv_b"].astype(dt_),
                                   None if cache is None else cache["conv"])
    q = jnp.einsum("bse,ehk->bshk", conv_out, p["wq"].astype(dt_))
    k = jnp.einsum("bse,ehk->bshk", conv_out, p["wk"].astype(dt_))
    v = jnp.einsum("bse,ehk->bshk", xin, p["wv"].astype(dt_))
    q = shard(q, ("batch", None, "heads", None))
    log_i = (jnp.einsum("bse,eh->bsh", conv_out, p["w_i"].astype(dt_))
             .astype(jnp.float32) + p["b_i"].astype(jnp.float32))
    log_f = jax.nn.log_sigmoid(
        jnp.einsum("bse,eh->bsh", conv_out, p["w_f"].astype(dt_))
        .astype(jnp.float32) + p["b_f"].astype(jnp.float32))

    state = None if cache is None else (cache["C"], cache["n"], cache["m"])
    if q.shape[1] > 1:
        chunk = min(64, q.shape[1])
        hs, (c, n, m) = mlstm_chunked(q, k, v, log_i, log_f, state, chunk)
    else:
        hs, (c, n, m) = mlstm_scan(q, k, v, log_i, log_f, state)
    hs = hs.reshape(*hs.shape[:2], -1)
    hs = rmsnorm(hs, p["out_norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", hs, p["w_down"].astype(dt_))
    new_cache = {"conv": conv_cache.astype(dt_), "C": c, "n": n, "m": m}
    return x + out, new_cache


def mlstm_cache_shapes(cfg, batch: int) -> dict:
    h = cfg.num_heads
    din = 2 * cfg.d_model
    hd = din // h
    return {
        "conv": ((batch, cfg.ssm_conv - 1, din), cfg.dtype, ("batch", None, "mlp")),
        "C": ((batch, h, hd, hd), "float32", ("batch", "heads", None, None)),
        "n": ((batch, h, hd), "float32", ("batch", "heads", None)),
        "m": ((batch, h), "float32", ("batch", "heads")),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_param_specs(cfg) -> dict:
    d, h = cfg.d_model, cfg.num_heads
    hd = d // h
    f = int(d * 8 / 3) // 64 * 64   # post-cell gated FFN, 4/3 factor (x2 for GLU)
    return {
        "norm": norm_spec(d),
        # input weights for the four gates (z, i, f, o)
        "w_gates": ParamSpec((d, 4, h, hd), ("embed", None, "heads", "head_dim")),
        # block-diagonal recurrent weights per head, per gate
        "r_gates": ParamSpec((4, h, hd, hd), (None, "heads", "head_dim", None)),
        "b_gates": ParamSpec((4, h, hd), (None, "heads", "head_dim"), init="zeros"),
        "ffn_gate": ParamSpec((d, f), ("embed", "mlp")),
        "ffn_up": ParamSpec((d, f), ("embed", "mlp")),
        "ffn_down": ParamSpec((f, d), ("mlp", "embed")),
    }


def slstm_scan(gates_in, r, b, state=None):
    """sLSTM recurrence. gates_in: (B,S,4,H,hd). Returns (h:(B,S,H,hd), state).

    State: (c, n, m, h_prev) each (B,H,hd).
    """
    bsz, s, _, h, hd = gates_in.shape
    if state is None:
        zeros = jnp.zeros((bsz, h, hd), jnp.float32)
        state = (zeros, zeros + 1e-6, zeros - 1e30, zeros)
    else:
        state = tuple(x.astype(jnp.float32) for x in state)

    rf = r.astype(jnp.float32)
    bf = b.astype(jnp.float32)

    def step(carry, g_t):
        c, n, m, h_prev = carry
        # recurrent contribution: block-diag per head
        rec = jnp.einsum("bhk,ghkj->bghj", h_prev, rf)        # (B,4,H,hd)
        pre = g_t.astype(jnp.float32) + rec + bf[None]
        zt = jnp.tanh(pre[:, 0])
        it = pre[:, 1]
        ft = pre[:, 2]
        ot = jax.nn.sigmoid(pre[:, 3])
        lf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(lf + m, it)
        i_ = jnp.exp(it - m_new)
        f_ = jnp.exp(lf + m - m_new)
        c = f_ * c + i_ * zt
        n = f_ * n + i_
        h_new = ot * c / jnp.maximum(n, 1e-6)
        return (c, n, m_new, h_new), h_new

    (c, n, m, h_last), hs = lax.scan(step, state,
                                     gates_in.transpose(1, 0, 2, 3, 4))
    return hs.transpose(1, 0, 2, 3), (c, n, m, h_last)


def slstm_block(cfg, p, x, *, cache=None):
    dt_ = cfg.cdtype
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    gates_in = jnp.einsum("bsd,dghk->bsghk", xn, p["w_gates"].astype(dt_))
    state = (None if cache is None else
             (cache["c"], cache["n"], cache["m"], cache["h"]))
    hs, (c, n, m, hl) = slstm_scan(gates_in, p["r_gates"], p["b_gates"], state)
    hs = hs.reshape(*hs.shape[:2], -1).astype(dt_)
    y = x + hs
    # gated FFN (GLU, 4/3 factor)
    gate = jnp.einsum("bsd,df->bsf", hs, p["ffn_gate"].astype(dt_))
    up = jnp.einsum("bsd,df->bsf", hs, p["ffn_up"].astype(dt_))
    hf = jax.nn.gelu(gate, approximate=True) * up
    hf = shard(hf, ("batch", None, "mlp"))
    y = y + jnp.einsum("bsf,fd->bsd", hf, p["ffn_down"].astype(dt_))
    new_cache = {"c": c, "n": n, "m": m, "h": hl}
    return y, new_cache


def slstm_cache_shapes(cfg, batch: int) -> dict:
    h, hd = cfg.num_heads, cfg.d_model // cfg.num_heads
    shp = ((batch, h, hd), "float32", ("batch", "heads", None))
    return {"c": shp, "n": shp, "m": shp, "h": shp}
