"""Tiered, async, checksummed checkpointing through the lifecycle store.

Checkpoints are Kotta's own dogfood for the paper's storage contribution:
every leaf of (params, opt_state) is written as an object under
``checkpoints/<run>/<step>/...`` in the :class:`ObjectStore`, so old
checkpoints age HOT→STD→IA→ARCHIVE under the LRU lifecycle policy exactly
like the paper's corpora, and restoring an archived checkpoint goes through
the Glacier-restore path.

Properties:
- sharded: one object per pytree leaf (parallel-writable on a real fleet);
- checksummed: SHA-256 per leaf + manifest (detects corruption on restore);
- async: ``save(..., blocking=False)`` snapshots to host memory and writes in
  a background thread (training continues);
- topology-independent: leaves are stored as full logical arrays and can be
  resharded onto any mesh at restore (elastic rescale after revocation).
"""
from __future__ import annotations

import hashlib
import json
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.core.lifecycle import ObjectStore, Tier


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out) or "root"


class Checkpointer:
    def __init__(self, store: ObjectStore, run_name: str,
                 tier: Tier = Tier.STD, keep_last: Optional[int] = None):
        self.store = store
        self.run = run_name
        self.tier = tier
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        self.saves = 0

    # -- keys ---------------------------------------------------------------
    def _prefix(self, step: int) -> str:
        return f"checkpoints/{self.run}/{step:08d}"

    def _manifest_key(self, step: int) -> str:
        return self._prefix(step) + "/MANIFEST.json"

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = True) -> None:
        # Snapshot to host memory synchronously (cheap); write async.
        leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
        host = [(_path_str(path), np.asarray(leaf)) for path, leaf in leaves]
        if blocking:
            self._write(step, host)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host), daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_leaves) -> None:
        manifest = {"run": self.run, "step": step, "leaves": []}
        for name, arr in host_leaves:
            # raw bytes + manifest dtype: np.save cannot represent ml_dtypes
            # (bfloat16 round-trips as void).
            data = np.ascontiguousarray(arr).tobytes()
            key = f"{self._prefix(step)}/{name}.npy"
            self.store.put(key, data, owner=f"run:{self.run}", tier=self.tier)
            manifest["leaves"].append({
                "name": name, "key": key, "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "sha256": hashlib.sha256(data).hexdigest(),
            })
        self.store.put(self._manifest_key(step),
                       json.dumps(manifest).encode(),
                       owner=f"run:{self.run}", tier=self.tier)
        self.saves += 1
        if self.keep_last is not None:
            self._gc()

    # -- restore -------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for key in self.store.keys(f"checkpoints/{self.run}/"):
            if key.endswith("MANIFEST.json"):
                out.append(int(key.split("/")[-2]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None) -> tuple[int, Any]:
        """Restore into the structure of ``like``. Returns (step, tree).

        Raises ObjectArchivedError if the checkpoint has aged into ARCHIVE
        (callers then go through the restore queue, paper §V-A).
        """
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints for run {self.run!r}")
        manifest = json.loads(self.store.get(self._manifest_key(step)))
        by_name = {e["name"]: e for e in manifest["leaves"]}

        leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
        out = []
        for path, leaf in leaves:
            name = _path_str(path)
            entry = by_name[name]
            data = self.store.get(entry["key"])
            if hashlib.sha256(data).hexdigest() != entry["sha256"]:
                raise IOError(f"checksum mismatch restoring {name}")
            dt = jax.numpy.dtype(entry["dtype"])
            arr = np.frombuffer(data, dtype=dt).reshape(entry["shape"])
            if list(arr.shape) != list(np.shape(leaf)):
                raise ValueError(f"{name}: saved {arr.shape} vs expected "
                                 f"{np.shape(leaf)} (topology change needs "
                                 f"logical-shape parity)")
            out.append(jax.numpy.asarray(arr))
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(like), out)
        return step, tree

    # -- gc ---------------------------------------------------------------------
    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep_last]:
            for key in self.store.keys(self._prefix(s)):
                self.store.delete(key)
