"""Elastic provisioning policies (paper §IV-C, §V-B, §VII-C).

The three scaling strategies evaluated in Table VII-C are all instances of one
``ScalingPolicy``:

- *No scaling*:     ``ScalingPolicy(min_nodes=N, max_nodes=N)``
- *Limited*:        ``ScalingPolicy(min_nodes=0, max_nodes=M)``
- *Unlimited*:      ``ScalingPolicy(min_nodes=0, max_nodes=None)``

``Provisioner.desired_change`` implements the paper's rule: "CLOUD KOTTA
provisions additional instances when there are pending jobs in the queues",
and terminates instances that have idled past ``idle_timeout_s`` (keeping
``min_nodes`` alive; the dev pool keeps ≥1 reliable on-demand node).
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class ScalingPolicy:
    min_nodes: int = 0
    max_nodes: Optional[int] = None  # None = unlimited
    idle_timeout_s: float = 600.0
    # market model: "on_demand" (reliable) or "spot" (preemptible)
    market: str = "spot"
    bid_fraction: float = 1.0  # bid = fraction × on-demand price

    @classmethod
    def none(cls, nodes: int, **kw) -> "ScalingPolicy":
        return cls(min_nodes=nodes, max_nodes=nodes, **kw)

    @classmethod
    def limited(cls, max_nodes: int, **kw) -> "ScalingPolicy":
        return cls(min_nodes=0, max_nodes=max_nodes, **kw)

    @classmethod
    def unlimited(cls, **kw) -> "ScalingPolicy":
        return cls(min_nodes=0, max_nodes=None, **kw)


@dataclass(frozen=True)
class ProvisioningModel:
    """Instance acquisition latency (paper §VII-C: avg 7:39, peak 30:00)."""

    base_delay_s: float = 300.0
    jitter_s: float = 300.0            # uniform extra
    volatility_prob: float = 0.03      # spot-market stall
    volatility_delay_s: float = 1500.0

    def sample(self, rng: random.Random) -> float:
        d = self.base_delay_s + rng.uniform(0.0, self.jitter_s)
        if rng.random() < self.volatility_prob:
            d += rng.uniform(0.0, self.volatility_delay_s)
        return d


class Provisioner:
    """Pure decision logic shared by the DES and the threaded runtime."""

    def __init__(self, policy: ScalingPolicy,
                 model: ProvisioningModel | None = None,
                 seed: int = 0):
        self.policy = policy
        self.model = model or ProvisioningModel()
        self.rng = random.Random(seed)

    def launch_count(self, pending_jobs: int, idle: int, provisioning: int,
                     total: int) -> int:
        """How many new instances to request right now."""
        deficit = pending_jobs - idle - provisioning
        floor_deficit = self.policy.min_nodes - total - provisioning
        want = max(deficit, floor_deficit, 0)
        if self.policy.max_nodes is not None:
            want = min(want, self.policy.max_nodes - total - provisioning)
        return max(want, 0)

    def should_terminate(self, idle_for_s: float, total: int) -> bool:
        if total <= self.policy.min_nodes:
            return False
        return idle_for_s >= self.policy.idle_timeout_s

    def provisioning_delay(self) -> float:
        return self.model.sample(self.rng)
