"""Role-based security fabric (paper §VI).

Implements Cloud Kotta's security model:

- **Principals** authenticate (the paper delegates to Login-with-Amazon OAuth2;
  here, a pluggable ``Authenticator``) and receive **short-term session
  tokens** (1 h API tokens, 6 h web sessions).
- **Roles** carry **policies** (allow/deny on action+resource glob patterns).
  Every principal starts with *no* privileges (least privilege) and is
  incrementally granted roles.
- **Trusted roles** (e.g. ``task-executor``) may **assume** user roles to stage
  that user's data, then revert — exactly the worker-node dance in §VI.
- **Signed URLs** give short-term, capability-style read access (the paper's
  DropBox-like sharing links).
- Every authorization decision is appended to an immutable **audit log**
  (paper: "CLOUD KOTTA tracks all data access by users and analyses").
"""
from __future__ import annotations

import fnmatch
import hashlib
import hmac
import secrets
from dataclasses import dataclass, field
from typing import Iterable, Optional

from .clock import Clock, hours

API_TOKEN_LIFETIME_S = hours(1)   # paper: delegated token valid for one hour
WEB_SESSION_LIFETIME_S = hours(6)  # paper: web sessions valid for six hours


class SecurityError(Exception):
    """Base class for authn/authz failures."""


class AuthenticationError(SecurityError):
    pass


class AuthorizationError(SecurityError):
    pass


class TokenExpiredError(SecurityError):
    pass


@dataclass(frozen=True)
class Principal:
    principal_id: str
    display_name: str = ""


@dataclass(frozen=True)
class Policy:
    """IAM-style statement: effect on (actions × resources) glob patterns."""

    effect: str                 # "allow" | "deny"
    actions: tuple[str, ...]    # e.g. ("data:Get", "data:Put", "jobs:*")
    resources: tuple[str, ...]  # e.g. ("dataset/wos/*",)

    def __post_init__(self):
        if self.effect not in ("allow", "deny"):
            raise ValueError(f"bad effect {self.effect!r}")

    def matches(self, action: str, resource: str) -> bool:
        return any(fnmatch.fnmatchcase(action, a) for a in self.actions) and any(
            fnmatch.fnmatchcase(resource, r) for r in self.resources
        )


def allow(actions: Iterable[str], resources: Iterable[str]) -> Policy:
    return Policy("allow", tuple(actions), tuple(resources))


def deny(actions: Iterable[str], resources: Iterable[str]) -> Policy:
    return Policy("deny", tuple(actions), tuple(resources))


@dataclass
class Role:
    """A named bundle of policies.

    ``trusted_assumers``: role names allowed to ``assume_role`` into this role
    (the paper's *task-executor* is trusted to assume user roles while staging
    that user's data).
    ``internal``: internal service roles (web-server, task-executor,
    queue-watcher) that may touch the database/queues/scaling controls.
    """

    name: str
    policies: list[Policy] = field(default_factory=list)
    trusted_assumers: set[str] = field(default_factory=set)
    internal: bool = False


@dataclass(frozen=True)
class SessionToken:
    token_id: str
    principal_id: str
    role_name: str
    issued_at: float
    expires_at: float
    parent_token_id: Optional[str] = None  # set for assumed-role sessions


@dataclass(frozen=True)
class AuditRecord:
    timestamp: float
    principal_id: str
    role_name: str
    action: str
    resource: str
    decision: str   # "allow" | "deny"
    detail: str = ""


class AuditLog:
    """Append-only audit trail with simple query support."""

    def __init__(self):
        self._records: list[AuditRecord] = []

    def append(self, rec: AuditRecord) -> None:
        self._records.append(rec)

    def records(
        self,
        principal_id: str | None = None,
        resource_glob: str | None = None,
        decision: str | None = None,
    ) -> list[AuditRecord]:
        out = self._records
        if principal_id is not None:
            out = [r for r in out if r.principal_id == principal_id]
        if resource_glob is not None:
            out = [r for r in out if fnmatch.fnmatchcase(r.resource, resource_glob)]
        if decision is not None:
            out = [r for r in out if r.decision == decision]
        return list(out)

    def __len__(self) -> int:
        return len(self._records)


class Authenticator:
    """Pluggable identity provider (paper: Login with Amazon / OAuth2).

    The default implementation holds a registry of known identities and their
    shared secrets — sufficient to model the redirect/token exchange without a
    network. ``authenticate`` returns the principal on success.
    """

    def __init__(self):
        self._secrets: dict[str, str] = {}
        self._principals: dict[str, Principal] = {}

    def register_identity(self, principal: Principal, secret: str) -> None:
        self._principals[principal.principal_id] = principal
        self._secrets[principal.principal_id] = secret

    def authenticate(self, principal_id: str, secret: str) -> Principal:
        expected = self._secrets.get(principal_id)
        if expected is None or not hmac.compare_digest(expected, secret):
            raise AuthenticationError(f"authentication failed for {principal_id!r}")
        return self._principals[principal_id]


class PolicyEngine:
    """The security fabric: roles, bindings, sessions, authorization, audit."""

    def __init__(self, clock: Clock | None = None, signing_key: bytes | None = None):
        self.clock = clock or Clock()
        self.audit = AuditLog()
        self.authenticator = Authenticator()
        self._roles: dict[str, Role] = {}
        self._bindings: dict[str, set[str]] = {}  # principal -> role names
        self._sessions: dict[str, SessionToken] = {}
        self._signing_key = signing_key or secrets.token_bytes(32)

    # -- administration -------------------------------------------------
    def register_role(self, role: Role) -> Role:
        if role.name in self._roles:
            raise ValueError(f"role {role.name!r} already registered")
        self._roles[role.name] = role
        return role

    def bind(self, principal: Principal, role_name: str) -> None:
        """Grant ``role_name`` to ``principal`` (incremental, least privilege)."""
        if role_name not in self._roles:
            raise KeyError(f"unknown role {role_name!r}")
        self._bindings.setdefault(principal.principal_id, set()).add(role_name)

    def unbind(self, principal: Principal, role_name: str) -> None:
        self._bindings.get(principal.principal_id, set()).discard(role_name)

    def roles_of(self, principal_id: str) -> set[str]:
        return set(self._bindings.get(principal_id, set()))

    # -- authentication / sessions --------------------------------------
    def login(
        self, principal_id: str, secret: str, role_name: str | None = None,
        lifetime_s: float = API_TOKEN_LIFETIME_S,
    ) -> SessionToken:
        """OAuth2-style exchange: credentials -> short-term delegated token."""
        principal = self.authenticator.authenticate(principal_id, secret)
        granted = self.roles_of(principal.principal_id)
        if role_name is None:
            if not granted:
                raise AuthorizationError(
                    f"{principal_id!r} has no roles (least privilege default)")
            role_name = sorted(granted)[0]
        if role_name not in granted:
            raise AuthorizationError(f"{principal_id!r} is not bound to {role_name!r}")
        return self._issue(principal.principal_id, role_name, lifetime_s)

    def web_session(self, principal_id: str, secret: str) -> SessionToken:
        """Paper: web interface translates tokens into 6-hour sessions."""
        return self.login(principal_id, secret, lifetime_s=WEB_SESSION_LIFETIME_S)

    def service_session(self, role_name: str) -> SessionToken:
        """Bootstrap a session for an *internal* service role."""
        role = self._roles.get(role_name)
        if role is None or not role.internal:
            raise AuthorizationError(f"{role_name!r} is not an internal service role")
        return self._issue(f"service:{role_name}", role_name, WEB_SESSION_LIFETIME_S)

    def _issue(self, principal_id: str, role_name: str, lifetime_s: float,
               parent: str | None = None) -> SessionToken:
        now = self.clock.now()
        tok = SessionToken(
            token_id=secrets.token_hex(16),
            principal_id=principal_id,
            role_name=role_name,
            issued_at=now,
            expires_at=now + lifetime_s,
            parent_token_id=parent,
        )
        self._sessions[tok.token_id] = tok
        return tok

    def _validate(self, token: SessionToken) -> SessionToken:
        live = self._sessions.get(token.token_id)
        if live is None or live != token:
            raise AuthenticationError("unknown or revoked token")
        if self.clock.now() >= token.expires_at:
            raise TokenExpiredError(f"token for {token.principal_id} expired")
        return token

    def revoke(self, token: SessionToken) -> None:
        self._sessions.pop(token.token_id, None)

    # -- role assumption (paper §VI worker dance) ------------------------
    def assume_role(self, token: SessionToken, target_role: str,
                    lifetime_s: float = API_TOKEN_LIFETIME_S) -> SessionToken:
        """Switch to ``target_role`` if the current role is trusted to do so."""
        self._validate(token)
        target = self._roles.get(target_role)
        if target is None:
            raise KeyError(f"unknown role {target_role!r}")
        current = token.role_name
        bound = target_role in self.roles_of(token.principal_id)
        trusted = current in target.trusted_assumers
        if not (bound or trusted):
            self.audit.append(AuditRecord(
                self.clock.now(), token.principal_id, current,
                "sts:AssumeRole", f"role/{target_role}", "deny"))
            raise AuthorizationError(
                f"role {current!r} may not assume {target_role!r}")
        self.audit.append(AuditRecord(
            self.clock.now(), token.principal_id, current,
            "sts:AssumeRole", f"role/{target_role}", "allow"))
        lifetime = min(lifetime_s, token.expires_at - self.clock.now())
        return self._issue(token.principal_id, target_role, lifetime,
                           parent=token.token_id)

    # -- authorization ---------------------------------------------------
    def is_authorized(self, token: SessionToken, action: str, resource: str) -> bool:
        try:
            self.check(token, action, resource)
            return True
        except SecurityError:
            return False

    def check(self, token: SessionToken, action: str, resource: str) -> None:
        """Default-deny; explicit deny beats allow. Raises on failure."""
        self._validate(token)
        role = self._roles.get(token.role_name)
        decision = "deny"
        if role is not None:
            matches = [p for p in role.policies if p.matches(action, resource)]
            if matches and not any(p.effect == "deny" for p in matches):
                decision = "allow"
        self.audit.append(AuditRecord(
            self.clock.now(), token.principal_id, token.role_name,
            action, resource, decision))
        if decision != "allow":
            raise AuthorizationError(
                f"{token.principal_id} ({token.role_name}) denied {action} on {resource}")

    # -- signed URLs -------------------------------------------------------
    def sign_url(self, token: SessionToken, resource: str,
                 lifetime_s: float = API_TOKEN_LIFETIME_S) -> str:
        """Short-term capability link for sharing a single object (paper §VI)."""
        self.check(token, "data:Share", resource)
        expires = int(self.clock.now() + lifetime_s)
        msg = f"{resource}|{expires}".encode()
        sig = hmac.new(self._signing_key, msg, hashlib.sha256).hexdigest()
        return f"kotta://{resource}?expires={expires}&sig={sig}"

    def verify_url(self, url: str) -> str:
        """Return the resource if the signed URL is intact and unexpired."""
        if not url.startswith("kotta://"):
            raise AuthorizationError("not a kotta signed URL")
        body = url[len("kotta://"):]
        resource, _, query = body.partition("?")
        params = dict(kv.split("=", 1) for kv in query.split("&") if "=" in kv)
        try:
            expires = int(params["expires"])
            sig = params["sig"]
        except (KeyError, ValueError) as e:
            raise AuthorizationError("malformed signed URL") from e
        msg = f"{resource}|{expires}".encode()
        want = hmac.new(self._signing_key, msg, hashlib.sha256).hexdigest()
        if not hmac.compare_digest(want, sig):
            raise AuthorizationError("signature mismatch")
        if self.clock.now() >= expires:
            raise TokenExpiredError("signed URL expired")
        return resource


# ---------------------------------------------------------------------------
# Standard Kotta deployment roles (paper Fig 3)
# ---------------------------------------------------------------------------

def install_standard_roles(engine: PolicyEngine) -> dict[str, Role]:
    """Register the paper's predefined roles and return them by name."""
    roles = {
        "kotta-public-only": Role(
            "kotta-public-only",
            policies=[allow(["data:Get", "data:List"], ["dataset/public/*"])],
        ),
        "web-server": Role(
            "web-server",
            policies=[
                allow(["db:*", "queue:Put", "queue:List", "jobs:*"], ["*"]),
                allow(["data:List"], ["dataset/*"]),
            ],
            internal=True,
        ),
        "task-executor": Role(
            "task-executor",
            policies=[
                allow(["db:Get", "db:Put", "queue:Get", "queue:Ack", "queue:Put"], ["*"]),
                allow(["data:Get", "data:Put"], ["results/*", "scratch/*"]),
                allow(["scale:Report"], ["pool/*"]),
            ],
            internal=True,
        ),
        "queue-watcher": Role(
            "queue-watcher",
            policies=[
                allow(["db:*", "queue:*", "scale:*"], ["*"]),
            ],
            internal=True,
        ),
    }
    for r in roles.values():
        engine.register_role(r)
    return roles


def make_dataset_role(engine: PolicyEngine, dataset: str,
                      downloadable: bool = False) -> Role:
    """Create the paper's ``kotta-read-<DS>-private`` style role.

    Non-downloadable datasets are readable only by compute (the worker's
    assumed role), mirroring the paper's "read-only access to specified
    compute nodes" bucket policies: the role is granted ``data:Get`` but a
    explicit deny on ``data:Download`` keeps bytes inside the enclave.
    """
    policies = [allow(["data:Get", "data:List"], [f"dataset/{dataset}/*"])]
    if downloadable:
        policies.append(allow(["data:Download", "data:Share"], [f"dataset/{dataset}/*"]))
    else:
        policies.append(deny(["data:Download"], [f"dataset/{dataset}/*"]))
    role = Role(f"kotta-read-{dataset}-private", policies=policies,
                trusted_assumers={"task-executor"})
    engine.register_role(role)
    return role


def make_serving_role(engine: PolicyEngine, tenant: str,
                      models: Iterable[str] = ("serve",),
                      data_zones: Iterable[str] = ()) -> Role:
    """Per-tenant serving-gateway role: ``kotta-serve-<tenant>``.

    Grants ``serve:Generate`` on the named model resources and ``data:Get``
    on the tenant's data zones (the prompt-context datasets the gateway
    checks at submit). Principals without this role are denied at the
    gateway — default-deny, with the deny audit-logged — and the gateway
    additionally namespaces the KV prefix cache by (tenant principal,
    data-zone), so authorization and cache isolation share one boundary.
    """
    policies = [allow(["serve:Generate"], [f"model/{m}" for m in models])]
    zones = tuple(data_zones)
    if zones:
        policies.append(allow(["data:Get"],
                              [f"dataset/{z}/*" for z in zones]))
    role = Role(f"kotta-serve-{tenant}", policies=policies)
    engine.register_role(role)
    return role


def provision_tenant(engine: PolicyEngine, tenant: str, secret: str,
                     models: Iterable[str] = ("serve",),
                     data_zones: Iterable[str] = ()) -> SessionToken:
    """Register a serving tenant end to end and return a live session.

    One call covers the identity + role + binding + login dance the
    gateway's launcher, benchmark and tests all need: the principal is
    registered with ``secret``, granted a fresh ``kotta-serve-<tenant>``
    role (see :func:`make_serving_role`), and logged in.
    """
    principal = Principal(tenant)
    engine.authenticator.register_identity(principal, secret)
    role = make_serving_role(engine, tenant, models=models,
                             data_zones=data_zones)
    engine.bind(principal, role.name)
    return engine.login(tenant, secret)
