"""Cost models (paper §V, §VII-B, §VII-E).

Reproduces, with the paper's own constants:

- S3-Standard / S3-Infrequent-Access / Glacier storage pricing and the
  lifecycle-policy cost model, Eqs (1)-(3) / Table III.
- Glacier retrieval (peak-rate) pricing, Eqs (1)-(2).
- Cost-aware placement with inter-region egress, Eqs (4)-(5) / Fig 7.
- EC2 on-demand/spot instance pricing used by Table VII-C.

Note on Eq (3): as printed in the paper the active fraction ``A_data``
multiplies the *Glacier* term, which cannot reproduce the paper's own
Table III ($880.259 for STD30-IA60-Glacier at 3%). Solving the table
backwards shows the intended semantics: the **active** fraction cycles
through STD→IA (amortised ``(C_std + 2·C_ia)/3`` per month over the
3-month window) while the **inactive** ``1 - A_data`` fraction rests in
Glacier. With that reading we match all Table III rows to the cent:

    STD30-IA60-Glacier(3%):  (C_std + 2·C_ia)/3 · 0.03 + C_gl · 0.97 = $880.26/yr
    STD30-IA60-Glacier(10%): ... = $974.20/yr

The paper also uses decimal units (10 TB = 10,000 GB); we follow suit.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

GB = 1.0  # all sizes in this module are decimal GB, as in the paper


@dataclass(frozen=True)
class StoragePricing:
    """2016-era AWS storage prices (paper Table III / Fig 2)."""

    # S3-Standard tiered $/GB-month: first 1 TB, next 49 TB, beyond.
    s3_std_tiers: tuple[tuple[float, float], ...] = (
        (1_000.0, 0.0300),
        (49_000.0, 0.0295),
        (math.inf, 0.0290),
    )
    s3_ia_per_gb_month: float = 0.0125
    glacier_per_gb_month: float = 0.0070
    ebs_per_gb_month: float = 0.1000          # gp2, for the static-EBS strawman
    glacier_free_monthly_frac: float = 0.05   # 5% of stored data/month free
    glacier_retrieval_hours: float = 4.0      # paper: avg retrieval time
    glacier_retrieval_per_gb_hour: float = 0.011  # $ per GB/hr of peak rate
    inter_region_transfer_per_gb: float = 0.020   # paper ref [10]
    s3_request_per_10k: float = 0.004             # noted as negligible


@dataclass(frozen=True)
class ComputePricing:
    """2016-era EC2 prices for the instance types in §VII."""

    on_demand_per_hour: dict[str, float] = field(default_factory=lambda: {
        "m4.xlarge": 0.239,   # §VII-D throughput experiment
        "c4.8xlarge": 1.675,  # §VII-E cost-aware provisioning
        "r3.8xlarge": 2.660,
    })
    # Long-run average spot discount observed in the paper's Table VII-C
    # ($10.26 spot vs $74.57 on-demand for the same node-hours).
    typical_spot_fraction: float = 0.138


def s3_std_monthly(gb: float, pricing: StoragePricing | None = None) -> float:
    """Tiered S3-Standard $/month for ``gb`` stored."""
    p = pricing or StoragePricing()
    remaining, cost = gb, 0.0
    for tier_gb, rate in p.s3_std_tiers:
        take = min(remaining, tier_gb)
        cost += take * rate
        remaining -= take
        if remaining <= 0:
            break
    return cost


def s3_ia_monthly(gb: float, pricing: StoragePricing | None = None) -> float:
    p = pricing or StoragePricing()
    return gb * p.s3_ia_per_gb_month


def glacier_monthly(gb: float, pricing: StoragePricing | None = None) -> float:
    p = pricing or StoragePricing()
    return gb * p.glacier_per_gb_month


def glacier_retrieval_monthly(
    daily_peak_gb: float,
    glacier_stored_gb: float,
    pricing: StoragePricing | None = None,
) -> float:
    """Paper Eqs (1)-(2): peak-rate Glacier retrieval fee for one month.

    ``daily_peak_gb`` is the largest single-day retrieval volume, assumed to be
    pulled within ``glacier_retrieval_hours`` (4 h). The free quota is 5% of
    stored data per month, pro-rated daily and spread over the same window.
    """
    p = pricing or StoragePricing()
    tx_time = p.glacier_retrieval_hours
    tx_peak = daily_peak_gb / tx_time                                   # Eq (1)
    tx_quota = glacier_stored_gb * p.glacier_free_monthly_frac / (30 * tx_time)
    if tx_peak <= tx_quota:
        return 0.0                                                       # Eq (2)
    return (tx_peak - tx_quota) * p.glacier_retrieval_per_gb_hour * 720.0


@dataclass(frozen=True)
class LifecycleCost:
    storage_annual: float
    access_annual: float
    access_hours: float  # retrieval latency exposure (0 when no Glacier stage)


def lifecycle_annual_cost(
    policy: str,
    total_gb: float,
    active_frac: float = 0.0,
    annual_recalls: int = 1,
    pricing: StoragePricing | None = None,
) -> LifecycleCost:
    """Annual cost of a storage strategy over ``total_gb`` (paper Table III).

    ``policy`` is one of the paper's strategies:
      ``"STD"`` | ``"IA"`` | ``"GLACIER"`` | ``"STD30-IA"`` | ``"STD30-IA60-GLACIER"``
    ``active_frac`` is A_data — the fraction of data touched within a 3-month
    window (paper: 3-10%). ``annual_recalls`` is how many times per year the
    active set is pulled back out of Glacier (for strategies that archive it).
    """
    p = pricing or StoragePricing()
    policy = policy.upper()
    std_mo = s3_std_monthly(total_gb, p)
    ia_mo = s3_ia_monthly(total_gb, p)
    gl_mo = glacier_monthly(total_gb, p)

    if policy == "STD":
        return LifecycleCost(12 * std_mo, 0.0, 0.0)
    if policy == "IA":
        return LifecycleCost(12 * ia_mo, 0.0, 0.0)
    if policy == "GLACIER":
        # Everything lives in Glacier; every month the working set (A_data
        # spread over its 3-month window) must be recalled in a one-day burst.
        burst = total_gb * active_frac / 3.0
        fee = glacier_retrieval_monthly(burst, total_gb, p)
        return LifecycleCost(12 * gl_mo, fee * 12, p.glacier_retrieval_hours)
    if policy == "STD30-IA":
        # Month 1 in STD, 11 months in IA (no access ⇒ everything ages out).
        return LifecycleCost(std_mo + 11 * ia_mo, 0.0, 0.0)
    if policy in ("STD30-IA60-GLACIER", "STD30-IA60-GL"):
        # Active fraction cycles STD(1mo)→IA(2mo); inactive rests in Glacier.
        cycle_mo = (std_mo + 2 * ia_mo) / 3.0
        storage_mo = cycle_mo * active_frac + gl_mo * (1.0 - active_frac)
        # Occasional recalls of archived objects: the paper reports a fixed
        # $169.73/yr for both the 3% and 10% policies; a one-day burst of the
        # monthly working set (total·A/3) priced by Eqs (1)-(2) yields $165.0
        # (the small residual comes from the paper mixing binary/decimal GB;
        # with 10 TiB the same formula gives $169.75). We use decimal GB
        # throughout, matching the storage column exactly.
        burst = total_gb * active_frac / 3.0
        fee = glacier_retrieval_monthly(burst, total_gb, p) * annual_recalls
        return LifecycleCost(12 * storage_mo, fee, p.glacier_retrieval_hours)
    raise ValueError(f"unknown storage policy {policy!r}")


# ---------------------------------------------------------------------------
# Cost-aware placement (paper §VII-E, Eqs (4)-(5), Fig 7)
# ---------------------------------------------------------------------------

def placement_cost(
    instance_price_per_hour: float,
    hours: float,
    data_down_gb: float,
    data_up_gb: float,
    same_region_as_data: bool,
    pricing: StoragePricing | None = None,
) -> float:
    """Total cost of a placement choice: P_total = P_i + P_transfer."""
    p = pricing or StoragePricing()
    compute = instance_price_per_hour * hours
    if same_region_as_data:
        transfer = 0.0                                                   # Eq (5)
    else:
        transfer = (data_down_gb + data_up_gb) * p.inter_region_transfer_per_gb
    return compute + transfer                                            # Eq (4)


# ---------------------------------------------------------------------------
# Roofline hardware constants (assignment: TPU v5e-class target)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TpuChipSpec:
    name: str = "tpu-v5e"
    peak_bf16_flops: float = 197e12   # FLOP/s
    hbm_bandwidth: float = 819e9      # B/s
    ici_link_bandwidth: float = 50e9  # B/s per link
    hbm_bytes: float = 16 * 1024**3


TPU_V5E = TpuChipSpec()
