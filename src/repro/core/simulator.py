"""Discrete-event simulator for the elastic-scaling study (paper §VII-C).

Reproduces Table VII-C and Fig 5: a 40-job workload submitted over four hours
(Poisson inter-arrivals, mean 0.1667 h), job durations {1, 3, 4} h with mix
{40%, 20%, 40%} (±5% jitter), input datasets of {1,3,5,7,9} GB staged from the
object store, executed under the *none / limited / unlimited* scaling
strategies on on-demand or spot markets.

The simulator shares its decision logic (``Provisioner``) and price model
(``SpotMarket``) with the live runtime, so the benchmark exercises the same
policy code that schedules real JAX jobs.
"""
from __future__ import annotations

import heapq
import itertools
import math
import random
from dataclasses import dataclass, field
from typing import Optional

from .clock import hours
from .cost import ComputePricing
from .elastic import Provisioner, ProvisioningModel, ScalingPolicy
from .market import DEFAULT_ZONES, SpotMarket


@dataclass
class SimJob:
    job_id: int
    arrival_s: float
    duration_s: float
    data_gb: float
    # filled during simulation
    stage_start_s: Optional[float] = None
    exec_start_s: Optional[float] = None
    done_s: Optional[float] = None
    attempts: int = 0

    @property
    def wait_s(self) -> float:
        return (self.stage_start_s or 0.0) - self.arrival_s


@dataclass
class SimInstance:
    inst_id: int
    market: str                     # "spot" | "on_demand"
    requested_s: float
    ready_s: Optional[float] = None
    terminated_s: Optional[float] = None
    idle_since_s: Optional[float] = None
    busy_job: Optional[int] = None
    revoked: bool = False

    def alive_hours(self) -> float:
        if self.ready_s is None or self.terminated_s is None:
            return 0.0
        return max(0.0, (self.terminated_s - self.ready_s) / 3600.0)


def make_paper_workload(seed: int = 7, n_jobs: int = 40,
                        window_h: float = 4.0) -> list[SimJob]:
    """The §VII-C synthetic workload."""
    rng = random.Random(seed)
    jobs, t = [], 0.0
    durations = [1.0, 3.0, 4.0]
    weights = [0.4, 0.2, 0.4]
    mean_interarrival_h = window_h / n_jobs  # paper: λ = 0.1667 h
    for i in range(n_jobs):
        t += rng.expovariate(1.0 / mean_interarrival_h)
        base_h = rng.choices(durations, weights)[0]
        dur_h = base_h * (1.0 + rng.uniform(-0.05, 0.05))
        data_gb = rng.choice([1.0, 3.0, 5.0, 7.0, 9.0])
        jobs.append(SimJob(i, hours(t), hours(dur_h), data_gb))
    return jobs


@dataclass
class SimReport:
    policy: str
    min_nodes: int
    max_nodes: Optional[int]
    makespan_s: float
    spot_cost: float
    on_demand_cost: float
    max_wait_s: float
    avg_wait_s: float
    revocations: int
    resubmissions: int
    peak_instances: int
    instance_hours: float
    jobs: list[SimJob] = field(default_factory=list)
    timeline: list[tuple[float, int, int]] = field(default_factory=list)  # (t, total, idle)


class ElasticSimulator:
    """Event-driven model of queues + provisioner + market."""

    ARRIVE, READY, STAGED, DONE, IDLE_CHECK, HOUR = range(6)

    def __init__(self, policy: ScalingPolicy,
                 workload: list[SimJob],
                 market: SpotMarket | None = None,
                 provisioning: ProvisioningModel | None = None,
                 pricing: ComputePricing | None = None,
                 instance_type: str = "m4.xlarge",
                 stage_bw_gb_s: float = 0.1,
                 stage_out_s: float = 10.0,
                 seed: int = 0):
        self.policy = policy
        self.provisioner = Provisioner(policy, provisioning, seed=seed)
        self.market = market or SpotMarket(seed=seed)
        self.pricing = pricing or ComputePricing()
        self.instance_type = instance_type
        self.zone = DEFAULT_ZONES[0]
        self.stage_bw_gb_s = stage_bw_gb_s
        self.stage_out_s = stage_out_s
        self.workload = [SimJob(j.job_id, j.arrival_s, j.duration_s, j.data_gb)
                         for j in workload]
        self._seq = itertools.count()
        self._events: list[tuple[float, int, int, tuple]] = []
        self._queue: list[int] = []
        self._instances: dict[int, SimInstance] = {}
        self._inst_ids = itertools.count()
        self._revocations = 0
        self._resubmissions = 0
        self._timeline: list[tuple[float, int, int]] = []

    # -- event helpers ------------------------------------------------------
    def _push(self, t: float, kind: int, payload: tuple = ()) -> None:
        heapq.heappush(self._events, (t, next(self._seq), kind, payload))

    def _price(self, t: float) -> float:
        return self.market.price(self.zone, self.instance_type, t / 3600.0)

    def _od_price(self) -> float:
        return self.pricing.on_demand_per_hour[self.instance_type]

    # -- accounting -----------------------------------------------------------
    def _bill(self, inst: SimInstance) -> tuple[float, float]:
        """(spot_cost, on_demand_cost) for an instance's lifetime."""
        if inst.ready_s is None or inst.terminated_s is None:
            return 0.0, 0.0
        od, spot = self._od_price(), 0.0
        t = inst.ready_s
        while t < inst.terminated_s:
            nxt = min(inst.terminated_s, (math.floor(t / 3600.0) + 1) * 3600.0)
            frac_h = (nxt - t) / 3600.0
            spot += frac_h * (self._price(t) if inst.market == "spot" else od)
            t = nxt
        return spot, od * inst.alive_hours()

    # -- main loop ---------------------------------------------------------------
    def run(self) -> SimReport:
        for job in self.workload:
            self._push(job.arrival_s, self.ARRIVE, (job.job_id,))
        self._push(3600.0, self.HOUR)
        # Static floor (the paper's "no scaling" pool exists from t=0).
        self._control(0.0)

        done = 0
        makespan_end = 0.0
        while self._events and done < len(self.workload):
            t, _, kind, payload = heapq.heappop(self._events)
            if kind == self.ARRIVE:
                self._queue.append(payload[0])
            elif kind == self.READY:
                inst = self._instances[payload[0]]
                if not inst.revoked and inst.terminated_s is None:
                    inst.ready_s = t
                    inst.idle_since_s = t
            elif kind == self.STAGED:
                job_id, inst_id = payload
                inst = self._instances[inst_id]
                if inst.busy_job == job_id and inst.terminated_s is None:
                    job = self.workload[job_id]
                    job.exec_start_s = t
                    self._push(t + job.duration_s + self.stage_out_s,
                               self.DONE, (job_id, inst_id))
            elif kind == self.DONE:
                job_id, inst_id = payload
                inst = self._instances[inst_id]
                if inst.busy_job == job_id and inst.terminated_s is None:
                    job = self.workload[job_id]
                    job.done_s = t
                    done += 1
                    makespan_end = max(makespan_end, t)
                    inst.busy_job = None
                    inst.idle_since_s = t
                    self._push(t + self.policy.idle_timeout_s, self.IDLE_CHECK,
                               (inst_id,))
            elif kind == self.IDLE_CHECK:
                inst = self._instances[payload[0]]
                if (inst.terminated_s is None and inst.busy_job is None
                        and inst.idle_since_s is not None):
                    idle_for = t - inst.idle_since_s
                    total = sum(1 for i in self._instances.values()
                                if i.terminated_s is None)
                    if self.provisioner.should_terminate(idle_for, total):
                        inst.terminated_s = t
            elif kind == self.HOUR:
                self._spot_sweep(t)
                if done < len(self.workload):
                    self._push(t + 3600.0, self.HOUR)
            self._control(t)
            total = sum(1 for i in self._instances.values()
                        if i.terminated_s is None and i.ready_s is not None)
            idle = sum(1 for i in self._instances.values()
                       if i.terminated_s is None and i.ready_s is not None
                       and i.busy_job is None)
            self._timeline.append((t, total, idle))

        # Tear down whatever is still alive at the end of the experiment.
        end = makespan_end
        for inst in self._instances.values():
            if inst.terminated_s is None:
                inst.terminated_s = max(end, inst.ready_s or end)

        spot_cost = od_cost = 0.0
        for inst in self._instances.values():
            s, o = self._bill(inst)
            spot_cost += s
            od_cost += o
        waits = [j.wait_s for j in self.workload]
        first = min(j.arrival_s for j in self.workload)
        return SimReport(
            policy=self._policy_name(),
            min_nodes=self.policy.min_nodes,
            max_nodes=self.policy.max_nodes,
            makespan_s=makespan_end - first,
            spot_cost=spot_cost,
            on_demand_cost=od_cost,
            max_wait_s=max(waits),
            avg_wait_s=sum(waits) / len(waits),
            revocations=self._revocations,
            resubmissions=self._resubmissions,
            peak_instances=max((n for _, n, _ in self._timeline), default=0),
            instance_hours=sum(i.alive_hours() for i in self._instances.values()),
            jobs=self.workload,
            timeline=self._timeline,
        )

    def _policy_name(self) -> str:
        if self.policy.max_nodes is None:
            return "unlimited"
        if self.policy.min_nodes == self.policy.max_nodes:
            return "none"
        return "limited"

    # -- pieces ---------------------------------------------------------------
    def _control(self, t: float) -> None:
        """Assign queued jobs to idle instances; provision for the deficit."""
        idle = [i for i in self._instances.values()
                if i.terminated_s is None and i.ready_s is not None
                and i.busy_job is None]
        while self._queue and idle:
            job_id = self._queue.pop(0)
            inst = idle.pop(0)
            job = self.workload[job_id]
            inst.busy_job = job_id
            inst.idle_since_s = None
            job.attempts += 1
            if job.stage_start_s is None:
                job.stage_start_s = t
            self._push(t + job.data_gb / self.stage_bw_gb_s, self.STAGED,
                       (job_id, inst.inst_id))
        provisioning = sum(1 for i in self._instances.values()
                           if i.terminated_s is None and i.ready_s is None)
        total = sum(1 for i in self._instances.values() if i.terminated_s is None)
        n = self.provisioner.launch_count(len(self._queue), len(idle),
                                          provisioning, total)
        for _ in range(n):
            inst = SimInstance(next(self._inst_ids), self.policy.market, t)
            self._instances[inst.inst_id] = inst
            delay = (self.provisioner.provisioning_delay()
                     if self.policy.market == "spot" or t > 0 else 0.0)
            # A static pool (no-scaling) is provisioned ahead of the workload.
            if self.policy.min_nodes == self.policy.max_nodes:
                delay = 0.0
            self._push(t + delay, self.READY, (inst.inst_id,))

    def _spot_sweep(self, t: float) -> None:
        """Hourly revocation check: market price above bid kills instances."""
        if self.policy.market != "spot":
            return
        bid = self._od_price() * self.policy.bid_fraction
        if self._price(t) <= bid:
            return
        for inst in self._instances.values():
            if inst.terminated_s is None and inst.market == "spot":
                inst.terminated_s = t
                inst.revoked = True
                self._revocations += 1
                if inst.busy_job is not None:
                    # Paper §V-B: reschedule on a fresh instance; progress lost.
                    job = self.workload[inst.busy_job]
                    job.exec_start_s = None
                    self._queue.insert(0, inst.busy_job)
                    self._resubmissions += 1
                    inst.busy_job = None


def run_table7c(seed: int = 7) -> list[SimReport]:
    """The five Table VII-C rows."""
    workload = make_paper_workload(seed=seed)
    rows = [
        ScalingPolicy.none(40),
        ScalingPolicy.none(20),
        ScalingPolicy.unlimited(),
        ScalingPolicy.limited(20),
        ScalingPolicy.limited(10),
    ]
    return [ElasticSimulator(p, workload, seed=seed).run() for p in rows]
