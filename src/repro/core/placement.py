"""Cost-aware placement policy (paper §VII-E) as a scheduler component.

Chooses where to provision the next instance/slice for a job, accounting for
spot price across zones AND the egress cost of moving the job's data out of
its home region (Eqs (4)-(5)). This is the live-runtime counterpart of
``benchmarks/cost_aware.py``; the KottaService provisioner can consult it
when acquiring capacity.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

from .cost import StoragePricing, placement_cost
from .market import AvailabilityZone, SpotMarket


@dataclass(frozen=True)
class PlacementDecision:
    zone: AvailabilityZone
    hourly_price: float
    expected_total: float
    cross_region: bool


class PlacementPolicy:
    """scope: "az" | "region" | "global" — the paper's three search scopes."""

    def __init__(self, market: SpotMarket, instance_type: str,
                 scope: str = "global",
                 pricing: Optional[StoragePricing] = None):
        if scope not in ("az", "region", "global"):
            raise ValueError(scope)
        self.market = market
        self.instance_type = instance_type
        self.scope = scope
        self.pricing = pricing or StoragePricing()

    def candidates(self, data_region: str) -> Sequence[AvailabilityZone]:
        zones = self.market.zones
        if self.scope == "az":
            return zones[:1]
        if self.scope == "region":
            return tuple(z for z in zones if z.region == data_region)
        return zones

    def place(self, *, data_region: str, est_hours: float,
              data_down_gb: float, data_up_gb: float,
              t_hours: float = 0.0) -> PlacementDecision:
        """Pick the zone minimizing P_total = P_i·h + P_transfer (Eq 4)."""
        best: Optional[PlacementDecision] = None
        for zone in self.candidates(data_region):
            price = self.market.price(zone, self.instance_type, t_hours)
            same = zone.region == data_region
            total = placement_cost(price, est_hours, data_down_gb,
                                   data_up_gb, same, self.pricing)
            if best is None or total < best.expected_total:
                best = PlacementDecision(zone, price, total, not same)
        assert best is not None
        return best
