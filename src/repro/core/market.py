"""Spot-market model (paper §IV-C, §V-B, §VII-E).

Generates deterministic, seeded price traces per (region, AZ, instance type)
that qualitatively match 2016-era EC2 spot behaviour: prices hover at a
fraction of on-demand with mean reversion, plus occasional sharp spikes above
on-demand local to a single AZ ("spot market volatility", §VII-C). The traces
drive:

- revocation of preemptible workers (price crosses the bid),
- the Fig-7 cost-aware placement comparison across 10 AZs in 4 regions.

The adaptation note: on a TPU fleet the same object models preemptible slice
reclamation; "AZ" maps to a pod/cell and "region" to a datacenter.
"""
from __future__ import annotations

import hashlib
import math
from dataclasses import dataclass, field

import numpy as np

from .cost import ComputePricing


@dataclass(frozen=True)
class AvailabilityZone:
    region: str
    name: str  # e.g. "us-east-1a"


# The paper's experiment: ten AZs spread across four regions.
DEFAULT_ZONES: tuple[AvailabilityZone, ...] = (
    AvailabilityZone("us-east-1", "us-east-1a"),
    AvailabilityZone("us-east-1", "us-east-1b"),
    AvailabilityZone("us-east-1", "us-east-1d"),
    AvailabilityZone("us-west-2", "us-west-2a"),
    AvailabilityZone("us-west-2", "us-west-2b"),
    AvailabilityZone("us-west-2", "us-west-2c"),
    AvailabilityZone("eu-west-1", "eu-west-1a"),
    AvailabilityZone("eu-west-1", "eu-west-1b"),
    AvailabilityZone("ap-southeast-1", "ap-southeast-1a"),
    AvailabilityZone("ap-southeast-1", "ap-southeast-1b"),
)


def _zone_seed(seed: int, zone: AvailabilityZone, instance_type: str) -> int:
    h = hashlib.sha256(f"{seed}:{zone.region}:{zone.name}:{instance_type}".encode())
    return int.from_bytes(h.digest()[:8], "big")


@dataclass
class SpotMarket:
    """Hourly spot-price traces with mean reversion and AZ-local spikes."""

    seed: int = 0
    pricing: ComputePricing = field(default_factory=ComputePricing)
    zones: tuple[AvailabilityZone, ...] = DEFAULT_ZONES
    base_fraction: float = 0.138      # long-run spot/on-demand ratio (Table VII-C)
    volatility: float = 0.25          # per-step lognormal sigma
    reversion: float = 0.20           # pull toward base each hour
    spike_prob: float = 0.01          # per-hour probability of an AZ spike
    spike_mult: tuple[float, float] = (2.0, 12.0)  # spike height ×on-demand base frac
    spike_duration_h: tuple[int, int] = (1, 5)
    # Revocation notice window (the EC2 2-minute spot warning): a consumer
    # polling ``notice`` learns ``notice_s`` seconds ahead that the price is
    # about to cross its bid, long enough to evacuate state gracefully.
    notice_s: float = 120.0

    def on_demand_price(self, instance_type: str) -> float:
        return self.pricing.on_demand_per_hour[instance_type]

    def trace(self, zone: AvailabilityZone, instance_type: str,
              hours: int) -> np.ndarray:
        """Deterministic hourly price trace of length ``hours``."""
        rng = np.random.default_rng(_zone_seed(self.seed, zone, instance_type))
        od = self.on_demand_price(instance_type)
        base = od * self.base_fraction * float(rng.uniform(0.6, 1.6))
        log_p = math.log(base)
        prices = np.empty(hours)
        spike_left, spike_level = 0, 0.0
        for t in range(hours):
            log_p += self.reversion * (math.log(base) - log_p)
            log_p += self.volatility * float(rng.standard_normal())
            p = math.exp(log_p)
            if spike_left > 0:
                p = max(p, spike_level)
                spike_left -= 1
            elif rng.random() < self.spike_prob:
                spike_left = int(rng.integers(*self.spike_duration_h))
                spike_level = base * float(rng.uniform(*self.spike_mult))
            prices[t] = min(p, od * 10.0)  # EC2 caps bids at 10x on-demand
        return prices

    def price(self, zone: AvailabilityZone, instance_type: str, t_hours: float) -> float:
        idx = max(0, int(t_hours))
        return float(self.trace(zone, instance_type, idx + 1)[idx])

    def cheapest_zone(self, instance_type: str, t_hours: float,
                      zones: tuple[AvailabilityZone, ...] | None = None,
                      ) -> tuple[AvailabilityZone, float]:
        zs = zones or self.zones
        best = min(zs, key=lambda z: self.price(z, instance_type, t_hours))
        return best, self.price(best, instance_type, t_hours)

    def revoked(self, zone: AvailabilityZone, instance_type: str,
                bid: float, t_hours: float) -> bool:
        """True if the market price exceeds the bid at time t."""
        return self.price(zone, instance_type, t_hours) > bid

    def notice(self, zone: AvailabilityZone, instance_type: str,
               bid: float, t_hours: float) -> bool:
        """Revocation notice: the price will exceed ``bid`` ``notice_s``
        seconds from ``t_hours``. The trace is deterministic, so the notice
        is exact — a consumer that polls every round sees it fire exactly
        one window ahead of :meth:`revoked` flipping true."""
        return self.revoked(zone, instance_type, bid,
                            t_hours + self.notice_s / 3600.0)
