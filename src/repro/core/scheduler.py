"""Reliable job management (paper §IV-D) — the threaded runtime.

Components, mirroring the paper's architecture figure:

- ``StateStore``   — the DynamoDB analogue: a transactional key-value store
  with **provisioned read/write capacity** (token buckets). The Fig-6
  throughput experiment saturates exactly here, like the paper's.
- ``JobQueue``     — the SQS analogue: leases with visibility timeouts.
- ``Worker``       — polls a queue, loads the task description from the
  StateStore, *assumes the submitting user's role* to stage inputs, reverts to
  ``task-executor`` for execution, writes status markers/heartbeats, stages
  outputs back, marks itself idle (the full §VI worker dance).
- ``QueueWatcher`` — resubmits tasks whose worker heartbeat went stale (spot
  revocation) and launches **speculative duplicates** of stragglers
  (beyond-paper: mitigation for slow nodes at scale).
- ``KottaService`` — user-facing facade: submit/monitor jobs, with RBAC.

Jobs whose inputs are still in ``ARCHIVE`` are parked in a *restore queue*
until the object store reports availability (paper §V-A).
"""
from __future__ import annotations

import enum
import itertools
import statistics
import threading
import uuid
import zlib
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Optional

from .clock import Clock
from .lifecycle import ObjectArchivedError, ObjectStore, SecureStorage, Tier
from .security import AuthorizationError, PolicyEngine, SessionToken


# ---------------------------------------------------------------------------
# StateStore (DynamoDB analogue)
# ---------------------------------------------------------------------------

class _TokenBucket:
    """Provisioned-capacity limiter: ``rate`` ops/s, burst = rate."""

    def __init__(self, rate: float, clock: Clock):
        self.rate = float(rate)
        self.clock = clock
        self._tokens = float(rate)
        self._last = clock.now()
        self._lock = threading.Lock()

    def acquire(self, n: float = 1.0) -> None:
        while True:
            with self._lock:
                now = self.clock.now()
                self._tokens = min(self.rate, self._tokens + (now - self._last) * self.rate)
                self._last = now
                if self._tokens >= n:
                    self._tokens -= n
                    return
                wait = (n - self._tokens) / self.rate
            self.clock.sleep(wait)

    def try_acquire(self, n: float = 1.0) -> bool:
        """Non-blocking acquire: take ``n`` tokens if available now, else
        report a throttle. The single-threaded serve gateway advances its
        own VirtualClock, so it can never block in ``acquire`` (nothing
        else would advance the clock) — its telemetry writes use this path
        and count the refusals, which is also exactly the DynamoDB
        ProvisionedThroughputExceeded signal the Fig-6 saturation
        experiment is about."""
        with self._lock:
            now = self.clock.now()
            self._tokens = min(self.rate, self._tokens + (now - self._last) * self.rate)
            self._last = now
            if self._tokens >= n:
                self._tokens -= n
                return True
            return False


class StateStore:
    """Transactional item store with provisioned read/write capacity.

    The paper provisioned DynamoDB at 100 reads/s and 400 writes/s for the
    throughput experiment; those are the defaults here.

    Blocking ops (``put_item`` …) wait out a capacity shortfall on the
    clock — correct for worker threads under a driver that advances the
    VirtualClock. The ``try_*`` variants never block: they fail fast and
    bump ``throttled_writes`` / ``throttled_reads``, for callers that ARE
    the clock driver (the serve gateway's telemetry flush).
    """

    def __init__(self, clock: Clock | None = None,
                 read_capacity: float = 100.0, write_capacity: float = 400.0):
        self.clock = clock or Clock()
        self.read_capacity = float(read_capacity)
        self.write_capacity = float(write_capacity)
        self._items: dict[str, dict[str, Any]] = {}
        self._lock = threading.Lock()
        self._reads = _TokenBucket(read_capacity, self.clock)
        self._writes = _TokenBucket(write_capacity, self.clock)
        self.read_count = 0
        self.write_count = 0
        self.throttled_reads = 0
        self.throttled_writes = 0

    def put_item(self, key: str, item: dict[str, Any]) -> None:
        self._writes.acquire()
        with self._lock:
            self._items[key] = dict(item)
            self.write_count += 1

    def update_item(self, key: str, **updates: Any) -> None:
        self._writes.acquire()
        with self._lock:
            self._items.setdefault(key, {}).update(updates)
            self.write_count += 1

    def get_item(self, key: str) -> Optional[dict[str, Any]]:
        self._reads.acquire()
        with self._lock:
            self.read_count += 1
            item = self._items.get(key)
            return dict(item) if item is not None else None

    def scan(self, prefix: str = "") -> dict[str, dict[str, Any]]:
        self._reads.acquire()
        with self._lock:
            self.read_count += 1
            return {k: dict(v) for k, v in self._items.items() if k.startswith(prefix)}

    # -- non-blocking (throttle-counting) variants ---------------------------
    def try_put_item(self, key: str, item: dict[str, Any]) -> bool:
        if not self._writes.try_acquire():
            with self._lock:
                self.throttled_writes += 1
            return False
        with self._lock:
            self._items[key] = dict(item)
            self.write_count += 1
        return True

    def try_update_item(self, key: str, **updates: Any) -> bool:
        if not self._writes.try_acquire():
            with self._lock:
                self.throttled_writes += 1
            return False
        with self._lock:
            self._items.setdefault(key, {}).update(updates)
            self.write_count += 1
        return True

    def try_get_item(self, key: str) -> tuple[bool, Optional[dict[str, Any]]]:
        """(served, item) — ``(False, None)`` means throttled, not absent."""
        if not self._reads.try_acquire():
            with self._lock:
                self.throttled_reads += 1
            return False, None
        with self._lock:
            self.read_count += 1
            item = self._items.get(key)
            return True, (dict(item) if item is not None else None)


class ShardedStateStore:
    """Hash-by-key sharding over N :class:`StateStore` partitions.

    The Kotta scaling move for the telemetry table: when one table's
    provisioned write capacity becomes the wall (Fig-6's ~1800 job/s knee),
    you shard the key space so each partition brings its own token bucket.
    Keys route by ``crc32(key) % shards`` — stable across processes and
    hash-seed randomization (the same choice as the serve stack's page
    hashing), so an item always lands on the shard that holds it.

    Aggregate ``write_count`` / ``throttled_writes`` / … sum over shards;
    ``scan`` merges every shard's view. With N shards of the same per-shard
    capacity the sustained write rate is N× a single store — asserted by
    the tier-1 overload tests.
    """

    def __init__(self, shards: int = 4, clock: Clock | None = None,
                 read_capacity: float = 100.0, write_capacity: float = 400.0):
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        self.clock = clock or Clock()
        self.shards = [StateStore(self.clock, read_capacity, write_capacity)
                       for _ in range(shards)]

    def shard_for(self, key: str) -> StateStore:
        return self.shards[zlib.crc32(key.encode()) % len(self.shards)]

    def put_item(self, key: str, item: dict[str, Any]) -> None:
        self.shard_for(key).put_item(key, item)

    def update_item(self, key: str, **updates: Any) -> None:
        self.shard_for(key).update_item(key, **updates)

    def get_item(self, key: str) -> Optional[dict[str, Any]]:
        return self.shard_for(key).get_item(key)

    def try_put_item(self, key: str, item: dict[str, Any]) -> bool:
        return self.shard_for(key).try_put_item(key, item)

    def try_update_item(self, key: str, **updates: Any) -> bool:
        return self.shard_for(key).try_update_item(key, **updates)

    def try_get_item(self, key: str) -> tuple[bool, Optional[dict[str, Any]]]:
        return self.shard_for(key).try_get_item(key)

    def scan(self, prefix: str = "") -> dict[str, dict[str, Any]]:
        merged: dict[str, dict[str, Any]] = {}
        for shard in self.shards:
            merged.update(shard.scan(prefix))
        return merged

    @property
    def read_count(self) -> int:
        return sum(s.read_count for s in self.shards)

    @property
    def write_count(self) -> int:
        return sum(s.write_count for s in self.shards)

    @property
    def throttled_reads(self) -> int:
        return sum(s.throttled_reads for s in self.shards)

    @property
    def throttled_writes(self) -> int:
        return sum(s.throttled_writes for s in self.shards)


# ---------------------------------------------------------------------------
# JobQueue (SQS analogue)
# ---------------------------------------------------------------------------

class JobQueue:
    """FIFO queue with leases: unacked messages reappear after the
    visibility timeout — the substrate the queue-watcher relies on."""

    def __init__(self, name: str, clock: Clock | None = None,
                 visibility_timeout_s: float = 3600.0):
        self.name = name
        self.clock = clock or Clock()
        self.visibility_timeout_s = visibility_timeout_s
        self._ready: list[str] = []
        self._leased: dict[str, float] = {}  # msg -> lease expiry
        self._lock = threading.Lock()

    def put(self, msg: str) -> None:
        with self._lock:
            self._ready.append(msg)

    def get(self) -> Optional[str]:
        with self._lock:
            now = self.clock.now()
            expired = [m for m, t in self._leased.items() if t <= now]
            for m in expired:
                del self._leased[m]
                self._ready.append(m)
            if not self._ready:
                return None
            msg = self._ready.pop(0)
            self._leased[msg] = now + self.visibility_timeout_s
            return msg

    def ack(self, msg: str) -> None:
        with self._lock:
            self._leased.pop(msg, None)

    def nack(self, msg: str) -> None:
        with self._lock:
            if msg in self._leased:
                del self._leased[msg]
                self._ready.insert(0, msg)

    def depth(self) -> int:
        with self._lock:
            return len(self._ready)


# ---------------------------------------------------------------------------
# Jobs
# ---------------------------------------------------------------------------

class JobStatus(str, enum.Enum):
    PENDING = "pending"
    WAITING_DATA = "waiting_data"   # parked until archive restore completes
    STAGING = "staging"
    RUNNING = "running"
    STAGING_OUT = "staging_out"
    COMPLETED = "completed"
    FAILED = "failed"
    CANCELLED = "cancelled"


@dataclass
class JobSpec:
    """Complete description of an analysis task (paper §IV-A)."""

    executable: str                      # name in the ExecutableRegistry
    args: dict[str, Any] = field(default_factory=dict)
    inputs: tuple[str, ...] = ()         # object-store keys to stage in
    outputs: tuple[str, ...] = ()        # keys to stage out (under results/)
    max_walltime_s: float = 3600.0
    queue: str = "prod"                  # "dev" | "prod"


class JobCancelled(Exception):
    pass


@dataclass
class JobContext:
    """Handed to executables: staged inputs + cancellation + heartbeat."""

    job_id: str
    staged_inputs: dict[str, bytes]
    outputs: dict[str, bytes] = field(default_factory=dict)
    _cancel: threading.Event = field(default_factory=threading.Event)
    _heartbeat: Optional[Callable[[dict], None]] = None
    clock: Clock = field(default_factory=Clock)

    def should_stop(self) -> bool:
        return self._cancel.is_set()

    def checkpoint(self) -> None:
        """Cooperative cancellation point; call between work slices."""
        if self._cancel.is_set():
            raise JobCancelled(self.job_id)

    def report(self, **markers: Any) -> None:
        if self._heartbeat:
            self._heartbeat(markers)


ExecutableFn = Callable[[JobContext], Any]


class ExecutableRegistry:
    def __init__(self):
        self._fns: dict[str, ExecutableFn] = {}

    def register(self, name: str, fn: ExecutableFn | None = None):
        if fn is None:  # decorator form
            def deco(f):
                self._fns[name] = f
                return f
            return deco
        self._fns[name] = fn
        return fn

    def resolve(self, name: str) -> ExecutableFn:
        if name not in self._fns:
            raise KeyError(f"unknown executable {name!r}")
        return self._fns[name]


# ---------------------------------------------------------------------------
# Worker
# ---------------------------------------------------------------------------

class Worker(threading.Thread):
    """One compute instance. ``preemptible`` workers can be revoked."""

    _ids = itertools.count()

    def __init__(self, service: "KottaService", queue_name: str,
                 preemptible: bool = True, poll_interval_s: float = 0.02):
        super().__init__(daemon=True, name=f"worker-{next(self._ids)}")
        self.service = service
        self.queue_name = queue_name
        self.preemptible = preemptible
        self.poll_interval_s = poll_interval_s
        self.idle = threading.Event()
        self.idle.set()
        self._stop = threading.Event()
        self._revoked = threading.Event()
        self._current_ctx: Optional[JobContext] = None
        self.jobs_done = 0

    # -- control -------------------------------------------------------------
    def shutdown(self) -> None:
        self._stop.set()

    def revoke(self) -> None:
        """Spot revocation: kill the instance; current job dies mid-flight."""
        self._revoked.set()
        self._stop.set()
        ctx = self._current_ctx
        if ctx is not None:
            ctx._cancel.set()

    # -- main loop -------------------------------------------------------------
    def run(self) -> None:
        svc = self.service
        token = svc.engine.service_session("task-executor")
        queue = svc.queues[self.queue_name]
        while not self._stop.is_set():
            job_id = queue.get()
            if job_id is None:
                svc.clock.sleep(self.poll_interval_s)
                continue
            self.idle.clear()
            try:
                self._execute(token, queue, job_id)
            finally:
                self.idle.set()
        svc._worker_exited(self)

    def _execute(self, token: SessionToken, queue: JobQueue, job_id: str) -> None:
        svc = self.service
        rec = svc.db.get_item(f"job/{job_id}")
        if rec is None or rec["status"] in (JobStatus.COMPLETED, JobStatus.CANCELLED):
            queue.ack(job_id)
            return

        spec: JobSpec = svc._specs[job_id]
        now = svc.clock.now()
        svc.db.update_item(f"job/{job_id}", status=JobStatus.STAGING,
                           worker=self.name, heartbeat=now,
                           started_at=rec.get("started_at") or now)

        # Park the job if any input is still archived (§V-A restore queue).
        archived = [k for k in spec.inputs if not svc.store.is_available(k)]
        if archived:
            for k in archived:
                svc.store.restore(k)
            svc.db.update_item(f"job/{job_id}", status=JobStatus.WAITING_DATA,
                               waiting_on=list(archived), worker=None)
            queue.ack(job_id)
            svc._parked[job_id] = tuple(archived)
            return

        # Stage inputs under the *user's* role (assume-role dance, §VI).
        # No inputs -> nothing to stage -> no role switch needed.
        try:
            staged = {}
            if spec.inputs:
                user_token = svc.engine.assume_role(token, rec["role"])
                staged = {k: svc.storage.get(user_token, k)
                          for k in spec.inputs}
                svc.engine.revoke(user_token)
        except AuthorizationError as e:
            svc.db.update_item(f"job/{job_id}", status=JobStatus.FAILED,
                               error=f"staging denied: {e}", completed_at=svc.clock.now())
            queue.ack(job_id)
            return

        ctx = JobContext(job_id=job_id, staged_inputs=staged, clock=svc.clock,
                         _heartbeat=lambda m: svc.db.update_item(
                             f"job/{job_id}", heartbeat=svc.clock.now(), **m))
        if self._revoked.is_set():
            ctx._cancel.set()
        self._current_ctx = ctx
        svc.db.update_item(f"job/{job_id}", status=JobStatus.RUNNING,
                           heartbeat=svc.clock.now())
        try:
            result = svc.registry.resolve(spec.executable)(ctx)
        except JobCancelled:
            # Revocation mid-run: leave the job leased; the queue-watcher (or
            # the visibility timeout) resubmits it.
            svc.db.update_item(f"job/{job_id}", status=JobStatus.PENDING,
                               worker=None, note="revoked mid-run")
            queue.nack(job_id)
            self._current_ctx = None
            return
        except Exception as e:  # noqa: BLE001 - job code is arbitrary
            svc.db.update_item(f"job/{job_id}", status=JobStatus.FAILED,
                               error=repr(e), completed_at=svc.clock.now())
            queue.ack(job_id)
            self._current_ctx = None
            return

        # Stage outputs back as private objects of the submitting user (§VI).
        svc.db.update_item(f"job/{job_id}", status=JobStatus.STAGING_OUT)
        for key, data in ctx.outputs.items():
            svc.store.put(key, data, owner=rec["user"], tier=Tier.STD)

        # First-completion-wins for speculative duplicates.
        final = svc.db.get_item(f"job/{job_id}")
        if final and final["status"] != JobStatus.COMPLETED:
            svc.db.update_item(f"job/{job_id}", status=JobStatus.COMPLETED,
                               exit_code=0, result=repr(result),
                               completed_at=svc.clock.now(), worker=self.name)
        queue.ack(job_id)
        self.jobs_done += 1
        self._current_ctx = None


# ---------------------------------------------------------------------------
# QueueWatcher
# ---------------------------------------------------------------------------

class QueueWatcher(threading.Thread):
    """Monitors heartbeats; resubmits orphaned jobs; unparks restored jobs;
    launches speculative duplicates of stragglers."""

    def __init__(self, service: "KottaService", heartbeat_timeout_s: float = 5.0,
                 straggler_factor: float = 3.0, interval_s: float = 0.05,
                 speculation: bool = True):
        super().__init__(daemon=True, name="queue-watcher")
        self.service = service
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.straggler_factor = straggler_factor
        self.interval_s = interval_s
        self.speculation = speculation
        self._stop = threading.Event()
        self.resubmissions = 0
        self.speculations = 0

    def shutdown(self) -> None:
        self._stop.set()

    def run(self) -> None:
        svc = self.service
        while not self._stop.is_set():
            try:
                self.sweep()
            except Exception:  # noqa: BLE001 - watcher must survive anything
                pass
            svc.clock.sleep(self.interval_s)

    def sweep(self) -> None:
        svc = self.service
        now = svc.clock.now()
        jobs = svc.db.scan("job/")

        # 1. unpark jobs whose archived inputs became available
        for job_id, keys in list(svc._parked.items()):
            if all(svc.store.is_available(k) for k in keys):
                del svc._parked[job_id]
                svc.db.update_item(f"job/{job_id}", status=JobStatus.PENDING,
                                   waiting_on=[])
                svc.queues[svc._specs[job_id].queue].put(job_id)

        durations = [r["completed_at"] - r["started_at"]
                     for r in jobs.values()
                     if r.get("status") == JobStatus.COMPLETED
                     and r.get("completed_at") and r.get("started_at")]
        median = statistics.median(durations) if durations else None

        for key, rec in jobs.items():
            job_id = key.split("/", 1)[1]
            status = rec.get("status")
            if status == JobStatus.RUNNING:
                hb = rec.get("heartbeat", 0.0)
                if now - hb > self.heartbeat_timeout_s:
                    # Worker died (revocation): resubmit.
                    svc.db.update_item(key, status=JobStatus.PENDING, worker=None,
                                       note="resubmitted by queue-watcher",
                                       attempt=rec.get("attempt", 0) + 1)
                    svc.queues[svc._specs[job_id].queue].put(job_id)
                    self.resubmissions += 1
                elif (self.speculation and median is not None
                      and not rec.get("speculated")
                      and now - rec.get("started_at", now) > self.straggler_factor * median):
                    # Straggler: speculative duplicate (first completion wins).
                    svc.db.update_item(key, speculated=True)
                    svc.queues[svc._specs[job_id].queue].put(job_id)
                    self.speculations += 1


# ---------------------------------------------------------------------------
# Service facade
# ---------------------------------------------------------------------------

class KottaService:
    """End-to-end service: security + storage + queues + workers + watcher."""

    def __init__(self, engine: PolicyEngine, store: ObjectStore,
                 registry: ExecutableRegistry | None = None,
                 clock: Clock | None = None,
                 db: StateStore | None = None,
                 watcher_kwargs: dict | None = None):
        self.engine = engine
        self.store = store
        self.storage = SecureStorage(store, engine)
        self.registry = registry or ExecutableRegistry()
        self.clock = clock or Clock()
        self.db = db or StateStore(self.clock)
        self.queues: dict[str, JobQueue] = {
            "dev": JobQueue("dev", self.clock),
            "prod": JobQueue("prod", self.clock),
        }
        self._specs: dict[str, JobSpec] = {}
        self._parked: dict[str, tuple[str, ...]] = {}
        self._workers: list[Worker] = []
        self._lock = threading.Lock()
        self.watcher = QueueWatcher(self, **(watcher_kwargs or {}))
        self._watcher_started = False

    # -- lifecycle -------------------------------------------------------------
    def start(self, dev_workers: int = 1, prod_workers: int = 0) -> None:
        # Paper: the development pool always holds ≥1 reliable on-demand node.
        for _ in range(max(1, dev_workers)):
            self.add_worker("dev", preemptible=False)
        for _ in range(prod_workers):
            self.add_worker("prod", preemptible=True)
        if not self._watcher_started:
            self.watcher.start()
            self._watcher_started = True

    def add_worker(self, queue_name: str, preemptible: bool = True) -> Worker:
        w = Worker(self, queue_name, preemptible=preemptible)
        with self._lock:
            self._workers.append(w)
        w.start()
        return w

    def _worker_exited(self, worker: Worker) -> None:
        with self._lock:
            if worker in self._workers:
                self._workers.remove(worker)

    def workers(self, queue_name: str | None = None) -> list[Worker]:
        with self._lock:
            return [w for w in self._workers
                    if queue_name is None or w.queue_name == queue_name]

    def shutdown(self) -> None:
        self.watcher.shutdown()
        for w in self.workers():
            w.shutdown()
        for w in self.workers():
            w.join(timeout=5.0)

    # -- user API ----------------------------------------------------------------
    def submit(self, token: SessionToken, spec: JobSpec) -> str:
        """Authorize, persist the full task description, enqueue (§IV-D)."""
        self.engine.check(token, "jobs:Submit", f"queue/{spec.queue}")
        for key in spec.inputs:
            # Submission-time authorization of data access under the user role.
            self.engine.check(token, "data:Get", key)
        job_id = uuid.uuid4().hex[:12]
        self._specs[job_id] = spec
        self.db.put_item(f"job/{job_id}", {
            "status": JobStatus.PENDING, "user": token.principal_id,
            "role": token.role_name, "queue": spec.queue,
            "executable": spec.executable,
            "submitted_at": self.clock.now(), "attempt": 0,
        })
        self.queues[spec.queue].put(job_id)
        return job_id

    def status(self, job_id: str) -> dict[str, Any]:
        rec = self.db.get_item(f"job/{job_id}")
        if rec is None:
            raise KeyError(job_id)
        return rec

    def wait(self, job_id: str, timeout_s: float = 30.0,
             poll_s: float = 0.02) -> dict[str, Any]:
        deadline = self.clock.now() + timeout_s
        while self.clock.now() < deadline:
            rec = self.status(job_id)
            if rec["status"] in (JobStatus.COMPLETED, JobStatus.FAILED,
                                 JobStatus.CANCELLED):
                return rec
            self.clock.sleep(poll_s)
        raise TimeoutError(f"job {job_id} still {self.status(job_id)['status']}")
