"""Clock abstraction.

Every time-dependent component in Kotta (token expiry, lifecycle staleness,
queue wait accounting, the discrete-event simulator) takes a ``Clock`` so that
production code uses wall time while tests and the Table VII-C reproduction use
a deterministic virtual clock.
"""
from __future__ import annotations

import heapq
import threading
import time as _time


class Clock:
    """Wall-clock seconds since epoch."""

    def now(self) -> float:
        return _time.time()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            _time.sleep(seconds)


class VirtualClock(Clock):
    """Deterministic manually-advanced clock.

    ``sleep`` registers a wakeup and blocks until some driver advances the
    clock past it (single-threaded DES uses ``advance`` directly and never
    blocks).
    """

    def __init__(self, start: float = 0.0):
        self._now = float(start)
        self._lock = threading.Lock()
        self._wakeups: list[tuple[float, int, threading.Event]] = []
        self._counter = 0

    def now(self) -> float:
        with self._lock:
            return self._now

    def advance(self, seconds: float) -> None:
        self.advance_to(self.now() + seconds)

    def advance_to(self, t: float) -> None:
        with self._lock:
            self._now = max(self._now, float(t))
            due = [w for w in self._wakeups if w[0] <= self._now]
            self._wakeups = [w for w in self._wakeups if w[0] > self._now]
        for _, _, ev in due:
            ev.set()

    def sleep(self, seconds: float) -> None:
        if seconds <= 0:
            return
        ev = threading.Event()
        with self._lock:
            self._counter += 1
            self._wakeups.append((self._now + seconds, self._counter, ev))
        ev.wait()

    def pending_wakeups(self) -> int:
        with self._lock:
            return len(self._wakeups)


SECONDS_PER_DAY = 86_400.0
SECONDS_PER_HOUR = 3_600.0


def days(n: float) -> float:
    return n * SECONDS_PER_DAY


def hours(n: float) -> float:
    return n * SECONDS_PER_HOUR
