"""Tiered object store with automated data-lifecycle management (paper §V-A).

Implements the paper's storage layer:

- Four tiers: ``HOT`` (instance-local / EBS-like staging space), ``STD``
  (S3-Standard), ``IA`` (S3-Infrequent-Access) and ``ARCHIVE`` (Glacier).
- An **LRU staleness lifecycle**: policies like ``STD30-IA60-ARCHIVE`` move an
  object down a tier when it has not been accessed for the stage's staleness
  window (paper Fig 2).
- **Archive semantics**: reading an ``ARCHIVE`` object fails fast with
  ``ObjectArchivedError``; callers request ``restore`` and the object becomes
  readable after the retrieval latency (4 h, paper Table III). The scheduler
  parks jobs on this signal (§V-A: "the job is placed in a separate queue
  until the data is available").
- **Server-side encryption at rest** (paper §VI): payloads are stored under a
  store-held key (SHA-256 CTR keystream); ``get`` transparently decrypts.
- Cost accounting via :mod:`repro.core.cost` so the Table III benchmark and
  the checkpointer share one price model.

TPU-framework mapping: checkpoints and datasets are written through this
store, so old checkpoints age HOT→STD→IA→ARCHIVE exactly like the paper's
corpora age out of S3.
"""
from __future__ import annotations

import enum
import hashlib
import itertools
import re
from dataclasses import dataclass, field
from typing import Iterable, Optional

from . import cost as cost_mod
from .clock import Clock, days, hours


class Tier(enum.Enum):
    HOT = "HOT"          # instance-local staging (EBS/ephemeral; HBM/host in TPU terms)
    STD = "STD"          # S3-Standard
    IA = "IA"            # S3-Infrequent-Access
    ARCHIVE = "ARCHIVE"  # Glacier

    @property
    def immediate(self) -> bool:
        return self is not Tier.ARCHIVE


#: Order used by lifecycle demotion.
TIER_ORDER = (Tier.HOT, Tier.STD, Tier.IA, Tier.ARCHIVE)

RESTORE_LATENCY_S = hours(4)  # paper: average Glacier retrieval time


class StorageError(Exception):
    pass


class ObjectNotFoundError(StorageError):
    pass


class ObjectArchivedError(StorageError):
    """Raised when reading an object that must first be restored."""

    def __init__(self, key: str, restore_eta: Optional[float] = None):
        self.key = key
        self.restore_eta = restore_eta
        super().__init__(f"object {key!r} is archived (restore_eta={restore_eta})")


@dataclass
class LifecycleStage:
    tier: Tier
    staleness_s: Optional[float]  # None for the terminal stage


@dataclass(frozen=True)
class LifecyclePolicy:
    """Parsed form of e.g. ``"STD30-IA60-ARCHIVE"`` (paper §V-A).

    Stage ``STD30`` means: objects rest in STD and move to the next stage
    after 30 days without access.
    """

    stages: tuple[LifecycleStage, ...]

    @classmethod
    def parse(cls, text: str) -> "LifecyclePolicy":
        stages = []
        for part in text.split("-"):
            m = re.fullmatch(r"([A-Za-z]+)(\d*)", part)
            if not m:
                raise ValueError(f"bad lifecycle stage {part!r}")
            tier = Tier[m.group(1).upper().replace("GLACIER", "ARCHIVE")]
            staleness = days(int(m.group(2))) if m.group(2) else None
            stages.append(LifecycleStage(tier, staleness))
        if any(s.staleness_s is None for s in stages[:-1]):
            raise ValueError("only the terminal stage may omit staleness")
        return cls(tuple(stages))

    def stage_of(self, tier: Tier) -> Optional[int]:
        for i, s in enumerate(self.stages):
            if s.tier is tier:
                return i
        return None

    def next_tier(self, tier: Tier, idle_s: float) -> Tier:
        """Tier the object should occupy given time since last access."""
        i = self.stage_of(tier)
        if i is None:
            return tier
        while i < len(self.stages) - 1:
            staleness = self.stages[i].staleness_s
            if staleness is None or idle_s < staleness:
                break
            idle_s -= staleness
            i += 1
        return self.stages[i].tier


DEFAULT_POLICY = LifecyclePolicy.parse("STD30-IA60-ARCHIVE")


@dataclass
class ObjectMeta:
    key: str
    size_bytes: int
    tier: Tier
    owner: str
    created_at: float
    last_access: float
    checksum: str
    restore_ready_at: Optional[float] = None  # set while a restore is in flight
    pinned: bool = False                      # exempt from lifecycle demotion


@dataclass(frozen=True)
class MigrationEvent:
    timestamp: float
    key: str
    src: Tier
    dst: Tier
    reason: str  # "lifecycle" | "restore" | "stage"


class ObjectStore:
    """In-memory tiered object store with lifecycle + restore machinery.

    Payloads are held encrypted-at-rest; metadata drives lifecycle/cost.
    ``tick()`` runs the lifecycle daemon once (tests/simulations call it with
    a virtual clock; the service wires it to a background thread).
    """

    def __init__(self, clock: Clock | None = None,
                 policy: LifecyclePolicy = DEFAULT_POLICY,
                 pricing: cost_mod.StoragePricing | None = None,
                 encryption_key: bytes | None = None):
        self.clock = clock or Clock()
        self.policy = policy
        self.pricing = pricing or cost_mod.StoragePricing()
        self._key = encryption_key or hashlib.sha256(b"kotta-at-rest").digest()
        self._meta: dict[str, ObjectMeta] = {}
        self._blobs: dict[str, bytes] = {}
        self.migrations: list[MigrationEvent] = []
        self._access_log: list[tuple[float, str, int]] = []  # (t, key, bytes)

    # -- encryption at rest ------------------------------------------------
    def _keystream(self, n: int, nonce: bytes) -> bytes:
        out, ctr = bytearray(), itertools.count()
        while len(out) < n:
            out += hashlib.sha256(self._key + nonce + str(next(ctr)).encode()).digest()
        return bytes(out[:n])

    def _seal(self, key: str, data: bytes) -> bytes:
        ks = self._keystream(len(data), key.encode())
        return bytes(a ^ b for a, b in zip(data, ks))

    _open = _seal  # XOR stream cipher is symmetric

    # -- CRUD ----------------------------------------------------------------
    def put(self, key: str, data: bytes, owner: str = "system",
            tier: Tier = Tier.STD, pinned: bool = False) -> ObjectMeta:
        now = self.clock.now()
        meta = ObjectMeta(
            key=key, size_bytes=len(data), tier=tier, owner=owner,
            created_at=now, last_access=now,
            checksum=hashlib.sha256(data).hexdigest(), pinned=pinned)
        self._meta[key] = meta
        self._blobs[key] = self._seal(key, data)
        return meta

    def head(self, key: str) -> ObjectMeta:
        meta = self._meta.get(key)
        if meta is None:
            raise ObjectNotFoundError(key)
        return meta

    def exists(self, key: str) -> bool:
        return key in self._meta

    def get(self, key: str) -> bytes:
        """Read an object; bumps LRU recency; archived objects must restore."""
        meta = self.head(key)
        self._complete_restore(meta)
        if meta.tier is Tier.ARCHIVE:
            raise ObjectArchivedError(key, meta.restore_ready_at)
        now = self.clock.now()
        meta.last_access = now
        self._access_log.append((now, key, meta.size_bytes))
        data = self._open(key, self._blobs[key])
        if hashlib.sha256(data).hexdigest() != meta.checksum:
            raise StorageError(f"checksum mismatch for {key!r} (corruption)")
        return data

    def delete(self, key: str) -> None:
        self._meta.pop(key, None)
        self._blobs.pop(key, None)

    def keys(self, prefix: str = "") -> list[str]:
        return sorted(k for k in self._meta if k.startswith(prefix))

    # -- archive restore -------------------------------------------------------
    def restore(self, key: str) -> float:
        """Request retrieval of an archived object. Returns ready time."""
        meta = self.head(key)
        if meta.tier is not Tier.ARCHIVE:
            return self.clock.now()
        if meta.restore_ready_at is None:
            meta.restore_ready_at = self.clock.now() + RESTORE_LATENCY_S
        return meta.restore_ready_at

    def is_available(self, key: str) -> bool:
        meta = self.head(key)
        self._complete_restore(meta)
        return meta.tier.immediate

    def _complete_restore(self, meta: ObjectMeta) -> None:
        if (meta.tier is Tier.ARCHIVE and meta.restore_ready_at is not None
                and self.clock.now() >= meta.restore_ready_at):
            self._migrate(meta, Tier.STD, "restore")
            meta.restore_ready_at = None
            meta.last_access = self.clock.now()

    # -- lifecycle daemon ------------------------------------------------------
    def tick(self) -> list[MigrationEvent]:
        """Apply the LRU lifecycle policy once; returns migrations performed."""
        now = self.clock.now()
        out = []
        for meta in self._meta.values():
            self._complete_restore(meta)
            if meta.pinned:
                continue
            idle = now - meta.last_access
            target = self.policy.next_tier(meta.tier, idle)
            if target is not meta.tier:
                out.append(self._migrate(meta, target, "lifecycle"))
        return out

    def _migrate(self, meta: ObjectMeta, dst: Tier, reason: str) -> MigrationEvent:
        ev = MigrationEvent(self.clock.now(), meta.key, meta.tier, dst, reason)
        meta.tier = dst
        self.migrations.append(ev)
        return ev

    # -- accounting -------------------------------------------------------------
    def bytes_in_tier(self, tier: Tier) -> int:
        return sum(m.size_bytes for m in self._meta.values() if m.tier is tier)

    def monthly_cost(self) -> float:
        """Current $/month footprint across tiers (decimal GB, paper prices)."""
        gb = lambda b: b / 1e9
        return (
            cost_mod.s3_std_monthly(gb(self.bytes_in_tier(Tier.STD)), self.pricing)
            + cost_mod.s3_ia_monthly(gb(self.bytes_in_tier(Tier.IA)), self.pricing)
            + cost_mod.glacier_monthly(gb(self.bytes_in_tier(Tier.ARCHIVE)), self.pricing)
            + gb(self.bytes_in_tier(Tier.HOT)) * self.pricing.ebs_per_gb_month
        )

    def access_events(self) -> list[tuple[float, str, int]]:
        return list(self._access_log)


class SecureStorage:
    """Security-fabric wrapper: every access is authorized + audited (§VI).

    Resource naming convention: keys ARE resource names, e.g.
    ``dataset/wos/part-00001`` or ``results/<user>/<job>/out.txt``.
    """

    def __init__(self, store: ObjectStore, engine):
        self.store = store
        self.engine = engine

    def put(self, token, key: str, data: bytes, tier: Tier = Tier.STD,
            pinned: bool = False) -> ObjectMeta:
        self.engine.check(token, "data:Put", key)
        return self.store.put(key, data, owner=token.principal_id, tier=tier,
                              pinned=pinned)

    def get(self, token, key: str) -> bytes:
        """In-enclave read (analysis staging)."""
        self.engine.check(token, "data:Get", key)
        return self.store.get(key)

    def download(self, token, key: str) -> bytes:
        """Out-of-enclave read; private datasets carry an explicit deny."""
        self.engine.check(token, "data:Download", key)
        return self.store.get(key)

    def get_via_signed_url(self, url: str) -> bytes:
        key = self.engine.verify_url(url)
        return self.store.get(key)

    def list(self, token, prefix: str) -> list[str]:
        self.engine.check(token, "data:List", prefix + "*")
        return self.store.keys(prefix)
