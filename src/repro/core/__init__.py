"""Kotta core: the paper's contributions as composable components.

- :mod:`repro.core.security`  — RBAC fabric, assume-role, signed URLs, audit (§VI)
- :mod:`repro.core.lifecycle` — tiered object store + LRU lifecycle (§V-A)
- :mod:`repro.core.cost`      — storage/compute/placement cost models (§V, §VII)
- :mod:`repro.core.market`    — spot price traces + revocation (§IV-C)
- :mod:`repro.core.scheduler` — queues, workers, queue-watcher (§IV-D)
- :mod:`repro.core.elastic`   — scaling policies / provisioner (§V-B)
- :mod:`repro.core.simulator` — discrete-event reproduction of §VII-C
"""
from .clock import Clock, VirtualClock, days, hours
from .cost import (ComputePricing, StoragePricing, TPU_V5E, TpuChipSpec,
                   lifecycle_annual_cost, placement_cost)
from .elastic import Provisioner, ProvisioningModel, ScalingPolicy
from .lifecycle import (LifecyclePolicy, ObjectArchivedError, ObjectStore,
                        SecureStorage, Tier)
from .market import DEFAULT_ZONES, AvailabilityZone, SpotMarket
from .placement import PlacementDecision, PlacementPolicy
from .scheduler import (ExecutableRegistry, JobContext, JobQueue, JobSpec,
                        JobStatus, KottaService, ShardedStateStore,
                        StateStore, Worker)
from .security import (AuditLog, AuthorizationError, Policy, PolicyEngine,
                       Principal, Role, SecurityError, SessionToken,
                       TokenExpiredError, allow, deny, install_standard_roles,
                       make_dataset_role)
from .simulator import (ElasticSimulator, SimJob, SimReport,
                        make_paper_workload, run_table7c)

__all__ = [k for k in dir() if not k.startswith("_")]
