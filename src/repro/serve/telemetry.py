"""Unified observability plane for the Kotta serving stack.

Cloud Kotta lands job state, audit records, and utilization in one
provisioned DynamoDB table so operators can see, bill, and scale the whole
system from a single backplane (PAPER.md §IV–§V; the Fig-6 saturation
experiment is driven off that telemetry). This module is the serve-side
half of that story:

- :class:`MetricsRegistry` — counters, gauges, and fixed-bucket histograms
  with labels (tenant, replica, job class), Prometheus text exposition
  (:meth:`MetricsRegistry.expose`) and a virtual-clock-aware snapshot API
  (:meth:`MetricsRegistry.snapshot`) whose timestamps come from the
  gateway's :class:`~repro.core.clock.VirtualClock`, so scrapes are
  deterministic across hosts just like the bench numbers.
- :func:`parse_exposition` — a strict parser for the exposition format,
  used by the round-trip test that proves what we serve is what a real
  Prometheus scraper would ingest.
- :class:`RegistryDict` — a write-through ``MutableMapping`` that lets the
  existing ad-hoc stats dicts (``gateway.stats``, ``engine.stats``,
  ``router.stats``) become *views over* registry series without changing a
  single call site: ``stats["shed"] += 1`` still works, and the delta also
  lands on the bound Prometheus counter. Counter-bound keys use **delta
  semantics** (only positive deltas increment the series), so an engine
  ``_reset_stats()`` zeroes the local mirror while the registry counter
  stays monotonic — exactly Prometheus counter-reset behavior.

Design notes
------------
Families are created idempotently: asking for an existing name returns the
existing family (and raises if the kind/labelnames disagree), so gateway,
engines, and router can all bind against one shared registry without
coordination. Collectors (callbacks registered via
:meth:`MetricsRegistry.register_collector`) run at scrape/snapshot time to
refresh gauges computed from live state — per-replica occupancy, queue
depth, SLO burn rate — the standard Prometheus collector pattern.
"""
from __future__ import annotations

import math
from collections.abc import MutableMapping
from typing import Callable, Iterable, Optional

__all__ = ["MetricsRegistry", "RegistryDict", "parse_exposition",
           "LATENCY_BUCKETS_S"]

# Fixed latency buckets (seconds) shared by the TTFT/TPOT/queue-wait
# histograms: log-ish spacing from sub-tick to the longest deadlines the
# benches use.
LATENCY_BUCKETS_S = (0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0,
                     10.0, 30.0, 60.0, 120.0, 300.0)

_INF = math.inf


def _format_value(v: float) -> str:
    """Lossless float formatting (repr round-trips exactly in Python);
    integral values render bare so ``5`` not ``5.0`` noise — the parser
    reads both."""
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    f = float(v)
    if f.is_integer() and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _escape_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _unescape_label(v: str) -> str:
    out, i = [], 0
    while i < len(v):
        c = v[i]
        if c == "\\" and i + 1 < len(v):
            nxt = v[i + 1]
            out.append({"\\": "\\", '"': '"', "n": "\n"}.get(nxt, "\\" + nxt))
            i += 2
        else:
            out.append(c)
            i += 1
    return "".join(out)


def _render_labels(labelnames: tuple, labelvalues: tuple) -> str:
    if not labelnames:
        return ""
    inner = ",".join(f'{n}="{_escape_label(str(v))}"'
                     for n, v in zip(labelnames, labelvalues))
    return "{" + inner + "}"


class _Series:
    """One (family, label-values) time series holding a scalar value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counter increment must be >= 0, got {amount}")
        self.value += amount

    def set(self, value: float) -> None:
        self.value = float(value)


class _HistogramSeries:
    """Cumulative fixed-bucket histogram series (Prometheus semantics:
    ``le`` buckets are cumulative, +Inf bucket == count)."""

    __slots__ = ("bounds", "counts", "sum", "count")

    def __init__(self, bounds: tuple):
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)   # last = +Inf overflow
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        v = float(value)
        self.sum += v
        self.count += 1
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def cumulative(self) -> list:
        """Per-``le`` cumulative counts, +Inf last."""
        out, run = [], 0
        for c in self.counts:
            run += c
            out.append(run)
        return out


class _Family:
    """A named metric family: kind + help + labelnames + its series."""

    def __init__(self, name: str, kind: str, help: str, labelnames: tuple,
                 buckets: tuple = ()):
        self.name = name
        self.kind = kind
        self.help = help
        self.labelnames = tuple(labelnames)
        self.buckets = tuple(float(b) for b in buckets)
        if self.kind == "histogram" and not self.buckets:
            raise ValueError(f"histogram {name!r} needs at least one bucket")
        if list(self.buckets) != sorted(set(self.buckets)):
            raise ValueError(f"histogram {name!r} buckets must be strictly "
                             f"increasing: {buckets}")
        self._series: dict[tuple, object] = {}

    # -- series access -------------------------------------------------------
    def _key(self, labels: dict) -> tuple:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"metric {self.name!r} expects labels {self.labelnames}, "
                f"got {tuple(sorted(labels))}")
        return tuple(str(labels[n]) for n in self.labelnames)

    def labels(self, **labels):
        key = self._key(labels)
        s = self._series.get(key)
        if s is None:
            s = (_HistogramSeries(self.buckets) if self.kind == "histogram"
                 else _Series())
            self._series[key] = s
        return s

    # -- convenience (no-label or inline-label updates) ----------------------
    def inc(self, amount: float = 1.0, **labels) -> None:
        if self.kind != "counter":
            raise TypeError(f"{self.name!r} is a {self.kind}, not a counter")
        self.labels(**labels).inc(amount)

    def set(self, value: float, **labels) -> None:
        if self.kind != "gauge":
            raise TypeError(f"{self.name!r} is a {self.kind}, not a gauge")
        self.labels(**labels).set(value)

    def observe(self, value: float, **labels) -> None:
        if self.kind != "histogram":
            raise TypeError(f"{self.name!r} is a {self.kind}, "
                            f"not a histogram")
        self.labels(**labels).observe(value)

    def clear(self) -> None:
        """Drop all series (collectors re-set gauges for live objects only,
        so retired replicas stop being exported)."""
        self._series.clear()

    def value(self, **labels) -> float:
        if self.kind == "histogram":
            raise TypeError(f"{self.name!r} is a histogram; read samples "
                            f"via snapshot()/expose()")
        s = self._series.get(self._key(labels))
        return 0.0 if s is None else s.value


class MetricsRegistry:
    """The serve stack's single metrics backplane.

    ``clock`` (any object with ``now()``) stamps snapshots; on the gateway
    this is the shared :class:`~repro.core.clock.VirtualClock`, so two runs
    of the same seeded bench produce byte-identical snapshot streams.
    """

    def __init__(self, clock=None):
        self.clock = clock
        self._families: dict[str, _Family] = {}
        self._collectors: list[Callable[[], None]] = []

    # -- family constructors (idempotent) ------------------------------------
    def _family(self, name: str, kind: str, help: str, labelnames: tuple,
                buckets: tuple = ()) -> _Family:
        fam = self._families.get(name)
        if fam is not None:
            if fam.kind != kind or fam.labelnames != tuple(labelnames):
                raise ValueError(
                    f"metric {name!r} already registered as {fam.kind}"
                    f"{fam.labelnames}, cannot re-register as {kind}"
                    f"{tuple(labelnames)}")
            return fam
        fam = _Family(name, kind, help, tuple(labelnames), buckets)
        self._families[name] = fam
        return fam

    def counter(self, name: str, help: str = "",
                labelnames: Iterable[str] = ()) -> _Family:
        return self._family(name, "counter", help, tuple(labelnames))

    def gauge(self, name: str, help: str = "",
              labelnames: Iterable[str] = ()) -> _Family:
        return self._family(name, "gauge", help, tuple(labelnames))

    def histogram(self, name: str, help: str = "",
                  buckets: Iterable[float] = LATENCY_BUCKETS_S,
                  labelnames: Iterable[str] = ()) -> _Family:
        return self._family(name, "histogram", help, tuple(labelnames),
                            tuple(buckets))

    def register_collector(self, fn: Callable[[], None]) -> None:
        """``fn`` runs before every expose()/snapshot() to refresh gauges
        computed from live state (occupancy, queue depth, burn rate)."""
        self._collectors.append(fn)

    # -- reads ---------------------------------------------------------------
    def collect(self) -> None:
        for fn in self._collectors:
            fn()

    def value(self, name: str, **labels) -> float:
        """Point read of one counter/gauge series (0.0 when unset)."""
        fam = self._families.get(name)
        if fam is None:
            return 0.0
        return fam.value(**labels)

    def families(self) -> list:
        return sorted(self._families)

    def snapshot(self) -> dict:
        """Structured scrape: ``{"ts", "families": {name: {...}}}``.

        Histogram buckets key on the same ``le`` strings the exposition
        renders, so ``parse_exposition(expose())["families"]`` equals
        ``snapshot()["families"]`` exactly (the round-trip contract).
        """
        self.collect()
        fams = {}
        for name in sorted(self._families):
            fam = self._families[name]
            samples = []
            # Sort by the label-item tuple — the same canonical order the
            # parser reconstructs, so round-trip equality is exact.
            ordered = sorted(fam._series,
                             key=lambda k: tuple(sorted(
                                 zip(fam.labelnames, k))))
            for key in ordered:
                s = fam._series[key]
                labels = dict(zip(fam.labelnames, key))
                if fam.kind == "histogram":
                    les = [_format_value(b) for b in fam.buckets] + ["+Inf"]
                    samples.append({
                        "labels": labels,
                        "buckets": dict(zip(les, (float(c) for c in
                                                  s.cumulative()))),
                        "sum": s.sum,
                        "count": float(s.count),
                    })
                else:
                    samples.append({"labels": labels, "value": s.value})
            fams[name] = {"kind": fam.kind, "samples": samples}
        return {"ts": (self.clock.now() if self.clock is not None else 0.0),
                "families": fams}

    def expose(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        self.collect()
        lines = []
        for name in sorted(self._families):
            fam = self._families[name]
            if fam.help:
                lines.append(f"# HELP {name} {fam.help}")
            lines.append(f"# TYPE {name} {fam.kind}")
            for key in sorted(fam._series):
                s = fam._series[key]
                if fam.kind == "histogram":
                    cum = s.cumulative()
                    les = [_format_value(b) for b in fam.buckets] + ["+Inf"]
                    for le, c in zip(les, cum):
                        lbl = _render_labels(fam.labelnames + ("le",),
                                             key + (le,))
                        lines.append(f"{name}_bucket{lbl} "
                                     f"{_format_value(c)}")
                    lbl = _render_labels(fam.labelnames, key)
                    lines.append(f"{name}_sum{lbl} {_format_value(s.sum)}")
                    lines.append(f"{name}_count{lbl} "
                                 f"{_format_value(s.count)}")
                else:
                    lbl = _render_labels(fam.labelnames, key)
                    lines.append(f"{name}{lbl} {_format_value(s.value)}")
        return "\n".join(lines) + ("\n" if lines else "")


# ---------------------------------------------------------------------------
# Exposition parser (round-trip verification)
# ---------------------------------------------------------------------------

def _parse_labels(block: str) -> dict:
    """Parse the inside of a ``{...}`` label block."""
    labels, i, n = {}, 0, len(block)
    while i < n:
        eq = block.index("=", i)
        lname = block[i:eq].strip()
        if block[eq + 1] != '"':
            raise ValueError(f"label value for {lname!r} not quoted")
        j = eq + 2
        raw = []
        while j < n:
            c = block[j]
            if c == "\\":
                raw.append(block[j:j + 2])
                j += 2
                continue
            if c == '"':
                break
            raw.append(c)
            j += 1
        else:
            raise ValueError("unterminated label value")
        labels[lname] = _unescape_label("".join(raw))
        i = j + 1
        if i < n and block[i] == ",":
            i += 1
    return labels


def _parse_value(tok: str) -> float:
    if tok == "+Inf":
        return math.inf
    if tok == "-Inf":
        return -math.inf
    return float(tok)


def parse_exposition(text: str) -> dict:
    """Parse Prometheus text exposition into the :meth:`snapshot` shape
    (minus ``ts``): ``{"families": {name: {"kind", "samples"}}}``.

    Strict on structure (TYPE before samples, histogram series complete
    with ``_sum``/``_count``) — it exists to *verify* the renderer, so it
    fails loudly on anything malformed.
    """
    families: dict[str, dict] = {}
    kinds: dict[str, str] = {}
    # name -> label-key-tuple -> accumulating sample
    acc: dict[str, dict] = {}
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.startswith("# HELP"):
            continue
        if line.startswith("# TYPE"):
            _, _, name, kind = line.split(None, 3)
            kinds[name] = kind
            acc.setdefault(name, {})
            continue
        if line.startswith("#"):
            continue
        # sample line: name{labels} value  |  name value
        if "{" in line:
            mname = line[:line.index("{")]
            rest = line[line.index("{") + 1:]
            lblock = rest[:rest.rindex("}")]
            vtok = rest[rest.rindex("}") + 1:].split()[0]
            labels = _parse_labels(lblock)
        else:
            mname, vtok = line.split()[:2]
            labels = {}
        value = _parse_value(vtok)
        base, part = mname, "value"
        for suffix in ("_bucket", "_sum", "_count"):
            cand = mname[:-len(suffix)] if mname.endswith(suffix) else None
            if cand is not None and kinds.get(cand) == "histogram":
                base, part = cand, suffix[1:]
                break
        if base not in kinds:
            raise ValueError(f"sample for {mname!r} before its TYPE line")
        le = labels.pop("le", None)
        key = tuple(sorted(labels.items()))
        sample = acc[base].setdefault(key, {"labels": labels})
        if kinds[base] == "histogram":
            if part == "bucket":
                if le is None:
                    raise ValueError(f"{mname}: histogram bucket missing le")
                sample.setdefault("buckets", {})[le] = value
            elif part == "sum":
                sample["sum"] = value
            elif part == "count":
                sample["count"] = value
            else:
                raise ValueError(f"unexpected histogram sample {mname!r}")
        else:
            sample["value"] = value
    for name, kind in kinds.items():
        if kind == "histogram":
            for key, sample in acc[name].items():
                if "sum" not in sample or "count" not in sample:
                    raise ValueError(f"histogram {name!r} series "
                                     f"{dict(key)!r} missing _sum/_count")
        families[name] = {
            "kind": kind,
            "samples": [acc[name][k] for k in sorted(acc[name])],
        }
    return {"families": families}


# ---------------------------------------------------------------------------
# Backed-dict compatibility layer
# ---------------------------------------------------------------------------

class RegistryDict(MutableMapping):
    """A dict whose writes flow through to bound registry series.

    The pre-telemetry serve stack kept counters in plain dicts and both
    tests and benches read them (``eng.stats["admitted"]``,
    ``gw.metrics()["shed"]``). This wrapper preserves every dict behavior
    (iteration, ``.get``, ``+=``, ``dict(...)`` copies) while teeing writes
    into the registry:

    - a key bound to a **counter** series applies *positive deltas* only
      (``stats[k] = new`` increments the series by ``max(new - old, 0)``),
      so local resets never decrement the monotonic series;
    - a key bound to a **gauge** series sets it outright;
    - an unbound key is local-only (scratch accumulators like
      ``accept_ema_sum`` stay out of the exposition).
    """

    def __init__(self):
        self._local: dict = {}
        self._sinks: dict = {}       # key -> (kind, series)

    def bind(self, key: str, family: Optional[_Family], initial: float = 0,
             **labels) -> None:
        """Bind ``key`` to one series of ``family`` (``None`` = local-only)
        and seed the local mirror with ``initial`` (pre-bind totals carry
        into the series so binding mid-life loses nothing)."""
        self._local[key] = initial
        if family is None:
            return
        series = family.labels(**labels)
        self._sinks[key] = (family.kind, series)
        if family.kind == "counter":
            if initial > 0:
                series.inc(initial)
        elif family.kind == "gauge":
            series.set(initial)
        else:
            raise TypeError(f"cannot bind dict key {key!r} to a "
                            f"{family.kind}")

    # -- MutableMapping ------------------------------------------------------
    def __setitem__(self, key, value):
        sink = self._sinks.get(key)
        if sink is not None:
            kind, series = sink
            if kind == "counter":
                delta = value - self._local.get(key, 0)
                if delta > 0:
                    series.inc(delta)
            else:
                series.set(value)
        self._local[key] = value

    def __getitem__(self, key):
        return self._local[key]

    def __delitem__(self, key):
        del self._local[key]
        self._sinks.pop(key, None)

    def __iter__(self):
        return iter(self._local)

    def __len__(self):
        return len(self._local)

    def __repr__(self):
        return f"RegistryDict({self._local!r})"
