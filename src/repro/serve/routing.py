"""Prefix-affinity fleet routing over the gateway's replica pool.

Cloud Kotta's execution model moves work to where the data already is
(PAPER.md §IV) — here the "data" is KV-cache pages. Each replica
advertises a radix fingerprint of its :class:`~repro.serve.paging.PrefixCache`
(``PrefixCache.fingerprint()``, a set of namespace-salted chain hashes, one
per cached page-granular prefix) and the router scores every queued request
against every live replica: matched prefix pages × page_size is the prefill
token count the fleet would NOT have to recompute if the request lands
there. Dispatch picks the best-affinity replica, falling back to
least-loaded when nothing matches, with a **load-imbalance cap** so a hot
tenant's affinity can't starve one replica while the rest idle.

The router never sees token content — only hashes — and a hash collision
can at worst misroute a request (a perf wobble): page aliasing is decided
by the replica's own namespace-scoped radix walk at admission, never here.

The router is also the fleet's **health authority**: replicas report a
heartbeat plus their modelled per-decode-step latency every gateway round
(virtual-clock time), and :meth:`FleetRouter.health` classifies each as

- ``up`` — heartbeating, latency in line with the fleet;
- ``degraded`` — heartbeating but a straggler: its latency EMA exceeds
  ``straggler_factor`` × the median of the *other* replicas' EMAs
  (leave-one-out, so one straggler cannot drag the baseline up with it);
- ``quarantined`` — no heartbeat for ``heartbeat_timeout_s``.

The gateway stops placing new work (dispatch, handoffs, evacuations) on
anything not ``up`` and drains queued-but-unstarted work off it; states
recover on their own when heartbeats return / latency normalizes.

Fingerprints also interlock with the storage hierarchy
(:mod:`repro.serve.kv_store`): a request whose prefix is already
device-resident somewhere (``best_match_tokens`` ≥ the demoted match)
skips the tiered restore entirely and routes on affinity, and a
completed restore (``engine.restore_pages``) re-registers the prefix in
the landing replica's radix cache, so the next fingerprint delta
advertises it fleet-wide.
"""
from __future__ import annotations

import statistics
from dataclasses import dataclass, field

from .paging import chain_hashes
from .telemetry import RegistryDict

HEALTH_UP = "up"
HEALTH_DEGRADED = "degraded"
HEALTH_QUARANTINED = "quarantined"


@dataclass
class ReplicaView:
    """Router-side snapshot of one dispatch target for a scoring round.

    ``load`` counts committed work (live + queued-this-round) and is bumped
    by the caller after each dispatch so one round's decisions see each
    other; ``fingerprint`` is immutable within a round (registration only
    happens later, at admission).
    """

    replica_id: int
    open_slots: int
    load: int
    page_size: int
    fingerprint: frozenset = field(default_factory=frozenset)


@dataclass(frozen=True)
class RouteDecision:
    replica_id: int
    matched_tokens: int
    reason: str     # "affinity" | "least_loaded" | "imbalance_cap" | "blind"


class FleetRouter:
    """Scores queued requests against replica fingerprints.

    Modes:
      - ``affinity``: best matched-prefix-token replica among those within
        ``imbalance_cap`` of the least-loaded; least-loaded when no replica
        matches any prefix page.
      - ``least_loaded``: most open slots (the pre-router gateway behavior).
      - ``blind``: round-robin, ignoring both cache state and load — the
        bench baseline for what affinity buys.

    ``window`` bounds the gateway's affinity lookahead: how many
    SLA-interchangeable jobs at the queue head it may scan for one whose
    prefix is resident on the currently-free capacity (the router itself
    is stateless per call; the gateway owns the queue scan).
    """

    MODES = ("affinity", "least_loaded", "blind")

    def __init__(self, mode: str = "affinity", imbalance_cap: int = 4,
                 window: int = 8, *, heartbeat_timeout_s: float = 10.0,
                 straggler_factor: float = 3.0, health_alpha: float = 0.5):
        if mode not in self.MODES:
            raise ValueError(f"routing mode must be one of {self.MODES}, got {mode!r}")
        if imbalance_cap < 1:
            raise ValueError(f"imbalance_cap must be >= 1, got {imbalance_cap}")
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if heartbeat_timeout_s <= 0:
            raise ValueError(f"heartbeat_timeout_s must be > 0, got "
                             f"{heartbeat_timeout_s}")
        if straggler_factor <= 1.0:
            raise ValueError(f"straggler_factor must be > 1, got "
                             f"{straggler_factor}")
        self.mode = mode
        self.imbalance_cap = imbalance_cap
        self.window = window
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.straggler_factor = straggler_factor
        self.health_alpha = health_alpha
        self._rr = 0
        # replica_id -> [last_heartbeat_s, decode-step latency EMA | None]
        self._health: dict[int, list] = {}
        self.stats = {"affinity": 0, "least_loaded": 0, "blind": 0,
                      "imbalance_cap": 0, "matched_tokens": 0}

    def bind_registry(self, registry) -> None:
        """Swap ``stats`` for a write-through view over ``registry`` series
        (``kotta_routing_decisions_total{reason=...}`` plus matched-token
        counter). Call sites keep mutating ``stats`` as a plain dict;
        totals accumulated before binding carry into the series."""
        decisions = registry.counter(
            "kotta_routing_decisions_total",
            "Dispatch routing decisions by outcome", ("reason",))
        matched = registry.counter(
            "kotta_routing_matched_tokens_total",
            "Prefill tokens matched to resident prefix pages by routing")
        rd = RegistryDict()
        for reason in ("affinity", "least_loaded", "blind", "imbalance_cap"):
            rd.bind(reason, decisions, initial=self.stats[reason],
                    reason=reason)
        rd.bind("matched_tokens", matched,
                initial=self.stats["matched_tokens"])
        self.stats = rd

    # -- health --------------------------------------------------------------
    def heartbeat(self, replica_id: int, now: float,
                  decode_step_s: float | None = None) -> None:
        """One replica's liveness report for this round. ``decode_step_s``
        is its observed per-decode-step latency (straggler signal); EMA'd
        with ``health_alpha`` so a cleared straggler recovers within a few
        rounds instead of instantly (or never)."""
        ent = self._health.setdefault(replica_id, [now, None])
        ent[0] = now
        if decode_step_s is not None:
            a = self.health_alpha
            ent[1] = decode_step_s if ent[1] is None \
                else (1 - a) * ent[1] + a * decode_step_s

    def forget(self, replica_id: int) -> None:
        """Drop a retired replica's health record (replica ids are never
        reused, so a stale record would only leak)."""
        self._health.pop(replica_id, None)

    def health(self, replica_id: int, now: float) -> str:
        """``up`` / ``degraded`` / ``quarantined``. A replica that never
        heartbeat is ``up``: fresh launches owe nothing yet."""
        ent = self._health.get(replica_id)
        if ent is None:
            return HEALTH_UP
        if now - ent[0] > self.heartbeat_timeout_s:
            return HEALTH_QUARANTINED
        if ent[1] is not None:
            # Leave-one-out: compare against the median of the OTHER
            # replicas' latency EMAs, so a lone straggler in a two-replica
            # fleet is still 'slower than everyone else'.
            others = [e[1] for rid, e in self._health.items()
                      if rid != replica_id and e[1] is not None
                      and now - e[0] <= self.heartbeat_timeout_s]
            if others and ent[1] > self.straggler_factor \
                    * statistics.median(others):
                return HEALTH_DEGRADED
        return HEALTH_UP

    def healths(self, now: float) -> dict[int, str]:
        return {rid: self.health(rid, now) for rid in self._health}

    # -- scoring -------------------------------------------------------------
    @staticmethod
    def _match_tokens(prompt, namespace, view: ReplicaView) -> int:
        """Prefill tokens ``view``'s cache already holds for this prompt:
        consecutive chain-hash hits from the root (the fingerprint is
        prefix-closed, so the first miss ends the cached chain)."""
        hits = 0
        for h in chain_hashes(prompt, view.page_size, namespace):
            if h not in view.fingerprint:
                break
            hits += 1
        return hits * view.page_size

    def best_match_tokens(self, prompt, namespace, views) -> int:
        """Best cached-token count across the fleet (admission feasibility
        wants "what will the winner skip", not who the winner is)."""
        return max((self._match_tokens(prompt, namespace, v) for v in views),
                   default=0)

    # -- routing -------------------------------------------------------------
    def route(self, prompt, namespace, views) -> RouteDecision | None:
        """Pick a dispatch target among ``views`` (replicas with an open
        slot). Returns None when ``views`` is empty."""
        views = [v for v in views if v.open_slots > 0]
        if not views:
            return None

        if self.mode == "blind":
            v = views[self._rr % len(views)]
            self._rr += 1
            self.stats["blind"] += 1
            return RouteDecision(v.replica_id, 0, "blind")

        least = min(views, key=lambda v: (v.load, v.replica_id))
        if self.mode == "least_loaded":
            self.stats["least_loaded"] += 1
            return RouteDecision(least.replica_id, 0, "least_loaded")

        # affinity: best matched tokens, load-capped against the minimum.
        min_load = least.load
        scored = [(self._match_tokens(prompt, namespace, v), v) for v in views]
        best_tokens, best = max(scored, key=lambda t: (t[0], -t[1].load,
                                                       -t[1].replica_id))
        if best_tokens <= 0:
            self.stats["least_loaded"] += 1
            return RouteDecision(least.replica_id, 0, "least_loaded")
        if best.load - min_load > self.imbalance_cap:
            # The affinity winner is already carrying imbalance_cap more
            # work than the idlest replica: spill to the best-matching
            # replica that is still within the cap (possibly zero match).
            capped = [(t, v) for t, v in scored
                      if v.load - min_load <= self.imbalance_cap]
            cap_tokens, cap_v = max(capped, key=lambda t: (t[0], -t[1].load,
                                                           -t[1].replica_id))
            self.stats["imbalance_cap"] += 1
            self.stats["matched_tokens"] += cap_tokens
            return RouteDecision(cap_v.replica_id, cap_tokens, "imbalance_cap")
        self.stats["affinity"] += 1
        self.stats["matched_tokens"] += best_tokens
        return RouteDecision(best.replica_id, best_tokens, "affinity")


class FingerprintTracker:
    """Per-replica fingerprint mirrors fed by PrefixCache epoch deltas.

    ``PrefixCache.fingerprint()`` walks the whole radix index — fine once,
    wasteful every dispatch round when almost nothing changed. The tracker
    keeps one mirrored hash set per replica and advances it with
    :meth:`~repro.serve.paging.PrefixCache.fingerprint_delta` (O(churn)
    since last round); it falls back to a full snapshot only on first
    contact or when the cache's journal has outrun the mirror. The mirror
    is exact, not approximate: delta-fed and snapshot-fed routers make
    identical decisions (tested), because replaying the journal reproduces
    the walk set-for-set.
    """

    def __init__(self):
        self._state: dict[int, tuple[int, set]] = {}   # id -> (epoch, fp)
        self.stats = {"snapshots": 0, "deltas": 0, "delta_hashes": 0}

    def refresh(self, replica_id: int, cache) -> frozenset:
        """Current fingerprint of ``cache``, advanced incrementally."""
        known = self._state.get(replica_id)
        if known is not None:
            epoch, fp = known
            delta = cache.fingerprint_delta(epoch)
            if delta is not None:
                new_epoch, added, removed = delta
                fp |= added
                fp -= removed
                self._state[replica_id] = (new_epoch, fp)
                self.stats["deltas"] += 1
                self.stats["delta_hashes"] += len(added) + len(removed)
                return frozenset(fp)
        fp = set(cache.fingerprint())
        self._state[replica_id] = (cache.epoch, fp)
        self.stats["snapshots"] += 1
        return frozenset(fp)

    def forget(self, replica_id: int) -> None:
        self._state.pop(replica_id, None)
