"""Deadline/cost-aware admission for the Kotta serving gateway.

Cloud Kotta's control plane never runs work "because it arrived": every task
goes through a queue whose consumers are provisioned against explicit cost
and urgency signals (dev vs prod queues, §IV-D; elastic provisioning against
queue depth, §IV-C; the Table VII-C cost/makespan trade). This module is the
serving-side analogue for generation requests:

- An :class:`AdmissionPolicy` keeps the gateway's pending queue **ordered**
  — :class:`DeadlineCostPolicy` runs earliest-deadline-first *within* a
  priority class (interactive before batch, the companion paper's
  interactive-analytics requirement), FCFS breaking ties.
- The same policy **sheds** requests that cannot meet their deadline at
  current occupancy: a slot-horizon feasibility walk (who frees a decode
  slot when, with the queue ahead of you) estimates each request's finish
  time, and an infeasible request surfaces a **typed rejection**
  (:class:`DeadlineInfeasible`) instead of hanging in the queue.
- Requests carrying a ``cost_budget`` are priced with the instance rates in
  :mod:`repro.core.cost` before they occupy capacity; a request whose
  estimated serving cost exceeds its budget is rejected with
  :class:`CostBudgetExceeded`.
- Before an **interactive** request is shed as infeasible, the policy may
  instead nominate a running lower-class request for **decode preemption**
  (:meth:`DeadlineCostPolicy.plan_preemption`): among the batch-class slots
  it picks the latest-deadline victim whose pause lets the interactive
  request start immediately *and* still leaves the victim able to meet its
  own deadline after a lossless resume (paused decode re-prefills nothing,
  so the resume cost is exactly its remaining decode steps). The companion
  paper's interactive-analytics requirement: scarce capacity serves the
  urgent class first, without breaking the batch class's promises.

Requests that a replica already accepted and then lost to spot revocation
are re-enqueued with ``requeued=True`` and are exempt from shedding —
Kotta's queue-watcher semantics: accepted work is completed, whatever the
market does (§IV-D resubmission).
"""
from __future__ import annotations

import enum
import heapq
import math
from dataclasses import dataclass, field, replace
from typing import Optional


class AdmissionError(Exception):
    """Typed admission rejection — shed requests fail fast, never hang."""

    reason = "rejected"


class DeadlineInfeasible(AdmissionError):
    """At current occupancy the request cannot finish by its deadline."""

    reason = "deadline_infeasible"


class CostBudgetExceeded(AdmissionError):
    """Estimated serving cost exceeds the request's cost budget."""

    reason = "cost_budget_exceeded"


class RetryBudgetExhausted(AdmissionError):
    """The job lost its replica more times than the gateway's retry budget
    allows; retrying further would let one cursed request spin forever."""

    reason = "retry_budget_exhausted"


class StorageBudgetExceeded(AdmissionError):
    """Demoting this tenant's KV pages would exceed its storage budget —
    the tiered KV store refuses the demotion with a typed error instead of
    silently dropping pages or billing past the cap."""

    reason = "storage_budget_exceeded"


class JobState(str, enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    PAUSED = "paused"        # decode-preempted; KV pages pinned on a replica
    # Waiting on an async KV restore from a lower storage tier — the
    # serving mirror of the batch scheduler's WAITING_DATA state: the job
    # holds its queue position but dispatch won't touch it until the
    # restore's modelled completion time passes.
    RESTORE_PENDING = "restore_pending"
    DONE = "done"
    SHED = "shed"


@dataclass
class ServeJob:
    """A generation request as a first-class Kotta job.

    ``deadline`` and timestamps are absolute gateway-clock seconds;
    ``priority`` is the class (lower = more urgent; 0 = interactive) and EDF
    runs *within* a class. ``namespace`` is the tenant-scoped prefix-cache
    key (tenant principal, data zone). ``requeued`` marks a job that lost
    its replica to spot revocation: it skips shed checks on readmission.

    Failure accounting: ``retries`` counts replica losses that sent the job
    back through the queue (capped by the gateway's retry budget);
    ``not_before`` is the capped-exponential-backoff gate — dispatch holds
    the job until the clock passes it. ``disturbed_at`` / ``recovered_at``
    bracket the most recent disturbance (evacuation or requeue) and its
    recovery (back in a decode slot), the pair behind the bench's
    recovered-request TTFT. ``evacuations`` counts notice-window KV
    migrations that carried the job's live state to a surviving replica.
    """

    rid: int
    tenant: str
    prompt: list[int]
    max_new: int
    submitted_at: float
    deadline: Optional[float] = None
    priority: int = 1
    cost_budget: Optional[float] = None
    namespace: object = None
    status: JobState = JobState.QUEUED
    tokens: Optional[list[int]] = None
    started_at: Optional[float] = None   # first admitted into a decode slot
    dispatched_at: Optional[float] = None   # left the central queue
                                            # (queue-wait histogram stamp)
    finished_at: Optional[float] = None
    error: Optional[AdmissionError] = None
    requeued: bool = False
    replica: Optional[int] = None
    retries: int = 0
    not_before: float = 0.0
    disturbed_at: Optional[float] = None
    recovered_at: Optional[float] = None
    evacuations: int = 0
    # Tiered-KV restore accounting: how many async tier restores this job
    # waited on (RESTORE_PENDING parks) and how many prompt tokens its
    # admission served from restored pages instead of re-prefill.
    restores: int = 0
    restored_tokens: int = 0


@dataclass(frozen=True)
class PreemptCandidate:
    """A running request the gateway could pause to admit a more urgent one.

    ``remaining_tokens`` is the victim's outstanding decode budget — after a
    lossless pause/resume (pages pinned, zero re-prefill) that is its ONLY
    remaining cost, which is what makes the feasibility arithmetic exact.
    """

    job: ServeJob
    remaining_tokens: int
    replica_id: int
    slot: int


@dataclass(frozen=True)
class ServiceModel:
    """Simulated replica service rates (virtual-clock seconds).

    The gateway runs on a :class:`repro.core.clock.VirtualClock`: decode
    wall time is modelled, not measured, so cost/deadline accounting is
    deterministic across hosts (the same move as the Table VII-C DES).
    Prefill is charged per *fresh* token — prompt tokens served from the
    tenant's prefix cache are free, so cache locality shows up in deadline
    headroom exactly like Kotta's data-local placement shows up in job
    turnaround.
    """

    prefill_tok_per_s: float = 4096.0
    decode_step_s: float = 0.05      # one lockstep token row across slots
    # Page-shipping bandwidth for disaggregated prefill->decode handoffs
    # (host/interconnect copy of the finished KV pages).
    kv_ship_bytes_per_s: float = 8e9
    # Calibration: measured end-to-end service time over the raw
    # prefill+decode estimate. The raw model ignores dispatch rounds,
    # chunked-prefill interleave, and queue-pump granularity, so under
    # sustained load the real per-request service time runs above it; the
    # saturation bench fits this factor from measured throughput
    # (:meth:`calibrated`) so admission's feasibility math tracks reality
    # instead of the optimistic floor. Applies to ``service_s`` only —
    # billing/shipping estimates stay raw.
    overhead: float = 1.0

    def prefill_s(self, n_tokens: int) -> float:
        return n_tokens / self.prefill_tok_per_s

    def ship_s(self, nbytes: int) -> float:
        return nbytes / self.kv_ship_bytes_per_s

    def service_s(self, prompt_len: int, max_new: int,
                  cached_tokens: int = 0) -> float:
        """End-to-end service estimate; ``cached_tokens`` is the prompt
        prefix the target replica already holds (routing-aware feasibility:
        an affinity hit shrinks the prefill bill, never below the one
        always-recomputed token)."""
        fresh = max(prompt_len - max(cached_tokens, 0), 1)
        return (self.prefill_s(fresh) + max_new * self.decode_step_s) \
            * self.overhead

    def assumed_req_per_s(self, prompt_len: int, max_new: int,
                          slots: int) -> float:
        """Throughput this model *assumes* ``slots`` decode slots deliver
        for a homogeneous workload — the number the saturation bench
        compares against measured throughput to expose model drift."""
        base = replace(self, overhead=1.0)
        return slots / base.service_s(prompt_len, max_new)

    def calibrated(self, measured_req_per_s: float, *, prompt_len: int,
                   max_new: int, slots: int) -> "ServiceModel":
        """Fit ``overhead`` so the model's implied throughput for this
        workload equals the measured one. Never calibrates below 1.0 — a
        measurement above the raw model (burst luck, cache hits) must not
        make admission *more* optimistic than physics."""
        if measured_req_per_s <= 0:
            raise ValueError(f"measured_req_per_s must be > 0, got "
                             f"{measured_req_per_s}")
        assumed = self.assumed_req_per_s(prompt_len, max_new, slots)
        return replace(self, overhead=max(1.0, assumed / measured_req_per_s))


class AdmissionPolicy:
    """FCFS baseline: submit order, no shedding (the pre-gateway engine)."""

    name = "fcfs"

    def order(self, jobs: list[ServeJob], now: float) -> list[ServeJob]:
        return sorted(jobs, key=lambda j: (j.submitted_at, j.rid))

    def plan(self, jobs: list[ServeJob], slot_free_s: list[float],
             now: float, price_per_slot_hour: float, *,
             cached_tokens: dict[int, int] | None = None,
             extra_delay_s: dict[int, float] | None = None,
             ) -> tuple[list[ServeJob], list[tuple[ServeJob,
                                                   AdmissionError]]]:
        """Return (keep_ordered, shed) — FCFS keeps everything.

        ``cached_tokens`` maps job rid -> prompt tokens the routing tier
        expects the chosen replica to serve from its prefix cache;
        ``extra_delay_s`` maps job rid -> pre-service latency the job must
        absorb before it can start (e.g. an async KV restore from a lower
        storage tier). Both are ignored by FCFS, which does no feasibility
        math.
        """
        return self.order(jobs, now), []

    def plan_preemption(self, job: ServeJob,
                        candidates: list[PreemptCandidate],
                        now: float) -> Optional[PreemptCandidate]:
        """Victim whose pause would admit ``job``; FCFS never preempts."""
        return None


FCFSPolicy = AdmissionPolicy


@dataclass
class DeadlineCostPolicy(AdmissionPolicy):
    """EDF within priority class + slot-horizon shedding + budget pricing.

    ``slot_free_s`` is the gateway's capacity horizon: one entry per decode
    slot across live and provisioning replicas, holding the absolute time
    that slot next frees (now, for an idle slot; the replica's ready time,
    for a provisioning one). The plan walks the ordered queue assigning
    each job the earliest slot — exactly the EDF feasibility test — and
    sheds jobs whose estimated finish overruns their deadline. Shedding is
    re-evaluated every round, so a job that was feasible when queued is
    still shed the moment a burst ahead of it makes the deadline hopeless
    (and capacity is spent on requests that can still win).
    """

    model: ServiceModel = field(default_factory=ServiceModel)
    # Decode preemption: pause the latest-deadline batch-class slot to admit
    # an otherwise-infeasible interactive request (both deadlines must hold).
    preempt: bool = True
    name = "edf_cost"

    def order(self, jobs: list[ServeJob], now: float) -> list[ServeJob]:
        return sorted(jobs, key=lambda j: (
            j.priority,
            j.deadline if j.deadline is not None else math.inf,
            j.submitted_at, j.rid))

    def plan(self, jobs, slot_free_s, now, price_per_slot_hour, *,
             cached_tokens=None, extra_delay_s=None):
        ordered = self.order(jobs, now)
        keep: list[ServeJob] = []
        shed: list[tuple[ServeJob, AdmissionError]] = []
        horizon = list(slot_free_s)
        heapq.heapify(horizon)
        for job in ordered:
            # Routing-aware feasibility: prompt tokens the router expects
            # the affinity target to serve from cache don't bill prefill
            # time, so a request that is only feasible ON its warm replica
            # is kept instead of shed. A pending tier restore adds its
            # modelled latency up front (restore-latency-aware deadline
            # feasibility): the job can't start until its pages are back,
            # but once they are, the restored prefix prefills for free.
            cached = 0 if cached_tokens is None \
                else cached_tokens.get(job.rid, 0)
            svc = self.model.service_s(len(job.prompt), job.max_new, cached)
            if extra_delay_s is not None:
                svc += max(0.0, extra_delay_s.get(job.rid, 0.0))
            if not job.requeued and job.cost_budget is not None:
                est_cost = svc / 3600.0 * price_per_slot_hour
                if est_cost > job.cost_budget:
                    shed.append((job, CostBudgetExceeded(
                        f"job {job.rid}: estimated ${est_cost:.4f} over "
                        f"budget ${job.cost_budget:.4f} "
                        f"({svc:.1f}s at ${price_per_slot_hour:.3f}/slot-h)"
                    )))
                    continue
            if horizon:
                slot_t = heapq.heappop(horizon)
                start = max(slot_t, now)
            else:
                # No capacity exists yet (all replicas still provisioning
                # and none announced): be optimistic — the provisioner
                # launches against queue depth — but still shed a job whose
                # deadline is hopeless even with an instant start.
                slot_t, start = None, now
            finish = start + svc
            if (not job.requeued and job.deadline is not None
                    and finish > job.deadline):
                shed.append((job, DeadlineInfeasible(
                    f"job {job.rid}: estimated finish t={finish:.1f}s "
                    f"misses deadline t={job.deadline:.1f}s at current "
                    f"occupancy")))
                if slot_t is not None:      # slot not consumed: hand it back
                    heapq.heappush(horizon, slot_t)
                continue
            keep.append(job)
            if slot_t is not None:
                heapq.heappush(horizon, finish)
        return keep, shed

    def plan_preemption(self, job, candidates, now):
        """Pick the victim whose pause admits ``job`` within BOTH deadlines.

        Eligibility: the victim must belong to a strictly lower priority
        class, ``job`` must finish by its deadline given an *instant* start
        on the freed slot, and the victim — resumed after ``job`` finishes,
        paying only its remaining decode steps (pause/resume is lossless:
        pages pinned, zero re-prefill) — must still meet its own deadline.
        Among eligible victims the LATEST-deadline one is paused: it has the
        most slack to absorb the added wait, so preemption consumes the
        cheapest SLA headroom first. Returns None (shed proceeds) when no
        victim qualifies or preemption is disabled.
        """
        if not self.preempt:
            return None
        svc = self.model.service_s(len(job.prompt), job.max_new)
        finish = now + svc
        if job.deadline is not None and finish > job.deadline:
            return None          # even an instant start cannot make it
        best, best_key = None, None
        for c in candidates:
            if c.job.priority <= job.priority:
                continue         # only a lower class may be paused
            resume_finish = finish \
                + c.remaining_tokens * self.model.decode_step_s
            if c.job.deadline is not None \
                    and resume_finish > c.job.deadline:
                continue         # pausing would break the victim's own SLA
            key = (math.inf if c.job.deadline is None else c.job.deadline,
                   c.job.submitted_at, c.job.rid)
            if best is None or key > best_key:
                best, best_key = c, key
        return best
