"""Refcounted page allocation and page-granular prefix caching.

The serving analogue of Cloud Kotta's tiered storage: the paper keeps ONE
copy of a hot shared dataset that many jobs read, instead of one copy per
job. Here the "dataset" is the KV cache of a common prompt prefix (system
prompt, few-shot header) and the "jobs" are decode requests:

- ``PageAllocator`` tracks a reference count per physical pool page. A page
  is *free* when no page-table row references it — but its contents stay
  valid until the page is actually reallocated, so a free page can be
  revived by a later cache hit (the storage-tier move: demoted, not
  destroyed).
- ``PrefixCache`` is a radix index over page-size token chunks: full pages
  are keyed ``(parent_page, page_tokens)`` so lookup walks the prompt one
  page at a time; a final sub-page remainder is kept as a *partial* entry
  under its parent, which is what lets admission copy-on-write the one
  boundary page instead of re-prefilling it.
- Every walk starts at a **namespace root** (default ``None``): the gateway
  namespaces prefix keys by (tenant, data-zone), so one tenant's cached KV
  pages can never be aliased into another tenant's request — deeper radix
  keys are parented by physical page ids, which are only reachable by first
  matching through the namespace's own root. This is the paper's §VI
  isolation guarantee carried down to the KV cache: shared *within* a
  security domain, invisible *across* domains.

The allocator's ``on_alloc`` hook evicts a page's index entries the moment
the page is repurposed, and recursively scrubs the subtree it anchored:
physical page ids are the radix parents, so entries must never outlive the
page contents they describe.

For fleet routing, ``PrefixCache.fingerprint`` summarises the whole index as
a flat set of namespace-salted **chain hashes** (:func:`chain_hashes`): one
hash per fully cached page-granular prefix, rolling from the namespace root
down the radix chain. A router can score "how many prefix pages of THIS
prompt does THAT replica already hold" from the fingerprint alone — no token
content crosses the wire, and a (vanishingly unlikely) hash collision can
only misroute a request, never alias pages: real admission still walks the
namespace-scoped radix tree.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass

import numpy as np

_FP_SALT = "kotta-prefix-fp"


@dataclass(frozen=True)
class EvictionEvent:
    """One :meth:`PrefixCache.evict` call's worth of index removals.

    The explicit eviction contract: ``pages`` is every page whose index
    entry was dropped (the reallocated page plus the scrubbed subtree it
    anchored), ``namespace`` is the (tenant, data-zone) domain those
    entries lived under — subtrees are namespace-pure, rooted at the
    namespace's own root — and ``epoch`` is the cache epoch after the
    removals. Subscribers (tier demotion, accounting) observe "these pages
    left the index" at the only moment it is knowable; every page in the
    event is either already demoted to a lower tier or refcount-zero free,
    never silently lost.
    """

    pages: tuple
    namespace: object
    epoch: int


def chain_hashes(prompt, page_size: int, namespace=None) -> list[int]:
    """Rolling chain hash of every full-page prefix of ``prompt``.

    ``out[i]`` identifies the (namespace, first ``(i+1)*page_size`` tokens)
    prefix; it extends ``out[i-1]``, so a replica fingerprint containing
    ``out[i]`` implies the whole chain up to page ``i`` is cached there
    (fingerprints are prefix-closed: eviction scrubs subtrees rootward-in).
    The namespace salts the seed, so identical token content under two
    (tenant, data-zone) namespaces never produces matching hashes — the
    router inherits the cache's isolation for free.
    """
    h = hash((_FP_SALT, namespace))
    out = []
    for i in range(len(prompt) // page_size):
        h = hash((h, tuple(prompt[i * page_size:(i + 1) * page_size])))
        out.append(h)
    return out


_ALL_NAMESPACES = object()


class PageAllocator:
    """Refcounted free-list over physical pages 1..num_pages-1 (0 = sink)."""

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self.refs = np.zeros(num_pages, np.int64)
        self._free = list(range(num_pages - 1, 0, -1))      # LIFO: 1 on top
        self._free_set = set(self._free)
        self.on_alloc = None            # callback(page) on reallocation

    def available(self) -> int:
        return len(self._free_set)

    def alloc(self) -> int:
        """Pop a free page; its old cached identity (if any) is evicted."""
        while self._free:
            p = self._free.pop()
            if p not in self._free_set:
                continue                 # stale entry: page was revived
            self._free_set.discard(p)
            if self.on_alloc is not None:
                self.on_alloc(p)
            self.refs[p] = 1
            return p
        raise RuntimeError("page pool exhausted")

    def share(self, p: int) -> None:
        """Add a reference; revives a free-but-still-valid cached page."""
        if self.refs[p] == 0:
            self._free_set.discard(p)    # its list entry goes stale
        self.refs[p] += 1

    def release(self, p: int) -> None:
        self.refs[p] -= 1
        assert self.refs[p] >= 0, f"page {p} over-released"
        if self.refs[p] == 0 and p not in self._free_set:
            self._free.append(p)
            self._free_set.add(p)


class PrefixCache:
    """Radix index from prompt-token chunks to the pool pages holding them.

    Holds NO page references itself: a cached page may have refcount 0 (all
    requests using it retired) and sit in the free list; it stays hittable
    until the allocator hands it out again, at which point ``evict`` removes
    it (and the subtree keyed under it) from the index.
    """

    # Fingerprint-delta journal depth: one entry per full-entry add/remove.
    # A consumer further behind than this takes a fresh snapshot (the
    # journal can't replay what it no longer holds).
    JOURNAL_DEPTH = 8192

    def __init__(self, page_size: int):
        self.page_size = page_size
        self._full = {}      # (parent_page|-1, tokens) -> page
        self._partial = {}   # parent_page|-1 -> list[(tokens, page)]
        # page -> ("full", key, ns) | ("partial", parent, toks, ns); the
        # trailing namespace is carried so EvictionEvents can name the
        # domain an entry lived under without re-walking to its root.
        self._owned = {}
        self._kids = {}      # parent_page -> list of full keys under it
        # Explicit eviction contract: callback(EvictionEvent) fired once
        # per evict() that removed at least one entry. Demotion subscribes
        # here — the single seam where "a page left the index" is
        # observable.
        self.on_evict = None
        # Incremental fingerprint: chain hash per owned full entry, plus an
        # epoch-tagged add/remove journal so routers can mirror the
        # fingerprint with deltas instead of a full snapshot per round.
        # Every mutation bumps ``epoch`` by exactly one and appends exactly
        # one journal entry, so the journal always covers the contiguous
        # epoch range (epoch - len(journal), epoch].
        self._chain = {}     # page -> chain hash (full entries only)
        self.epoch = 0
        self._journal: deque = deque(maxlen=self.JOURNAL_DEPTH)

    def _record(self, sign: int, h: int) -> None:
        self.epoch += 1
        self._journal.append((self.epoch, sign, h))

    @staticmethod
    def _root(namespace):
        """Radix root for ``namespace``; distinct from every physical page
        id, so cross-namespace walks can never meet."""
        return ("root", namespace)

    # -- lookup --------------------------------------------------------------
    def lookup(self, prompt, namespace=None) -> tuple[list[int], int]:
        """Longest cached prefix of ``prompt`` within ``namespace``.

        Returns (chain, match_len): ``chain`` holds the full pages covering
        ``match_len // page_size`` pages plus, if ``match_len`` ends
        mid-page, the page holding that partial tail (the copy-on-write
        source). Entries registered under a different namespace are
        unreachable: the walk starts at the namespace's own root.
        """
        ps = self.page_size
        chain: list[int] = []
        parent, i = self._root(namespace), 0
        while (i + 1) * ps <= len(prompt):
            page = self._full.get((parent, tuple(prompt[i * ps:(i + 1) * ps])))
            if page is None:
                break
            chain.append(page)
            parent = page
            i += 1
        match = i * ps
        best_toks, best_page = (), -1
        for toks, page in self._partial.get(parent, ()):
            if len(toks) > len(best_toks) and \
                    tuple(prompt[match:match + len(toks)]) == toks:
                best_toks, best_page = toks, page
        if best_page >= 0:
            chain.append(best_page)
            match += len(best_toks)
        return chain, match

    # -- registration --------------------------------------------------------
    def register(self, prompt, pages, namespace=None) -> None:
        """Record a freshly prefilled prompt's pages under ``namespace``.

        Existing entries win (their pages are what later lookups alias); our
        private duplicate simply stays out of the index. ``pages`` is the
        request's page list: ``pages[i]`` holds rows [i*ps, (i+1)*ps).
        The same token content registered under two namespaces keeps two
        physical copies — exactly the tenant-isolation requirement.
        """
        ps = self.page_size
        parent = self._root(namespace)
        parent_hash = hash((_FP_SALT, namespace))
        n_full = len(prompt) // ps
        for i in range(n_full):
            tup = tuple(prompt[i * ps:(i + 1) * ps])
            key = (parent, tup)
            page = self._full.get(key)
            if page is None:
                page = pages[i]
                self._full[key] = page
                self._owned[page] = ("full", key, namespace)
                self._kids.setdefault(parent, []).append(key)
                self._chain[page] = hash((parent_hash, tup))
                self._record(+1, self._chain[page])
            parent_hash = self._chain[page]
            parent = page
        rem = tuple(prompt[n_full * ps:])
        if rem and n_full < len(pages):
            lst = self._partial.setdefault(parent, [])
            if all(toks != rem for toks, _ in lst):
                lst.append((rem, pages[n_full]))
                self._owned[pages[n_full]] = ("partial", parent, rem,
                                              namespace)

    # -- eviction ------------------------------------------------------------
    def evict(self, page: int) -> None:
        """Drop ``page``'s entries: its physical contents are being reused.

        Fires ``on_evict`` with one :class:`EvictionEvent` covering the
        page and its scrubbed subtree when any entry was removed.
        """
        dropped: list[tuple[int, object]] = []   # (page, namespace)
        owned = self._owned.pop(page, None)
        if owned is not None:
            dropped.append((page, owned[-1]))
            if owned[0] == "full":
                self._full.pop(owned[1], None)
                ch = self._chain.pop(page, None)
                if ch is not None:
                    self._record(-1, ch)
                # Also unlink from the parent's child list: namespace roots
                # are never scrubbed, so a stale key left here would leak
                # one entry per eviction for the gateway's lifetime.
                kids = self._kids.get(owned[1][0])
                if kids is not None:
                    try:
                        kids.remove(owned[1])
                    except ValueError:
                        pass
                    if not kids:
                        del self._kids[owned[1][0]]
            else:
                _, parent, toks, _ns = owned
                lst = self._partial.get(parent)
                if lst is not None:
                    lst[:] = [e for e in lst if e[0] != toks]
        # Entries keyed under this page id would silently re-anchor to the
        # page's NEW contents — scrub the whole subtree.
        self._scrub(page, dropped)
        if dropped and self.on_evict is not None:
            self.on_evict(EvictionEvent(
                pages=tuple(p for p, _ in dropped),
                namespace=dropped[0][1],
                epoch=self.epoch))

    def _scrub(self, page: int,
               dropped: list[tuple[int, object]] | None = None) -> None:
        for key in self._kids.pop(page, ()):
            child = self._full.pop(key, None)
            if child is not None:
                ent = self._owned.get(child)
                if ent is not None and ent[0] == "full" and ent[1] == key:
                    del self._owned[child]
                    if dropped is not None:
                        dropped.append((child, ent[2]))
                    ch = self._chain.pop(child, None)
                    if ch is not None:
                        self._record(-1, ch)
                    self._scrub(child, dropped)
        for toks, child in self._partial.pop(page, ()):
            ent = self._owned.get(child)
            if ent is not None and ent[0] == "partial" and ent[1] == page \
                    and ent[2] == toks:
                del self._owned[child]
                if dropped is not None:
                    dropped.append((child, ent[3]))

    # -- introspection -------------------------------------------------------
    def __len__(self) -> int:
        return len(self._full) + sum(len(v) for v in self._partial.values())

    def fingerprint(self, namespace=_ALL_NAMESPACES) -> frozenset:
        """Compact advertisement of every fully cached page-granular prefix.

        Returns the set of :func:`chain_hashes` values reachable from the
        namespace root(s): one hash per cached full page, chained through
        its radix ancestry. The set is *prefix-closed* — registration adds
        every depth along the chain and eviction scrubs subtrees rootward-in
        — so a router can score a prompt by counting consecutive hits of its
        own ``chain_hashes`` against this set (stop at the first miss).
        Partial (sub-page) entries are deliberately excluded: they only save
        a copy-on-write, not prefill FLOPs, so they don't move routing.

        By default all namespaces are merged (the router scores a request
        with the request's OWN namespace salt, so cross-namespace hashes
        can't collide by construction); pass ``namespace=`` to advertise a
        single domain.
        """
        fp = set()
        if namespace is _ALL_NAMESPACES:
            roots = [r for r in self._kids
                     if isinstance(r, tuple) and r[0] == "root"]
        else:
            roots = [self._root(namespace)]
        for root in roots:
            seed = hash((_FP_SALT, root[1]))
            stack = [(root, seed)]
            while stack:
                parent, h = stack.pop()
                for key in self._kids.get(parent, ()):
                    page = self._full.get(key)
                    if page is None:
                        continue
                    ch = hash((h, key[1]))
                    fp.add(ch)
                    stack.append((page, ch))
        return frozenset(fp)

    def fingerprint_delta(self, since_epoch: int
                          ) -> tuple[int, frozenset, frozenset] | None:
        """Fingerprint changes (all namespaces) since ``since_epoch``.

        Returns ``(epoch, added, removed)`` where replaying
        ``fp | added - removed`` onto the snapshot taken at ``since_epoch``
        reproduces :meth:`fingerprint` at the current epoch — the router's
        O(churn) alternative to a full frozenset snapshot every dispatch
        round. Returns ``None`` when ``since_epoch`` predates the journal
        (the consumer fell more than ``JOURNAL_DEPTH`` mutations behind, or
        claims an epoch from another cache's future): take a fresh snapshot.
        Add-then-remove pairs inside the window collapse to nothing, so the
        delta stays small however hot the churn.
        """
        if since_epoch > self.epoch or \
                since_epoch < self.epoch - len(self._journal):
            return None
        added: set = set()
        removed: set = set()
        for ep, sign, h in self._journal:
            if ep <= since_epoch:
                continue
            if sign > 0:
                if h in removed:
                    removed.discard(h)
                else:
                    added.add(h)
            else:
                if h in added:
                    added.discard(h)
                else:
                    removed.add(h)
        return self.epoch, frozenset(added), frozenset(removed)
