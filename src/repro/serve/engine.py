"""Continuous-batching serve engine over a shared block-paged KV cache.

Cloud Kotta's provisioning argument, applied to token decode. The paper keeps
utilization high under bursty multi-user load by (a) pooling capacity that
static per-user provisioning would strand, (b) admitting work the moment
capacity frees up (its elastic worker pools / spot market), and (c) keeping
ONE copy of a hot shared dataset that many jobs read (its tiered storage).
This engine is the serving analogue, with the KV cache playing the role of
the provisioned resource:

- **Slots are worker nodes.** ``max_decode_slots`` fixed batch lanes decode
  in lockstep at hardware speed; a request occupies a slot only while live,
  exactly like a Kotta job occupies a pool node.
- **Pages are the storage tier.** The physical KV pool is one shared array of
  ``page_size``-row pages; each request addresses its logical KV stream
  through a per-slot page-table row. Paging provisions per *actual* demand
  and returns capacity on completion with zero copies or compaction.
- **The queue is the job queue.** Between decode chunks the engine retires
  finished sequences and admits waiting prompts into the freed slots/pages —
  continuous batching, the scheduling move that gives Kotta its up-to-16x
  cost reduction over static provisioning.
- **Admission is O(new tokens), not O(prompt length).** Prompts are prefilled
  in fixed ``prefill_chunk``-token steps whose KV rows are scattered straight
  into pool pages (``prefill_paged``): no dense ragged cache, no
  re-layout/transpose into pages afterwards, and one jit signature per batch
  bucket instead of one per prompt-length pad bucket.
- **Prompt prefixes are shared copy-on-write.** A page-granular radix index
  (:mod:`repro.serve.paging`) maps token chunks to the pool pages already
  holding their KV. Admission aliases every fully-matched page into the new
  request's page-table row (refcount++), copy-on-writes the one partially
  matched boundary page, and prefills only the unmatched suffix — the
  paper's shared-dataset tiering, for caches. Retirement decrefs instead of
  freeing, and a retired request's pages stay hittable until actually
  reallocated.
- **No host round-trips on the hot path.** The decode loop is a
  ``lax.fori_loop`` of exactly ``decode_chunk`` on-device steps (a static
  bound: one compile, ever) with the pool donated to each chunk; tokens
  accumulate on device and cross to the host once per chunk.
- **Speculative multi-token decode** (``enable_spec_decode``): each on-device
  step drafts ``spec_tokens`` candidates per slot by bigram prompt-lookup
  over the slot's own token history (kept on device in the chunk carry),
  scores all drafts plus the current token in ONE multi-query paged verify
  pass (:mod:`repro.kernels.verify_attention`), and emits the verified
  prefix — up to ``spec_tokens + 1`` tokens per step for the cost of one
  cache sweep. Greedy outputs are token-identical to the non-speculative
  path; rejected draft tails roll back by construction (the next step
  re-writes their KV rows) and writes past a slot's token budget are routed
  to the sink page so shared/refcounted pages are never corrupted. The trip
  count stays static: still one compile, ever. The draft lookup is FUSED
  with the verify pass into one jitted step per loop iteration
  (:func:`repro.train.train_step.build_fused_spec_step` over
  :mod:`repro.serve.drafting`).
- **Per-slot adaptive speculation** (``spec_adaptive_k``): each slot's
  accept-rate EMA governs its own speculative window ``kslot`` in [1, K] —
  halved when drafts keep getting rejected, re-doubled when acceptance
  recovers — and each chunk dispatches at the smallest jitted verify-window
  *bucket* covering the live slots, so low-acceptance workloads stop paying
  for K verify rows per step. Greedy outputs stay token-identical for any
  window schedule (accepted prefixes are always exact greedy matches).
- **int8-quantized KV pages** (``kv_cache_dtype="int8"``): the pool stores
  K/V rows as int8 with per-row f32 scale pages, quantized on scatter and
  dequantized inside the attention-kernel tile loads (f32 accumulation) —
  ~``4*hd/(hd+4)``x the slot-token capacity at a fixed HBM budget, with the
  f32 layout untouched as the parity baseline (see kernels/kv_quant).

Physical page 0 is reserved as a write sink: idle slots keep ``pos=0`` and an
all-zero page-table row, and prefill pads route their KV writes there, so
masked writes can never corrupt pages belonging to live requests.

Beyond the one-shot ``generate`` loop the engine exposes a **stepped API**
(``enqueue`` / ``admit`` / ``decode_step`` / ``abort``) so an external
control plane can drive it request-by-request: the Kotta serving gateway
(:mod:`repro.serve.gateway`) keeps the queue deadline/cost-ordered, scopes
each request's prefix-cache ``namespace`` by (tenant, data-zone), and
re-enqueues a revoked spot replica's requests through ``abort`` — turning
every generation request into a first-class secured, scheduled Kotta job.

**Deadline-aware decode preemption** rides on the stepped API:
``preempt(slot)`` pauses a running request mid-stream with zero lost work —
its KV pages stay allocated and *pinned* (their refcounts are untouched, so
the allocator can never hand them out, and eviction-on-realloc can never
scrub their prefix-cache entries) while the host-side cursor / token
history / draft state parks in a :class:`PausedRequest`. The freed slot
admits an interactive request immediately. ``resume`` re-attaches the
parked pages to a fresh slot through the page table and continues decoding
with **zero re-prefill** (``prefill_tokens`` does not move) and greedy
tokens identical to an uninterrupted run — with or without speculative
decode, whose per-slot history buffer is parked and restored too.

``ServeEngine`` (static batch, dense cache) is kept as the fallback path for
recurrent-state families and as the benchmark baseline;
``prefill_mode="dense"`` keeps the PR-1 bucketed dense-prefill admission
path alive as an in-engine baseline/oracle.
"""
from __future__ import annotations

import enum
import math
import time
from collections import deque
from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models import get_family
from repro.train.train_step import (build_decode_step, build_fused_spec_step,
                                    build_paged_decode_step,
                                    build_paged_prefill_step,
                                    build_prefill_step)

from .drafting import build_ngram_draft
from .paging import PageAllocator, PrefixCache
from .telemetry import RegistryDict


@dataclass
class ServeResult:
    tokens: np.ndarray          # (B, max_new)
    prompt_lens: list[int]


class ServeEngine:
    """Legacy static-batch engine: pads the batch, dense per-request cache."""

    def __init__(self, cfg, params, *, max_len: int = 512):
        if cfg.encoder_only:
            raise ValueError("encoder-only models cannot decode")
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.family = get_family(cfg)
        self._prefill = jax.jit(build_prefill_step(cfg))
        self._decode = jax.jit(build_decode_step(cfg))

    def _pad_cache(self, cache, cur_len: int):
        """Grow the prefill cache to max_len along the cache_seq axis."""
        def grow(x):
            # cache_seq axis = 2 for (L,B,S,KV,hd); SSM states have no seq axis.
            if x.ndim >= 3 and x.shape[2] == cur_len:
                pad = [(0, 0)] * x.ndim
                pad[2] = (0, self.max_len - cur_len)
                return jnp.pad(x, pad)
            return x
        return jax.tree.map(grow, cache)

    def generate(self, prompts: list[list[int]], max_new: int = 16) -> ServeResult:
        b = len(prompts)
        plen = max(len(p) for p in prompts)
        toks = np.zeros((b, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, plen - len(p):] = p  # left-pad so last position is newest
        batch = {"tokens": jnp.asarray(toks)}
        logits, cache = self._prefill(self.params, batch)
        cache = self._pad_cache(cache, plen)
        pos = jnp.full((b,), plen - 1, jnp.int32)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        # Tokens accumulate on device; one host transfer at the end (the seed
        # did a blocking np.asarray round-trip per decoded token).
        out = jnp.zeros((b, max_new), jnp.int32)
        for t in range(max_new):
            out = out.at[:, t].set(next_tok)
            pos = pos + 1
            step_batch = {"tokens": next_tok[:, None], "pos": pos}
            next_tok, _, cache = self._decode(self.params, step_batch, cache)
        return ServeResult(np.asarray(out), [len(p) for p in prompts])


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------

@dataclass
class EngineRequest:
    """One generation request as the engine's queue sees it.

    ``rid`` is an opaque caller-chosen id (``generate`` uses the prompt
    index; the gateway uses its job ids). ``max_new`` is per-request — the
    stepped API admits requests with heterogeneous budgets in one wave.
    ``namespace`` scopes the prefix cache: pages registered under one
    namespace are invisible to lookups from another (the gateway keys it by
    (tenant, data-zone), so cross-tenant prompts can never alias KV pages).
    """
    rid: object
    prompt: list[int]
    max_new: int
    namespace: object = None


@dataclass
class _Live:
    """A request occupying a slot."""
    req: EngineRequest
    pages: list[int]
    emitted: int = 0
    tokens: list[int] = field(default_factory=list)


@dataclass
class PausedRequest:
    """A preempted request parked host-side, its KV pages still pinned.

    ``pages`` keep their refcounts (never released, never reallocatable)
    so the sequence's KV survives any eviction pressure while paused;
    ``cur``/``pos``/``limit`` — and ``hist``, the speculative-decode
    drafting history row — are the exact slot state at the chunk boundary
    where the request was paused. ``resume`` restores all of it into a
    fresh slot with zero re-prefill.
    """
    req: EngineRequest
    pages: list[int]
    emitted: int
    tokens: list[int]
    cur: int
    pos: int
    limit: int
    hist: np.ndarray | None = None
    # Adaptive-speculation state (spec decode only): the slot's speculative
    # window and accept-rate EMA survive preemption, so a resumed request
    # picks its tuned window back up instead of re-warming from K.
    kslot: int = 0
    ema: float = 0.0


class ExportReason(str, enum.Enum):
    """Why a request's pages are leaving the device pool.

    The one residency API (:meth:`ContinuousBatchingEngine.export`)
    serves three movements that used to be parallel code paths —
    cross-replica shipping and cross-tier demotion are two *transports*
    behind the same gather:

    - ``HANDOFF``: disaggregated prefill -> decode replica hop.
    - ``EVACUATE``: revocation-notice migration off a dying replica.
    - ``DEMOTE``: tier demotion into a :class:`~repro.serve.kv_store.TieredKVStore`
      (device -> host/object storage instead of device -> device).
    """

    HANDOFF = "handoff"
    EVACUATE = "evacuate"
    DEMOTE = "demote"


@dataclass
class ShippedKV:
    """A request's finished KV pages in flight between engines (or tiers).

    The disaggregated-serving handoff payload: a prefill-role replica runs
    admission prefill, ``export`` snapshots the request's *content*
    pages (the ``ceil(pos / page_size)`` pages actually holding KV rows —
    trailing decode-budget pages are empty and never ship) into host arrays,
    and ``import_pages`` on a decode-role replica re-registers everything:
    fresh pages from the destination allocator, a page-table row, the
    destination's radix prefix cache (so the shipped prefix stays shareable
    after the hop), and the decode cursor exactly where the source stopped.
    Greedy decode continues token-identically to a never-shipped run.

    ``content`` maps every pool leaf name to a ``(L, KV, n_content,
    page_size[, hd])`` host array — for int8 pools that is the int8 data
    pages AND their f32 ``k_scale``/``v_scale`` pages, so dequantization
    state travels with the data.

    The payload is not prefill-specific: a request exported **mid-decode**
    (the evacuation path) carries its already-decoded KV rows in the same
    content pages, its emitted tokens in ``tokens``, and — under
    speculative decode — its tuned adaptive window ``kslot`` and
    accept-rate EMA, so the destination resumes with the speculation
    controller warm instead of re-learning from K. ``consumed`` flips true
    on a successful import: a payload is a one-shot move, and importing it
    twice would mint two live copies of one request (a second import
    raises ``ValueError``; a *failed* import leaves it re-importable).
    """
    req: EngineRequest
    emitted: int
    tokens: list[int]
    cur: int                    # next token to emit (seeds dest decode)
    pos: int                    # == len(prompt) + emitted
    content: dict[str, np.ndarray]
    kv_cache_dtype: str
    page_size: int
    hist: np.ndarray | None = None     # spec-decode drafting history, if any
    kslot: int = 0              # adaptive speculative window (0 = untracked)
    ema: float = 0.0            # accept-rate EMA riding along with kslot
    consumed: bool = False      # set by a successful import / restore
    reason: ExportReason = ExportReason.HANDOFF

    @property
    def n_content(self) -> int:
        return next(iter(self.content.values())).shape[2]

    def page_nbytes(self) -> int:
        """Bytes of ONE shipped page across every content leaf — int8 data
        pages AND their f32 scale pages alike. The single source of truth
        for per-page sizing: ship budgets, tier capacities and metrics all
        multiply this by a page count, so no stats path can count data
        pages while forgetting the scales."""
        n = self.n_content
        return sum(a.nbytes // n for a in self.content.values())

    @property
    def nbytes(self) -> int:
        """Wire size of the shipped pages (data + scale pages alike);
        derived from :meth:`page_nbytes` so every sizing path agrees."""
        return self.page_nbytes() * self.n_content


def _next_pow2(n: int) -> int:
    """Bucket size for wave-shaped device calls: a handful of jit
    signatures (1, 2, 4, ...) instead of one per wave width."""
    return 1 << max(0, n - 1).bit_length()


@dataclass
class _Admit:
    """A request accepted into the current admission wave."""
    slot: int
    req: EngineRequest
    pages: list[int]
    start: int                  # first position to prefill (= prefix match)
    group: int = 1              # intra-wave prefill stage (same-wave dedup)


class ContinuousBatchingEngine:
    """Continuous-batching decode over a shared paged KV pool (module doc)."""

    def __init__(self, cfg, params, *, max_len: int = 512,
                 max_slots: int | None = None, num_pages: int | None = None,
                 decode_chunk: int | None = None,
                 prefill_chunk: int | None = None,
                 prefill_mode: str = "paged",
                 enable_prefix_cache: bool | None = None,
                 enable_spec_decode: bool | None = None,
                 spec_tokens: int | None = None,
                 spec_ngram: int | None = None,
                 kv_cache_dtype: str | None = None,
                 spec_adaptive_k: bool | None = None,
                 role: str = "unified"):
        if cfg.encoder_only:
            raise ValueError("encoder-only models cannot decode")
        if prefill_mode not in ("paged", "dense"):
            raise ValueError(f"prefill_mode {prefill_mode!r}")
        if role not in ("unified", "prefill", "decode"):
            raise ValueError(f"role must be 'unified', 'prefill' or "
                             f"'decode', got {role!r}")
        self.role = role
        if role == "prefill":
            # A prefill-specialized replica only runs admission prefill and
            # ships the finished pages out (export_pages); it never decodes,
            # so speculation has nothing to govern there.
            if enable_spec_decode:
                raise ValueError("role='prefill' engines never decode; "
                                 "enable_spec_decode is meaningless there")
            enable_spec_decode = False
            spec_adaptive_k = False
        self.kv_cache_dtype = cfg.kv_cache_dtype if kv_cache_dtype is None \
            else kv_cache_dtype
        if self.kv_cache_dtype not in ("f32", "int8"):
            raise ValueError(f"kv_cache_dtype must be 'f32' or 'int8', got "
                             f"{self.kv_cache_dtype!r}")
        # The dense baseline prefills an unquantized ragged cache and
        # re-layouts it into whole pages, bypassing quantize-on-scatter; an
        # explicit request for both is a contradiction, not a default.
        if self.kv_cache_dtype == "int8" and prefill_mode == "dense":
            raise ValueError("kv_cache_dtype='int8' requires "
                             "prefill_mode='paged' (dense prefill re-layouts "
                             "an unquantized cache into pool pages and "
                             "bypasses quantize-on-scatter)")
        step = build_paged_decode_step(cfg)   # raises for recurrent families
        self.cfg = cfg
        self.params = params
        self.family = get_family(cfg)
        self.page_size = cfg.page_size
        self.max_slots = cfg.max_decode_slots if max_slots is None \
            else max_slots
        if self.max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {self.max_slots}")
        self.pages_per_seq = math.ceil(max_len / self.page_size)
        # +1: physical page 0 is the reserved idle-slot/pad write sink.
        self.num_pages = (num_pages or self.max_slots * self.pages_per_seq) + 1
        if enable_spec_decode is None:
            enable_spec_decode = cfg.enable_spec_decode
        self.spec_tokens = cfg.spec_tokens if spec_tokens is None \
            else spec_tokens
        self.spec_ngram = cfg.spec_ngram if spec_ngram is None else spec_ngram
        self.spec_decode = bool(enable_spec_decode)
        self.spec_adaptive_k = bool(
            cfg.spec_adaptive_k if spec_adaptive_k is None
            else spec_adaptive_k)
        if self.spec_adaptive_k and not self.spec_decode:
            raise ValueError("spec_adaptive_k=True requires "
                             "enable_spec_decode=True (the adaptive window "
                             "governs speculative drafting)")
        if self.spec_decode:
            # Fail here, with the knob named, instead of as a shape error
            # deep inside the verify step / Pallas kernel.
            k = self.spec_tokens
            if k < 1:
                raise ValueError(
                    f"enable_spec_decode requires spec_tokens >= 1, got {k} "
                    "(each verify step scores spec_tokens drafts + the "
                    "current token)")
            if self.spec_ngram not in (2, 3):
                raise ValueError(
                    f"spec_ngram must be 2 (bigram) or 3 (trigram draft "
                    f"keys), got {self.spec_ngram}")
            window = k + 1
            if window > self.pages_per_seq * self.page_size:
                raise ValueError(
                    f"spec_tokens+1 = {window} verify rows exceed the "
                    f"{self.pages_per_seq * self.page_size}-row page-table "
                    f"window (max_len {max_len}, page_size "
                    f"{self.page_size}); shrink spec_tokens or raise "
                    "max_len")
            group = cfg.num_heads // cfg.num_kv_heads
            if cfg.attn_impl == "pallas" and (window * group) % 8:
                raise ValueError(
                    f"verify query tile (spec_tokens+1)*G = {window}*{group}"
                    f" = {window * group} rows must be a multiple of 8 "
                    "sublanes for the Pallas verify kernel; adjust "
                    "spec_tokens (or num_kv_heads)")
        if decode_chunk is None:
            # Occupancy heuristic (BENCH_serve batch-32 droop): hold
            # slots * chunk * expected-tokens-per-step ≈ decode_chunk_tokens
            # per dispatch — narrow batches take long chunks to amortize the
            # host sync, wide batches take short chunks so freed slots
            # re-admit waiters sooner (p95 TTFT), the sync already being
            # amortized across slots. A speculative step emits 1..K+1 tokens,
            # so spec chunks are shortened by the FULL window: an oversized
            # chunk sails past every slot's budget and burns dead masked
            # steps (each costing a whole verify pass), while an undersized
            # chunk merely adds a cheap host sync.
            per_step = 1 + self.spec_tokens if self.spec_decode else 1
            decode_chunk = min(cfg.decode_chunk_max,
                               max(2, cfg.decode_chunk_min // per_step,
                                   cfg.decode_chunk_tokens
                                   // (self.max_slots * per_step)))
        self.decode_chunk = decode_chunk
        self.prefill_chunk = prefill_chunk or cfg.prefill_chunk
        if self.decode_chunk < 1:
            raise ValueError(f"decode_chunk must be >= 1, got "
                             f"{self.decode_chunk}")
        if self.prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got "
                             f"{self.prefill_chunk}")
        self.prefill_mode = prefill_mode

        # int8 pools add (L,KV,P,ps) f32 per-row scale pages; all downstream
        # paths (model scatter, kernels, COW) handle the dict structurally.
        self.pool = self.family.paged_pool(cfg, self.num_pages,
                                           self.kv_cache_dtype)

        self.alloc = PageAllocator(self.num_pages)
        # Prefix sharing needs paged prefill: the dense path re-writes whole
        # pad-rounded pages and would clobber aliased prefix pages. An
        # explicit request for both is a contradiction, not a default.
        if enable_prefix_cache and prefill_mode == "dense":
            raise ValueError("enable_prefix_cache=True requires "
                             "prefill_mode='paged' (dense prefill re-writes "
                             "whole pages and cannot alias shared prefixes)")
        if enable_prefix_cache is None:
            enable_prefix_cache = cfg.enable_prefix_cache
        self.prefix_cache = PrefixCache(self.page_size) \
            if (enable_prefix_cache and prefill_mode == "paged") else None
        if self.prefix_cache is not None:
            self.alloc.on_alloc = self.prefix_cache.evict

        s = self.max_slots
        self._page_table = np.zeros((s, self.pages_per_seq), np.int32)
        self._pos = np.zeros(s, np.int32)
        self._cur = np.zeros(s, np.int32)
        self._active = np.zeros(s, bool)
        # Per-slot KV write limit (prompt_len + max_new): spec-decode draft
        # windows running past it are routed to the sink page.
        self._limit = np.zeros(s, np.int32)
        # Per-slot adaptive speculation: current speculative window (1..K,
        # seeded at K on admit) and accept-rate EMA. Host-side: updated once
        # per chunk from the chunk's (n_out, n_it) tallies.
        self._kslot = np.zeros(s, np.int32)
        self._ema = np.zeros(s, np.float64)
        # Per-slot token history (prompt + verified output) for on-device
        # n-gram drafting; lives in the decode-chunk carry while decoding.
        self.hist_len = self.pages_per_seq * self.page_size
        self._hist = jnp.zeros((s, self.hist_len), jnp.int32) \
            if self.spec_decode else None
        self._live: dict[int, _Live] = {}
        # Preempted requests parked host-side; their pages stay pinned.
        self._paused: dict[object, PausedRequest] = {}
        # Tier demotion (set by the control plane when a TieredKVStore is
        # attached): a finishing request's content pages are exported
        # (reason=DEMOTE) *before* retirement and parked in ``demoted_out``
        # for the gateway to drain into the store — so by the time
        # eviction-on-realloc scrubs the index entries, the content already
        # lives in a lower tier (demoted, not destroyed).
        self.demote_on_retire = False
        self.demoted_out: list[ShippedKV] = []
        # Admission queue, consumed front-first by ``admit``. The caller
        # controls its order: ``generate`` fills it FCFS, the gateway keeps
        # it policy-ordered (EDF within priority class).
        self._queue: deque[EngineRequest] = deque()
        self.stats: dict[str, float] = {}
        self._reset_stats()

        # -- jitted steps ----------------------------------------------------
        self._prefill_ragged = jax.jit(
            lambda p, b: self.family.prefill_ragged(cfg, p, b))

        self._n_prefill_traces = 0
        pstep = build_paged_prefill_step(cfg)

        def prefill_chunk_fn(params, batch, pool):
            self._n_prefill_traces += 1
            return pstep(params, batch, pool)

        self._prefill_chunked = jax.jit(prefill_chunk_fn, donate_argnums=(2,))

        self._n_decode_traces = 0

        def decode_chunk_fn(params, cur, pos, page_table, active, budget,
                            pool):
            self._n_decode_traces += 1
            out = jnp.zeros((s, self.decode_chunk), jnp.int32)

            def body(i, carry):
                cur, pos, pool, out = carry
                out = out.at[:, i].set(cur)
                # A slot whose token budget is spent mid-chunk must stop
                # writing KV: its pos sits at prompt_len + max_new, and for a
                # row that fills its whole page table the clamped gather
                # would redirect that write INTO the request's last real
                # page, corrupting prompt rows the prefix cache may already
                # share. Masking the row to the all-zero sink makes the
                # overshoot steps harmless.
                live = active & (i < budget)
                pt = jnp.where(live[:, None], page_table, 0)
                batch = {"tokens": cur[:, None], "pos": pos,
                         "page_table": pt}
                nxt, _, pool = step(params, batch, pool)
                cur = jnp.where(live, nxt, cur)
                pos = jnp.where(live, pos + 1, pos)
                return cur, pos, pool, out

            # Static trip count: ragged remaining-token counts can never mint
            # new jit signatures; spent slots idle against the sink page.
            return lax.fori_loop(0, self.decode_chunk, body,
                                 (cur, pos, pool, out))

        # Donating the pool lets XLA scatter new KV rows in place instead of
        # copying the whole pool every chunk.
        self._chunk = jax.jit(decode_chunk_fn, donate_argnums=(6,))

        if self.spec_decode:
            k_spec = self.spec_tokens
            hlen = self.hist_len
            ngram = self.spec_ngram
            group = cfg.num_heads // cfg.num_kv_heads

            # Verify-window buckets: the adaptive controller shrinks a
            # slot's speculative window kslot per its accept-rate EMA, and
            # the host dispatches each chunk at the smallest bucket covering
            # every live slot's window — a genuinely narrower verify pass
            # (fewer query rows), not just masked acceptance. Buckets are
            # pow2 sizes plus K itself, filtered by the Pallas sublane rule
            # ((b+1)*G % 8 == 0) so every bucket is dispatchable; K always
            # survives the filter (validated above). Non-adaptive engines
            # use the single bucket K, keeping one chunk trace ever.
            if self.spec_adaptive_k:
                cand = {1 << i for i in range(k_spec.bit_length())}
                cand.add(k_spec)
                self._spec_buckets = sorted(
                    b for b in cand if b <= k_spec
                    and (cfg.attn_impl != "pallas"
                         or ((b + 1) * group) % 8 == 0))
            else:
                self._spec_buckets = [k_spec]
            self._spec_chunks: dict[int, object] = {}

            def make_spec_chunk(kb: int):
                """Build + jit the decode chunk for verify-window bucket kb.

                Each ``fori_loop`` step is ONE fused dispatch
                (:func:`build_fused_spec_step`): n-gram draft lookup, window
                assembly, KV scatter and the multi-query verify all in the
                same traced step. Acceptance is additionally masked to the
                slot's own window ``kslot <= kb``, so two slots in the same
                chunk can run different effective speculation depths.
                """
                t_spec = kb + 1
                fstep = build_fused_spec_step(
                    cfg, build_ngram_draft(hlen, kb, ngram))

                def spec_chunk_fn(params, cur, pos, hist, page_table, active,
                                  budget, limit, kslot, pool):
                    self._n_decode_traces += 1
                    out = jnp.zeros((s, self.decode_chunk * t_spec),
                                    jnp.int32)
                    n_out = jnp.zeros(s, jnp.int32)
                    n_it = jnp.zeros(s, jnp.int32)
                    bidx = jnp.arange(s)

                    def body(i, carry):
                        cur, pos, hist, n_out, n_it, pool, out = carry
                        live = active & (n_out < budget)
                        # The verified current token enters the history
                        # first: hist[:pos+1] is now the exact token stream
                        # the fused step's draft lookup reads.
                        hist = hist.at[bidx, pos].set(cur)
                        pt = jnp.where(live[:, None], page_table, 0)
                        wl = jnp.where(live, limit, 0)
                        batch = {"cur": cur, "pos": pos, "hist": hist,
                                 "page_table": pt, "write_limit": wl}
                        window, drafts, nxt, pool = fstep(params, batch,
                                                          pool)
                        # Drafted tokens become history; the tail past the
                        # next pos is re-written before any read.
                        hidx = pos[:, None] + 1 + jnp.arange(kb)[None, :]
                        hist = hist.at[bidx[:, None], hidx].set(
                            drafts, mode="drop")
                        # -- acceptance: longest draft prefix the model
                        # agrees with, capped at the slot's own adaptive
                        # window; nxt[:, a] is the correction after it ----
                        match = (drafts == nxt[:, :kb]) & \
                                (jnp.arange(kb)[None, :] < kslot[:, None])
                        a = jnp.cumprod(match.astype(jnp.int32),
                                        axis=1).sum(axis=1)        # (S,)
                        # -- emit cur + accepted drafts; the tail beyond
                        # 1+a is overwritten by the next step's emission --
                        base = jnp.where(live, n_out, out.shape[1])
                        oidx = base[:, None] + jnp.arange(t_spec)[None, :]
                        out = out.at[bidx[:, None], oidx].set(window,
                                                              mode="drop")
                        n_out = n_out + jnp.where(live, 1 + a, 0)
                        n_it = n_it + live.astype(jnp.int32)
                        cur = jnp.where(live, nxt[bidx, a], cur)
                        pos = jnp.where(live, pos + 1 + a, pos)
                        return cur, pos, hist, n_out, n_it, pool, out

                    # Static trip count, exactly like the plain decode
                    # chunk: one compile per bucket, however the accept
                    # rate fluctuates.
                    return lax.fori_loop(
                        0, self.decode_chunk, body,
                        (cur, pos, hist, n_out, n_it, pool, out))

                return jax.jit(spec_chunk_fn, donate_argnums=(9,))

            self._make_spec_chunk = make_spec_chunk

        @partial(jax.jit, donate_argnums=(0,))
        def cow_copy(pool, src, dst):
            """src/dst: (n,) int32 — one dispatch copies a whole wave's
            boundary pages; pad pairs are (0, 0), a sink-to-sink no-op.
            Page axis is 2 for EVERY pool leaf (data and scale pages alike),
            so the copy is one structural map over the dict."""
            return {name: leaf.at[:, :, dst].set(leaf[:, :, src])
                    for name, leaf in pool.items()}

        self._cow = cow_copy
        self._writer_cache = {}
        # Page-shipping gather/scatter, jitted lazily per pow2 page-count
        # bucket (page axis 2 on every pool leaf, like _cow).
        self._ship_gather_cache = {}
        self._ship_scatter_cache = {}

    # -- stats ---------------------------------------------------------------
    _STAT_ZEROS = {"admitted": 0, "prefill_tokens": 0, "cached_tokens": 0,
                   "cow_copies": 0, "admit_seconds": 0.0,
                   "spec_steps": 0, "spec_emitted": 0,
                   "preempted": 0, "resumed": 0,
                   "page_exports": 0, "page_imports": 0,
                   "page_demotes": 0, "page_restores": 0,
                   "accept_ema_sum": 0.0, "accept_ema_n": 0}
    # Keys exported when bound to a MetricsRegistry; the scratch
    # accumulators (admit_seconds, accept EMA terms) stay local-only.
    _STAT_EXPORTED = ("admitted", "prefill_tokens", "cached_tokens",
                      "cow_copies", "spec_steps", "spec_emitted",
                      "preempted", "resumed", "page_exports", "page_imports",
                      "page_demotes", "page_restores")

    def _reset_stats(self):
        stats = getattr(self, "stats", None)
        if isinstance(stats, RegistryDict):
            # Registry-bound: zero the local mirror in place. Counter
            # deltas are positive-only, so the bound series stay monotonic
            # across resets (Prometheus counter-reset semantics).
            for k, v in self._STAT_ZEROS.items():
                stats[k] = v
        else:
            self.stats = dict(self._STAT_ZEROS)

    def bind_registry(self, registry, engine: str) -> None:
        """Swap ``stats`` for a write-through view over ``registry``
        counters labeled ``{engine=...}``; pre-bind totals carry into the
        series and call sites keep the plain-dict idiom."""
        rd = RegistryDict()
        for key in self._STAT_EXPORTED:
            fam = registry.counter(
                f"kotta_engine_{key}_total",
                f"Engine {key.replace('_', ' ')} (cumulative)", ("engine",))
            rd.bind(key, fam, initial=self.stats[key], engine=engine)
        for key in self._STAT_ZEROS:
            if key not in self._STAT_EXPORTED:
                rd.bind(key, None, initial=self.stats[key])
        self.stats = rd

    @property
    def prefix_hit_rate(self) -> float:
        tot = self.stats["cached_tokens"] + self.stats["prefill_tokens"]
        return self.stats["cached_tokens"] / tot if tot else 0.0

    @property
    def mean_accepted_len(self) -> float:
        """Mean drafted tokens accepted per verify step (0 <= . <= K).

        Every verify step emits 1 + accepted tokens, so this is
        (emitted - steps) / steps over the last ``generate`` call.
        """
        steps = self.stats["spec_steps"]
        return (self.stats["spec_emitted"] - steps) / steps if steps else 0.0

    @property
    def mean_accept_ema(self) -> float:
        """Mean final per-slot accept-rate EMA over retired requests."""
        n = self.stats["accept_ema_n"]
        return self.stats["accept_ema_sum"] / n if n else 0.0

    def slot_spec_state(self) -> dict[int, dict[str, float]]:
        """Live per-slot adaptive-speculation state (bench introspection)."""
        return {slot: {"kslot": int(self._kslot[slot]),
                       "accept_ema": float(self._ema[slot])}
                for slot in sorted(self._live)}

    # -- legacy dense page writer (prompt KV -> pool), per (pad, group) ------
    def _write_pages(self, k, v, pages):
        """k/v: (L, G, S_pad, KV, hd) prompt cache; pages: (G * npp,) int32."""
        key = (k.shape[1], k.shape[2])
        if key not in self._writer_cache:
            ps = self.page_size

            @partial(jax.jit, donate_argnums=(0, 1))
            def write(pool_k, pool_v, k, v, pages):
                nl, g, s_pad, nkv, hd = k.shape
                npp = g * (s_pad // ps)
                kp = k.reshape(nl, npp, ps, nkv, hd).transpose(0, 3, 1, 2, 4)
                vp = v.reshape(nl, npp, ps, nkv, hd).transpose(0, 3, 1, 2, 4)
                pool_k = pool_k.at[:, :, pages].set(kp.astype(pool_k.dtype))
                pool_v = pool_v.at[:, :, pages].set(vp.astype(pool_v.dtype))
                return pool_k, pool_v

            self._writer_cache[key] = write
        self.pool["k"], self.pool["v"] = self._writer_cache[key](
            self.pool["k"], self.pool["v"], k, v,
            jnp.asarray(pages, jnp.int32))

    # -- admission -----------------------------------------------------------
    def _admit_wave(self) -> int:
        """Admit requests from the queue, front-first, while slots and pages
        last.

        The queue's order IS the admission policy: ``generate`` keeps it
        FCFS, the serving gateway keeps it deadline/cost-ordered
        (:mod:`repro.serve.admission`) — ``_admit_wave`` just consumes it.

        Each accepted request first consults the prefix cache (within the
        request's namespace): fully matched pages are aliased into its
        page-table row (refcount++), a partially matched boundary page is
        copy-on-written, and only the remaining suffix is prefilled — chunk
        by chunk, batched across the wave.

        **Same-wave dedup:** a request's pages are registered in the radix
        index the moment it is accepted, so a later request in the SAME
        wave (e.g. an identical prompt) aliases them instead of prefilling
        privately. Content for those pages only exists after the donor's
        prefill runs, so the wave is prefilled in dependency *groups*: a
        request that aliases an in-wave donor lands in a later group than
        the donor, each group is one batched prefill, and a group's
        copy-on-write boundary copies are dispatched after its donors'
        group has prefilled but before its own prefill reads them.
        """
        t0 = time.perf_counter()
        ps = self.page_size
        wave: list[_Admit] = []
        cow_pairs: dict[int, list[tuple[int, int]]] = {}   # group -> pairs
        page_group: dict[int, int] = {}    # page -> group whose prefill fills it
        while self._queue:
            req = self._queue[0]
            prompt = req.prompt
            plen = len(prompt)
            free_slots = [i for i in range(self.max_slots)
                          if not self._active[i]]
            if not free_slots:
                break
            need_total = math.ceil((plen + req.max_new) / ps)  # checked at
            if self.prefix_cache is not None:                  # enqueue
                chain, raw = self.prefix_cache.lookup(prompt, req.namespace)
                # Always recompute at least the last prompt token: its logits
                # seed decode, and capping also keeps a fully-cached prompt
                # from needing zero prefill steps.
                match = min(raw, plen - 1)
            else:
                chain, match = [], 0
            n_alias, cow_m = divmod(match, ps)
            cow_src = chain[n_alias] if cow_m else None
            n_fresh = need_total - n_alias
            # Pin every matched page (incl. the copy-on-write source) BEFORE
            # allocating: a cache hit on a retired request's page finds it in
            # the free list, and an unpinned hit could be reallocated as one
            # of our own fresh pages, clobbering the prefix it still holds.
            shared = chain[:n_alias]
            for p in shared:
                self.alloc.share(p)
            if cow_src is not None:
                self.alloc.share(cow_src)
            if self.alloc.available() < n_fresh:
                for p in shared:                # not enough pages: wave ends
                    self.alloc.release(p)
                if cow_src is not None:
                    self.alloc.release(cow_src)
                break
            slot = free_slots[0]
            fresh = [self.alloc.alloc() for _ in range(n_fresh)]
            pages = shared + fresh
            # Aliasing an in-wave donor sequences us after its prefill.
            deps = shared if cow_src is None else shared + [cow_src]
            group = 1 + max((page_group.get(p, 0) for p in deps), default=0)
            if cow_src is not None:
                # Boundary page: first cow_m rows of the matched page are this
                # prompt's KV; copy them into our private page and append.
                # The copy is deferred to our group's dispatch — the pin on
                # cow_src holds until it lands.
                cow_pairs.setdefault(group, []).append((cow_src, fresh[0]))
                self.stats["cow_copies"] += 1
            for p in fresh:
                page_group[p] = group
            self._active[slot] = True          # reserve within this wave
            row = np.zeros(self.pages_per_seq, np.int32)
            row[:len(pages)] = pages
            self._page_table[slot] = row
            self.stats["cached_tokens"] += match
            self.stats["prefill_tokens"] += plen - match
            wave.append(_Admit(slot, req, pages, match, group))
            if self.prefix_cache is not None:
                # Publish now so the rest of this wave can alias; the grouped
                # prefill below guarantees the content lands first.
                self.prefix_cache.register(prompt, pages, req.namespace)
            self._queue.popleft()

        if wave:
            for g in sorted({a.group for a in wave}):
                self._dispatch_cows(cow_pairs.get(g, ()))
                members = [a for a in wave if a.group == g]
                if self.prefill_mode == "dense":
                    self._prefill_dense(members)
                else:
                    self._prefill_paged_wave(members)
            for a in wave:
                self._live[a.slot] = _Live(a.req, a.pages)
            if self.spec_decode:
                self._load_histories(wave)
            self.stats["admitted"] += len(wave)
        self.stats["admit_seconds"] += time.perf_counter() - t0
        return len(wave)

    def _dispatch_cows(self, cow_pairs) -> None:
        """One device dispatch copies a prefill group's boundary pages,
        padded to a pow2 bucket (pad pairs write sink -> sink)."""
        if not cow_pairs:
            return
        n = _next_pow2(len(cow_pairs))
        src = np.zeros(n, np.int32)
        dst = np.zeros(n, np.int32)
        for i, (s_, d_) in enumerate(cow_pairs):
            src[i], dst[i] = s_, d_
        self.pool = self._cow(self.pool, jnp.asarray(src), jnp.asarray(dst))
        for s_, _ in cow_pairs:
            self.alloc.release(s_)              # pin no longer needed

    def _load_histories(self, wave: list[_Admit]) -> None:
        """Seed the on-device drafting history + write limit for new slots."""
        rows = np.zeros((len(wave), self.hist_len), np.int32)
        slots = np.zeros(len(wave), np.int32)
        for i, a in enumerate(wave):
            rows[i, :len(a.req.prompt)] = a.req.prompt
            slots[i] = a.slot
            self._limit[a.slot] = len(a.req.prompt) + a.req.max_new
            # Speculation starts wide open; the per-chunk EMA update shrinks
            # the window if this request's drafts keep getting rejected.
            self._kslot[a.slot] = self.spec_tokens
            self._ema[a.slot] = 0.0
        self._hist = self._hist.at[jnp.asarray(slots)].set(jnp.asarray(rows))

    # -- paged chunked prefill (default admission path) ----------------------
    def _prefill_paged_wave(self, wave: list[_Admit]) -> None:
        """Prefill every wave member's suffix in fixed-width chunk steps.

        The batch is padded to a power-of-two bucket so the jitted step sees
        a handful of (bucket, chunk) signatures total — never one per prompt
        length. Pad rows carry ``kv_len=0`` so all their KV writes land in
        the sink page.
        """
        ps, c = self.page_size, self.prefill_chunk
        gp = _next_pow2(len(wave))
        page_tables = np.zeros((gp, self.pages_per_seq), np.int32)
        for i, a in enumerate(wave):
            page_tables[i] = self._page_table[a.slot]
        pt_dev = jnp.asarray(page_tables)
        nsteps = max(math.ceil((len(a.req.prompt) - a.start) / c)
                     for a in wave)

        step_toks = []
        for j in range(nsteps):
            toks = np.zeros((gp, c), np.int32)
            qs = np.zeros(gp, np.int32)
            kl = np.zeros(gp, np.int32)
            li = np.zeros(gp, np.int32)
            for i, a in enumerate(wave):
                plen = len(a.req.prompt)
                s0 = a.start + j * c
                qs[i] = s0
                kl[i] = plen
                li[i] = plen - 1 - s0                 # clamped in the step
                seg = a.req.prompt[s0:s0 + c]
                if seg:
                    toks[i, :len(seg)] = seg
            batch = {"tokens": jnp.asarray(toks), "q_start": jnp.asarray(qs),
                     "kv_len": jnp.asarray(kl), "page_table": pt_dev,
                     "logit_idx": jnp.asarray(li)}
            nxt, _, self.pool = self._prefill_chunked(self.params, batch,
                                                      self.pool)
            step_toks.append(nxt)

        # The first sampled token of request i comes from the chunk holding
        # position plen-1; sync each needed step array once.
        host: dict[int, np.ndarray] = {}
        for i, a in enumerate(wave):
            j = (len(a.req.prompt) - 1 - a.start) // c
            if j not in host:
                host[j] = np.asarray(step_toks[j])
            self._cur[a.slot] = host[j][i]
            self._pos[a.slot] = len(a.req.prompt)

    # -- dense ragged prefill (PR-1 baseline, kept as in-engine oracle) ------
    def _prefill_dense(self, wave: list[_Admit]) -> None:
        """Batched-by-pad-bucket dense prefill + page re-layout (legacy)."""
        ps = self.page_size
        by_pad: dict[int, list[_Admit]] = {}
        for a in wave:
            s_pad = math.ceil(len(a.req.prompt) / ps) * ps
            by_pad.setdefault(s_pad, []).append(a)

        for s_pad, items in by_pad.items():
            g = len(items)
            npp = s_pad // ps
            toks = np.zeros((g, s_pad), np.int32)
            lens = np.zeros(g, np.int32)
            for i, a in enumerate(items):
                toks[i, :len(a.req.prompt)] = a.req.prompt
                lens[i] = len(a.req.prompt)
            batch = {"tokens": jnp.asarray(toks),
                     "length": jnp.asarray(lens)}
            logits, cache = self._prefill_ragged(self.params, batch)
            prompt_pages = np.concatenate(
                [np.asarray(a.pages[:npp], np.int32) for a in items])
            self._write_pages(cache["k"], cache["v"], prompt_pages)
            first = np.array(jnp.argmax(logits, axis=-1), np.int32)  # 1 sync
            for i, a in enumerate(items):
                self._pos[a.slot] = len(a.req.prompt)
                self._cur[a.slot] = first[i]

    def _retire(self, slot: int) -> _Live:
        live = self._live.pop(slot)
        for p in live.pages:
            self.alloc.release(p)       # refcount--: aliased pages survive
        self._active[slot] = False
        self._page_table[slot] = 0          # all-zero row -> sink page 0
        self._pos[slot] = 0
        self._cur[slot] = 0
        self._limit[slot] = 0               # spec writes masked until re-seeded
        if self.spec_decode:
            # Fold the request's final accept-rate EMA into the run stats
            # (serve_bench reports the mean) before clearing the slot.
            self.stats["accept_ema_sum"] += float(self._ema[slot])
            self.stats["accept_ema_n"] += 1
            self._kslot[slot] = 0
            self._ema[slot] = 0.0
        return live

    # -- invariants (exercised by tests) -------------------------------------
    def _debug_check_refcounts(self) -> None:
        """Every physical page's refcount == page-table rows referencing it
        (a paused request's pinned pages count as one reference each)."""
        counts = np.zeros(self.num_pages, np.int64)
        for live in self._live.values():
            for p in live.pages:
                counts[p] += 1
        for paused in self._paused.values():
            for p in paused.pages:
                counts[p] += 1
        if not np.array_equal(counts[1:], self.alloc.refs[1:]):
            bad = np.nonzero(counts[1:] != self.alloc.refs[1:])[0] + 1
            raise AssertionError(
                f"refcount drift on pages {bad.tolist()}: "
                f"rows={counts[bad].tolist()} refs={self.alloc.refs[bad].tolist()}")

    # -- stepped serving API (the gateway drives these) ----------------------
    def _validate_request(self, req: EngineRequest) -> None:
        """Reject requests that can never run — before reserving anything."""
        p = req.prompt
        max_len = self.pages_per_seq * self.page_size
        pool_cap = self.num_pages - 1
        if not p:
            raise ValueError(f"request {req.rid}: empty prompt (nothing to "
                             "prefill)")
        if len(p) + req.max_new > max_len:
            raise ValueError(f"request {req.rid}: {len(p)}+{req.max_new} "
                             f"tokens exceed max_len {max_len}")
        need = math.ceil((len(p) + req.max_new) / self.page_size)
        if need > pool_cap:
            raise ValueError(
                f"request {req.rid}: needs {need} pages for "
                f"{len(p)}+{req.max_new} tokens but the pool only holds "
                f"{pool_cap}; raise num_pages or shorten the request")

    def enqueue(self, req: EngineRequest) -> None:
        """Append a validated request to the admission queue."""
        self._validate_request(req)
        self._queue.append(req)

    def admit(self) -> int:
        """Run one admission wave off the queue; returns requests admitted."""
        return self._admit_wave()

    @property
    def queued(self) -> int:
        return len(self._queue)

    @property
    def live(self) -> int:
        return len(self._live)

    @property
    def paused(self) -> int:
        return len(self._paused)

    @property
    def free_slots(self) -> int:
        """Physically unoccupied decode slots. Paused requests hold pages but
        no slot, so this is what ``resume`` needs to be positive."""
        return int(self.max_slots - np.count_nonzero(self._active))

    @property
    def has_work(self) -> bool:
        return bool(self._queue or self._live or self._paused)

    @property
    def open_slots(self) -> int:
        """Slots not yet claimed by a live or queued request (the gateway
        dispatches new work only while this is positive, keeping the
        per-replica queue shallow so EDF reordering stays effective)."""
        return self.max_slots - len(self._live) - len(self._queue)

    def remaining_tokens(self) -> list[int]:
        """Per live slot, tokens still to emit — scheduling estimates."""
        return [l.req.max_new - l.emitted for l in self._live.values()]

    def preempt(self, slot: int) -> PausedRequest:
        """Pause the request in ``slot`` mid-stream and free the slot.

        The request's KV pages stay allocated and **pinned**: their
        refcounts are untouched, so the allocator can never reallocate them
        (and eviction-on-realloc can never scrub the prefix-cache entries
        they anchor) however hard later admissions churn the pool. The
        cursor, emitted-token tally, write limit and — under speculative
        decode — the slot's drafting-history row park host-side in the
        returned :class:`PausedRequest`. ``resume`` undoes all of it with
        zero re-prefill.
        """
        if slot not in self._live:
            raise KeyError(f"slot {slot} has no live request to preempt")
        live = self._live.pop(slot)
        hist = np.array(self._hist[slot]) if self.spec_decode else None
        paused = PausedRequest(
            req=live.req, pages=live.pages, emitted=live.emitted,
            tokens=live.tokens, cur=int(self._cur[slot]),
            pos=int(self._pos[slot]), limit=int(self._limit[slot]),
            hist=hist, kslot=int(self._kslot[slot]),
            ema=float(self._ema[slot]))
        self._paused[live.req.rid] = paused
        # Identical to _retire EXCEPT the pages are not released: the slot
        # idles against the sink page while the paused sequence's KV waits.
        self._active[slot] = False
        self._page_table[slot] = 0
        self._pos[slot] = 0
        self._cur[slot] = 0
        self._limit[slot] = 0
        self._kslot[slot] = 0
        self._ema[slot] = 0.0
        self.stats["preempted"] += 1
        return paused

    def resume(self, paused: PausedRequest) -> int:
        """Re-admit a preempted request into a free slot; returns the slot.

        Zero re-prefill: the parked pages are re-attached through the page
        table, the cursor/limit/history restored, and decoding continues
        exactly where :meth:`preempt` stopped it — greedy tokens are
        identical to a never-paused run. Raises ``RuntimeError`` when every
        slot is occupied (check :attr:`free_slots` first).
        """
        if self._paused.get(paused.req.rid) is not paused:
            raise KeyError(f"request {paused.req.rid} is not paused on this "
                           "engine")
        free = [i for i in range(self.max_slots) if not self._active[i]]
        if not free:
            raise RuntimeError("no free slot to resume into")
        slot = free[0]
        del self._paused[paused.req.rid]
        row = np.zeros(self.pages_per_seq, np.int32)
        row[:len(paused.pages)] = paused.pages
        self._page_table[slot] = row
        self._active[slot] = True
        self._pos[slot] = paused.pos
        self._cur[slot] = paused.cur
        self._limit[slot] = paused.limit
        if self.spec_decode:
            self._hist = self._hist.at[slot].set(jnp.asarray(paused.hist))
            # Restore the tuned speculation window (0 = paused before this
            # engine tracked it; re-warm from K).
            self._kslot[slot] = paused.kslot or self.spec_tokens
            self._ema[slot] = paused.ema
        self._live[slot] = _Live(paused.req, paused.pages, paused.emitted,
                                 paused.tokens)
        self.stats["resumed"] += 1
        return slot

    # -- page residency (one export seam: cross-replica shipping and
    # cross-tier demotion are two transports behind the same gather) ---------
    def export(self, slot: int | None = None, *, rid: object = None,
               reason: ExportReason = ExportReason.HANDOFF) -> ShippedKV:
        """Ship a request out of this engine as a :class:`ShippedKV`.

        THE residency exit point, unifying what used to be two parallel
        methods: pass ``slot=`` for a live request (gathered, then retired
        through the normal refcount path) or ``rid=`` for a request parked
        by :meth:`preempt` (gathered, then its pin dropped). Exactly one
        must be given — slot ints and caller rids can share values, so
        positional guessing would be ambiguous. ``reason`` tags the payload
        with *why* the pages left (handoff / evacuate / demote) without
        changing the gather.

        Only *content* pages travel — the ``ceil(pos / page_size)`` pages
        holding prefilled (and already-decoded) KV rows; trailing pages
        allocated against the decode budget are empty and are simply
        released. Aliased prefix pages are gathered like any other page, so
        the payload is always a self-contained private copy; this engine's
        prefix-cache entries survive, keeping the replica a valid affinity
        target for the next request with the same prefix.

        Works mid-decode, not just post-prefill: a request that already
        emitted tokens ships its decoded KV rows, emitted tokens, and (spec
        decode) its drafting history plus tuned kslot/accept-EMA. Greedy
        decode continues token-identically wherever the payload lands.
        """
        if (slot is None) == (rid is None):
            raise ValueError("export needs exactly one of slot= (live "
                             "request) or rid= (paused request)")
        if slot is not None:
            if slot not in self._live:
                raise KeyError(f"slot {slot} has no live request to export")
            live = self._live[slot]
            hist = np.array(self._hist[slot]) if self.spec_decode else None
            payload = self._export(
                req=live.req, emitted=live.emitted, tokens=list(live.tokens),
                cur=int(self._cur[slot]), pos=int(self._pos[slot]),
                pages=live.pages, hist=hist, kslot=int(self._kslot[slot]),
                ema=float(self._ema[slot]), reason=reason)
            self._retire(slot)
            return payload
        paused = self._paused.get(rid)
        if paused is None:
            raise KeyError(f"request {rid} is not paused on this engine")
        payload = self._export(
            req=paused.req, emitted=paused.emitted,
            tokens=list(paused.tokens), cur=paused.cur, pos=paused.pos,
            pages=paused.pages, hist=paused.hist, kslot=paused.kslot,
            ema=paused.ema, reason=reason)
        del self._paused[rid]
        for p in paused.pages:
            self.alloc.release(p)       # unpin: aliased pages survive
        return payload

    def export_pages(self, slot: int, *,
                     reason: ExportReason = ExportReason.HANDOFF
                     ) -> ShippedKV:
        """Deprecated alias for ``export(slot=...)`` (pre-residency name)."""
        return self.export(slot=slot, reason=reason)

    def export_paused(self, rid: object, *,
                      reason: ExportReason = ExportReason.EVACUATE
                      ) -> ShippedKV:
        """Deprecated alias for ``export(rid=...)`` (pre-residency name)."""
        return self.export(rid=rid, reason=reason)

    def _export(self, *, req, emitted, tokens, cur, pos, pages, hist,
                kslot, ema,
                reason: ExportReason = ExportReason.HANDOFF) -> ShippedKV:
        """Gather ``ceil(pos/page_size)`` content pages into a payload."""
        ps = self.page_size
        n_content = math.ceil(pos / ps)
        nb = _next_pow2(max(1, n_content))
        idx = np.zeros(nb, np.int32)            # pads gather the sink page
        idx[:n_content] = pages[:n_content]
        gather = self._ship_gather_cache.get(nb)
        if gather is None:
            def gather_fn(pool, idx):
                return {name: leaf[:, :, idx] for name, leaf in pool.items()}
            gather = self._ship_gather_cache[nb] = jax.jit(gather_fn)
        gathered = gather(self.pool, jnp.asarray(idx))
        content = {name: np.ascontiguousarray(
                       np.asarray(a)[:, :, :n_content])
                   for name, a in gathered.items()}
        self.stats["page_exports"] += 1
        return ShippedKV(
            req=req, emitted=emitted, tokens=tokens, cur=cur, pos=pos,
            content=content, kv_cache_dtype=self.kv_cache_dtype,
            page_size=ps, hist=hist, kslot=kslot, ema=ema, reason=reason)

    def page_nbytes(self) -> int:
        """Wire bytes of ONE shipped page across every pool leaf (data +
        scale pages) — what the evacuation planner multiplies by a
        request's content-page count to budget the notice window without
        exporting first."""
        return sum(leaf.nbytes // leaf.shape[2] for leaf in
                   self.pool.values())

    def import_pages(self, payload: ShippedKV) -> int:
        """Re-register a :class:`ShippedKV` payload here; returns the slot.

        Fresh pages come from THIS engine's allocator (the full
        prompt+budget span, not just the shipped content pages), the
        page-table row re-attaches them, the prompt re-registers in this
        engine's radix prefix cache (existing entries win, exactly like
        admission), and the decode cursor resumes where the source stopped —
        greedy tokens are identical to a run that never hopped. Raises
        ``ValueError`` on a layout mismatch or a re-imported payload and
        ``RuntimeError`` when no slot or not enough pages are free (the
        caller retries later — only a *successful* import marks the payload
        consumed).
        """
        if payload.consumed:
            raise ValueError(
                f"payload for request {payload.req.rid} was already "
                "imported; a ShippedKV is a one-shot move, not a template")
        if payload.kv_cache_dtype != self.kv_cache_dtype:
            raise ValueError(
                f"shipped pages are {payload.kv_cache_dtype!r} but this "
                f"engine's pool is {self.kv_cache_dtype!r}")
        if payload.page_size != self.page_size:
            raise ValueError(
                f"shipped page_size {payload.page_size} != engine "
                f"page_size {self.page_size}")
        if set(payload.content) != set(self.pool):
            raise ValueError(
                f"shipped pool leaves {sorted(payload.content)} != engine "
                f"pool leaves {sorted(self.pool)}")
        req = payload.req
        self._validate_request(req)
        if payload.pos != len(req.prompt) + payload.emitted:
            raise ValueError(
                f"inconsistent payload for request {req.rid}: pos "
                f"{payload.pos} != prompt {len(req.prompt)} + emitted "
                f"{payload.emitted}")
        free = [i for i in range(self.max_slots) if not self._active[i]]
        if not free:
            raise RuntimeError("no free slot to import into")
        ps = self.page_size
        need_total = math.ceil((len(req.prompt) + req.max_new) / ps)
        n_content = payload.n_content
        if n_content > need_total:
            raise ValueError(
                f"payload ships {n_content} content pages but request "
                f"{req.rid} spans only {need_total}")
        if self.alloc.available() < need_total:
            raise RuntimeError(
                f"insufficient free pages to import request {req.rid}: "
                f"need {need_total}, have {self.alloc.available()}")
        slot = free[0]
        pages = [self.alloc.alloc() for _ in range(need_total)]
        nb = _next_pow2(max(1, n_content))
        dst = np.zeros(nb, np.int32)            # pads scatter into the sink
        dst[:n_content] = pages[:n_content]
        scatter = self._ship_scatter_cache.get(nb)
        if scatter is None:
            @partial(jax.jit, donate_argnums=(0,))
            def scatter_fn(pool, content, dst):
                return {name: pool[name].at[:, :, dst].set(
                            content[name].astype(pool[name].dtype))
                        for name in pool}
            scatter = self._ship_scatter_cache[nb] = scatter_fn
        padded = {}
        for name, a in payload.content.items():
            buf = np.zeros(a.shape[:2] + (nb,) + a.shape[3:], a.dtype)
            buf[:, :, :n_content] = a
            padded[name] = jnp.asarray(buf)
        self.pool = scatter(self.pool, padded, jnp.asarray(dst))
        row = np.zeros(self.pages_per_seq, np.int32)
        row[:need_total] = pages
        self._page_table[slot] = row
        self._active[slot] = True
        self._pos[slot] = payload.pos
        self._cur[slot] = payload.cur
        self._limit[slot] = len(req.prompt) + req.max_new
        if self.spec_decode:
            # Seed the drafting history: the parked row if the source ran
            # spec decode too, else reconstructed from prompt + emitted
            # tokens (identical content — draft tails past pos are always
            # re-written before any read).
            if payload.hist is not None and \
                    len(payload.hist) == self.hist_len:
                hrow = np.asarray(payload.hist, np.int32)
            else:
                hrow = np.zeros(self.hist_len, np.int32)
                hrow[:len(req.prompt)] = req.prompt
                hrow[len(req.prompt):payload.pos] = payload.tokens[
                    :payload.pos - len(req.prompt)]
            self._hist = self._hist.at[slot].set(jnp.asarray(hrow))
            # Restore the source's tuned speculation window, capped at this
            # engine's K (0 = the source never tracked one: warm from K).
            self._kslot[slot] = min(payload.kslot, self.spec_tokens) \
                or self.spec_tokens
            self._ema[slot] = payload.ema
        if self.prefix_cache is not None:
            # The shipped prefix stays shareable after the hop: later
            # requests on THIS engine alias these pages instead of
            # re-prefilling (existing entries win, exactly like admission).
            self.prefix_cache.register(req.prompt, pages, req.namespace)
        self._live[slot] = _Live(req, pages, payload.emitted,
                                 list(payload.tokens))
        self.stats["page_imports"] += 1
        payload.consumed = True
        return slot

    def restore_pages(self, payload: ShippedKV) -> list[int]:
        """Land a demoted payload's content pages back in the device pool
        WITHOUT occupying a decode slot; returns the restored page list.

        The tier-restore transport behind the residency API: the store's
        payload is scattered into freshly allocated pages, the covered
        token stream (prompt + emitted tokens) is registered in the radix
        prefix cache under the payload's namespace, and the pages are
        immediately released to refcount zero — *free-but-hittable*,
        exactly the state a retired request's pages occupy. The next
        admission of a prompt sharing the stream pins and aliases them
        with **zero re-prefill**; if nothing claims them, the allocator
        reuses them and eviction scrubs the entries as usual. int8 scale
        pages scatter alongside their data pages (the content dict is
        structural), so token identity holds for f32 and int8 pools alike.

        Raises ``ValueError`` on layout mismatch / re-used payload and
        ``RuntimeError`` when fewer than ``n_content`` pages are free (the
        caller retries a later round).
        """
        if payload.consumed:
            raise ValueError(
                f"payload for request {payload.req.rid} was already "
                "imported or restored; a ShippedKV is a one-shot move")
        if payload.kv_cache_dtype != self.kv_cache_dtype:
            raise ValueError(
                f"restored pages are {payload.kv_cache_dtype!r} but this "
                f"engine's pool is {self.kv_cache_dtype!r}")
        if payload.page_size != self.page_size:
            raise ValueError(
                f"restored page_size {payload.page_size} != engine "
                f"page_size {self.page_size}")
        if set(payload.content) != set(self.pool):
            raise ValueError(
                f"restored pool leaves {sorted(payload.content)} != engine "
                f"pool leaves {sorted(self.pool)}")
        if self.prefix_cache is None:
            raise RuntimeError("restore_pages needs the prefix cache: "
                               "restored pages are only reachable through "
                               "the radix index")
        n_content = payload.n_content
        if self.alloc.available() < n_content:
            raise RuntimeError(
                f"insufficient free pages to restore request "
                f"{payload.req.rid}: need {n_content}, have "
                f"{self.alloc.available()}")
        pages = [self.alloc.alloc() for _ in range(n_content)]
        nb = _next_pow2(max(1, n_content))
        dst = np.zeros(nb, np.int32)            # pads scatter into the sink
        dst[:n_content] = pages
        scatter = self._ship_scatter_cache.get(nb)
        if scatter is None:
            @partial(jax.jit, donate_argnums=(0,))
            def scatter_fn(pool, content, dst):
                return {name: pool[name].at[:, :, dst].set(
                            content[name].astype(pool[name].dtype))
                        for name in pool}
            scatter = self._ship_scatter_cache[nb] = scatter_fn
        padded = {}
        for name, a in payload.content.items():
            buf = np.zeros(a.shape[:2] + (nb,) + a.shape[3:], a.dtype)
            buf[:, :, :n_content] = a
            padded[name] = jnp.asarray(buf)
        self.pool = scatter(self.pool, padded, jnp.asarray(dst))
        # Register the full covered token stream — prompt plus the tokens
        # decoded before demotion — so a resumed session's longer prompt
        # walks straight down the restored chain.
        req = payload.req
        stream = list(req.prompt) + list(
            payload.tokens[:payload.pos - len(req.prompt)])
        self.prefix_cache.register(stream, pages, req.namespace)
        for p in pages:
            self.alloc.release(p)       # free-but-hittable, like retirement
        self.stats["page_restores"] += 1
        payload.consumed = True
        return pages

    def drop_queued(self) -> list[EngineRequest]:
        """Hand back queued-but-unadmitted requests (e.g. transient page
        pressure); live and paused requests are untouched."""
        dropped = list(self._queue)
        self._queue.clear()
        return dropped

    def abort(self) -> list[EngineRequest]:
        """Drop all live, paused and queued requests; return them for
        re-enqueue.

        The spot-revocation path: a revoked replica's requests restart from
        scratch on another replica (greedy decode is deterministic, so the
        retry emits identical tokens). Pages — including a paused request's
        pinned pages — are released through the normal refcount path, so
        refcounts stay exact and cached prefixes survive until reallocated.
        """
        dropped = [self._live[s].req for s in sorted(self._live)]
        for slot in list(self._live):
            self._retire(slot)
        for paused in self._paused.values():
            for p in paused.pages:
                self.alloc.release(p)
            dropped.append(paused.req)
        self._paused.clear()
        dropped.extend(self._queue)
        self._queue.clear()
        return dropped

    def decode_step(self, on_chunk=None) -> list[tuple[EngineRequest,
                                                       list[int]]]:
        """Run ONE on-device decode chunk; returns requests that finished.

        ``on_chunk(steps, seconds)`` (optional) observes the chunk.
        ``steps`` is the chunk's *device* trip count — always
        ``decode_chunk`` — so ``seconds / steps`` is the inter-token
        latency. It is NOT a count of usable tokens: a slot whose
        ``max_new`` budget ends mid-chunk idles (masked against the sink
        page) for the remaining steps. Under speculative decode one step
        emits 1..spec_tokens+1 tokens per slot, so ``seconds / steps`` is
        per-VERIFY-step latency there.
        """
        if self.role == "prefill":
            raise RuntimeError("decode_step on a prefill-role engine: "
                               "export_pages its admitted slots to a "
                               "decode-role replica instead")
        if not self._live:
            return []
        budget = np.zeros(self.max_slots, np.int32)
        for slot, live in self._live.items():
            budget[slot] = live.req.max_new - live.emitted
        t0 = time.perf_counter()
        if self.spec_decode:
            # Smallest verify bucket covering every live slot's adaptive
            # window: a chunk full of low-acceptance slots dispatches a
            # genuinely narrower verify pass. Chunks are jitted lazily per
            # bucket; non-adaptive engines always land on bucket K.
            kslot = np.maximum(np.where(self._active, self._kslot, 1), 1)
            kmax = int(kslot[self._active].max())
            kb = min(b for b in self._spec_buckets if b >= kmax)
            chunk = self._spec_chunks.get(kb)
            if chunk is None:
                chunk = self._spec_chunks[kb] = self._make_spec_chunk(kb)
            cur, pos, self._hist, n_out, n_it, self.pool, out = chunk(
                self.params, jnp.asarray(self._cur),
                jnp.asarray(self._pos), self._hist,
                jnp.asarray(self._page_table),
                jnp.asarray(self._active), jnp.asarray(budget),
                jnp.asarray(self._limit),
                jnp.asarray(kslot.astype(np.int32)), self.pool)
            n_out_host = np.asarray(n_out)
            n_it_host = np.asarray(n_it)
            self.stats["spec_steps"] += int(n_it_host.sum())
            # -- per-slot accept-rate EMA + adaptive window control -------
            # rate = accepted drafts / drafted tokens this chunk; EMA with
            # alpha=0.5 reacts within a couple of chunks. High acceptance
            # re-opens the window (x2, capped at K), low acceptance halves
            # it (floor 1) so near-random content stops paying for K-token
            # verify rows it never accepts.
            for slot in self._live:
                it = int(n_it_host[slot])
                if not it:
                    continue
                rate = (int(n_out_host[slot]) - it) / (it * int(kslot[slot]))
                self._ema[slot] = 0.5 * self._ema[slot] + 0.5 * rate
                if self.spec_adaptive_k:
                    if self._ema[slot] > 0.8:
                        self._kslot[slot] = min(2 * int(self._kslot[slot]),
                                                self.spec_tokens)
                    elif self._ema[slot] < 0.3:
                        self._kslot[slot] = max(int(self._kslot[slot]) // 2,
                                                1)
        else:
            cur, pos, self.pool, out = self._chunk(
                self.params, jnp.asarray(self._cur),
                jnp.asarray(self._pos), jnp.asarray(self._page_table),
                jnp.asarray(self._active), jnp.asarray(budget), self.pool)
            n_out_host = None              # every live slot emits the chunk
        out_host = np.asarray(out)                      # one sync per chunk
        if on_chunk is not None:
            on_chunk(self.decode_chunk, time.perf_counter() - t0)
        self._cur = np.array(cur)          # np.array: writable host copies
        self._pos = np.array(pos)
        finished: list[tuple[EngineRequest, list[int]]] = []
        for slot in list(self._live):
            live = self._live[slot]
            ntok = self.decode_chunk if n_out_host is None \
                else int(n_out_host[slot])
            if n_out_host is not None:
                # Count only delivered tokens: the final verify step can
                # overshoot the budget and its truncated tail must not
                # inflate mean_accepted_len.
                self.stats["spec_emitted"] += min(
                    ntok, live.req.max_new - live.emitted)
            live.tokens.extend(out_host[slot, :ntok].tolist())
            live.emitted += ntok
            if live.emitted >= live.req.max_new:
                finished.append((live.req, live.tokens[:live.req.max_new]))
                if self.demote_on_retire:
                    # Export-before-retire: the finished stream's content
                    # pages leave for a lower tier *before* the refcounts
                    # drop, so a later eviction-on-realloc scrubs index
                    # entries whose KV already lives off-device. export()
                    # retires the slot itself.
                    self.demoted_out.append(self.export(
                        slot=slot, reason=ExportReason.DEMOTE))
                    self.stats["page_demotes"] += 1
                else:
                    self._retire(slot)
        return finished

    # -- the serving loop ----------------------------------------------------
    def generate(self, prompts: list[list[int]], max_new: int = 16,
                 on_chunk=None) -> ServeResult:
        """Greedy-decode ``max_new`` tokens for every prompt, FCFS admission.

        A convenience loop over the stepped API (enqueue / admit /
        decode_step); see :meth:`decode_step` for ``on_chunk`` semantics.
        """
        if not prompts:
            return ServeResult(np.zeros((0, max_new), np.int32), [])
        if self.has_work:
            raise RuntimeError("generate() on a busy engine: drain or abort "
                               "the stepped API first")
        reqs = [EngineRequest(rid, list(p), max_new)
                for rid, p in enumerate(prompts)]
        for r in reqs:                        # validate before reserving
            self._validate_request(r)
        self._reset_stats()
        self._queue.extend(reqs)
        done: dict[object, list[int]] = {}
        self._admit_wave()
        if self._queue and not self._live:
            raise RuntimeError("admission stalled: request needs more pages "
                               "than the pool holds free")
        while self._live:
            for req, toks in self.decode_step(on_chunk=on_chunk):
                done[req.rid] = toks
            self._admit_wave()
            if self._queue and not self._live:
                raise RuntimeError("admission stalled: request needs more "
                                   "pages than the pool holds free")
        tokens = np.stack([np.asarray(done[i], np.int32)
                           for i in range(len(prompts))])
        return ServeResult(tokens, [len(p) for p in prompts])
