"""Continuous-batching serve engine over a shared block-paged KV cache.

Cloud Kotta's provisioning argument, applied to token decode. The paper keeps
utilization high under bursty multi-user load by (a) pooling capacity that
static per-user provisioning would strand, and (b) admitting work the moment
capacity frees up (its elastic worker pools / spot market). This engine is
the serving analogue, with the KV cache playing the role of the provisioned
resource:

- **Slots are worker nodes.** ``max_decode_slots`` fixed batch lanes decode
  in lockstep at hardware speed; a request occupies a slot only while live,
  exactly like a Kotta job occupies a pool node.
- **Pages are the storage tier.** The physical KV pool is one shared array of
  ``page_size``-row pages; each request addresses its logical KV stream
  through a per-slot page-table row. A static-batch engine provisions a dense
  ``max_len`` cache per request up front (the "for peak demand" sizing the
  paper's Table III costs out); paging provisions per *actual* demand and
  returns capacity on completion with zero copies or compaction.
- **The queue is the job queue.** Between decode chunks the engine retires
  finished sequences (evicting them frees their pages immediately) and admits
  waiting prompts into the freed slots/pages — continuous batching, the
  scheduling move that gives Kotta its up-to-16x cost reduction over static
  provisioning.
- **No host round-trips on the hot path.** The decode loop is a
  ``lax.fori_loop`` over on-device steps with the pool donated to each chunk;
  tokens accumulate on device and cross to the host once per chunk, not once
  per token (the seed engine's ``np.asarray`` per step).

Physical page 0 is reserved as a write sink: idle slots keep ``pos=0`` and an
all-zero page-table row, so their (masked, discarded) decode writes can never
corrupt pages belonging to live requests.

``ServeEngine`` (static batch, dense cache) is kept as the fallback path for
recurrent-state families and as the benchmark baseline.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models import get_family
from repro.train.train_step import (build_decode_step, build_paged_decode_step,
                                    build_prefill_step)


@dataclass
class ServeResult:
    tokens: np.ndarray          # (B, max_new)
    prompt_lens: list[int]


class ServeEngine:
    """Legacy static-batch engine: pads the batch, dense per-request cache."""

    def __init__(self, cfg, params, *, max_len: int = 512):
        if cfg.encoder_only:
            raise ValueError("encoder-only models cannot decode")
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.family = get_family(cfg)
        self._prefill = jax.jit(build_prefill_step(cfg))
        self._decode = jax.jit(build_decode_step(cfg))

    def _pad_cache(self, cache, cur_len: int):
        """Grow the prefill cache to max_len along the cache_seq axis."""
        def grow(x):
            # cache_seq axis = 2 for (L,B,S,KV,hd); SSM states have no seq axis.
            if x.ndim >= 3 and x.shape[2] == cur_len:
                pad = [(0, 0)] * x.ndim
                pad[2] = (0, self.max_len - cur_len)
                return jnp.pad(x, pad)
            return x
        return jax.tree.map(grow, cache)

    def generate(self, prompts: list[list[int]], max_new: int = 16) -> ServeResult:
        b = len(prompts)
        plen = max(len(p) for p in prompts)
        toks = np.zeros((b, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, plen - len(p):] = p  # left-pad so last position is newest
        batch = {"tokens": jnp.asarray(toks)}
        logits, cache = self._prefill(self.params, batch)
        cache = self._pad_cache(cache, plen)
        pos = jnp.full((b,), plen - 1, jnp.int32)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        # Tokens accumulate on device; one host transfer at the end (the seed
        # did a blocking np.asarray round-trip per decoded token).
        out = jnp.zeros((b, max_new), jnp.int32)
        for t in range(max_new):
            out = out.at[:, t].set(next_tok)
            pos = pos + 1
            step_batch = {"tokens": next_tok[:, None], "pos": pos}
            next_tok, _, cache = self._decode(self.params, step_batch, cache)
        return ServeResult(np.asarray(out), [len(p) for p in prompts])


# ---------------------------------------------------------------------------
# Continuous batching
# ---------------------------------------------------------------------------

@dataclass
class _Live:
    """A request occupying a slot."""
    rid: int
    prompt_len: int
    max_new: int
    pages: list[int]
    emitted: int = 0
    tokens: list[int] = field(default_factory=list)


class ContinuousBatchingEngine:
    """Continuous-batching decode over a shared paged KV pool (module doc)."""

    def __init__(self, cfg, params, *, max_len: int = 512,
                 max_slots: int | None = None, num_pages: int | None = None,
                 decode_chunk: int = 16):
        if cfg.encoder_only:
            raise ValueError("encoder-only models cannot decode")
        step = build_paged_decode_step(cfg)   # raises for recurrent families
        self.cfg = cfg
        self.params = params
        self.family = get_family(cfg)
        self.page_size = cfg.page_size
        self.max_slots = max_slots or cfg.max_decode_slots
        self.pages_per_seq = math.ceil(max_len / self.page_size)
        # +1: physical page 0 is the reserved idle-slot write sink.
        self.num_pages = (num_pages or self.max_slots * self.pages_per_seq) + 1
        self.decode_chunk = decode_chunk

        shape = self.family.paged_pool_shape(cfg, self.num_pages)
        self.pool = {"k": jnp.zeros(shape, cfg.cdtype),
                     "v": jnp.zeros(shape, cfg.cdtype)}
        self._free_pages = list(range(self.num_pages - 1, 0, -1))

        s = self.max_slots
        self._page_table = np.zeros((s, self.pages_per_seq), np.int32)
        self._pos = np.zeros(s, np.int32)
        self._cur = np.zeros(s, np.int32)
        self._active = np.zeros(s, bool)
        self._live: dict[int, _Live] = {}

        self._prefill = jax.jit(
            lambda p, b: self.family.prefill_ragged(cfg, p, b))

        def decode_chunk_fn(params, cur, pos, page_table, active, pool, steps):
            out = jnp.zeros((s, self.decode_chunk), jnp.int32)

            def body(i, carry):
                cur, pos, pool, out = carry
                out = out.at[:, i].set(cur)
                batch = {"tokens": cur[:, None], "pos": pos,
                         "page_table": page_table}
                nxt, _, pool = step(params, batch, pool)
                cur = jnp.where(active, nxt, cur)
                pos = jnp.where(active, pos + 1, pos)
                return cur, pos, pool, out

            return lax.fori_loop(0, steps, body, (cur, pos, pool, out))

        # Donating the pool lets XLA scatter new KV rows in place instead of
        # copying the whole pool every chunk.
        self._chunk = jax.jit(decode_chunk_fn, donate_argnums=(5,))
        self._writer_cache = {}

    # -- page writer (prompt KV -> pool), one compile per (pad, group) -------
    def _write_pages(self, k, v, pages):
        """k/v: (L, G, S_pad, KV, hd) prompt cache; pages: (G * npp,) int32."""
        key = (k.shape[1], k.shape[2])
        if key not in self._writer_cache:
            ps = self.page_size

            @partial(jax.jit, donate_argnums=(0, 1))
            def write(pool_k, pool_v, k, v, pages):
                nl, g, s_pad, nkv, hd = k.shape
                npp = g * (s_pad // ps)
                kp = k.reshape(nl, npp, ps, nkv, hd).transpose(0, 3, 1, 2, 4)
                vp = v.reshape(nl, npp, ps, nkv, hd).transpose(0, 3, 1, 2, 4)
                pool_k = pool_k.at[:, :, pages].set(kp.astype(pool_k.dtype))
                pool_v = pool_v.at[:, :, pages].set(vp.astype(pool_v.dtype))
                return pool_k, pool_v

            self._writer_cache[key] = write
        self.pool["k"], self.pool["v"] = self._writer_cache[key](
            self.pool["k"], self.pool["v"], k, v,
            jnp.asarray(pages, jnp.int32))

    # -- admission -----------------------------------------------------------
    def _admit_wave(self, pending: list, max_new: int) -> int:
        """Admit queued requests FCFS while slots and pages last.

        Admitted prompts are prefilled *batched by pad bucket* — one prefill
        dispatch, one page write and one host sync per bucket instead of per
        request (admission would otherwise dominate bursty arrivals).
        """
        ps = self.page_size
        wave = []                      # (slot, rid, prompt, pages)
        while pending:
            rid, prompt = pending[-1]
            t = len(prompt)
            need = math.ceil((t + max_new) / ps)   # validated in generate()
            free_slots = [i for i in range(self.max_slots)
                          if not self._active[i]]
            if not free_slots or len(self._free_pages) < need:
                break
            slot = free_slots[0]
            pages = [self._free_pages.pop() for _ in range(need)]
            self._active[slot] = True          # reserve within this wave
            wave.append((slot, rid, list(prompt), pages))
            pending.pop()

        by_pad: dict[int, list] = {}
        for item in wave:
            s_pad = math.ceil(len(item[2]) / ps) * ps
            by_pad.setdefault(s_pad, []).append(item)

        for s_pad, items in by_pad.items():
            g = len(items)
            npp = s_pad // ps
            toks = np.zeros((g, s_pad), np.int32)
            lens = np.zeros(g, np.int32)
            for i, (_, _, prompt, _) in enumerate(items):
                toks[i, :len(prompt)] = prompt
                lens[i] = len(prompt)
            batch = {"tokens": jnp.asarray(toks),
                     "length": jnp.asarray(lens)}
            logits, cache = self._prefill(self.params, batch)
            prompt_pages = np.concatenate(
                [np.asarray(pages[:npp], np.int32)
                 for _, _, _, pages in items])
            self._write_pages(cache["k"], cache["v"], prompt_pages)
            first = np.array(jnp.argmax(logits, axis=-1), np.int32)  # 1 sync
            for i, (slot, rid, prompt, pages) in enumerate(items):
                t = len(prompt)
                row = np.zeros(self.pages_per_seq, np.int32)
                row[:len(pages)] = pages
                self._page_table[slot] = row
                self._pos[slot] = t
                self._cur[slot] = first[i]
                self._live[slot] = _Live(rid, t, max_new, pages)
        return len(wave)

    def _retire(self, slot: int) -> _Live:
        live = self._live.pop(slot)
        self._free_pages.extend(reversed(live.pages))
        self._active[slot] = False
        self._page_table[slot] = 0          # all-zero row -> sink page 0
        self._pos[slot] = 0
        self._cur[slot] = 0
        return live

    # -- the serving loop ----------------------------------------------------
    def generate(self, prompts: list[list[int]], max_new: int = 16,
                 on_chunk=None) -> ServeResult:
        """Greedy-decode ``max_new`` tokens for every prompt, FCFS admission.

        ``on_chunk(steps, seconds)`` (optional) observes each decode chunk —
        every active slot emits ``steps`` tokens in ``seconds``, so the
        benchmark derives inter-token latency as ``seconds / steps``.
        """
        if not prompts:
            return ServeResult(np.zeros((0, max_new), np.int32), [])
        max_len = self.pages_per_seq * self.page_size
        for rid, p in enumerate(prompts):     # validate before reserving
            if not p:
                raise ValueError(f"request {rid}: empty prompt (nothing to "
                                 "prefill)")
            if len(p) + max_new > max_len:
                raise ValueError(f"request {rid}: {len(p)}+{max_new} tokens "
                                 f"exceed max_len {max_len}")
        pending = list(enumerate(prompts))[::-1]        # FCFS from the end
        done: dict[int, list[int]] = {}
        self._admit_wave(pending, max_new)
        if pending and not self._live:
            raise RuntimeError("admission stalled: request needs more pages "
                               "than the pool holds free")

        while self._live:
            remaining = min(l.max_new - l.emitted for l in self._live.values())
            steps = min(self.decode_chunk, remaining)
            t0 = time.perf_counter()
            cur, pos, self.pool, out = self._chunk(
                self.params, jnp.asarray(self._cur), jnp.asarray(self._pos),
                jnp.asarray(self._page_table), jnp.asarray(self._active),
                self.pool, steps)
            out_host = np.asarray(out[:, :steps])       # one sync per chunk
            if on_chunk is not None:
                on_chunk(steps, time.perf_counter() - t0)
            self._cur = np.array(cur)      # np.array: writable host copies
            self._pos = np.array(pos)
            for slot in list(self._live):
                live = self._live[slot]
                live.tokens.extend(out_host[slot].tolist())
                live.emitted += steps
                if live.emitted >= live.max_new:
                    done[live.rid] = live.tokens[:live.max_new]
                    self._retire(slot)
            self._admit_wave(pending, max_new)
            if pending and not self._live:
                raise RuntimeError("admission stalled: request needs more "
                                   "pages than the pool holds free")

        tokens = np.stack([np.asarray(done[i], np.int32)
                           for i in range(len(prompts))])
        return ServeResult(tokens, [len(p) for p in prompts])
