"""Batched serving engine: prefill + greedy decode over a shared KV cache.

The paper's serving analogue: analysis jobs that *serve* a model near the
data. The engine pads a request batch to a fixed shape, prefills once, then decodes token-by-token with jit-compiled steps.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_family
from repro.train.train_step import build_decode_step, build_prefill_step


@dataclass
class ServeResult:
    tokens: np.ndarray          # (B, max_new)
    prompt_lens: list[int]


class ServeEngine:
    def __init__(self, cfg, params, *, max_len: int = 512):
        if cfg.encoder_only:
            raise ValueError("encoder-only models cannot decode")
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.family = get_family(cfg)
        self._prefill = jax.jit(build_prefill_step(cfg))
        self._decode = jax.jit(build_decode_step(cfg))

    def _pad_cache(self, cache, cur_len: int):
        """Grow the prefill cache to max_len along the cache_seq axis."""
        def grow(x):
            # cache_seq axis = 2 for (L,B,S,KV,hd); SSM states have no seq axis.
            if x.ndim >= 3 and x.shape[2] == cur_len:
                pad = [(0, 0)] * x.ndim
                pad[2] = (0, self.max_len - cur_len)
                return jnp.pad(x, pad)
            return x
        return jax.tree.map(grow, cache)

    def generate(self, prompts: list[list[int]], max_new: int = 16) -> ServeResult:
        b = len(prompts)
        plen = max(len(p) for p in prompts)
        toks = np.zeros((b, plen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, plen - len(p):] = p  # left-pad so last position is newest
        batch = {"tokens": jnp.asarray(toks)}
        logits, cache = self._prefill(self.params, batch)
        cache = self._pad_cache(cache, plen)
        pos = jnp.full((b,), plen - 1, jnp.int32)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        out = np.zeros((b, max_new), np.int32)
        for t in range(max_new):
            out[:, t] = np.asarray(next_tok)
            pos = pos + 1
            step_batch = {"tokens": next_tok[:, None], "pos": pos}
            next_tok, _, cache = self._decode(self.params, step_batch, cache)
        return ServeResult(out, [len(p) for p in prompts])
