"""Device-side n-gram draft-table lookup for speculative decode.

Extracted from the engine's decode-chunk body so the draft proposal can be
FUSED with the multi-query verify pass into one jitted step
(:func:`repro.train.train_step.build_fused_spec_step`): the decode chunk
then issues exactly one fused dispatch per ``fori_loop`` iteration instead
of interleaving a separate drafting computation with the verify call. The
engine injects the built callable (plain function injection — ``serve``
imports ``train``, never the reverse, so no circular import).

The proposal itself is the PR-3 prompt-lookup scheme: find the latest
earlier occurrence of the trailing n-gram ending at the current token and
replay the tokens that followed it. A bad (or absent) match only lowers the
accept rate — verification restores exactness, so greedy outputs stay
token-identical to plain decode for ANY draft quality.
"""
from __future__ import annotations

import jax.numpy as jnp


def build_ngram_draft(hist_len: int, k_spec: int, ngram: int):
    """Return ``draft(hist, cur, pos) -> (S, k_spec)`` int32 draft tokens.

    hist: (S, hist_len) per-slot token history, with ``hist[b, :pos[b]+1]``
    the exact verified stream INCLUDING ``cur`` at position ``pos`` (the
    caller writes ``cur`` in before drafting); cur/pos: (S,) int32. Pure and
    trace-friendly: no host syncs, shapes static in ``k_spec``.
    """
    if ngram not in (2, 3):
        raise ValueError(f"ngram must be 2 (bigram) or 3 (trigram), got "
                         f"{ngram}")

    def draft(hist, cur, pos):
        s = hist.shape[0]
        bidx = jnp.arange(s)
        # Latest earlier occurrence of the trailing bigram
        # (hist[pos-1], cur); the K tokens that followed it are the draft.
        prev = hist[bidx, pos - 1]
        hit = (hist[:, :-1] == prev[:, None]) & \
              (hist[:, 1:] == cur[:, None])
        j = jnp.arange(hist_len - 1)
        # window ends at j+1; only strictly-earlier ends count
        cand = jnp.where(hit & ((j + 1)[None, :] < pos[:, None]), j, -1)
        best = cand.max(axis=1)
        src = jnp.where(best >= 0, best + 2, pos + 1)
        if ngram == 3:
            # Trigram keys disambiguate contexts a bigram conflates; no
            # trigram occurrence (or pos < 2) falls back to the bigram
            # match above, which itself degenerates to "repeat cur".
            p2 = hist[bidx, jnp.maximum(pos - 2, 0)]
            hit3 = (hist[:, :-2] == p2[:, None]) & \
                   (hist[:, 1:-1] == prev[:, None]) & \
                   (hist[:, 2:] == cur[:, None])
            j3 = jnp.arange(hist_len - 2)
            cand3 = jnp.where(
                hit3 & ((j3 + 2)[None, :] < pos[:, None])
                & (pos[:, None] >= 2), j3, -1)
            best3 = cand3.max(axis=1)
            src = jnp.where(best3 >= 0, best3 + 3, src)
        # A recent match reaches past the known history (e.g. a period-1
        # token run matches at pos-2): extrapolate it periodically by
        # wrapping indices beyond pos back by the match distance. With no
        # match this degenerates to period 1 at pos — i.e. draft "repeat
        # cur", which catches run onsets for free.
        period = jnp.maximum(pos - (src - 1), 1)
        q_idx = src[:, None] + jnp.arange(k_spec)[None, :]
        over = jnp.maximum(q_idx - pos[:, None], 0)
        wrap = (over + period[:, None] - 1) // period[:, None]
        didx = q_idx - wrap * period[:, None]
        return hist[bidx[:, None], jnp.clip(didx, 0, hist_len - 1)]

    return draft
