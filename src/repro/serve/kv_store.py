"""Tiered KV-cache hierarchy (device -> host -> object store).

Cloud Kotta's defining storage idea is *tiered data with an archive/restore
queue*: jobs whose inputs sit in ARCHIVE park in ``WAITING_DATA`` until an
async restore completes (PAPER.md §V-A; ``core/scheduler.py`` models this
for batch jobs), and the companion interactive-analytics paper shows that
same tiering is what makes low-latency **resumed** access affordable. This
module applies it to KV pages: a cold conversation's cache should pay
restore *bandwidth*, not re-prefill *FLOPs*.

Three tiers, one page-residency API:

=========  ====================================  =========================
Tier       Medium                                Priced as
=========  ====================================  =========================
DEVICE     the engine's paged HBM pool           (compute-instance rate)
HOST       ``ShippedKV`` numpy buffers in RAM    EBS $/GB-month / 720
OBJECT     serialized blobs (S3-model)           S3-std $/GB-month / 720
=========  ====================================  =========================

:class:`TieredKVStore` owns everything below DEVICE:

- **Demotion.** When a request finishes on an engine with
  ``demote_on_retire`` set, its content pages are exported
  (``reason=DEMOTE``) through the same :meth:`ContinuousBatchingEngine.export`
  gather that cross-replica shipping uses, and land here keyed by
  (namespace, token stream). HOST is capacity-bounded: overflow spills the
  LRU entry (by last-touch, virtual-clock time) down to OBJECT, where the
  arrays are genuinely serialized to bytes. A per-tenant storage budget is
  enforced with a typed :class:`~repro.serve.admission.StorageBudgetExceeded`
  — demotion *refuses* past the budget, it never silently drops or
  over-bills. int8 scale pages ride inside the payload's structural
  ``content`` dict, so token identity survives demote/restore for f32 and
  int8 pools alike.

- **Async restore.** A radix hit on a demoted stream
  (:meth:`TieredKVStore.match`) yields a :class:`RestoreTicket` whose
  ``ready_at`` models the tier's restore latency on the gateway's
  VirtualClock (bytes / tier bandwidth, plus a base fetch latency for
  OBJECT — the Glacier-style retrieval delay). The gateway parks the job
  ``RESTORE_PENDING`` — exactly mirroring the batch scheduler's
  ``WAITING_DATA`` — and on completion lands the payload back in the
  device pool via :meth:`ContinuousBatchingEngine.restore_pages` (pages
  free-but-hittable), then admits with **zero re-prefill**. An entry
  evicted while its ticket was in flight makes :meth:`complete_restore`
  return ``None``: the job falls back to plain re-prefill, no crash.

- **Accounting.** :meth:`accrue` integrates GB-hours per (tier, tenant)
  against :class:`repro.core.cost.StoragePricing` rates, feeding the
  gateway's cost counters and the ``MetricsRegistry`` families bound by
  :meth:`bind_registry`.

Demoted pages stay tenant-namespaced exactly like resident ones: entries
are keyed by the prefix cache's (tenant, data-zone) namespace and
:meth:`match` never crosses it — the paper's §VI isolation carried down
one more tier.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.cost import StoragePricing

from .admission import StorageBudgetExceeded
from .engine import ExportReason, ShippedKV  # noqa: F401  (re-exported API)
from .paging import EvictionEvent
from .telemetry import RegistryDict

__all__ = ["Tier", "ExportReason", "PageResidency", "RestoreTicket",
           "TieredKVStore", "StorageBudgetExceeded", "EvictionEvent"]


class Tier(str, enum.Enum):
    DEVICE = "device"
    HOST = "host"
    OBJECT = "object"


@runtime_checkable
class PageResidency(Protocol):
    """The residency surface every page transport goes through.

    An engine satisfies this structurally: ``export`` gathers a request's
    content pages off the device (reason-tagged: handoff / evacuate /
    demote), ``import_pages`` revives a payload as a *live* request,
    ``restore_pages`` lands a payload as *free-but-hittable* cache pages,
    and ``page_nbytes`` is the per-page sizing truth (data + scale leaves)
    that ship budgets and tier capacities multiply. Cross-replica shipping
    and cross-tier demotion are two transports behind this one API.
    """

    def export(self, slot: int | None = None, *, rid: object = None,
               reason: ExportReason = ExportReason.HANDOFF) -> ShippedKV:
        ...

    def import_pages(self, payload: ShippedKV) -> int:
        ...

    def restore_pages(self, payload: ShippedKV) -> list:
        ...

    def page_nbytes(self) -> int:
        ...


@dataclass(frozen=True)
class RestoreTicket:
    """An in-flight async restore: redeem via ``complete_restore`` once the
    gateway clock passes ``ready_at``. ``tokens`` is the stored stream
    length the restore makes alias-able (what admission will not
    re-prefill); ``tier`` is where the bytes are coming from."""

    key: tuple
    rid: object                 # job that requested the restore
    tier: Tier
    requested_at: float
    ready_at: float
    nbytes: int
    tokens: int


@dataclass
class _Entry:
    """One demoted token stream resident in HOST or OBJECT."""

    key: tuple                  # (namespace, token-stream tuple)
    tenant: str
    namespace: object
    tier: Tier
    nbytes: int
    page_size: int
    stream_len: int
    last_touch: float
    payload: ShippedKV | None = None      # HOST: the live numpy payload
    blobs: dict | None = None             # OBJECT: name -> (bytes, dtype, shape)


def _serialize(content: dict) -> dict:
    """OBJECT-tier representation: raw bytes + enough layout to rebuild."""
    return {name: (a.tobytes(), a.dtype.str, a.shape)
            for name, a in content.items()}


def _deserialize(blobs: dict) -> dict:
    return {name: np.frombuffer(b, dtype=np.dtype(d)).reshape(shape).copy()
            for name, (b, d, shape) in blobs.items()}


class TieredKVStore:
    """Demotion, async restore and GB-hour accounting below the device tier.

    ``host_capacity_bytes`` bounds the HOST tier (LRU spills to OBJECT);
    ``object_capacity_bytes`` bounds OBJECT (LRU entries are *dropped* —
    the archive is finite, and a restore racing such a drop falls back to
    re-prefill); ``tenant_budget_bytes`` caps one tenant's total demoted
    footprint across both tiers (typed refusal past it). Restore latency
    is modelled per tier: ``nbytes / *_restore_bytes_per_s`` plus
    ``object_restore_base_s`` for OBJECT fetches.
    """

    def __init__(self, *, host_capacity_bytes: int,
                 object_capacity_bytes: int | None = None,
                 tenant_budget_bytes: int | None = None,
                 pricing: StoragePricing | None = None,
                 host_restore_bytes_per_s: float = 2e9,
                 object_restore_bytes_per_s: float = 2.5e8,
                 object_restore_base_s: float = 0.5):
        if host_capacity_bytes < 0:
            raise ValueError(f"host_capacity_bytes must be >= 0, got "
                             f"{host_capacity_bytes}")
        if object_capacity_bytes is not None and object_capacity_bytes < 0:
            raise ValueError(f"object_capacity_bytes must be >= 0, got "
                             f"{object_capacity_bytes}")
        if host_restore_bytes_per_s <= 0 or object_restore_bytes_per_s <= 0:
            raise ValueError("restore bandwidths must be > 0")
        self.host_capacity_bytes = host_capacity_bytes
        self.object_capacity_bytes = object_capacity_bytes
        self.tenant_budget_bytes = tenant_budget_bytes
        self.pricing = pricing or StoragePricing()
        self.host_restore_bytes_per_s = host_restore_bytes_per_s
        self.object_restore_bytes_per_s = object_restore_bytes_per_s
        self.object_restore_base_s = object_restore_base_s
        # $/GB-hour per tier: monthly storage rates over 720 h/month —
        # HOST priced as EBS (RAM standing in for instance-attached
        # storage), OBJECT as the first S3-standard volume tier.
        self.rate_per_gb_hour = {
            Tier.HOST: self.pricing.ebs_per_gb_month / 720.0,
            Tier.OBJECT: self.pricing.s3_std_tiers[0][1] / 720.0,
        }
        self._entries: dict[tuple, _Entry] = {}
        self.host_bytes = 0
        self.object_bytes = 0
        self.tenant_bytes: dict[str, int] = {}
        # GB-hour + $ accrual, integrated on the virtual clock.
        self.gb_hours = {Tier.HOST: 0.0, Tier.OBJECT: 0.0}
        self.cost_by_tier = {Tier.HOST: 0.0, Tier.OBJECT: 0.0}
        self.cost_by_tenant: dict[str, float] = {}
        self.gb_hours_by_tenant: dict[str, dict] = {}
        self._last_accrue: float | None = None
        self.stats: dict = {
            "demotions_host": 0, "demotions_object": 0, "spills": 0,
            "restores_host": 0, "restores_object": 0, "restore_misses": 0,
            "budget_refusals": 0, "object_evictions": 0,
            "eviction_events": 0, "device_evicted_pages": 0,
        }
        self._registry = None

    # -- introspection -------------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def tier_of(self, key: tuple) -> Tier | None:
        ent = self._entries.get(key)
        return None if ent is None else ent.tier

    @property
    def usd_total(self) -> float:
        return sum(self.cost_by_tier.values())

    # -- eviction subscription ----------------------------------------------
    def on_eviction(self, event: EvictionEvent) -> None:
        """Subscriber for :attr:`PrefixCache.on_evict`: counts device-tier
        index evictions. Content safety does not depend on this callback —
        finished streams were already exported at retirement — but the
        counters make "pages left the device index" observable, and tests
        assert every evicted page was demoted or refcount-zero free."""
        self.stats["eviction_events"] += 1
        self.stats["device_evicted_pages"] += len(event.pages)

    # -- demotion ------------------------------------------------------------
    def demote(self, payload: ShippedKV, tenant: str, now: float) -> Tier:
        """Park ``payload``'s pages below the device tier; returns where.

        Lands in HOST, spilling LRU HOST entries to OBJECT while over
        ``host_capacity_bytes`` (an entry larger than the whole HOST tier
        goes straight to OBJECT). Raises
        :class:`~repro.serve.admission.StorageBudgetExceeded` when the
        tenant's demoted footprint would exceed its budget — the caller
        sheds/forgoes instead of the store silently dropping pages.
        """
        req = payload.req
        stream = tuple(req.prompt) + tuple(
            payload.tokens[:payload.pos - len(req.prompt)])
        key = (req.namespace, stream)
        nbytes = payload.nbytes
        old = self._entries.pop(key, None)
        if old is not None:
            self._drop_bytes(old)
        budget = self.tenant_budget_bytes
        if budget is not None and \
                self.tenant_bytes.get(tenant, 0) + nbytes > budget:
            if old is not None:     # replacement refused: old copy is gone
                pass
            self.stats["budget_refusals"] += 1
            raise StorageBudgetExceeded(
                f"tenant {tenant!r}: demoting {nbytes}B would exceed its "
                f"{budget}B storage budget "
                f"({self.tenant_bytes.get(tenant, 0)}B already demoted)")
        ent = _Entry(key=key, tenant=tenant, namespace=req.namespace,
                     tier=Tier.HOST, nbytes=nbytes,
                     page_size=payload.page_size, stream_len=len(stream),
                     last_touch=now, payload=payload)
        if nbytes > self.host_capacity_bytes:
            self._spill_entry(ent)          # straight to OBJECT
            self._entries[key] = ent
            self.object_bytes += nbytes
            self.stats["demotions_object"] += 1
        else:
            self._entries[key] = ent
            self.host_bytes += nbytes
            self.stats["demotions_host"] += 1
            self._enforce_host_capacity()
        self.tenant_bytes[tenant] = self.tenant_bytes.get(tenant, 0) + nbytes
        self._enforce_object_capacity()
        return self._entries[key].tier if key in self._entries \
            else Tier.OBJECT

    def _lru(self, tier: Tier) -> _Entry | None:
        cands = [e for e in self._entries.values() if e.tier is tier]
        return min(cands, key=lambda e: e.last_touch) if cands else None

    def _spill_entry(self, ent: _Entry) -> None:
        """HOST -> OBJECT: genuinely serialize the arrays to bytes."""
        ent.blobs = _serialize(ent.payload.content)
        ent.payload.content = None
        ent.tier = Tier.OBJECT

    def _enforce_host_capacity(self) -> None:
        while self.host_bytes > self.host_capacity_bytes:
            victim = self._lru(Tier.HOST)
            if victim is None:
                break
            self._spill_entry(victim)
            self.host_bytes -= victim.nbytes
            self.object_bytes += victim.nbytes
            self.stats["spills"] += 1

    def _enforce_object_capacity(self) -> None:
        cap = self.object_capacity_bytes
        if cap is None:
            return
        while self.object_bytes > cap:
            victim = self._lru(Tier.OBJECT)
            if victim is None:
                break
            del self._entries[victim.key]
            self._drop_bytes(victim)
            self.stats["object_evictions"] += 1

    def _drop_bytes(self, ent: _Entry) -> None:
        if ent.tier is Tier.HOST:
            self.host_bytes -= ent.nbytes
        else:
            self.object_bytes -= ent.nbytes
        t = self.tenant_bytes.get(ent.tenant, 0) - ent.nbytes
        if t <= 0:
            self.tenant_bytes.pop(ent.tenant, None)
        else:
            self.tenant_bytes[ent.tenant] = t

    # -- lookup / restore ----------------------------------------------------
    def match(self, namespace, prompt) -> tuple[tuple, int, Tier] | None:
        """Longest demoted stream (within ``namespace``) that prefixes
        ``prompt`` with at least one full page of alias-able KV. Returns
        ``(key, stream_tokens, tier)`` or ``None``. Never crosses
        namespaces: a tenant's archived pages are as invisible to other
        tenants as its resident ones."""
        best = None
        for key, ent in self._entries.items():
            if ent.namespace != namespace:
                continue
            n = ent.stream_len
            if n > len(prompt) or n < ent.page_size:
                continue
            if tuple(prompt[:n]) != key[1]:
                continue
            if best is None or n > best[1]:
                best = (key, n, ent.tier)
        return best

    def restore_delay_s(self, key: tuple) -> float | None:
        """Modelled restore latency for ``key`` (None when absent) — what
        admission adds to the job's service estimate while it parks."""
        ent = self._entries.get(key)
        if ent is None:
            return None
        if ent.tier is Tier.HOST:
            return ent.nbytes / self.host_restore_bytes_per_s
        return self.object_restore_base_s \
            + ent.nbytes / self.object_restore_bytes_per_s

    def request_restore(self, key: tuple, rid: object,
                        now: float) -> RestoreTicket:
        """Enqueue an async restore of ``key``; the ticket's ``ready_at``
        is ``now`` + the tier's modelled latency. The entry is touched
        (LRU-warms) but NOT pinned: capacity pressure can still evict it
        mid-flight, in which case ``complete_restore`` returns None."""
        ent = self._entries.get(key)
        if ent is None:
            raise KeyError(f"no demoted entry for key {key!r}")
        ent.last_touch = now
        delay = self.restore_delay_s(key)
        return RestoreTicket(key=key, rid=rid, tier=ent.tier,
                             requested_at=now, ready_at=now + delay,
                             nbytes=ent.nbytes, tokens=ent.stream_len)

    def complete_restore(self, ticket: RestoreTicket,
                         now: float | None = None) -> ShippedKV | None:
        """Redeem a due ticket: the entry leaves the store and its payload
        (deserialized for OBJECT) is returned for
        ``engine.restore_pages``. Returns ``None`` when the entry was
        evicted while the restore was in flight — the caller falls back to
        plain re-prefill (restore-racing-eviction is survivable, the
        stream is merely cold again)."""
        if now is not None and now < ticket.ready_at:
            raise ValueError(
                f"restore for {ticket.rid!r} not due until "
                f"t={ticket.ready_at:.3f} (now t={now:.3f})")
        ent = self._entries.pop(ticket.key, None)
        if ent is None:
            self.stats["restore_misses"] += 1
            return None
        self._drop_bytes(ent)
        if ent.tier is Tier.OBJECT:
            ent.payload.content = _deserialize(ent.blobs)
            ent.blobs = None
            self.stats["restores_object"] += 1
        else:
            self.stats["restores_host"] += 1
        return ent.payload

    # -- accounting ----------------------------------------------------------
    def accrue(self, now: float) -> float:
        """Integrate storage GB-hours (per tier, per tenant) since the last
        call at the StoragePricing rates; returns the $ accrued."""
        if self._last_accrue is None:
            self._last_accrue = now
            return 0.0
        dt_h = (now - self._last_accrue) / 3600.0
        self._last_accrue = now
        if dt_h <= 0:
            return 0.0
        total = 0.0
        for ent in self._entries.values():
            gb = ent.nbytes / 1e9
            gbh = gb * dt_h
            usd = gbh * self.rate_per_gb_hour[ent.tier]
            self.gb_hours[ent.tier] += gbh
            self.cost_by_tier[ent.tier] += usd
            self.cost_by_tenant[ent.tenant] = \
                self.cost_by_tenant.get(ent.tenant, 0.0) + usd
            per = self.gb_hours_by_tenant.setdefault(
                ent.tenant, {Tier.HOST: 0.0, Tier.OBJECT: 0.0})
            per[ent.tier] += gbh
            total += usd
        return total

    # -- metrics -------------------------------------------------------------
    def bind_registry(self, registry) -> None:
        """Bind counters for the event stats (write-through RegistryDict,
        same idiom as router/engine) and register a collector that
        refreshes per-tier byte / GB-hour / cost gauges at scrape time."""
        demotions = registry.counter(
            "kotta_kv_store_demotions_total",
            "KV page streams demoted below the device tier", ("tier",))
        restores = registry.counter(
            "kotta_kv_store_restores_total",
            "KV page streams restored toward the device tier", ("tier",))
        events = registry.counter(
            "kotta_kv_store_events_total",
            "Tier-management events by kind", ("kind",))
        rd = RegistryDict()
        rd.bind("demotions_host", demotions,
                initial=self.stats["demotions_host"], tier="host")
        rd.bind("demotions_object", demotions,
                initial=self.stats["demotions_object"], tier="object")
        rd.bind("restores_host", restores,
                initial=self.stats["restores_host"], tier="host")
        rd.bind("restores_object", restores,
                initial=self.stats["restores_object"], tier="object")
        for kind in ("restore_misses", "budget_refusals", "spills",
                     "object_evictions", "eviction_events",
                     "device_evicted_pages"):
            rd.bind(kind, events, initial=self.stats[kind], kind=kind)
        self.stats = rd
        tier_bytes = registry.gauge(
            "kotta_kv_store_bytes", "Resident demoted bytes per tier",
            ("tier",))
        gbh = registry.gauge(
            "kotta_kv_store_gb_hours",
            "Accrued storage GB-hours per tier", ("tier",))
        cost = registry.gauge(
            "kotta_kv_store_cost_usd",
            "Accrued storage cost per tier (USD)", ("tier",))
        tenant_cost = registry.gauge(
            "kotta_kv_store_tenant_cost_usd",
            "Accrued storage cost per tenant (USD)", ("tenant",))

        def collect():
            tier_bytes.set(self.host_bytes, tier="host")
            tier_bytes.set(self.object_bytes, tier="object")
            for t in (Tier.HOST, Tier.OBJECT):
                gbh.set(self.gb_hours[t], tier=t.value)
                cost.set(self.cost_by_tier[t], tier=t.value)
            for tenant, usd in self.cost_by_tenant.items():
                tenant_cost.set(usd, tenant=tenant)

        registry.register_collector(collect)
        self._registry = registry
