"""Fault-injection harness for the serving fleet.

Cloud Kotta treats worker loss as an expected event, not an outage
(§IV-B, §V): spot nodes are revoked by the market, in-flight jobs return
to the queue, and retries are idempotent. A robustness claim like that is
only as good as the failures it was exercised against — one market trace
revokes one replica one way. :class:`FaultInjector` turns the failure
space into data: a schedule of :class:`FaultEvent`\\ s on the gateway's
:class:`~repro.core.clock.VirtualClock`, either **scripted** (fixed
times/targets — the bench's reproducible fault schedule) or
**seeded-random** (:meth:`FaultInjector.random`, Poisson arrivals per
fault class — the chaos tests' coverage sweep).

Fault classes (``FaultEvent.kind``):

- ``crash`` — the replica dies NOW, no notice: the hard-loss baseline
  (requeue + backoff is the only recovery).
- ``revoke_notice`` — a revocation notice with ``duration_s`` of warning
  (default: the market's ``notice_s``), the EC2 2-minute-warning model;
  the gateway's notice-window KV evacuation
  (``engine.export(..., reason=EVACUATE)`` on the unified
  :class:`~repro.serve.kv_store.PageResidency` surface) gets to race
  the deadline.
- ``straggler`` — the replica's modelled step latency is multiplied by
  ``magnitude`` for ``duration_s``; the router's leave-one-out straggler
  detection should mark it DEGRADED and drain it.
- ``heartbeat_loss`` — the replica stops heartbeating for ``duration_s``;
  the router should QUARANTINE it until the heartbeat returns.

The injector is passive: the gateway polls :meth:`pop_due` once per round
with the current virtual time and applies what fired. ``target`` indexes
the gateway's live decode-capable replicas (sorted by id, modulo count),
so schedules stay meaningful whatever the fleet size; an event with no
live target is recorded in ``skipped`` rather than silently dropped.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

KINDS = ("crash", "revoke_notice", "straggler", "heartbeat_loss")


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault. ``at_s`` is absolute virtual-clock seconds."""

    at_s: float
    kind: str
    target: int = 0             # index into live decode replicas (mod count)
    duration_s: float = 0.0     # straggler / heartbeat_loss window; for
                                # revoke_notice, the notice length (0 = the
                                # market's default)
    magnitude: float = 4.0      # straggler latency multiplier

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"fault kind must be one of {KINDS}, "
                             f"got {self.kind!r}")


@dataclass
class FaultInjector:
    """An ordered fault schedule the gateway consumes round by round."""

    schedule: tuple[FaultEvent, ...] = ()
    fired: list[FaultEvent] = field(default_factory=list)
    skipped: list[FaultEvent] = field(default_factory=list)

    def __post_init__(self):
        self.schedule = tuple(sorted(self.schedule, key=lambda e: e.at_s))
        self._idx = 0

    def pop_due(self, now: float) -> list[FaultEvent]:
        """Events whose time has come; each is returned exactly once."""
        due = []
        while self._idx < len(self.schedule) \
                and self.schedule[self._idx].at_s <= now:
            due.append(self.schedule[self._idx])
            self._idx += 1
        return due

    @property
    def pending(self) -> int:
        return len(self.schedule) - self._idx

    @classmethod
    def random(cls, seed: int, horizon_s: float, *,
               crash_rate_h: float = 0.5,
               revoke_rate_h: float = 1.0,
               straggler_rate_h: float = 1.0,
               heartbeat_loss_rate_h: float = 0.5,
               notice_s: float = 0.0,
               duration_s: tuple[float, float] = (5.0, 30.0),
               magnitude: tuple[float, float] = (2.0, 8.0),
               max_targets: int = 8) -> "FaultInjector":
        """Seeded Poisson fault schedule over ``[0, horizon_s)``.

        Rates are per *hour* of virtual time, per fault class. The same
        seed always produces the same schedule (``np.random.default_rng``),
        which is what lets the chaos tests pin three seeds in CI and stay
        deterministic. ``notice_s`` = 0 defers to the market's notice
        window at fire time.
        """
        rng = np.random.default_rng(seed)
        events: list[FaultEvent] = []
        for kind, rate_h in (("crash", crash_rate_h),
                             ("revoke_notice", revoke_rate_h),
                             ("straggler", straggler_rate_h),
                             ("heartbeat_loss", heartbeat_loss_rate_h)):
            if rate_h <= 0:
                continue
            t = 0.0
            while True:
                t += float(rng.exponential(3600.0 / rate_h))
                if t >= horizon_s:
                    break
                dur = float(rng.uniform(*duration_s))
                if kind == "revoke_notice":
                    dur = notice_s
                events.append(FaultEvent(
                    at_s=t, kind=kind,
                    target=int(rng.integers(0, max_targets)),
                    duration_s=dur,
                    magnitude=float(rng.uniform(*magnitude))))
        return cls(schedule=tuple(events))
