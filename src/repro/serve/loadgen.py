"""Open-loop trace-driven traffic generation for the serving gateway.

Every bench before this PR was closed-loop: submit a few dozen requests,
drain, measure. Closed loops cannot find a saturation wall — completions
gate submissions, so offered load self-limits exactly when the system
starts to fall behind. This module generates **open-loop** traffic the way
the paper's Fig-6 experiment drives DynamoDB: arrivals are a function of
*virtual time only*, independent of completions, so overload actually
queues, sheds, and burns SLO — and "max sustained req/s at the 99%
deadline-hit bar" becomes measurable.

The arrival process models a large consumer population on a shared
platform (the "million users" the paper's GeoDeepDive/social-media
workloads imply):

- **Poisson arrivals with diurnal modulation** — a non-homogeneous Poisson
  process via Lewis thinning: base rate x ``(1 + amplitude *
  sin(2*pi*t/period))``, so a trace can sweep through its own peak.
- **Zipf-distributed users** mapped onto a fixed tenant set — a handful of
  heavy principals dominate, the long tail trickles, matching every
  production multi-tenant trace. ``users`` can be 10**6 without
  materializing anything per-user: user identity only seeds that
  request's unique prompt tail.
- **Shared prefixes** — each tenant has a hot prompt prefix (system
  prompt / dataset preamble) its requests share, which is what makes
  prefix caching and affinity routing matter under load.
- **Mixed classes** — interactive (priority 0, tight deadline) vs batch
  (priority 1, loose deadline) split by ``interactive_fraction``.
- **Session resumption** (``resume_fraction > 0``) — a fraction of
  requests come back after an exponential **cold gap**
  (``cold_gap_mean_s``): the resumed arrival replays the original prompt
  plus the assistant's reply plus a fresh user turn, which is exactly the
  traffic the tiered KV hierarchy exists for (the gap is long enough for
  the session's pages to have been demoted off the device).
  :func:`run_open_loop` splices the original request's actual emitted
  tokens into the resumed prompt at submit time, so the resumed stream
  token-identically extends the demoted one. A resume whose original is
  still in flight holds until the reply lands (a follow-up turn cannot
  precede the reply it quotes) — the one departure from pure open-loop
  arrivals, and the reason resumed prompts are identical across runs that
  differ only in service speed.

Determinism: everything derives from ``seed`` via ``numpy.random
.RandomState``; the same config always yields byte-identical traces, so
saturation numbers are comparable across hosts (the repo-wide virtual
clock discipline).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from .admission import JobState

__all__ = ["TrafficConfig", "Arrival", "generate_trace", "offered_load",
           "run_open_loop"]


@dataclass(frozen=True)
class TrafficConfig:
    """Knobs for one generated trace. Token ids stay inside
    ``vocab_size``; prompt lengths are ``prefix_tokens`` (shared, hot)
    plus a unique per-request tail of ``tail_tokens_min..max``."""

    duration_s: float = 30.0
    base_rate_rps: float = 4.0
    diurnal_amplitude: float = 0.0        # 0..1 of base rate
    diurnal_period_s: float = 60.0        # compressed "day" in sim seconds
    tenants: int = 4
    users: int = 1_000_000                # population behind the tenants
    zipf_alpha: float = 1.3               # >1; lower = heavier tail
    prefix_tokens: int = 16               # shared per-tenant hot prefix
    tail_tokens_min: int = 2
    tail_tokens_max: int = 8
    interactive_fraction: float = 0.5
    interactive_deadline_s: float = 8.0
    batch_deadline_s: float = 60.0
    interactive_max_new: int = 8
    batch_max_new: int = 8
    vocab_size: int = 256
    # Session resumption: a resumed arrival follows its original after an
    # exponential cold gap, carrying the original prompt plus a fresh
    # ``resume_tail_tokens``-token user turn. 0.0 keeps old traces
    # byte-identical (no extra rng draws happen).
    resume_fraction: float = 0.0
    cold_gap_mean_s: float = 30.0
    resume_tail_tokens: int = 4
    seed: int = 0


@dataclass(frozen=True)
class Arrival:
    """One request, fully determined at generation time."""

    at_s: float                 # absolute virtual arrival time
    tenant_idx: int             # 0..tenants-1 (caller maps to principals)
    user: int                   # Zipf-ranked user id behind the request
    prompt: tuple
    max_new: int
    deadline_s: float           # relative to arrival
    priority: int               # 0 interactive, 1 batch
    # Session linkage: resumes share a session id with their original.
    # ``prompt`` on a resumed arrival is original-prompt + fresh tail;
    # run_open_loop splices the original's emitted tokens in between.
    session: int = -1           # -1: not part of a resumed session
    resumed: bool = False


def _rate_at(cfg: TrafficConfig, t: float) -> float:
    return cfg.base_rate_rps * (1.0 + cfg.diurnal_amplitude
                                * math.sin(2.0 * math.pi * t
                                           / cfg.diurnal_period_s))


def _zipf_user(rng: np.random.RandomState, cfg: TrafficConfig) -> int:
    """Zipf-ranked user id in [0, users): rank 0 is the heaviest user.
    Rejection-sample numpy's unbounded Zipf down to the population."""
    while True:
        u = int(rng.zipf(cfg.zipf_alpha)) - 1
        if u < cfg.users:
            return u


def generate_trace(cfg: TrafficConfig) -> list[Arrival]:
    """The full arrival list for ``cfg``, sorted by time.

    Non-homogeneous Poisson via Lewis thinning: candidates arrive at the
    peak rate, and each survives with probability rate(t)/peak — exact for
    any bounded rate function, and O(peak x duration) cheap.
    """
    if cfg.diurnal_amplitude < 0 or cfg.diurnal_amplitude > 1:
        raise ValueError(f"diurnal_amplitude must be in [0, 1], got "
                         f"{cfg.diurnal_amplitude}")
    if cfg.zipf_alpha <= 1.0:
        raise ValueError(f"zipf_alpha must be > 1, got {cfg.zipf_alpha}")
    rng = np.random.RandomState(cfg.seed)
    # Per-tenant hot prefixes: deterministic, disjoint-ish token blocks.
    prefixes = [tuple(int(x) for x in
                      rng.randint(0, cfg.vocab_size, size=cfg.prefix_tokens))
                for _ in range(cfg.tenants)]
    peak = cfg.base_rate_rps * (1.0 + cfg.diurnal_amplitude)
    out: list[Arrival] = []
    next_session = 0
    t = 0.0
    while True:
        t += float(rng.exponential(1.0 / peak))
        if t >= cfg.duration_s:
            break
        if float(rng.uniform()) > _rate_at(cfg, t) / peak:
            continue                       # thinned candidate
        user = _zipf_user(rng, cfg)
        tenant = user % cfg.tenants
        ntail = int(rng.randint(cfg.tail_tokens_min,
                                cfg.tail_tokens_max + 1))
        # The tail is the user's own context: seeded by user id so repeat
        # visits from one user share MORE than the tenant prefix, while
        # two users never collide past it.
        tail_rng = np.random.RandomState(
            (cfg.seed * 1_000_003 + user * 7919) % (2 ** 31))
        tail = tuple(int(x) for x in
                     tail_rng.randint(0, cfg.vocab_size, size=ntail))
        interactive = float(rng.uniform()) < cfg.interactive_fraction
        prompt = prefixes[tenant] + tail
        max_new = (cfg.interactive_max_new if interactive
                   else cfg.batch_max_new)
        deadline = (cfg.interactive_deadline_s if interactive
                    else cfg.batch_deadline_s)
        prio = 0 if interactive else 1
        session = -1
        resume: Optional[Arrival] = None
        # Guarded draws: with resume_fraction == 0 the rng stream is
        # untouched and pre-existing traces stay byte-identical.
        if cfg.resume_fraction > 0 and \
                float(rng.uniform()) < cfg.resume_fraction:
            session = next_session
            next_session += 1
            gap = float(rng.exponential(cfg.cold_gap_mean_s))
            rtail = tuple(int(x) for x in tail_rng.randint(
                0, cfg.vocab_size, size=cfg.resume_tail_tokens))
            resume = Arrival(
                at_s=t + gap, tenant_idx=tenant, user=user,
                prompt=prompt + rtail, max_new=max_new,
                deadline_s=deadline, priority=prio,
                session=session, resumed=True)
        out.append(Arrival(
            at_s=t, tenant_idx=tenant, user=user, prompt=prompt,
            max_new=max_new, deadline_s=deadline, priority=prio,
            session=session))
        if resume is not None:
            out.append(resume)
    out.sort(key=lambda a: a.at_s)     # resumes land out of order
    return out


def offered_load(trace: list[Arrival], cfg: TrafficConfig) -> float:
    return len(trace) / cfg.duration_s if cfg.duration_s else 0.0


def run_open_loop(gw, tokens: list, trace: list[Arrival], *,
                  max_rounds: int = 200_000,
                  on_submit: Optional[Callable] = None) -> int:
    """Drive ``gw`` through ``trace`` open-loop, then drain.

    ``tokens[i]`` is the session token for tenant index ``i``. Before each
    gateway round, every arrival whose virtual time has come is submitted —
    regardless of how far behind the fleet is (that is the whole point).
    Submission errors from admission shed paths do not exist here (``submit``
    only raises on authorization failure); shed happens inside ``step``.
    Returns the number of rounds stepped; raises if the trace + drain does
    not complete within ``max_rounds`` (a wedged gateway, not overload —
    overload resolves by shedding).
    """
    i = 0
    rounds = 0
    start = gw.clock.now()          # trace times are relative to run start
    sessions: dict[int, tuple] = {}    # session id -> (rid, orig prompt len)
    # Resumed arrivals whose original is still in flight: a follow-up turn
    # cannot precede the reply it quotes, so these hold until the original
    # reaches a terminal state (DONE -> splice the reply in; SHED -> resume
    # without it) and submit at the next round. Everything else stays pure
    # open-loop; with resume_fraction == 0 this pool is always empty.
    pending: list[Arrival] = []

    def _ready(a: Arrival) -> bool:
        if not a.resumed or a.session not in sessions:
            return True
        job = gw.jobs[sessions[a.session][0]]
        return job.status is JobState.DONE or job.status is JobState.SHED

    def _submit(a: Arrival) -> None:
        prompt = list(a.prompt)
        if a.resumed and a.session in sessions:
            # The resumed conversation includes the assistant's actual
            # reply: splice the original's emitted tokens between its
            # prompt and the fresh user turn.
            orid, plen = sessions[a.session]
            job = gw.jobs[orid]
            if job.tokens is not None:
                prompt = prompt[:plen] + list(job.tokens) + prompt[plen:]
        rid = gw.submit(tokens[a.tenant_idx], prompt,
                        max_new=a.max_new, deadline_s=a.deadline_s,
                        priority=a.priority)
        if a.session >= 0 and not a.resumed:
            sessions[a.session] = (rid, len(a.prompt))
        if on_submit is not None:
            on_submit(a, rid)

    while i < len(trace) or pending or gw.outstanding():
        now = gw.clock.now()
        for a in [a for a in pending if _ready(a)]:
            pending.remove(a)
            _submit(a)
        while i < len(trace) and start + trace[i].at_s <= now:
            a = trace[i]
            i += 1
            if _ready(a):
                _submit(a)
            else:
                pending.append(a)
        gw.step()
        rounds += 1
        if rounds > max_rounds:
            raise RuntimeError(
                f"open-loop run exceeded {max_rounds} rounds "
                f"({i}/{len(trace)} submitted, {len(pending)} pending, "
                f"{gw.outstanding()} outstanding)")
    return rounds
