"""Open-loop trace-driven traffic generation for the serving gateway.

Every bench before this PR was closed-loop: submit a few dozen requests,
drain, measure. Closed loops cannot find a saturation wall — completions
gate submissions, so offered load self-limits exactly when the system
starts to fall behind. This module generates **open-loop** traffic the way
the paper's Fig-6 experiment drives DynamoDB: arrivals are a function of
*virtual time only*, independent of completions, so overload actually
queues, sheds, and burns SLO — and "max sustained req/s at the 99%
deadline-hit bar" becomes measurable.

The arrival process models a large consumer population on a shared
platform (the "million users" the paper's GeoDeepDive/social-media
workloads imply):

- **Poisson arrivals with diurnal modulation** — a non-homogeneous Poisson
  process via Lewis thinning: base rate x ``(1 + amplitude *
  sin(2*pi*t/period))``, so a trace can sweep through its own peak.
- **Zipf-distributed users** mapped onto a fixed tenant set — a handful of
  heavy principals dominate, the long tail trickles, matching every
  production multi-tenant trace. ``users`` can be 10**6 without
  materializing anything per-user: user identity only seeds that
  request's unique prompt tail.
- **Shared prefixes** — each tenant has a hot prompt prefix (system
  prompt / dataset preamble) its requests share, which is what makes
  prefix caching and affinity routing matter under load.
- **Mixed classes** — interactive (priority 0, tight deadline) vs batch
  (priority 1, loose deadline) split by ``interactive_fraction``.

Determinism: everything derives from ``seed`` via ``numpy.random
.RandomState``; the same config always yields byte-identical traces, so
saturation numbers are comparable across hosts (the repo-wide virtual
clock discipline).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

__all__ = ["TrafficConfig", "Arrival", "generate_trace", "offered_load",
           "run_open_loop"]


@dataclass(frozen=True)
class TrafficConfig:
    """Knobs for one generated trace. Token ids stay inside
    ``vocab_size``; prompt lengths are ``prefix_tokens`` (shared, hot)
    plus a unique per-request tail of ``tail_tokens_min..max``."""

    duration_s: float = 30.0
    base_rate_rps: float = 4.0
    diurnal_amplitude: float = 0.0        # 0..1 of base rate
    diurnal_period_s: float = 60.0        # compressed "day" in sim seconds
    tenants: int = 4
    users: int = 1_000_000                # population behind the tenants
    zipf_alpha: float = 1.3               # >1; lower = heavier tail
    prefix_tokens: int = 16               # shared per-tenant hot prefix
    tail_tokens_min: int = 2
    tail_tokens_max: int = 8
    interactive_fraction: float = 0.5
    interactive_deadline_s: float = 8.0
    batch_deadline_s: float = 60.0
    interactive_max_new: int = 8
    batch_max_new: int = 8
    vocab_size: int = 256
    seed: int = 0


@dataclass(frozen=True)
class Arrival:
    """One request, fully determined at generation time."""

    at_s: float                 # absolute virtual arrival time
    tenant_idx: int             # 0..tenants-1 (caller maps to principals)
    user: int                   # Zipf-ranked user id behind the request
    prompt: tuple
    max_new: int
    deadline_s: float           # relative to arrival
    priority: int               # 0 interactive, 1 batch


def _rate_at(cfg: TrafficConfig, t: float) -> float:
    return cfg.base_rate_rps * (1.0 + cfg.diurnal_amplitude
                                * math.sin(2.0 * math.pi * t
                                           / cfg.diurnal_period_s))


def _zipf_user(rng: np.random.RandomState, cfg: TrafficConfig) -> int:
    """Zipf-ranked user id in [0, users): rank 0 is the heaviest user.
    Rejection-sample numpy's unbounded Zipf down to the population."""
    while True:
        u = int(rng.zipf(cfg.zipf_alpha)) - 1
        if u < cfg.users:
            return u


def generate_trace(cfg: TrafficConfig) -> list[Arrival]:
    """The full arrival list for ``cfg``, sorted by time.

    Non-homogeneous Poisson via Lewis thinning: candidates arrive at the
    peak rate, and each survives with probability rate(t)/peak — exact for
    any bounded rate function, and O(peak x duration) cheap.
    """
    if cfg.diurnal_amplitude < 0 or cfg.diurnal_amplitude > 1:
        raise ValueError(f"diurnal_amplitude must be in [0, 1], got "
                         f"{cfg.diurnal_amplitude}")
    if cfg.zipf_alpha <= 1.0:
        raise ValueError(f"zipf_alpha must be > 1, got {cfg.zipf_alpha}")
    rng = np.random.RandomState(cfg.seed)
    # Per-tenant hot prefixes: deterministic, disjoint-ish token blocks.
    prefixes = [tuple(int(x) for x in
                      rng.randint(0, cfg.vocab_size, size=cfg.prefix_tokens))
                for _ in range(cfg.tenants)]
    peak = cfg.base_rate_rps * (1.0 + cfg.diurnal_amplitude)
    out: list[Arrival] = []
    t = 0.0
    while True:
        t += float(rng.exponential(1.0 / peak))
        if t >= cfg.duration_s:
            break
        if float(rng.uniform()) > _rate_at(cfg, t) / peak:
            continue                       # thinned candidate
        user = _zipf_user(rng, cfg)
        tenant = user % cfg.tenants
        ntail = int(rng.randint(cfg.tail_tokens_min,
                                cfg.tail_tokens_max + 1))
        # The tail is the user's own context: seeded by user id so repeat
        # visits from one user share MORE than the tenant prefix, while
        # two users never collide past it.
        tail_rng = np.random.RandomState(
            (cfg.seed * 1_000_003 + user * 7919) % (2 ** 31))
        tail = tuple(int(x) for x in
                     tail_rng.randint(0, cfg.vocab_size, size=ntail))
        interactive = float(rng.uniform()) < cfg.interactive_fraction
        out.append(Arrival(
            at_s=t, tenant_idx=tenant, user=user,
            prompt=prefixes[tenant] + tail,
            max_new=(cfg.interactive_max_new if interactive
                     else cfg.batch_max_new),
            deadline_s=(cfg.interactive_deadline_s if interactive
                        else cfg.batch_deadline_s),
            priority=0 if interactive else 1))
    return out


def offered_load(trace: list[Arrival], cfg: TrafficConfig) -> float:
    return len(trace) / cfg.duration_s if cfg.duration_s else 0.0


def run_open_loop(gw, tokens: list, trace: list[Arrival], *,
                  max_rounds: int = 200_000,
                  on_submit: Optional[Callable] = None) -> int:
    """Drive ``gw`` through ``trace`` open-loop, then drain.

    ``tokens[i]`` is the session token for tenant index ``i``. Before each
    gateway round, every arrival whose virtual time has come is submitted —
    regardless of how far behind the fleet is (that is the whole point).
    Submission errors from admission shed paths do not exist here (``submit``
    only raises on authorization failure); shed happens inside ``step``.
    Returns the number of rounds stepped; raises if the trace + drain does
    not complete within ``max_rounds`` (a wedged gateway, not overload —
    overload resolves by shedding).
    """
    i = 0
    rounds = 0
    start = gw.clock.now()          # trace times are relative to run start
    while i < len(trace) or gw.outstanding():
        now = gw.clock.now()
        while i < len(trace) and start + trace[i].at_s <= now:
            a = trace[i]
            i += 1
            rid = gw.submit(tokens[a.tenant_idx], list(a.prompt),
                            max_new=a.max_new, deadline_s=a.deadline_s,
                            priority=a.priority)
            if on_submit is not None:
                on_submit(a, rid)
        gw.step()
        rounds += 1
        if rounds > max_rounds:
            raise RuntimeError(
                f"open-loop run exceeded {max_rounds} rounds "
                f"({i}/{len(trace)} submitted, {gw.outstanding()} "
                "outstanding)")
    return rounds
