"""Kotta serving gateway: every generation request is a Kotta job.

Cloud Kotta's core contribution is the control plane around the executor —
fine-grained security over shared data (§VI), queue-driven elastic
provisioning that cuts cost up to 16x (§IV-C, Table VII-C), and execution
placed where the economics say (§VII-E). :class:`KottaServeGateway` wraps
those three planes around one or more :class:`ContinuousBatchingEngine`
replicas, so serve traffic gets exactly what batch analytics got:

- **Security** (§VI): ``submit`` takes a short-term :class:`SessionToken`
  and authorizes ``serve:Generate`` on the model resource (plus ``data:Get``
  on the request's data zone) through :class:`PolicyEngine` — default-deny,
  every allow/deny appended to the immutable audit log. The engine's radix
  prefix cache is **tenant-scoped**: each request's page-granular prefix
  keys are namespaced by (tenant, data-zone), so one tenant's cached KV
  pages can never be aliased into another tenant's request, while requests
  inside a tenant still share copy-on-write.
- **Scheduling** (§IV-D): admission is a pluggable policy
  (:mod:`repro.serve.admission`). The default
  :class:`~repro.serve.admission.DeadlineCostPolicy` keeps the pending
  queue EDF-ordered within priority classes, sheds requests that cannot
  meet their deadline at current occupancy (typed rejection, never a
  hang), and prices requests against their cost budget with
  :mod:`repro.core.cost` instance rates. The engine's ``_admit_wave``
  consumes this policy-ordered queue verbatim.
- **Decode preemption** (companion paper's interactive analytics): before
  an interactive request is shed as infeasible, the policy may nominate
  the latest-deadline running batch-class request for a lossless pause
  (:meth:`~repro.serve.admission.DeadlineCostPolicy.plan_preemption`) —
  its engine slot frees immediately, its KV pages stay pinned, and it
  resumes with zero re-prefill the moment a slot opens (accepted work
  completes ahead of new admissions, Kotta's queue-watcher promise).
  Every pause/resume is a typed audit record (``serve:Preempt`` /
  ``serve:Resume``) and lands in the gateway stats (``preemptions``,
  ``resumes``, ``preempt_wait_s``).
- **Elasticity** (§IV-C): replica count follows queue depth through
  :class:`repro.core.elastic.Provisioner`; spot replicas bid into
  :class:`repro.core.market.SpotMarket` and can be **revoked mid-decode**
  — the gateway aborts the engine (the normal retire path: refcounts stay
  exact, cached prefixes survive), re-enqueues the live requests exempt
  from shedding, and another replica completes them. Greedy decode is
  deterministic, so a requeued request emits identical tokens. Retired
  engines park in a standby pool (a warm pool: jit caches survive
  relaunch).

Time is a :class:`repro.core.clock.VirtualClock` driven by a
:class:`~repro.serve.admission.ServiceModel` — decode/prefill seconds are
modelled, so per-token and per-replica-second **cost accounting** is
deterministic and comparable across hosts, exactly like the Table VII-C
discrete-event reproduction. ``benchmarks/gateway_bench.py`` reports the
elastic-spot gateway against a static on-demand fleet.
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.clock import Clock, VirtualClock
from repro.core.cost import ComputePricing
from repro.core.elastic import Provisioner, ProvisioningModel, ScalingPolicy
from repro.core.market import SpotMarket
from repro.core.security import (AuditRecord, PolicyEngine, SessionToken)

from .admission import (AdmissionPolicy, DeadlineCostPolicy,
                        DeadlineInfeasible, JobState, PreemptCandidate,
                        ServeJob, ServiceModel)
from .engine import ContinuousBatchingEngine, EngineRequest, PausedRequest


class _Replica:
    """One engine instance with a market identity and a billing meter."""

    _ids = itertools.count()

    def __init__(self, engine: ContinuousBatchingEngine, zone, market: str,
                 bid: float, ready_at: float):
        self.id = next(self._ids)
        self.engine = engine
        self.zone = zone
        self.market = market            # "spot" | "on_demand"
        self.bid = bid                  # $/h; spot revokes when price > bid
        self.ready_at = ready_at
        self.state = "provisioning"     # -> "live" -> "retired"
        self.idle_since: Optional[float] = None
        self.jobs: set[int] = set()
        # prefill-token watermark: stats are cumulative per engine, and
        # engines are reused across launches (warm pool).
        self.pt_mark = engine.stats["prefill_tokens"]


@dataclass
class _PausedJob:
    """A decode-preempted job parked on a replica (pages pinned there)."""

    replica: "_Replica"
    paused: PausedRequest
    job: ServeJob
    since: float                    # pause timestamp: added wait accounting


class KottaServeGateway:
    """Secure, deadline/cost-aware, elastic front for serve replicas."""

    def __init__(self, engine_factory: Callable[[], ContinuousBatchingEngine],
                 security: PolicyEngine, *,
                 model_resource: str = "model/serve",
                 admission: AdmissionPolicy | None = None,
                 scaling: ScalingPolicy | None = None,
                 market: SpotMarket | None = None,
                 provisioning: ProvisioningModel | None = None,
                 pricing: ComputePricing | None = None,
                 instance_type: str = "c4.8xlarge",
                 service_model: ServiceModel | None = None,
                 clock: Clock | None = None,
                 idle_tick_s: float = 1.0,
                 seed: int = 0):
        self._engine_factory = engine_factory
        self.security = security
        self.model_resource = model_resource
        self.model = service_model or ServiceModel()
        # The default policy estimates with the SAME service model the
        # gateway bills with — shed decisions and accounting must agree.
        self.admission = admission or DeadlineCostPolicy(model=self.model)
        self.scaling = scaling or ScalingPolicy.none(1, market="on_demand")
        self.market = market
        self.pricing = pricing or (market.pricing if market is not None
                                   else ComputePricing())
        self.instance_type = instance_type
        # One clock for both planes: scheduling time must also drive token
        # expiry and audit timestamps, or the security fabric is time-inert
        # (a 1 h session token would outlive a week-long trace). Callers
        # that pass neither clock get a shared fresh VirtualClock.
        if clock is None and isinstance(security.clock, VirtualClock):
            clock = security.clock
        self.clock = clock if clock is not None else VirtualClock()
        self.idle_tick_s = idle_tick_s
        self.provisioner = Provisioner(self.scaling, provisioning, seed=seed)

        self.jobs: dict[int, ServeJob] = {}
        self.completed_order: list[int] = []
        self._queue: list[ServeJob] = []
        self._rids = itertools.count()
        self._replicas: list[_Replica] = []
        self._standby: list[ContinuousBatchingEngine] = []
        self._paused: list[_PausedJob] = []
        self.stats = {"rounds": 0, "launches": 0, "terminations": 0,
                      "revocations": 0, "requeues": 0, "shed": 0,
                      "tokens": 0, "cost_usd": 0.0, "replica_seconds": 0.0,
                      "peak_replicas": 0, "preemptions": 0, "resumes": 0,
                      "preempt_wait_s": 0.0}

        # One engine up front: it validates request shapes at submit time
        # and seeds the warm pool; every replica is factory-identical.
        self._standby.append(engine_factory())
        self._slots_per_replica = self._standby[0].max_slots
        # Pre-provision the floor, ready immediately — the paper's dev pool
        # always holds >= min reliable nodes (static baselines start hot).
        now = self.clock.now()
        self._start_time = now
        for _ in range(self.scaling.min_nodes):
            self._launch(now, ready_now=True)

    # -- user API ------------------------------------------------------------
    def submit(self, token: SessionToken, prompt: list[int], *,
               max_new: int = 16, deadline_s: float | None = None,
               priority: int = 1, cost_budget: float | None = None,
               data_zone: str | None = None) -> int:
        """Authorize and enqueue one generation request; returns its job id.

        Raises :class:`repro.core.security.SecurityError` on a deny — the
        deny (like every allow) is already in the audit log. ``deadline_s``
        is relative to now; ``priority`` is the class (0 = interactive).
        """
        self.security.check(token, "serve:Generate", self.model_resource)
        if data_zone is not None:
            self.security.check(token, "data:Get",
                                f"dataset/{data_zone}/serve-context")
        now = self.clock.now()
        rid = next(self._rids)
        job = ServeJob(
            rid=rid, tenant=token.principal_id, prompt=list(prompt),
            max_new=max_new, submitted_at=now,
            deadline=None if deadline_s is None else now + deadline_s,
            priority=priority, cost_budget=cost_budget,
            namespace=(token.principal_id, data_zone))
        # Fail fast on shapes that can never fit a replica's pool.
        self._probe_engine()._validate_request(
            EngineRequest(rid, job.prompt, job.max_new, job.namespace))
        self.jobs[rid] = job
        self._queue.append(job)
        return rid

    def result(self, rid: int) -> list[int]:
        """Completed tokens; raises the job's typed rejection if shed."""
        job = self.jobs[rid]
        if job.status is JobState.DONE:
            return job.tokens
        if job.status is JobState.SHED:
            raise job.error
        raise RuntimeError(f"job {rid} still {job.status.value}")

    def outstanding(self) -> int:
        return sum(1 for j in self.jobs.values()
                   if j.status in (JobState.QUEUED, JobState.RUNNING,
                                   JobState.PAUSED))

    def drain(self, max_rounds: int = 20_000) -> None:
        """Step until every submitted job is DONE or SHED."""
        for _ in range(max_rounds):
            if not self.outstanding():
                return
            self.step()
        raise RuntimeError(f"gateway did not drain in {max_rounds} rounds "
                           f"({self.outstanding()} jobs outstanding)")

    # -- one scheduling round --------------------------------------------------
    def step(self) -> None:
        """One gateway round: activate, revoke, resume, shed/order (which
        may preempt), dispatch, pump, autoscale, bill, and advance the
        virtual clock.

        Resume runs BEFORE shed/dispatch: paused jobs are accepted work and
        re-take freed slots ahead of new admissions (Kotta §IV-D — accepted
        work is completed, whatever the market or the burst does). A job
        preempted in this round's shed phase therefore cannot bounce
        straight back into the slot its preemptor needs — the interactive
        request is dispatched later the same round, and the victim resumes
        no earlier than the next round's slot surplus.
        """
        now = self.clock.now()
        self.stats["rounds"] += 1
        for r in self._replicas:
            if r.state == "provisioning" and r.ready_at <= now:
                r.state = "live"
                r.idle_since = now
        self._check_revocations(now)
        self._resume_paused(now)
        self._shed_and_order(now)
        self._dispatch()
        work_s = self._pump(now)
        self._autoscale(now)
        tick = work_s if work_s > 0 else self.idle_tick_s
        self._accrue(now, tick)
        self.clock.advance(tick)

    # -- security/market helpers ----------------------------------------------
    def _probe_engine(self) -> ContinuousBatchingEngine:
        if self._standby:
            return self._standby[-1]
        return self._replicas[0].engine

    def _od_price(self) -> float:
        return self.pricing.on_demand_per_hour[self.instance_type]

    def _replica_price(self, r: _Replica, now: float) -> float:
        if r.market == "spot":
            if self.market is not None and r.zone is not None:
                return self.market.price(r.zone, self.instance_type,
                                         now / 3600.0)
            return self._od_price() * self.pricing.typical_spot_fraction
        return self._od_price()

    def _price_per_slot_hour(self, now: float) -> float:
        live = [r for r in self._replicas if r.state == "live"]
        if live:
            per_h = sum(self._replica_price(r, now) for r in live) / len(live)
        elif self.scaling.market == "spot":
            if self.market is not None:
                per_h = self.market.cheapest_zone(self.instance_type,
                                                  now / 3600.0)[1]
            else:
                per_h = self._od_price() * self.pricing.typical_spot_fraction
        else:
            per_h = self._od_price()
        return per_h / self._slots_per_replica

    # -- revocation -------------------------------------------------------------
    def _check_revocations(self, now: float) -> None:
        if self.market is None:
            return
        for r in list(self._replicas):
            if r.state == "live" and r.market == "spot" and \
                    self.market.revoked(r.zone, self.instance_type, r.bid,
                                        now / 3600.0):
                self._revoke(r)

    def revoke_replica(self, replica_id: int) -> None:
        """Force-revoke a live replica (tests / operator chaos drills)."""
        for r in self._replicas:
            if r.id == replica_id and r.state == "live":
                self._revoke(r)
                return
        raise KeyError(f"no live replica {replica_id}")

    def _revoke(self, r: _Replica) -> None:
        """Spot reclaim: requests restart elsewhere; none are lost.

        ``abort`` also surrenders the replica's PAUSED requests (their
        pinned pages die with the instance), so their jobs re-enter the
        queue alongside the live ones — exempt from shedding, like any
        revocation casualty.
        """
        dropped = r.engine.abort()
        self._paused = [e for e in self._paused if e.replica is not r]
        self._return_to_queue(r, dropped, requeued=True)
        self.stats["revocations"] += 1
        self._retire_replica(r, terminated=False)

    def _return_to_queue(self, r: _Replica, reqs: list[EngineRequest], *,
                         requeued: bool) -> None:
        for req in reqs:
            job = self.jobs[req.rid]
            job.status = JobState.QUEUED
            job.requeued = job.requeued or requeued
            job.tokens = None
            job.started_at = None       # restarts from scratch: TTFT resets
            job.replica = None
            r.jobs.discard(req.rid)
            self._queue.append(job)
            if requeued:
                self.stats["requeues"] += 1

    # -- admission ---------------------------------------------------------------
    def _slot_horizon(self, now: float) -> list[float]:
        """When does each decode slot (live or provisioning) next free?"""
        horizon: list[float] = []
        step_s = self.model.decode_step_s
        for r in self._replicas:
            if r.state == "live":
                remaining = r.engine.remaining_tokens()
                horizon.extend(now + rem * step_s for rem in remaining)
                horizon.extend([now] * max(
                    self._slots_per_replica - len(remaining)
                    - r.engine.queued, 0))
            elif r.state == "provisioning":
                horizon.extend([r.ready_at] * self._slots_per_replica)
        return horizon

    def _shed_and_order(self, now: float) -> None:
        keep, shed = self.admission.plan(
            self._queue, self._slot_horizon(now), now,
            self._price_per_slot_hour(now))
        for job, err in shed:
            # Last resort before shedding a deadline-infeasible request:
            # pause a running lower-class request (policy's choice) so the
            # urgent one starts now. Preemption frees a slot, so the job
            # goes back into the keep set and dispatches this same round.
            if isinstance(err, DeadlineInfeasible) \
                    and self._try_preempt(job, now):
                keep.append(job)
                continue
            job.status = JobState.SHED
            job.error = err
            job.finished_at = now
            self.stats["shed"] += 1
        self._queue = self.admission.order(keep, now)

    # -- decode preemption -------------------------------------------------------
    def _try_preempt(self, job: ServeJob, now: float) -> bool:
        """Pause the policy's victim so ``job`` can start now; False if the
        policy finds no victim that keeps both deadlines."""
        cands = []
        for r in self._replicas:
            if r.state != "live":
                continue
            for slot, live in r.engine._live.items():
                victim = self.jobs.get(live.req.rid)
                if victim is None:
                    continue
                cands.append(PreemptCandidate(
                    victim, live.req.max_new - live.emitted, r.id, slot))
        choice = self.admission.plan_preemption(job, cands, now)
        if choice is None:
            return False
        r = next(x for x in self._replicas if x.id == choice.replica_id)
        paused = r.engine.preempt(choice.slot)
        victim = choice.job
        victim.status = JobState.PAUSED
        self._paused.append(_PausedJob(r, paused, victim, since=now))
        self.stats["preemptions"] += 1
        self.security.audit.append(AuditRecord(
            timestamp=now, principal_id=victim.tenant,
            role_name="serve-gateway", action="serve:Preempt",
            resource=self.model_resource, decision="allow",
            detail=f"job {victim.rid} paused (pages pinned, "
                   f"{choice.remaining_tokens} tokens remaining) to admit "
                   f"interactive job {job.rid}"))
        return True

    def _resume_paused(self, now: float) -> None:
        """Resume paused jobs into freed slots — ahead of new dispatches."""
        still: list[_PausedJob] = []
        for entry in self._paused:
            r = entry.replica
            if r.state != "live" or not r.engine.free_slots:
                still.append(entry)
                continue
            r.engine.resume(entry.paused)
            entry.job.status = JobState.RUNNING
            wait = now - entry.since
            self.stats["resumes"] += 1
            self.stats["preempt_wait_s"] += wait
            self.security.audit.append(AuditRecord(
                timestamp=now, principal_id=entry.job.tenant,
                role_name="serve-gateway", action="serve:Resume",
                resource=self.model_resource, decision="allow",
                detail=f"job {entry.job.rid} resumed after {wait:.2f}s "
                       "paused (zero re-prefill)"))
        self._paused = still

    def _dispatch(self) -> None:
        """Hand policy-ordered queue heads to replicas with open slots."""
        live = [r for r in self._replicas if r.state == "live"]
        while self._queue:
            r = max(live, key=lambda x: x.engine.open_slots, default=None)
            if r is None or r.engine.open_slots <= 0:
                break
            job = self._queue.pop(0)
            r.engine.enqueue(EngineRequest(job.rid, job.prompt, job.max_new,
                                           job.namespace))
            job.status = JobState.RUNNING
            job.replica = r.id
            r.jobs.add(job.rid)

    # -- the data plane -----------------------------------------------------------
    def _pump(self, now: float) -> float:
        """Admit + decode one chunk on every live replica; returns the
        round's simulated seconds (max across replicas — they run in
        parallel)."""
        round_s = 0.0
        for r in self._replicas:
            if r.state != "live":
                continue
            eng = r.engine
            if not eng.has_work:
                if r.idle_since is None:
                    r.idle_since = now
                continue
            r.idle_since = None
            eng.admit()
            for live in eng._live.values():
                job = self.jobs.get(live.req.rid)
                if job is not None and job.started_at is None:
                    # First decode-slot occupancy: the TTFT clock stops here
                    # (modelled prefill is charged identically either way).
                    job.started_at = now
            fresh = eng.stats["prefill_tokens"] - r.pt_mark
            r.pt_mark = eng.stats["prefill_tokens"]
            work = self.model.prefill_s(fresh)
            if eng.live:
                finished = eng.decode_step()
                work += eng.decode_chunk * self.model.decode_step_s
                for req, toks in finished:
                    job = self.jobs[req.rid]
                    job.status = JobState.DONE
                    job.tokens = toks
                    job.finished_at = now + work
                    job.replica = None
                    r.jobs.discard(req.rid)
                    self.completed_order.append(req.rid)
                    self.stats["tokens"] += len(toks)
            elif eng.queued:
                # Admission produced nothing (transient page pressure, e.g.
                # a paused request's pinned pages): give the QUEUED requests
                # back to the central queue so another replica — or a later
                # round here — picks them up. drop_queued, not abort: an
                # abort would also surrender the paused requests parked on
                # this replica, releasing the very pages they pin.
                self._return_to_queue(r, eng.drop_queued(), requeued=False)
            round_s = max(round_s, work)
        return round_s

    # -- elasticity ----------------------------------------------------------------
    def _autoscale(self, now: float) -> None:
        live = [r for r in self._replicas if r.state == "live"]
        provisioning = sum(1 for r in self._replicas
                           if r.state == "provisioning")
        idle = sum(1 for r in live if not r.engine.has_work)
        n = self.provisioner.launch_count(len(self._queue), idle,
                                          provisioning, len(live))
        for _ in range(n):
            self._launch(now)
        for r in live:
            if r.engine.has_work or r.jobs or r.idle_since is None:
                continue
            total = sum(1 for x in self._replicas if x.state == "live")
            if self.provisioner.should_terminate(now - r.idle_since, total):
                self._retire_replica(r, terminated=True)

    def _launch(self, now: float, ready_now: bool = False) -> _Replica:
        engine = self._standby.pop() if self._standby \
            else self._engine_factory()
        zone = None
        if self.market is not None:
            zone = self.market.cheapest_zone(self.instance_type,
                                             now / 3600.0)[0]
        bid = self.scaling.bid_fraction * self._od_price()
        delay = 0.0 if ready_now else self.provisioner.provisioning_delay()
        r = _Replica(engine, zone, self.scaling.market, bid,
                     ready_at=now + delay)
        if delay == 0.0:
            r.state = "live"
            r.idle_since = now
        self._replicas.append(r)
        self.stats["launches"] += 1
        return r

    def _retire_replica(self, r: _Replica, *, terminated: bool) -> None:
        r.state = "retired"
        self._replicas.remove(r)
        self._standby.append(r.engine)
        if terminated:
            self.stats["terminations"] += 1

    # -- billing / reporting ----------------------------------------------------
    def _accrue(self, now: float, tick: float) -> None:
        live = [r for r in self._replicas if r.state == "live"]
        for r in live:
            self.stats["cost_usd"] += \
                self._replica_price(r, now) * tick / 3600.0
            self.stats["replica_seconds"] += tick
        self.stats["peak_replicas"] = max(self.stats["peak_replicas"],
                                          len(live))

    def replicas(self, state: str = "live") -> list[_Replica]:
        return [r for r in self._replicas if r.state == state]

    def metrics(self) -> dict:
        """Serving report: throughput, deadline SLA, spend — the serving
        analogue of the Table VII-C makespan/cost/wait rows."""
        done = [j for j in self.jobs.values() if j.status is JobState.DONE]
        lat = sorted(j.finished_at - j.submitted_at for j in done)
        hits = sum(1 for j in done
                   if j.deadline is None or j.finished_at <= j.deadline)
        sim_s = self.clock.now() - self._start_time
        # Nearest-rank percentile: ceil(q*n)-1, not int(q*n) (which would
        # report the single worst latency as p95 for any n <= 20).
        def _pct(xs):
            return (lambda q: xs[min(max(math.ceil(q * len(xs)) - 1, 0),
                                     len(xs) - 1)]) \
                if xs else (lambda q: 0.0)
        pct = _pct(lat)
        # Interactive TTFT: queue wait until the first decode-slot
        # occupancy (modelled prefill excluded — identical across modes).
        inter = [j for j in self.jobs.values() if j.priority == 0]
        ittft = _pct(sorted(j.started_at - j.submitted_at
                            for j in inter
                            if j.status is JobState.DONE
                            and j.started_at is not None))
        idone = [j for j in inter if j.status is JobState.DONE]
        ihits = sum(1 for j in idone
                    if j.deadline is None or j.finished_at <= j.deadline)
        return {
            "jobs": len(self.jobs), "completed": len(done),
            "shed": self.stats["shed"],
            "tokens": self.stats["tokens"],
            "sim_seconds": sim_s,
            "tok_per_sim_s": self.stats["tokens"] / sim_s if sim_s else 0.0,
            "cost_usd": self.stats["cost_usd"],
            "usd_per_1k_tokens": (self.stats["cost_usd"] * 1e3
                                  / max(self.stats["tokens"], 1)),
            "replica_seconds": self.stats["replica_seconds"],
            "peak_replicas": self.stats["peak_replicas"],
            "deadline_hit_rate": hits / len(done) if done else 0.0,
            "sla_rate": hits / len(self.jobs) if self.jobs else 0.0,
            "p50_latency_s": pct(0.50), "p95_latency_s": pct(0.95),
            "interactive_jobs": len(inter),
            "interactive_completed": len(idone),
            "interactive_sla_rate": ihits / len(inter) if inter else 0.0,
            "interactive_p50_ttft_s": ittft(0.50),
            "interactive_p99_ttft_s": ittft(0.99),
            "preemptions": self.stats["preemptions"],
            "resumes": self.stats["resumes"],
            "preempt_wait_s": self.stats["preempt_wait_s"],
            "revocations": self.stats["revocations"],
            "requeues": self.stats["requeues"],
            "launches": self.stats["launches"],
            "terminations": self.stats["terminations"],
        }
