"""Kotta serving gateway: every generation request is a Kotta job.

Cloud Kotta's core contribution is the control plane around the executor —
fine-grained security over shared data (§VI), queue-driven elastic
provisioning that cuts cost up to 16x (§IV-C, Table VII-C), and execution
placed where the economics say (§VII-E). :class:`KottaServeGateway` wraps
those three planes around one or more :class:`ContinuousBatchingEngine`
replicas, so serve traffic gets exactly what batch analytics got:

- **Security** (§VI): ``submit`` takes a short-term :class:`SessionToken`
  and authorizes ``serve:Generate`` on the model resource (plus ``data:Get``
  on the request's data zone) through :class:`PolicyEngine` — default-deny,
  every allow/deny appended to the immutable audit log. The engine's radix
  prefix cache is **tenant-scoped**: each request's page-granular prefix
  keys are namespaced by (tenant, data-zone), so one tenant's cached KV
  pages can never be aliased into another tenant's request, while requests
  inside a tenant still share copy-on-write.
- **Scheduling** (§IV-D): admission is a pluggable policy
  (:mod:`repro.serve.admission`). The default
  :class:`~repro.serve.admission.DeadlineCostPolicy` keeps the pending
  queue EDF-ordered within priority classes, sheds requests that cannot
  meet their deadline at current occupancy (typed rejection, never a
  hang), and prices requests against their cost budget with
  :mod:`repro.core.cost` instance rates. The engine's ``_admit_wave``
  consumes this policy-ordered queue verbatim.
- **Decode preemption** (companion paper's interactive analytics): before
  an interactive request is shed as infeasible, the policy may nominate
  the latest-deadline running batch-class request for a lossless pause
  (:meth:`~repro.serve.admission.DeadlineCostPolicy.plan_preemption`) —
  its engine slot frees immediately, its KV pages stay pinned, and it
  resumes with zero re-prefill the moment a slot opens (accepted work
  completes ahead of new admissions, Kotta's queue-watcher promise).
  Every pause/resume is a typed audit record (``serve:Preempt`` /
  ``serve:Resume``) and lands in the gateway stats (``preemptions``,
  ``resumes``, ``preempt_wait_s``).
- **Elasticity** (§IV-C): replica count follows queue depth through
  :class:`repro.core.elastic.Provisioner`; spot replicas bid into
  :class:`repro.core.market.SpotMarket` and can be **revoked mid-decode**
  — the gateway aborts the engine (the normal retire path: refcounts stay
  exact, cached prefixes survive), re-enqueues the live requests exempt
  from shedding, and another replica completes them. Greedy decode is
  deterministic, so a requeued request emits identical tokens. Retired
  engines park in a standby pool (a warm pool: jit caches survive
  relaunch).
- **Graceful failure** (§IV-B; worker loss is an event, not an outage):
  the market's **revocation notice** (``SpotMarket.notice_s``, the
  2-minute spot warning) arrives one window ahead of the price crossing
  the bid, and the gateway spends it **evacuating** the replica — every
  live and PAUSED request's KV pages ship out mid-decode
  (``export(reason=EVACUATE)``) and re-import on a surviving
  replica via FleetRouter placement, so recovery costs a page copy, not a
  re-prefill, and greedy tokens stay identical to an undisturbed run.
  Only when the window is too short for the payload does the job fall
  back to requeue — now with **capped exponential backoff** and a
  **retry budget** (exhaustion is a typed ``RetryBudgetExhausted`` shed,
  never a hot requeue loop). Replicas heartbeat into the router each
  round; non-UP replicas (stragglers → DEGRADED, heartbeat loss →
  QUARANTINED) take no new placements and are drained. A pluggable
  :class:`~repro.serve.faults.FaultInjector` drives crash / notice /
  straggler / heartbeat-loss schedules through the same paths for the
  chaos tests and the ``fault_recovery`` bench. Every failure transition
  is audited (``serve:Revoke`` / ``serve:Evacuate`` / ``serve:Requeue``).

- **Placement** (§IV, execution near the data): dispatch goes through a
  :class:`~repro.serve.routing.FleetRouter`. Each replica advertises a
  radix **fingerprint** of its prefix cache
  (:meth:`~repro.serve.paging.PrefixCache.fingerprint`) and the router
  scores every queued request against every live replica — matched prefix
  pages x page_size is prefill work the fleet skips — dispatching to the
  best-affinity replica with a least-loaded fallback and a load-imbalance
  cap (``routing="affinity" | "least_loaded" | "blind"``). Affinity
  estimates also feed admission feasibility: a request that is only
  deadline-feasible on its warm replica is kept, not shed.
- **Disaggregated prefill/decode** (``prefill_replicas > 0``): dedicated
  prefill-role replicas (wide chunks, never decode) run admission prefill
  and ship each request's finished KV pages to a decode-role replica
  through the engine page-residency interface
  (:meth:`~repro.serve.engine.ContinuousBatchingEngine.export` /
  ``import_pages``). Handoffs re-register the shipped prefix in the
  destination's radix cache, so it stays shareable after the hop; greedy
  tokens are identical to a never-shipped run. Ship time is billed at
  ``ServiceModel.kv_ship_bytes_per_s`` and the wire bytes land in
  ``page_ship_bytes``.
- **Tiered KV hierarchy** (``kv_store=``): with a
  :class:`~repro.serve.kv_store.TieredKVStore` attached, a finished
  request's pages demote (``export(reason=DEMOTE)``) into HOST / OBJECT
  tiers instead of being destroyed, and a queued job whose prompt
  prefixes a demoted stream parks ``RESTORE_PENDING`` (the batch
  scheduler's WAITING_DATA, one layer down) while an async restore lands
  the pages back on a replica via ``restore_pages`` — resumed sessions
  pay restore bandwidth, not re-prefill FLOPs, and storage GB-hours are
  billed per (tier, tenant) through :class:`repro.core.cost.StoragePricing`.

Time is a :class:`repro.core.clock.VirtualClock` driven by a
:class:`~repro.serve.admission.ServiceModel` — decode/prefill seconds are
modelled, so per-token and per-replica-second **cost accounting** is
deterministic and comparable across hosts, exactly like the Table VII-C
discrete-event reproduction. ``benchmarks/gateway_bench.py`` reports the
elastic-spot gateway against a static on-demand fleet, and affinity
routing against blind dispatch on a Zipf-skewed tenant trace.
"""
from __future__ import annotations

import itertools
import math
from collections import deque
from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.clock import Clock, VirtualClock
from repro.core.cost import ComputePricing
from repro.core.elastic import Provisioner, ProvisioningModel, ScalingPolicy
from repro.core.market import SpotMarket
from repro.core.security import (AuditRecord, PolicyEngine, SessionToken)

from .admission import (AdmissionPolicy, DeadlineCostPolicy,
                        DeadlineInfeasible, JobState, PreemptCandidate,
                        RetryBudgetExhausted, ServeJob, ServiceModel,
                        StorageBudgetExceeded)
from .engine import (ContinuousBatchingEngine, EngineRequest, ExportReason,
                     PausedRequest, ShippedKV)
from .faults import FaultInjector
from .kv_store import TieredKVStore
from .routing import (HEALTH_UP, FingerprintTracker, FleetRouter,
                      ReplicaView)
from .telemetry import LATENCY_BUCKETS_S, MetricsRegistry, RegistryDict

# Per-token decode latency buckets (TPOT lives well under the TTFT range).
TPOT_BUCKETS_S = (0.005, 0.01, 0.025, 0.05, 0.075, 0.1, 0.25, 0.5, 1.0)


class _Replica:
    """One engine instance with a market identity and a billing meter."""

    _ids = itertools.count()

    def __init__(self, engine: ContinuousBatchingEngine, zone, market: str,
                 bid: float, ready_at: float):
        self.id = next(self._ids)
        self.engine = engine
        self.role = engine.role         # "unified" | "prefill" | "decode"
        self.zone = zone
        self.market = market            # "spot" | "on_demand"
        self.bid = bid                  # $/h; spot revokes when price > bid
        self.ready_at = ready_at
        self.state = "provisioning"     # -> "live" -> "retired"
        self.idle_since: Optional[float] = None
        self.jobs: set[int] = set()
        self.dispatched = 0             # requests routed here, lifetime
        # prefill-token watermark: stats are cumulative per engine, and
        # engines are reused across launches (warm pool).
        self.pt_mark = engine.stats["prefill_tokens"]
        # Failure plane: a pending revocation notice (absolute deadline the
        # instance disappears at) and injected-fault state.
        self.notice_deadline: Optional[float] = None
        self.latency_mult = 1.0         # straggler fault: decode slowdown
        self.straggler_until: Optional[float] = None
        self.hb_lost_until: Optional[float] = None


@dataclass
class _PausedJob:
    """A decode-preempted job parked on a replica (pages pinned there)."""

    replica: "_Replica"
    paused: PausedRequest
    job: ServeJob
    since: float                    # pause timestamp: added wait accounting


class KottaServeGateway:
    """Secure, deadline/cost-aware, elastic front for serve replicas."""

    def __init__(self, engine_factory: Callable[[], ContinuousBatchingEngine],
                 security: PolicyEngine, *,
                 model_resource: str = "model/serve",
                 admission: AdmissionPolicy | None = None,
                 scaling: ScalingPolicy | None = None,
                 market: SpotMarket | None = None,
                 provisioning: ProvisioningModel | None = None,
                 pricing: ComputePricing | None = None,
                 instance_type: str = "c4.8xlarge",
                 service_model: ServiceModel | None = None,
                 clock: Clock | None = None,
                 idle_tick_s: float = 1.0,
                 routing: str | FleetRouter = "affinity",
                 imbalance_cap: int = 4,
                 prefill_replicas: int = 0,
                 prefill_engine_factory:
                     Callable[[], ContinuousBatchingEngine] | None = None,
                 retry_budget: int = 5,
                 backoff_base_s: float = 2.0,
                 backoff_cap_s: float = 60.0,
                 evacuate_on_notice: bool = True,
                 notice_s: float | None = None,
                 fault_injector: FaultInjector | None = None,
                 kv_store: TieredKVStore | None = None,
                 registry: MetricsRegistry | None = None,
                 telemetry_store=None,
                 telemetry_flush_s: float = 5.0,
                 slo_target: float = 0.99,
                 slo_window_s: float = 300.0,
                 seed: int = 0):
        self._engine_factory = engine_factory
        self.security = security
        self.model_resource = model_resource
        self.model = service_model or ServiceModel()
        self.router = routing if isinstance(routing, FleetRouter) \
            else FleetRouter(routing, imbalance_cap=imbalance_cap)
        # The default policy estimates with the SAME service model the
        # gateway bills with — shed decisions and accounting must agree.
        self.admission = admission or DeadlineCostPolicy(model=self.model)
        self.scaling = scaling or ScalingPolicy.none(1, market="on_demand")
        self.market = market
        self.pricing = pricing or (market.pricing if market is not None
                                   else ComputePricing())
        self.instance_type = instance_type
        # One clock for both planes: scheduling time must also drive token
        # expiry and audit timestamps, or the security fabric is time-inert
        # (a 1 h session token would outlive a week-long trace). Callers
        # that pass neither clock get a shared fresh VirtualClock.
        if clock is None and isinstance(security.clock, VirtualClock):
            clock = security.clock
        self.clock = clock if clock is not None else VirtualClock()
        self.idle_tick_s = idle_tick_s
        self.provisioner = Provisioner(self.scaling, provisioning, seed=seed)
        # Failure-plane knobs: how many replica losses one job may absorb
        # before a typed shed, the capped-exponential requeue backoff, and
        # whether a revocation notice triggers KV evacuation (off = the
        # PR-4 abort/requeue baseline the fault_recovery bench compares
        # against). ``notice_s`` is the window for injected/operator
        # notices; market notices use the market's own ``notice_s``.
        if retry_budget < 0:
            raise ValueError(f"retry_budget must be >= 0, got {retry_budget}")
        self.retry_budget = retry_budget
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        self.evacuate_on_notice = evacuate_on_notice
        self.notice_s = notice_s if notice_s is not None else \
            (market.notice_s if market is not None else 120.0)
        self.faults = fault_injector
        self._fp_tracker = FingerprintTracker()
        # Tiered KV hierarchy (None disables demotion/restore entirely):
        # finished requests' pages demote into the store at retirement, and
        # queued jobs whose prompt prefixes a demoted stream park
        # RESTORE_PENDING while the async restore runs.
        self.kv_store = kv_store
        # rid -> [RestoreTicket, redeemed payload | None, delivery attempts]
        self._restores: dict[int, list] = {}

        self.jobs: dict[int, ServeJob] = {}
        self.completed_order: list[int] = []
        self._queue: list[ServeJob] = []
        self._rids = itertools.count()
        self._replicas: list[_Replica] = []
        self._standby: list[ContinuousBatchingEngine] = []
        self._paused: list[_PausedJob] = []
        # KV payloads in flight between replicas (prefill handoffs AND
        # evacuated requests), FIFO with a delivery-attempt counter.
        self._handoffs: list[list] = []    # [payload, job rid, attempts]

        # --- observability plane (one registry for the whole stack) --------
        # Gateway counters, every engine's stats, and the router's decision
        # counts all land in this registry; the `stats` dicts everywhere
        # stay readable/writable as plain dicts (RegistryDict views), so
        # nothing upstream of this PR changes shape.
        self.registry = registry if registry is not None \
            else MetricsRegistry(clock=self.clock)
        self.telemetry_store = telemetry_store
        self.telemetry_flush_s = telemetry_flush_s
        self.slo_target = slo_target
        self.slo_window_s = slo_window_s
        self._engine_seq = itertools.count()
        self._slo_events: deque = deque()      # (event time, deadline hit?)
        self._health_seen: dict[int, str] = {}
        self._audit_cursor = 0                 # audit records already staged
        self._write_seq = itertools.count()
        # Writes destined for the telemetry StateStore, FIFO; bounded so a
        # throttled table under sustained overload degrades to dropped
        # telemetry, never to unbounded gateway memory.
        self._pending_writes: deque = deque()
        self._last_flush = self.clock.now()
        self._build_metrics()
        self.stats = self._build_stats()
        self.router.bind_registry(self.registry)
        if self.kv_store is not None:
            self.kv_store.bind_registry(self.registry)

        # One engine up front: it validates request shapes at submit time
        # and seeds the warm pool; every autoscaled replica is
        # factory-identical (and never prefill-role — those never decode).
        self._standby.append(self._bind_engine(engine_factory()))
        if self._standby[0].role == "prefill":
            raise ValueError(
                "engine_factory must build decode-capable engines "
                "(role 'unified' or 'decode'); pass prefill-role engines "
                "through prefill_engine_factory")
        self._slots_per_replica = self._standby[0].max_slots
        # Pre-provision the floor, ready immediately — the paper's dev pool
        # always holds >= min reliable nodes (static baselines start hot).
        now = self.clock.now()
        self._start_time = now
        # Prefill-role replicas are static infrastructure: launched hot,
        # on-demand (never spot-revoked), never idle-terminated — they are
        # the fleet's admission front end, not elastic decode capacity.
        if prefill_replicas > 0 and prefill_engine_factory is None:
            raise ValueError("prefill_replicas > 0 requires a "
                             "prefill_engine_factory")
        for _ in range(prefill_replicas):
            eng = self._bind_engine(prefill_engine_factory())
            if eng.role != "prefill":
                raise ValueError("prefill_engine_factory must build "
                                 f"role='prefill' engines, got {eng.role!r}")
            r = _Replica(eng, None, "on_demand", 0.0, ready_at=now)
            r.state = "live"
            r.idle_since = now
            self._replicas.append(r)
            self.stats["launches"] += 1
        self._disaggregated = prefill_replicas > 0
        for _ in range(self.scaling.min_nodes):
            self._launch(now, ready_now=True)

    # -- observability -------------------------------------------------------
    # Gateway lifecycle counters exported as kotta_gateway_<key>_total.
    _STAT_COUNTERS = ("rounds", "launches", "terminations", "revocations",
                      "requeues", "shed", "tokens", "cost_usd",
                      "replica_seconds", "preemptions", "resumes",
                      "preempt_wait_s", "page_ships", "page_ship_bytes",
                      "notices", "evacuations", "evacuated_pages_bytes",
                      "retries", "backoff_wait_s", "wasted_decode_tokens",
                      "faults_injected", "telemetry_flushes",
                      "telemetry_writes", "telemetry_dropped",
                      "statestore_throttled", "kv_demotions",
                      "kv_demoted_bytes", "kv_restores",
                      "kv_restore_fallbacks", "kv_budget_refusals",
                      "restored_tokens", "storage_cost_usd")

    MAX_PENDING_WRITES = 10_000

    def _build_stats(self) -> RegistryDict:
        rd = RegistryDict()
        for key in self._STAT_COUNTERS:
            fam = self.registry.counter(
                f"kotta_gateway_{key}_total",
                f"Gateway {key.replace('_', ' ')} (cumulative)")
            rd.bind(key, fam, initial=0)
        rd.bind("peak_replicas",
                self.registry.gauge("kotta_gateway_peak_replicas",
                                    "High-water mark of live replicas"),
                initial=0)
        return rd

    def _build_metrics(self) -> None:
        reg = self.registry
        tc = ("tenant", "class")
        self._m_requests = reg.counter(
            "kotta_requests_total", "Requests admitted past authorization",
            tc)
        self._m_completed = reg.counter(
            "kotta_requests_completed_total", "Requests finished DONE", tc)
        self._m_shed_reason = reg.counter(
            "kotta_requests_shed_total", "Requests shed, by typed reason",
            ("tenant", "reason"))
        self._m_tenant_tokens = reg.counter(
            "kotta_tenant_tokens_total", "Decoded tokens delivered",
            ("tenant",))
        self._m_tenant_cost = reg.counter(
            "kotta_tenant_cost_usd_total",
            "Modelled serving spend attributed to the tenant (service "
            "seconds priced at the fleet's per-slot rate)", ("tenant",))
        self._m_ttft = reg.histogram(
            "kotta_request_ttft_seconds",
            "Submit to first decode-slot occupancy", LATENCY_BUCKETS_S, tc)
        self._m_tpot = reg.histogram(
            "kotta_request_tpot_seconds",
            "Decode seconds per emitted token", TPOT_BUCKETS_S, tc)
        self._m_qwait = reg.histogram(
            "kotta_request_queue_wait_seconds",
            "Submit to dispatch onto a replica", LATENCY_BUCKETS_S, tc)
        self._m_health_trans = reg.counter(
            "kotta_replica_health_transitions_total",
            "Router health-state transitions observed by the gateway",
            ("from_state", "to_state"))
        rr = ("replica", "role")
        self._g_occupancy = reg.gauge(
            "kotta_replica_occupancy", "Live decode slots / max slots", rr)
        self._g_queue_depth = reg.gauge(
            "kotta_replica_queue_depth", "Engine-queued requests", rr)
        self._g_hit_rate = reg.gauge(
            "kotta_replica_prefix_hit_rate",
            "Prompt tokens served from the prefix cache (lifetime)", rr)
        self._g_gw_queue = reg.gauge(
            "kotta_gateway_queue_depth", "Central pending-queue depth")
        self._g_live = reg.gauge(
            "kotta_gateway_live_replicas", "Replicas currently live")
        self._g_burn = reg.gauge(
            "kotta_slo_burn_rate",
            "Deadline-miss fraction over the SLO window / error budget "
            "(1.0 = burning exactly the budget)")
        self._g_slo_target = reg.gauge(
            "kotta_slo_target", "Deadline-hit SLO target")
        self._g_slo_target.set(self.slo_target)
        reg.register_collector(self._collect_gauges)

    def _bind_engine(self, eng: ContinuousBatchingEngine
                     ) -> ContinuousBatchingEngine:
        """Adopt an engine into the shared registry (idempotent: warm-pool
        engines come back already bound) and, when a tiered KV store is
        attached, into the storage hierarchy: decode-capable engines demote
        finished requests' pages instead of destroying them, and their
        prefix-cache evictions stream into the store's counters."""
        if not isinstance(eng.stats, RegistryDict):
            eng.bind_registry(self.registry, f"e{next(self._engine_seq)}")
        if self.kv_store is not None and eng.role != "prefill":
            eng.demote_on_retire = True
            if eng.prefix_cache is not None:
                eng.prefix_cache.on_evict = self.kv_store.on_eviction
        return eng

    @staticmethod
    def _job_class(job: ServeJob) -> str:
        return "interactive" if job.priority == 0 else "batch"

    def _collect_gauges(self) -> None:
        """Scrape-time refresh of gauges computed from live state (the
        Prometheus collector pattern) — retired replicas drop out of the
        exposition because the families are rebuilt from scratch."""
        now = self.clock.now()
        for fam in (self._g_occupancy, self._g_queue_depth,
                    self._g_hit_rate):
            fam.clear()
        live = 0
        for r in sorted(self._replicas, key=lambda x: x.id):
            if r.state == "retired":
                continue
            if r.state == "live":
                live += 1
            eng = r.engine
            lbl = {"replica": str(r.id), "role": r.role}
            self._g_occupancy.set(eng.live / eng.max_slots, **lbl)
            self._g_queue_depth.set(eng.queued, **lbl)
            self._g_hit_rate.set(eng.prefix_hit_rate, **lbl)
        self._g_gw_queue.set(len(self._queue))
        self._g_live.set(live)
        while self._slo_events and \
                self._slo_events[0][0] < now - self.slo_window_s:
            self._slo_events.popleft()
        if self._slo_events:
            miss = sum(1 for _, hit in self._slo_events if not hit) \
                / len(self._slo_events)
            self._g_burn.set(miss / max(1.0 - self.slo_target, 1e-9))
        else:
            self._g_burn.set(0.0)

    def _observe_completion(self, job: ServeJob) -> None:
        lbl = {"tenant": job.tenant, "class": self._job_class(job)}
        self._m_completed.inc(1, **lbl)
        ntoks = len(job.tokens or ())
        if job.started_at is not None:
            self._m_ttft.observe(job.started_at - job.submitted_at, **lbl)
            if ntoks:
                self._m_tpot.observe(
                    (job.finished_at - job.started_at) / ntoks, **lbl)
        if job.dispatched_at is not None:
            self._m_qwait.observe(job.dispatched_at - job.submitted_at,
                                  **lbl)
        self._m_tenant_tokens.inc(ntoks, tenant=job.tenant)
        # $/tenant: the job's modelled service seconds at the fleet's
        # current per-slot rate — the same arithmetic admission prices
        # budgets with, so showback and shed decisions agree.
        svc = self.model.prefill_s(len(job.prompt)) \
            + ntoks * self.model.decode_step_s
        self._m_tenant_cost.inc(
            svc / 3600.0 * self._price_per_slot_hour(job.finished_at),
            tenant=job.tenant)
        hit = job.deadline is None or job.finished_at <= job.deadline
        self._slo_events.append((job.finished_at, hit))
        self._stage_job_write(job)

    def _observe_shed(self, job: ServeJob, reason: str, now: float) -> None:
        self._m_shed_reason.inc(1, tenant=job.tenant, reason=reason)
        self._slo_events.append((now, False))
        self._stage_job_write(job)

    def _observe_health(self, now: float) -> None:
        for r in self._replicas:
            if r.state != "live" or r.role == "prefill":
                continue
            h = self.router.health(r.id, now)
            prev = self._health_seen.get(r.id)
            if prev is not None and prev != h:
                self._m_health_trans.inc(1, from_state=prev, to_state=h)
            self._health_seen[r.id] = h

    # -- telemetry -> StateStore flush ---------------------------------------
    def _stage_job_write(self, job: ServeJob) -> None:
        """Terminal job state becomes a StateStore item — the Kotta move:
        serve jobs land in the same provisioned table batch jobs use, so
        one backplane answers 'what happened to request N' for both."""
        if self.telemetry_store is None:
            return
        self._stage_write(f"servejob/{job.rid}", {
            "tenant": job.tenant, "status": job.status.value,
            "class": self._job_class(job),
            "tokens": len(job.tokens or ()),
            "submitted_at": job.submitted_at,
            "finished_at": job.finished_at,
            "retries": job.retries, "evacuations": job.evacuations,
            "error": type(job.error).__name__ if job.error else None})

    def _stage_write(self, key: str, item: dict) -> None:
        self._pending_writes.append((key, item))
        while len(self._pending_writes) > self.MAX_PENDING_WRITES:
            self._pending_writes.popleft()
            self.stats["telemetry_dropped"] += 1

    def _flush_telemetry(self, now: float) -> None:
        """Every ``telemetry_flush_s`` virtual seconds, push staged writes
        (audit records, terminal job states) plus one registry snapshot
        into the telemetry StateStore.

        Only the non-blocking ``try_put_item`` path is used: the gateway
        drives its own VirtualClock, so a blocking capacity wait here would
        deadlock the simulation — and the refusal count IS the signal
        (provisioned-throughput-exceeded) the saturation bench sweeps for.
        Throttled writes stay staged and retry next flush; a throttled
        snapshot is simply dropped (the next interval's supersedes it).
        """
        store = self.telemetry_store
        if store is None or now - self._last_flush < self.telemetry_flush_s:
            return
        self._last_flush = now
        self.stats["telemetry_flushes"] += 1
        self._stage_audit_tail()
        while self._pending_writes:
            key, item = self._pending_writes[0]
            if not store.try_put_item(key, item):
                self.stats["statestore_throttled"] += 1
                break
            self._pending_writes.popleft()
            self.stats["telemetry_writes"] += 1
        snap = self.registry.snapshot()
        if store.try_put_item(f"metrics/{next(self._write_seq):08d}", snap):
            self.stats["telemetry_writes"] += 1
        else:
            self.stats["statestore_throttled"] += 1

    def _stage_audit_tail(self) -> None:
        audit = self.security.audit
        if len(audit) > self._audit_cursor:
            for rec in audit.records()[self._audit_cursor:]:
                self._stage_write(f"audit/{next(self._write_seq):08d}", {
                    "ts": rec.timestamp, "principal": rec.principal_id,
                    "role": rec.role_name, "action": rec.action,
                    "resource": rec.resource, "decision": rec.decision,
                    "detail": rec.detail})
            self._audit_cursor = len(audit)

    def flush_telemetry(self) -> None:
        """End-of-run epilogue: drain EVERY staged telemetry write plus a
        final snapshot into the StateStore, advancing the virtual clock to
        refill write capacity when throttled (each refusal still counts —
        the wall is the wall, even during shutdown). ``step()`` never calls
        this; benches and the CLI do, so runs end with a complete table."""
        store = self.telemetry_store
        if store is None:
            return
        self._stage_audit_tail()
        while self._pending_writes:
            key, item = self._pending_writes[0]
            if store.try_put_item(key, item):
                self._pending_writes.popleft()
                self.stats["telemetry_writes"] += 1
            else:
                self.stats["statestore_throttled"] += 1
                self.clock.advance(1.0)
        key = f"metrics/{next(self._write_seq):08d}"
        snap = self.registry.snapshot()
        while not store.try_put_item(key, snap):
            self.stats["statestore_throttled"] += 1
            self.clock.advance(1.0)
        self.stats["telemetry_writes"] += 1

    # -- user API ------------------------------------------------------------
    def submit(self, token: SessionToken, prompt: list[int], *,
               max_new: int = 16, deadline_s: float | None = None,
               priority: int = 1, cost_budget: float | None = None,
               data_zone: str | None = None) -> int:
        """Authorize and enqueue one generation request; returns its job id.

        Raises :class:`repro.core.security.SecurityError` on a deny — the
        deny (like every allow) is already in the audit log. ``deadline_s``
        is relative to now; ``priority`` is the class (0 = interactive).
        """
        self.security.check(token, "serve:Generate", self.model_resource)
        if data_zone is not None:
            self.security.check(token, "data:Get",
                                f"dataset/{data_zone}/serve-context")
        now = self.clock.now()
        rid = next(self._rids)
        job = ServeJob(
            rid=rid, tenant=token.principal_id, prompt=list(prompt),
            max_new=max_new, submitted_at=now,
            deadline=None if deadline_s is None else now + deadline_s,
            priority=priority, cost_budget=cost_budget,
            namespace=(token.principal_id, data_zone))
        # Fail fast on shapes that can never fit a replica's pool — checked
        # against a decode-capable engine AND, when disaggregated, a
        # prefill-role engine (both pools must hold the request).
        er = EngineRequest(rid, job.prompt, job.max_new, job.namespace)
        self._validation_engine()._validate_request(er)
        for r in self._replicas:
            if r.role == "prefill":
                r.engine._validate_request(er)
                break
        self.jobs[rid] = job
        self._queue.append(job)
        self._m_requests.inc(1, tenant=job.tenant,
                             **{"class": self._job_class(job)})
        return rid

    def result(self, rid: int) -> list[int]:
        """Completed tokens; raises the job's typed rejection if shed."""
        job = self.jobs[rid]
        if job.status is JobState.DONE:
            return job.tokens
        if job.status is JobState.SHED:
            raise job.error
        raise RuntimeError(f"job {rid} still {job.status.value}")

    def outstanding(self) -> int:
        return sum(1 for j in self.jobs.values()
                   if j.status in (JobState.QUEUED, JobState.RUNNING,
                                   JobState.PAUSED,
                                   JobState.RESTORE_PENDING))

    def drain(self, max_rounds: int = 20_000) -> None:
        """Step until every submitted job is DONE or SHED."""
        for _ in range(max_rounds):
            if not self.outstanding():
                return
            self.step()
        raise RuntimeError(f"gateway did not drain in {max_rounds} rounds "
                           f"({self.outstanding()} jobs outstanding)")

    # -- one scheduling round --------------------------------------------------
    def step(self) -> None:
        """One gateway round: activate, revoke, resume, deliver/request
        tier restores, shed/order (which may preempt), dispatch, pump,
        autoscale, bill, and advance the virtual clock.

        Resume runs BEFORE shed/dispatch: paused jobs are accepted work and
        re-take freed slots ahead of new admissions (Kotta §IV-D — accepted
        work is completed, whatever the market or the burst does). A job
        preempted in this round's shed phase therefore cannot bounce
        straight back into the slot its preemptor needs — the interactive
        request is dispatched later the same round, and the victim resumes
        no earlier than the next round's slot surplus.
        """
        now = self.clock.now()
        self.stats["rounds"] += 1
        for r in self._replicas:
            if r.state == "provisioning" and r.ready_at <= now:
                r.state = "live"
                r.idle_since = now
        self._inject_faults(now)
        self._check_revocations(now)
        evac_s = self._evacuate_noticed(now)
        self._heartbeats(now)
        self._observe_health(now)
        self._drain_unhealthy(now)
        self._resume_paused(now)
        if self.kv_store is not None:
            self._deliver_restores(now)
            self._check_restores(now)
        self._shed_and_order(now)
        self._dispatch(now)
        work_s = max(self._pump(now), evac_s)
        self._autoscale(now)
        tick = work_s if work_s > 0 else self.idle_tick_s
        self._accrue(now, tick)
        self._flush_telemetry(now)
        self.clock.advance(tick)

    # -- replica accessors ------------------------------------------------------
    def replica_engine(self, replica_id: int) -> ContinuousBatchingEngine:
        """The engine behind a specific (non-retired) replica id.

        The explicit accessor for a heterogeneous fleet — there is no
        "the" engine once replicas differ by role, so callers must say
        which one they mean. Raises ``KeyError`` for an unknown id.
        """
        for r in self._replicas:
            if r.id == replica_id:
                return r.engine
        raise KeyError(f"no replica {replica_id}")

    def _validation_engine(self) -> ContinuousBatchingEngine:
        """A decode-capable engine for submit-time shape validation (the
        warm standby when one exists — every autoscaled replica is
        factory-identical to it)."""
        if self._standby:
            return self._standby[-1]
        for r in self._replicas:
            if r.role != "prefill":
                return r.engine
        raise RuntimeError("no decode-capable engine to validate against")

    # -- security/market helpers ----------------------------------------------

    def _od_price(self) -> float:
        return self.pricing.on_demand_per_hour[self.instance_type]

    def _replica_price(self, r: _Replica, now: float) -> float:
        if r.market == "spot":
            if self.market is not None and r.zone is not None:
                return self.market.price(r.zone, self.instance_type,
                                         now / 3600.0)
            return self._od_price() * self.pricing.typical_spot_fraction
        return self._od_price()

    def _price_per_slot_hour(self, now: float) -> float:
        live = [r for r in self._replicas if r.state == "live"]
        if live:
            per_h = sum(self._replica_price(r, now) for r in live) / len(live)
        elif self.scaling.market == "spot":
            if self.market is not None:
                per_h = self.market.cheapest_zone(self.instance_type,
                                                  now / 3600.0)[1]
            else:
                per_h = self._od_price() * self.pricing.typical_spot_fraction
        else:
            per_h = self._od_price()
        return per_h / self._slots_per_replica

    # -- fault injection ---------------------------------------------------------
    def _inject_faults(self, now: float) -> None:
        """Expire transient fault windows, then apply whatever the injected
        schedule says fires this round. Targets index the live
        decode-capable fleet sorted by id (mod count), so one schedule is
        meaningful at any fleet size; events with no live target land in
        ``injector.skipped`` rather than vanishing."""
        for r in self._replicas:
            if r.straggler_until is not None and now >= r.straggler_until:
                r.latency_mult = 1.0
                r.straggler_until = None
            if r.hb_lost_until is not None and now >= r.hb_lost_until:
                r.hb_lost_until = None
        if self.faults is None:
            return
        for ev in self.faults.pop_due(now):
            targets = sorted((r for r in self._replicas
                              if r.state == "live" and r.role != "prefill"),
                             key=lambda x: x.id)
            if not targets:
                self.faults.skipped.append(ev)
                continue
            r = targets[ev.target % len(targets)]
            self.faults.fired.append(ev)
            self.stats["faults_injected"] += 1
            if ev.kind == "crash":
                self._revoke(r, now)
            elif ev.kind == "revoke_notice":
                if r.notice_deadline is None:
                    self._notice(r, now, ev.duration_s or self.notice_s)
            elif ev.kind == "straggler":
                r.latency_mult = ev.magnitude
                r.straggler_until = now + ev.duration_s
            elif ev.kind == "heartbeat_loss":
                r.hb_lost_until = now + ev.duration_s

    # -- health ------------------------------------------------------------------
    def _heartbeats(self, now: float) -> None:
        """Every live replica reports liveness + modelled decode-step
        latency to the router — unless a heartbeat_loss fault is eating its
        reports. Stragglers report their slowed latency, which is exactly
        what the router's leave-one-out detector keys on."""
        for r in self._replicas:
            if r.state != "live":
                continue
            if r.hb_lost_until is not None and now < r.hb_lost_until:
                continue
            step_s = None if r.role == "prefill" \
                else self.model.decode_step_s * r.latency_mult
            self.router.heartbeat(r.id, now, step_s)

    def _drain_unhealthy(self, now: float) -> None:
        """Non-UP replicas take no new placements (the dispatch-target and
        handoff filters) and give queued-but-unstarted work back to the
        central queue; work already in a slot rides out the episode (a
        straggler still finishes, just slowly)."""
        for r in self._replicas:
            if r.state != "live" or r.role == "prefill":
                continue
            if self.router.health(r.id, now) != HEALTH_UP and \
                    r.engine.queued:
                self._return_to_queue(r, r.engine.drop_queued(),
                                      requeued=False)

    # -- revocation -------------------------------------------------------------
    def _check_revocations(self, now: float) -> None:
        if self.market is None:
            return
        for r in list(self._replicas):
            if r.state != "live" or r.market != "spot":
                continue
            if self.market.revoked(r.zone, self.instance_type, r.bid,
                                   now / 3600.0):
                self._revoke(r, now)
            elif r.notice_deadline is None and \
                    self.market.notice(r.zone, self.instance_type, r.bid,
                                       now / 3600.0):
                self._notice(r, now, self.market.notice_s)

    def revoke_replica(self, replica_id: int,
                       notice_s: float | None = None) -> None:
        """Force-revoke a live replica (tests / operator chaos drills).

        ``notice_s=None`` is the no-warning crash; a value runs the
        graceful path — a revocation notice with that many seconds of
        evacuation window before the instance disappears.
        """
        now = self.clock.now()
        for r in self._replicas:
            if r.id == replica_id and r.state == "live":
                if notice_s is None:
                    self._revoke(r, now)
                elif r.notice_deadline is None:
                    self._notice(r, now, notice_s)
                return
        raise KeyError(f"no live replica {replica_id}")

    def _notice(self, r: _Replica, now: float, window_s: float) -> None:
        """A revocation notice landed: the instance dies at
        ``now + window_s``. The replica immediately stops taking new work
        (dispatch/handoff filters key on ``notice_deadline``); the window
        itself is spent by :meth:`_evacuate_noticed`."""
        r.notice_deadline = now + window_s
        self.stats["notices"] += 1
        self.security.audit.append(AuditRecord(
            timestamp=now, principal_id=f"replica-{r.id}",
            role_name="serve-gateway", action="serve:Revoke",
            resource=self.model_resource, decision="allow",
            detail=f"replica {r.id} revocation notice: {window_s:.0f}s "
                   f"window, {r.engine.live} live / "
                   f"{len([e for e in self._paused if e.replica is r])} "
                   "paused requests to evacuate"))

    def _evacuate_noticed(self, now: float) -> float:
        """Spend pending notice windows. With ``evacuate_on_notice`` the
        replica is evacuated (KV ships out) the round the notice lands;
        without it (the requeue baseline) the replica decodes until the
        deadline, then takes the hard revoke. Returns evacuation ship
        seconds (copies run in parallel with the round's compute)."""
        evac_s = 0.0
        for r in list(self._replicas):
            if r.state != "live" or r.notice_deadline is None:
                continue
            if self.evacuate_on_notice:
                evac_s = max(evac_s, self._evacuate_replica(r, now))
            elif now >= r.notice_deadline:
                self._revoke(r, now)
        return evac_s

    def _evacuate_replica(self, r: _Replica, now: float) -> float:
        """Ship every request the notice window can carry; requeue the rest.

        Budgeting is per request against the remaining window: estimated
        ship time is ``page_nbytes() x ceil(pos/page_size)`` at the service
        model's wire rate, accumulated across requests (they share the
        instance's uplink). Export order is **tightest deadline first**
        across paused AND live requests: when the window cannot carry
        everything, the budget goes to the requests with the least slack —
        a loose-deadline request survives a requeue-with-backoff, an urgent
        one does not (deadline ties keep the old paused-then-live order).
        Whatever does not fit restarts from the queue with backoff. The
        exported payloads live in the gateway's handoff queue, NOT on the
        replica, so they survive the instance's death even if delivery
        takes a few rounds.
        """
        eng = r.engine
        budget = r.notice_deadline - now
        spent = 0.0
        page_b = eng.page_nbytes()
        ps = eng.page_size
        # (deadline, kind, handle, est ship seconds); stable sort on the
        # deadline alone preserves paused-then-live insertion order on ties.
        cands: list[tuple[float, str, int, float]] = []
        for entry in [e for e in self._paused if e.replica is r]:
            dl = self.jobs[entry.paused.req.rid].deadline
            cands.append((math.inf if dl is None else dl, "paused",
                          entry.paused.req.rid,
                          self.model.ship_s(
                              page_b * math.ceil(entry.paused.pos / ps))))
        for slot in sorted(eng._live):
            dl = self.jobs[eng._live[slot].req.rid].deadline
            cands.append((math.inf if dl is None else dl, "live", slot,
                          self.model.ship_s(
                              page_b * math.ceil(int(eng._pos[slot]) / ps))))
        cands.sort(key=lambda c: c[0])
        exports: list[ShippedKV] = []
        for _, kind, handle, est in cands:
            if spent + est > budget:
                continue
            exports.append(
                eng.export(rid=handle, reason=ExportReason.EVACUATE)
                if kind == "paused"
                else eng.export(slot=handle, reason=ExportReason.EVACUATE))
            spent += est
        for payload in exports:
            rid = payload.req.rid
            job = self.jobs[rid]
            job.status = JobState.RUNNING       # in flight to a new slot
            job.replica = None
            job.disturbed_at = now
            job.recovered_at = None
            job.evacuations += 1
            r.jobs.discard(rid)
            self._handoffs.append([payload, rid, 0])
            self.stats["evacuations"] += 1
            self.stats["evacuated_pages_bytes"] += payload.nbytes
            self.security.audit.append(AuditRecord(
                timestamp=now, principal_id=job.tenant,
                role_name="serve-gateway", action="serve:Evacuate",
                resource=self.model_resource, decision="allow",
                detail=f"job {rid} evacuated off replica {r.id} mid-decode "
                       f"({payload.emitted} tokens emitted, "
                       f"{payload.nbytes} KV bytes shipped)"))
        self._paused = [e for e in self._paused if e.replica is not r]
        # Engine-queued work never started here: straight back to the
        # central queue, shed-exempt but with NO retry accounting — nothing
        # was computed, so nothing was lost. Backoff exists to stop a job
        # from hammering a failing fleet, not to punish standing in line.
        self._return_to_queue(r, eng.drop_queued(), requeued=True)
        # Whatever the window could not carry restarts from the prompt.
        for req in eng.abort():
            r.jobs.discard(req.rid)
            self._requeue_job(self.jobs[req.rid], now,
                              detail=f"notice window too short on replica "
                                     f"{r.id}")
        self.stats["revocations"] += 1
        self.security.audit.append(AuditRecord(
            timestamp=now, principal_id=f"replica-{r.id}",
            role_name="serve-gateway", action="serve:Revoke",
            resource=self.model_resource, decision="allow",
            detail=f"replica {r.id} retired gracefully: {len(exports)} "
                   f"requests evacuated in {spent:.2f}s of a "
                   f"{budget:.0f}s notice window"))
        self._retire_replica(r, terminated=False)
        return spent

    def _revoke(self, r: _Replica, now: float) -> None:
        """Hard loss (spot reclaim / crash): requests restart elsewhere;
        none are lost, but every token already decoded here is wasted.

        ``abort`` also surrenders the replica's PAUSED requests (their
        pinned pages die with the instance), so their jobs re-enter the
        queue alongside the live ones — with backoff, counted against each
        job's retry budget.
        """
        eng = r.engine
        self.stats["wasted_decode_tokens"] += \
            sum(l.emitted for l in eng._live.values()) + \
            sum(p.emitted for p in eng._paused.values())
        # Queued-but-unstarted work lost nothing: shed-exempt requeue, no
        # retry/backoff accounting. Live + paused requests lost real decode
        # state and go through the budgeted backoff path.
        self._return_to_queue(r, eng.drop_queued(), requeued=True)
        dropped = eng.abort()
        self._paused = [e for e in self._paused if e.replica is not r]
        for req in dropped:
            r.jobs.discard(req.rid)
            self._requeue_job(self.jobs[req.rid], now,
                              detail=f"replica {r.id} lost without notice")
        self.stats["revocations"] += 1
        self.security.audit.append(AuditRecord(
            timestamp=now, principal_id=f"replica-{r.id}",
            role_name="serve-gateway", action="serve:Revoke",
            resource=self.model_resource, decision="allow",
            detail=f"replica {r.id} revoked without notice: "
                   f"{len(dropped)} requests requeued"))
        self._retire_replica(r, terminated=False)

    def _requeue_job(self, job: ServeJob, now: float,
                     detail: str = "") -> None:
        """Return a disturbed job to the queue with capped exponential
        backoff — or shed it, typed, when its retry budget is spent."""
        job.tokens = None
        job.started_at = None       # restarts from scratch: TTFT resets
        job.dispatched_at = None
        job.replica = None
        job.disturbed_at = now
        job.recovered_at = None
        job.retries += 1
        if job.retries > self.retry_budget:
            job.status = JobState.SHED
            job.error = RetryBudgetExhausted(
                f"job {job.rid} lost its replica {job.retries} times "
                f"(budget {self.retry_budget}); shedding, not spinning")
            job.finished_at = now
            self.stats["shed"] += 1
            self._observe_shed(job, job.error.reason, now)
            self.security.audit.append(AuditRecord(
                timestamp=now, principal_id=job.tenant,
                role_name="serve-gateway", action="serve:Requeue",
                resource=self.model_resource, decision="deny",
                detail=f"job {job.rid} retry budget exhausted "
                       f"({job.retries} > {self.retry_budget}): {detail}"))
            return
        backoff = min(self.backoff_cap_s,
                      self.backoff_base_s * 2 ** (job.retries - 1))
        job.status = JobState.QUEUED
        job.requeued = True
        job.not_before = now + backoff
        self._queue.append(job)
        self.stats["requeues"] += 1
        self.stats["retries"] += 1
        self.stats["backoff_wait_s"] += backoff
        self.security.audit.append(AuditRecord(
            timestamp=now, principal_id=job.tenant,
            role_name="serve-gateway", action="serve:Requeue",
            resource=self.model_resource, decision="allow",
            detail=f"job {job.rid} requeued (retry {job.retries}/"
                   f"{self.retry_budget}, backoff {backoff:.1f}s): "
                   f"{detail}"))

    def _return_to_queue(self, r: _Replica, reqs: list[EngineRequest], *,
                         requeued: bool) -> None:
        """Give never-started engine-queued work back to the central queue
        (no retry accounting: nothing was lost — see :meth:`_requeue_job`
        for the disturbed-job path)."""
        for req in reqs:
            job = self.jobs[req.rid]
            job.status = JobState.QUEUED
            job.requeued = job.requeued or requeued
            job.tokens = None
            job.started_at = None       # restarts from scratch: TTFT resets
            job.dispatched_at = None
            job.replica = None
            r.jobs.discard(req.rid)
            self._queue.append(job)
            if requeued:
                self.stats["requeues"] += 1

    # -- admission ---------------------------------------------------------------
    def _slot_horizon(self, now: float) -> list[float]:
        """When does each decode slot (live or provisioning) next free?

        Prefill-role replicas contribute nothing: they hold no decode
        capacity (their slots turn over within the admission round), so
        feasibility must be argued entirely from decode-capable slots.
        """
        horizon: list[float] = []
        step_s = self.model.decode_step_s
        now = self.clock.now()
        for r in self._replicas:
            if r.role == "prefill":
                continue
            if r.state == "live" and (
                    r.notice_deadline is not None
                    or self.router.health(r.id, now) != HEALTH_UP):
                # Dying or unhealthy capacity argues nothing: feasibility
                # promised against it would be broken the moment it drains.
                continue
            if r.state == "live":
                remaining = r.engine.remaining_tokens()
                horizon.extend(now + rem * step_s for rem in remaining)
                horizon.extend([now] * max(
                    r.engine.max_slots - len(remaining)
                    - r.engine.queued, 0))
            elif r.state == "provisioning":
                horizon.extend([r.ready_at] * r.engine.max_slots)
        return horizon

    def _shed_and_order(self, now: float) -> None:
        # Routing-aware feasibility: under affinity routing, tell admission
        # how many prompt tokens the best-matching dispatch target would
        # serve from its prefix cache — those tokens bill no prefill time.
        cached: dict[int, int] | None = None
        if self.router.mode == "affinity" and self._queue:
            views = self._target_views()
            cached = {job.rid: self.router.best_match_tokens(
                          job.prompt, job.namespace, views)
                      for job in self._queue}
        # RESTORE_PENDING jobs are feasibility-checked honestly: the async
        # restore's remaining latency is pre-service delay, and the stream
        # it lands counts as cached tokens (zero re-prefill once admitted).
        kwargs: dict = {}
        if self._restores:
            kwargs["extra_delay_s"] = {
                rid: max(0.0, item[0].ready_at - now)
                for rid, item in self._restores.items()}
            cached = dict(cached or {})
            for rid, item in self._restores.items():
                cached[rid] = max(cached.get(rid, 0), item[0].tokens)
        keep, shed = self.admission.plan(
            self._queue, self._slot_horizon(now), now,
            self._price_per_slot_hour(now), cached_tokens=cached, **kwargs)
        for job, err in shed:
            # Last resort before shedding a deadline-infeasible request:
            # pause a running lower-class request (policy's choice) so the
            # urgent one starts now. Preemption frees a slot, so the job
            # goes back into the keep set and dispatches this same round.
            if isinstance(err, DeadlineInfeasible) \
                    and self._try_preempt(job, now):
                keep.append(job)
                continue
            self._restores.pop(job.rid, None)   # a shed job's ticket dies
            job.status = JobState.SHED
            job.error = err
            job.finished_at = now
            self.stats["shed"] += 1
            self._observe_shed(job, err.reason, now)
        self._queue = self.admission.order(keep, now)

    # -- decode preemption -------------------------------------------------------
    def _try_preempt(self, job: ServeJob, now: float) -> bool:
        """Pause the policy's victim so ``job`` can start now; False if the
        policy finds no victim that keeps both deadlines."""
        cands = []
        for r in self._replicas:
            if r.state != "live":
                continue
            for slot, live in r.engine._live.items():
                victim = self.jobs.get(live.req.rid)
                if victim is None:
                    continue
                cands.append(PreemptCandidate(
                    victim, live.req.max_new - live.emitted, r.id, slot))
        choice = self.admission.plan_preemption(job, cands, now)
        if choice is None:
            return False
        r = next(x for x in self._replicas if x.id == choice.replica_id)
        paused = r.engine.preempt(choice.slot)
        victim = choice.job
        victim.status = JobState.PAUSED
        self._paused.append(_PausedJob(r, paused, victim, since=now))
        self.stats["preemptions"] += 1
        self.security.audit.append(AuditRecord(
            timestamp=now, principal_id=victim.tenant,
            role_name="serve-gateway", action="serve:Preempt",
            resource=self.model_resource, decision="allow",
            detail=f"job {victim.rid} paused (pages pinned, "
                   f"{choice.remaining_tokens} tokens remaining) to admit "
                   f"interactive job {job.rid}"))
        return True

    def _resume_paused(self, now: float) -> None:
        """Resume paused jobs into freed slots — ahead of new dispatches."""
        still: list[_PausedJob] = []
        for entry in self._paused:
            r = entry.replica
            if r.state != "live" or not r.engine.free_slots:
                still.append(entry)
                continue
            r.engine.resume(entry.paused)
            entry.job.status = JobState.RUNNING
            wait = now - entry.since
            self.stats["resumes"] += 1
            self.stats["preempt_wait_s"] += wait
            self.security.audit.append(AuditRecord(
                timestamp=now, principal_id=entry.job.tenant,
                role_name="serve-gateway", action="serve:Resume",
                resource=self.model_resource, decision="allow",
                detail=f"job {entry.job.rid} resumed after {wait:.2f}s "
                       "paused (zero re-prefill)"))
        self._paused = still

    # -- tiered KV hierarchy (demote / restore) ---------------------------------
    def _check_restores(self, now: float) -> None:
        """Park QUEUED jobs whose prompt prefixes a demoted stream.

        The exact mirror of the batch scheduler's ARCHIVE -> WAITING_DATA
        transition: instead of re-prefilling a cold conversation, the job
        waits ``RESTORE_PENDING`` on an async tier restore whose modelled
        latency gates dispatch through the same ``not_before`` hold the
        requeue backoff uses. Jobs already as warm on the live fleet
        (affinity fingerprint match >= the stored stream) skip the restore
        — a device hit beats any lower tier.
        """
        store = self.kv_store
        views = None
        for job in self._queue:
            if (job.status is not JobState.QUEUED or job.requeued
                    or job.not_before > now
                    or job.rid in self._restores):
                continue
            hit = store.match(job.namespace, job.prompt)
            if hit is None:
                continue
            key, tokens, tier = hit
            if self.router.mode == "affinity":
                if views is None:
                    views = self._target_views()
                if self.router.best_match_tokens(
                        job.prompt, job.namespace, views) >= tokens:
                    continue
            ticket = store.request_restore(key, job.rid, now)
            self._restores[job.rid] = [ticket, None, 0]
            job.status = JobState.RESTORE_PENDING
            job.not_before = ticket.ready_at
            job.restores += 1
            self.security.audit.append(AuditRecord(
                timestamp=now, principal_id=job.tenant,
                role_name="serve-gateway", action="serve:Restore",
                resource=self.model_resource, decision="allow",
                detail=f"job {job.rid} parked RESTORE_PENDING: "
                       f"{tokens}-token stream on {tier.value} tier, "
                       f"ready in {ticket.ready_at - now:.2f}s"))

    def _deliver_restores(self, now: float) -> None:
        """Land due restores on the fleet; fall back to re-prefill on loss.

        A due ticket is redeemed once (the payload survives placement
        retries); ``complete_restore`` returning None means the entry was
        evicted while the restore was in flight — the job simply rejoins
        the queue cold. Placement is least-loaded over UP decode-capable
        replicas via :meth:`ContinuousBatchingEngine.restore_pages`, which
        re-registers the stream as free-but-hittable cache pages, so the
        job's own admission aliases them with zero re-prefill.
        """
        store = self.kv_store
        for rid in list(self._restores):
            ticket, payload, attempts = self._restores[rid]
            job = self.jobs[rid]
            if job.status is not JobState.RESTORE_PENDING:
                del self._restores[rid]         # shed while parked
                continue
            if now < ticket.ready_at:
                continue
            if payload is None:
                payload = store.complete_restore(ticket, now)
                if payload is None:
                    self._restore_fallback(job, now,
                                           "entry evicted mid-restore")
                    continue
                self._restores[rid][1] = payload
            dests = sorted(
                (r for r in self._replicas
                 if r.state == "live" and r.role != "prefill"
                 and r.notice_deadline is None
                 and self.router.health(r.id, now) == HEALTH_UP),
                key=lambda x: (x.engine.live + x.engine.queued, x.id))
            landed = None
            for r in dests:
                try:
                    r.engine.restore_pages(payload)
                except RuntimeError:
                    continue                    # no pages here: try the next
                landed = r
                break
            if landed is None:
                self._restores[rid][2] = attempts + 1
                if attempts + 1 >= self.MAX_DELIVERY_ATTEMPTS:
                    self._restore_fallback(
                        job, now, f"no capacity after {attempts + 1} rounds")
                continue
            del self._restores[rid]
            job.status = JobState.QUEUED
            job.not_before = 0.0
            job.restored_tokens += ticket.tokens
            self.stats["kv_restores"] += 1
            self.stats["restored_tokens"] += ticket.tokens
            self.security.audit.append(AuditRecord(
                timestamp=now, principal_id=job.tenant,
                role_name="serve-gateway", action="serve:Restore",
                resource=self.model_resource, decision="allow",
                detail=f"job {rid}: {ticket.tokens}-token stream restored "
                       f"from {ticket.tier.value} onto replica {landed.id} "
                       f"({ticket.nbytes}B, zero re-prefill)"))

    def _restore_fallback(self, job: ServeJob, now: float,
                          detail: str) -> None:
        """Restore lost the race (eviction or no capacity): the job rejoins
        the queue cold and re-prefills — never a crash, never a hang."""
        del self._restores[job.rid]
        job.status = JobState.QUEUED
        job.not_before = 0.0
        self.stats["kv_restore_fallbacks"] += 1
        self.security.audit.append(AuditRecord(
            timestamp=now, principal_id=job.tenant,
            role_name="serve-gateway", action="serve:Restore",
            resource=self.model_resource, decision="deny",
            detail=f"job {job.rid} falls back to re-prefill: {detail}"))

    def _demote_payload(self, payload: ShippedKV, now: float) -> None:
        """One finished request's pages into the store, budget permitting.

        A :class:`StorageBudgetExceeded` refusal is typed and audited, and
        the payload is simply forgone — the tenant's conversation restarts
        cold next time, it does not fail."""
        job = self.jobs.get(payload.req.rid)
        tenant = job.tenant if job is not None else payload.req.namespace[0]
        try:
            tier = self.kv_store.demote(payload, tenant, now)
        except StorageBudgetExceeded as err:
            self.stats["kv_budget_refusals"] += 1
            self.security.audit.append(AuditRecord(
                timestamp=now, principal_id=tenant,
                role_name="serve-gateway", action="serve:Demote",
                resource=self.model_resource, decision="deny",
                detail=str(err)))
            return
        self.stats["kv_demotions"] += 1
        self.stats["kv_demoted_bytes"] += payload.nbytes
        self.security.audit.append(AuditRecord(
            timestamp=now, principal_id=tenant,
            role_name="serve-gateway", action="serve:Demote",
            resource=self.model_resource, decision="allow",
            detail=f"job {payload.req.rid}: {payload.nbytes}B of KV pages "
                   f"demoted to {tier.value} tier at retirement"))

    def _dispatch_targets(self) -> list[_Replica]:
        """Replicas the router may place new requests on: the prefill fleet
        when disaggregated (decode replicas only take handoffs), every
        decode-capable live replica otherwise — minus anything under a
        revocation notice or not UP in the router's health view."""
        want = "prefill" if self._disaggregated else None
        now = self.clock.now()
        return [r for r in self._replicas if r.state == "live"
                and (r.role == "prefill") == (want == "prefill")
                and r.notice_deadline is None
                and self.router.health(r.id, now) == HEALTH_UP]

    def _target_views(self) -> list[ReplicaView]:
        """Router-side snapshots of the current dispatch targets.

        Fingerprints are collected only under affinity routing (the other
        modes never read them); they are stable within a round — admission,
        which registers new prefixes, runs later, in ``_pump``.
        """
        views = []
        for r in self._dispatch_targets():
            eng = r.engine
            fp = frozenset()
            if self.router.mode == "affinity" and eng.prefix_cache is not None:
                fp = self._fp_tracker.refresh(r.id, eng.prefix_cache)
            views.append(ReplicaView(
                r.id, eng.open_slots, load=eng.live + eng.queued,
                page_size=eng.page_size, fingerprint=fp))
        return views

    def _affinity_window(self) -> int:
        """Queue prefix the router may reorder within: the run of jobs
        sharing the head's (priority, deadline), capped at ``window``.

        Jobs with identical priority AND deadline are SLA-interchangeable —
        EDF ordered them by (submit, rid) only — so picking the one whose
        prefix is resident on the open capacity costs nothing in deadline
        terms. The window never crosses an EDF boundary: a tighter-deadline
        or higher-class head can NEVER be bypassed by an affinity hit
        behind it.
        """
        head = self._queue[0]
        n = 1
        for job in self._queue[1:self.router.window]:
            if (job.priority, job.deadline) != (head.priority,
                                                head.deadline):
                break
            n += 1
        return n

    def _dispatch(self, now: float) -> None:
        """Route queued jobs to replicas with open slots.

        The queue's policy order governs WHO runs first up to affinity
        lookahead: within the head's SLA-interchangeable window
        (:meth:`_affinity_window`) the router may dispatch a job whose
        prefix is resident on the free capacity ahead of a head that would
        cold-prefill there — under backlog, routing the head alone
        degenerates to blind placement, because the head rarely matches
        whichever slot happens to be free. Across EDF boundaries order is
        absolute. Each placement bumps the chosen view's load so one
        round's decisions see each other. When disaggregated, new work
        lands exclusively on prefill replicas, throttled by downstream
        decode capacity (free decode slots minus handoffs already in
        flight) so finished KV payloads can't pile up faster than decode
        replicas drain them.
        """
        # Backoff hold: requeued jobs still inside their backoff window are
        # not dispatchable this round (they keep their queue standing —
        # shed/order already saw them).
        held = [j for j in self._queue if j.not_before > now]
        if held:
            self._queue = [j for j in self._queue if j.not_before <= now]
        targets = {r.id: r for r in self._dispatch_targets()}
        views = self._target_views()
        budget = None
        if self._disaggregated:
            budget = sum(r.engine.open_slots for r in self._replicas
                         if r.state == "live" and r.role != "prefill") \
                - len(self._handoffs)
        while self._queue:
            if budget is not None and budget <= 0:
                break
            pick = 0
            if self.router.mode == "affinity" and len(self._queue) > 1:
                # Best matched tokens within the window wins; policy order
                # breaks ties, so zero-match backlogs stay exactly FIFO.
                # Score only against views with an open slot: a match on a
                # busy replica can't be dispatched to this round.
                free = [v for v in views if v.open_slots > 0]
                best = 0
                for i in range(self._affinity_window()):
                    j = self._queue[i]
                    m = self.router.best_match_tokens(j.prompt, j.namespace,
                                                      free)
                    if m > best:
                        best, pick = m, i
            job = self._queue[pick]
            decision = self.router.route(job.prompt, job.namespace, views)
            if decision is None:
                break
            self._queue.pop(pick)
            r = targets[decision.replica_id]
            r.engine.enqueue(EngineRequest(job.rid, job.prompt, job.max_new,
                                           job.namespace))
            job.status = JobState.RUNNING
            job.replica = r.id
            if job.dispatched_at is None:
                job.dispatched_at = now
            r.jobs.add(job.rid)
            r.dispatched += 1
            for v in views:
                if v.replica_id == r.id:
                    v.open_slots -= 1
                    v.load += 1
            if budget is not None:
                budget -= 1
        if held:
            self._queue = self.admission.order(self._queue + held, now)

    # -- the data plane -----------------------------------------------------------
    MAX_DELIVERY_ATTEMPTS = 50

    def _deliver_handoffs(self, now: float) -> float:
        """Import in-flight KV payloads (prefill handoffs and evacuated
        requests) into decode-capable replicas.

        FIFO over the handoff queue; destinations are live, decode-capable,
        not under a revocation notice, and UP in the router's health view.
        Placement is router-guided: under affinity routing the payload's
        prefix may already be resident somewhere (an evacuated request
        landing back on a warm replica re-imports nothing extra but keeps
        sharing), falling back to least-loaded. A payload that no replica
        can take this round (no free slot, or not enough free pages) stays
        queued and retries next round — up to ``MAX_DELIVERY_ATTEMPTS``,
        after which the copy is abandoned and the job restarts from the
        prompt via the requeue path (a payload must never strand a job
        forever). Returns the round's ship seconds (max across deliveries —
        the copies run in parallel).
        """
        if not self._handoffs:
            return 0.0
        ship_s = 0.0
        dests = [r for r in self._replicas
                 if r.state == "live" and r.role != "prefill"
                 and r.notice_deadline is None
                 and self.router.health(r.id, now) == HEALTH_UP]
        still: list[list] = []
        for item in self._handoffs:
            payload, rid, attempts = item
            job = self.jobs[rid]
            placed = False
            # Least-loaded decode replica first: handoff placement balances
            # the decode fleet the way least-loaded dispatch would.
            order = sorted(dests, key=lambda x: (x.engine.live
                                                 + x.engine.queued, x.id))
            if self.router.mode == "affinity" and len(order) > 1:
                views = [ReplicaView(
                             x.id, x.engine.open_slots,
                             load=x.engine.live + x.engine.queued,
                             page_size=x.engine.page_size,
                             fingerprint=self._fp_tracker.refresh(
                                 x.id, x.engine.prefix_cache))
                         for x in order if x.engine.open_slots > 0
                         and x.engine.prefix_cache is not None]
                decision = self.router.route(payload.req.prompt,
                                             payload.req.namespace, views)
                if decision is not None:
                    # Stable sort: the router's pick first, the rest keep
                    # least-loaded order as fallbacks.
                    order.sort(key=lambda x: x.id != decision.replica_id)
            for r in order:
                if not r.engine.free_slots:
                    continue
                try:
                    r.engine.import_pages(payload)
                except RuntimeError:
                    continue            # out of pages here: try the next
                job.replica = r.id
                job.status = JobState.RUNNING
                r.jobs.add(rid)
                r.idle_since = None
                if job.started_at is None:
                    # TTFT stops at first DECODE-slot occupancy — the
                    # disaggregated analogue of the unified admit stamp.
                    job.started_at = now
                ship_s = max(ship_s, self.model.ship_s(payload.nbytes))
                placed = True
                break
            if not placed:
                item[2] = attempts + 1
                if item[2] >= self.MAX_DELIVERY_ATTEMPTS:
                    self._requeue_job(job, now,
                                      detail="KV payload undeliverable "
                                             f"after {item[2]} rounds")
                else:
                    still.append(item)
        self._handoffs = still
        return ship_s

    def _pump(self, now: float) -> float:
        """Admit + decode one chunk on every live replica; returns the
        round's simulated seconds (max across replicas — they run in
        parallel). Disaggregated fleets first deliver in-flight KV
        handoffs (so this round's decode includes them), then the prefill
        replicas admit-and-export a fresh batch for the next round."""
        round_s = self._deliver_handoffs(now)
        for r in self._replicas:
            if r.state != "live":
                continue
            eng = r.engine
            if not eng.has_work:
                if r.idle_since is None:
                    r.idle_since = now
                continue
            r.idle_since = None
            admitted = eng.admit()
            fresh = eng.stats["prefill_tokens"] - r.pt_mark
            r.pt_mark = eng.stats["prefill_tokens"]
            work = self.model.prefill_s(fresh)
            if r.role == "prefill":
                # Prefill replicas never decode: every admitted request's
                # finished pages ship out immediately, freeing the slot for
                # the next admission wave. The source's prefix cache keeps
                # the registered entries, so the NEXT request with this
                # prefix pays only its fresh suffix here.
                for slot in sorted(eng._live):
                    rid = eng._live[slot].req.rid
                    payload = eng.export(slot=slot,
                                         reason=ExportReason.HANDOFF)
                    self._handoffs.append([payload, rid, 0])
                    self.jobs[rid].replica = None     # in flight
                    r.jobs.discard(rid)
                    self.stats["page_ships"] += 1
                    self.stats["page_ship_bytes"] += payload.nbytes
                if not admitted and eng.queued:
                    self._return_to_queue(r, eng.drop_queued(),
                                          requeued=False)
            elif eng.live:
                for live in eng._live.values():
                    job = self.jobs.get(live.req.rid)
                    if job is None:
                        continue
                    if job.started_at is None:
                        # First decode-slot occupancy: the TTFT clock stops
                        # here (modelled prefill is charged identically
                        # either way).
                        job.started_at = now
                    if job.disturbed_at is not None \
                            and job.recovered_at is None:
                        # First decode occupancy AFTER a disturbance: the
                        # recovered-TTFT clock (evacuation vs requeue) stops
                        # here, whichever path brought the job back.
                        job.recovered_at = now
                finished = eng.decode_step()
                work += eng.decode_chunk * self.model.decode_step_s \
                    * r.latency_mult
                for req, toks in finished:
                    job = self.jobs[req.rid]
                    job.status = JobState.DONE
                    job.tokens = toks
                    job.finished_at = now + work
                    job.replica = None
                    r.jobs.discard(req.rid)
                    self.completed_order.append(req.rid)
                    self.stats["tokens"] += len(toks)
                    self._observe_completion(job)
                if self.kv_store is not None and eng.demoted_out:
                    # Retirement demoted these requests' pages off the
                    # device (reason=DEMOTE): park them in the tier store.
                    for payload in eng.demoted_out:
                        self._demote_payload(payload, now)
                    eng.demoted_out.clear()
            elif eng.queued:
                # Admission produced nothing (transient page pressure, e.g.
                # a paused request's pinned pages): give the QUEUED requests
                # back to the central queue so another replica — or a later
                # round here — picks them up. drop_queued, not abort: an
                # abort would also surrender the paused requests parked on
                # this replica, releasing the very pages they pin.
                self._return_to_queue(r, eng.drop_queued(), requeued=False)
            round_s = max(round_s, work)
        return round_s

    # -- elasticity ----------------------------------------------------------------
    def _autoscale(self, now: float) -> None:
        # Elasticity governs DECODE capacity only: prefill-role replicas
        # are the static admission front end — never counted, launched, or
        # idle-terminated here.
        live = [r for r in self._replicas
                if r.state == "live" and r.role != "prefill"]
        provisioning = sum(1 for r in self._replicas
                           if r.state == "provisioning")
        idle = sum(1 for r in live if not r.engine.has_work)
        n = self.provisioner.launch_count(len(self._queue), idle,
                                          provisioning, len(live))
        for _ in range(n):
            self._launch(now)
        for r in live:
            if r.engine.has_work or r.jobs or r.idle_since is None:
                continue
            total = sum(1 for x in self._replicas
                        if x.state == "live" and x.role != "prefill")
            if self.provisioner.should_terminate(now - r.idle_since, total):
                self._retire_replica(r, terminated=True)

    def _launch(self, now: float, ready_now: bool = False) -> _Replica:
        engine = self._standby.pop() if self._standby \
            else self._bind_engine(self._engine_factory())
        zone = None
        if self.market is not None:
            zone = self.market.cheapest_zone(self.instance_type,
                                             now / 3600.0)[0]
        bid = self.scaling.bid_fraction * self._od_price()
        delay = 0.0 if ready_now else self.provisioner.provisioning_delay()
        r = _Replica(engine, zone, self.scaling.market, bid,
                     ready_at=now + delay)
        if delay == 0.0:
            r.state = "live"
            r.idle_since = now
        self._replicas.append(r)
        self.stats["launches"] += 1
        return r

    def _retire_replica(self, r: _Replica, *, terminated: bool) -> None:
        r.state = "retired"
        self._replicas.remove(r)
        self._standby.append(r.engine)
        # Replica ids never recur: stale health / fingerprint mirrors for a
        # retired id would only leak (and a parked engine's cache keeps
        # mutating if relaunched, so the mirror must restart anyway).
        self.router.forget(r.id)
        self._fp_tracker.forget(r.id)
        self._health_seen.pop(r.id, None)
        if terminated:
            self.stats["terminations"] += 1

    # -- billing / reporting ----------------------------------------------------
    def _accrue(self, now: float, tick: float) -> None:
        live = [r for r in self._replicas if r.state == "live"]
        for r in live:
            self.stats["cost_usd"] += \
                self._replica_price(r, now) * tick / 3600.0
            self.stats["replica_seconds"] += tick
        self.stats["peak_replicas"] = max(self.stats["peak_replicas"],
                                          len(live))
        if self.kv_store is not None:
            # Storage GB-hours accrue on the same virtual clock but stay a
            # separate meter: compute $/token and storage $/GB-hour answer
            # different sizing questions (the bench sums them).
            self.stats["storage_cost_usd"] += self.kv_store.accrue(now)

    def replicas(self, state: str = "live") -> list[_Replica]:
        return [r for r in self._replicas if r.state == state]

    def metrics(self) -> dict:
        """Serving report: throughput, deadline SLA, spend — the serving
        analogue of the Table VII-C makespan/cost/wait rows."""
        done = [j for j in self.jobs.values() if j.status is JobState.DONE]
        lat = sorted(j.finished_at - j.submitted_at for j in done)
        hits = sum(1 for j in done
                   if j.deadline is None or j.finished_at <= j.deadline)
        sim_s = self.clock.now() - self._start_time
        # Nearest-rank percentile: ceil(q*n)-1, not int(q*n) (which would
        # report the single worst latency as p95 for any n <= 20).
        def _pct(xs):
            return (lambda q: xs[min(max(math.ceil(q * len(xs)) - 1, 0),
                                     len(xs) - 1)]) \
                if xs else (lambda q: 0.0)
        pct = _pct(lat)
        # Interactive TTFT: queue wait until the first decode-slot
        # occupancy (modelled prefill excluded — identical across modes).
        inter = [j for j in self.jobs.values() if j.priority == 0]
        ittft = _pct(sorted(j.started_at - j.submitted_at
                            for j in inter
                            if j.status is JobState.DONE
                            and j.started_at is not None))
        idone = [j for j in inter if j.status is JobState.DONE]
        ihits = sum(1 for j in idone
                    if j.deadline is None or j.finished_at <= j.deadline)
        # Per-replica observability: the routing tier's decisions must be
        # auditable from the outside — who got the work, how full each
        # replica is, and whether affinity is actually landing cache hits.
        now = self.clock.now()
        per_replica = []
        for r in sorted(self._replicas, key=lambda x: x.id):
            if r.state == "retired":
                continue
            eng = r.engine
            per_replica.append({
                "replica": r.id, "role": r.role, "state": r.state,
                "live": eng.live, "queued": eng.queued,
                "open_slots": eng.open_slots,
                "occupancy": eng.live / eng.max_slots,
                "prefix_hit_rate": eng.prefix_hit_rate,
                "dispatched": r.dispatched,
                "health": self.router.health(r.id, now),
                "noticed": r.notice_deadline is not None,
            })
        health_counts = {"up": 0, "degraded": 0, "quarantined": 0}
        for row in per_replica:
            if row["state"] == "live":
                health_counts[row["health"]] += 1
        # Recovered TTFT: disturbance (notice/crash hit the job) to the
        # first decode-slot occupancy afterwards — the figure of merit the
        # fault_recovery bench compares across evacuation and requeue.
        disturbed = [j for j in self.jobs.values()
                     if j.disturbed_at is not None]
        rec = sorted(j.recovered_at - j.disturbed_at for j in disturbed
                     if j.recovered_at is not None)
        rpct = _pct(rec)
        ships = self.stats["page_ships"]
        return {
            "jobs": len(self.jobs), "completed": len(done),
            "shed": self.stats["shed"],
            "tokens": self.stats["tokens"],
            "sim_seconds": sim_s,
            "tok_per_sim_s": self.stats["tokens"] / sim_s if sim_s else 0.0,
            "cost_usd": self.stats["cost_usd"],
            "usd_per_1k_tokens": (self.stats["cost_usd"] * 1e3
                                  / max(self.stats["tokens"], 1)),
            "replica_seconds": self.stats["replica_seconds"],
            "peak_replicas": self.stats["peak_replicas"],
            "deadline_hit_rate": hits / len(done) if done else 0.0,
            "sla_rate": hits / len(self.jobs) if self.jobs else 0.0,
            "p50_latency_s": pct(0.50), "p95_latency_s": pct(0.95),
            "interactive_jobs": len(inter),
            "interactive_completed": len(idone),
            "interactive_sla_rate": ihits / len(inter) if inter else 0.0,
            "interactive_p50_ttft_s": ittft(0.50),
            "interactive_p99_ttft_s": ittft(0.99),
            "preemptions": self.stats["preemptions"],
            "resumes": self.stats["resumes"],
            "preempt_wait_s": self.stats["preempt_wait_s"],
            "revocations": self.stats["revocations"],
            "requeues": self.stats["requeues"],
            "notices": self.stats["notices"],
            "evacuations": self.stats["evacuations"],
            "evacuated_pages_bytes": self.stats["evacuated_pages_bytes"],
            "retries": self.stats["retries"],
            "backoff_wait_s": self.stats["backoff_wait_s"],
            "wasted_decode_tokens": self.stats["wasted_decode_tokens"],
            "faults_injected": self.stats["faults_injected"],
            "disturbed_jobs": len(disturbed),
            "recovered_jobs": len(rec),
            "recovered_ttft_mean_s": (sum(rec) / len(rec)) if rec else 0.0,
            "recovered_ttft_p99_s": rpct(0.99),
            "replica_health": health_counts,
            "fingerprint_tracker": dict(self._fp_tracker.stats),
            "launches": self.stats["launches"],
            "terminations": self.stats["terminations"],
            "routing_mode": self.router.mode,
            "routing": dict(self.router.stats),
            "queue_depth": len(self._queue),
            "page_ships": ships,
            "page_ship_bytes": self.stats["page_ship_bytes"],
            "page_ship_bytes_per_ship": (self.stats["page_ship_bytes"]
                                         / ships if ships else 0.0),
            "handoffs_in_flight": len(self._handoffs),
            "kv_demotions": self.stats["kv_demotions"],
            "kv_demoted_bytes": self.stats["kv_demoted_bytes"],
            "kv_restores": self.stats["kv_restores"],
            "kv_restore_fallbacks": self.stats["kv_restore_fallbacks"],
            "kv_budget_refusals": self.stats["kv_budget_refusals"],
            "restored_tokens": self.stats["restored_tokens"],
            "storage_cost_usd": self.stats["storage_cost_usd"],
            "restore_pending": sum(
                1 for j in self.jobs.values()
                if j.status is JobState.RESTORE_PENDING),
            "kv_host_bytes": (self.kv_store.host_bytes
                              if self.kv_store is not None else 0),
            "kv_object_bytes": (self.kv_store.object_bytes
                                if self.kv_store is not None else 0),
            "kv_store": (dict(self.kv_store.stats)
                         if self.kv_store is not None else None),
            "per_replica": per_replica,
            "slo_burn_rate": self._slo_burn_rate(),
            "telemetry_flushes": self.stats["telemetry_flushes"],
            "telemetry_writes": self.stats["telemetry_writes"],
            "telemetry_dropped": self.stats["telemetry_dropped"],
            "statestore_throttled": self.stats["statestore_throttled"],
        }

    def _slo_burn_rate(self) -> float:
        self.registry.collect()
        return self.registry.value("kotta_slo_burn_rate")
