from .engine import ContinuousBatchingEngine, ServeEngine, ServeResult
from .paging import PageAllocator, PrefixCache

__all__ = ["ServeEngine", "ContinuousBatchingEngine", "ServeResult",
           "PageAllocator", "PrefixCache"]
