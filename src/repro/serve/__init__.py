from .admission import (AdmissionError, AdmissionPolicy, CostBudgetExceeded,
                        DeadlineCostPolicy, DeadlineInfeasible, FCFSPolicy,
                        JobState, PreemptCandidate, RetryBudgetExhausted,
                        ServeJob, ServiceModel, StorageBudgetExceeded)
from .drafting import build_ngram_draft
from .engine import (ContinuousBatchingEngine, EngineRequest, ExportReason,
                     PausedRequest, ServeEngine, ServeResult, ShippedKV)
from .faults import FaultEvent, FaultInjector
from .gateway import KottaServeGateway
from .kv_store import PageResidency, RestoreTicket, Tier, TieredKVStore
from .paging import EvictionEvent, PageAllocator, PrefixCache, chain_hashes
from .loadgen import Arrival, TrafficConfig, generate_trace, run_open_loop
from .routing import (HEALTH_DEGRADED, HEALTH_QUARANTINED, HEALTH_UP,
                      FingerprintTracker, FleetRouter, ReplicaView,
                      RouteDecision)
from .telemetry import (LATENCY_BUCKETS_S, MetricsRegistry, RegistryDict,
                        parse_exposition)

__all__ = ["ServeEngine", "ContinuousBatchingEngine", "EngineRequest",
           "PausedRequest", "ServeResult", "ShippedKV", "PageAllocator",
           "PrefixCache", "chain_hashes", "FleetRouter", "ReplicaView",
           "RouteDecision", "FingerprintTracker", "HEALTH_UP",
           "HEALTH_DEGRADED", "HEALTH_QUARANTINED", "KottaServeGateway",
           "ServeJob", "JobState", "ServiceModel", "AdmissionPolicy",
           "FCFSPolicy", "DeadlineCostPolicy", "PreemptCandidate",
           "AdmissionError", "DeadlineInfeasible", "CostBudgetExceeded",
           "RetryBudgetExhausted", "FaultEvent", "FaultInjector",
           "build_ngram_draft", "MetricsRegistry", "RegistryDict",
           "parse_exposition", "LATENCY_BUCKETS_S", "TrafficConfig",
           "Arrival", "generate_trace", "run_open_loop", "ExportReason",
           "EvictionEvent", "PageResidency", "RestoreTicket", "Tier",
           "TieredKVStore", "StorageBudgetExceeded"]
