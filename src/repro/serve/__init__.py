from .engine import ContinuousBatchingEngine, ServeEngine, ServeResult

__all__ = ["ServeEngine", "ContinuousBatchingEngine", "ServeResult"]
