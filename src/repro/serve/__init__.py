from .admission import (AdmissionError, AdmissionPolicy, CostBudgetExceeded,
                        DeadlineCostPolicy, DeadlineInfeasible, FCFSPolicy,
                        JobState, PreemptCandidate, RetryBudgetExhausted,
                        ServeJob, ServiceModel)
from .drafting import build_ngram_draft
from .engine import (ContinuousBatchingEngine, EngineRequest, PausedRequest,
                     ServeEngine, ServeResult, ShippedKV)
from .faults import FaultEvent, FaultInjector
from .gateway import KottaServeGateway
from .paging import PageAllocator, PrefixCache, chain_hashes
from .routing import (HEALTH_DEGRADED, HEALTH_QUARANTINED, HEALTH_UP,
                      FingerprintTracker, FleetRouter, ReplicaView,
                      RouteDecision)

__all__ = ["ServeEngine", "ContinuousBatchingEngine", "EngineRequest",
           "PausedRequest", "ServeResult", "ShippedKV", "PageAllocator",
           "PrefixCache", "chain_hashes", "FleetRouter", "ReplicaView",
           "RouteDecision", "FingerprintTracker", "HEALTH_UP",
           "HEALTH_DEGRADED", "HEALTH_QUARANTINED", "KottaServeGateway",
           "ServeJob", "JobState", "ServiceModel", "AdmissionPolicy",
           "FCFSPolicy", "DeadlineCostPolicy", "PreemptCandidate",
           "AdmissionError", "DeadlineInfeasible", "CostBudgetExceeded",
           "RetryBudgetExhausted", "FaultEvent", "FaultInjector",
           "build_ngram_draft"]
