from .admission import (AdmissionError, AdmissionPolicy, CostBudgetExceeded,
                        DeadlineCostPolicy, DeadlineInfeasible, FCFSPolicy,
                        JobState, PreemptCandidate, ServeJob, ServiceModel)
from .drafting import build_ngram_draft
from .engine import (ContinuousBatchingEngine, EngineRequest, PausedRequest,
                     ServeEngine, ServeResult, ShippedKV)
from .gateway import KottaServeGateway
from .paging import PageAllocator, PrefixCache, chain_hashes
from .routing import FleetRouter, ReplicaView, RouteDecision

__all__ = ["ServeEngine", "ContinuousBatchingEngine", "EngineRequest",
           "PausedRequest", "ServeResult", "ShippedKV", "PageAllocator",
           "PrefixCache", "chain_hashes", "FleetRouter", "ReplicaView",
           "RouteDecision", "KottaServeGateway", "ServeJob", "JobState",
           "ServiceModel", "AdmissionPolicy", "FCFSPolicy",
           "DeadlineCostPolicy", "PreemptCandidate", "AdmissionError",
           "DeadlineInfeasible", "CostBudgetExceeded", "build_ngram_draft"]
