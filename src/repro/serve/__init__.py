from .admission import (AdmissionError, AdmissionPolicy, CostBudgetExceeded,
                        DeadlineCostPolicy, DeadlineInfeasible, FCFSPolicy,
                        JobState, PreemptCandidate, ServeJob, ServiceModel)
from .drafting import build_ngram_draft
from .engine import (ContinuousBatchingEngine, EngineRequest, PausedRequest,
                     ServeEngine, ServeResult)
from .gateway import KottaServeGateway
from .paging import PageAllocator, PrefixCache

__all__ = ["ServeEngine", "ContinuousBatchingEngine", "EngineRequest",
           "PausedRequest", "ServeResult", "PageAllocator", "PrefixCache",
           "KottaServeGateway", "ServeJob", "JobState", "ServiceModel",
           "AdmissionPolicy", "FCFSPolicy", "DeadlineCostPolicy",
           "PreemptCandidate", "AdmissionError", "DeadlineInfeasible",
           "CostBudgetExceeded", "build_ngram_draft"]
