from .admission import (AdmissionError, AdmissionPolicy, CostBudgetExceeded,
                        DeadlineCostPolicy, DeadlineInfeasible, FCFSPolicy,
                        JobState, ServeJob, ServiceModel)
from .engine import (ContinuousBatchingEngine, EngineRequest, ServeEngine,
                     ServeResult)
from .gateway import KottaServeGateway
from .paging import PageAllocator, PrefixCache

__all__ = ["ServeEngine", "ContinuousBatchingEngine", "EngineRequest",
           "ServeResult", "PageAllocator", "PrefixCache",
           "KottaServeGateway", "ServeJob", "JobState", "ServiceModel",
           "AdmissionPolicy", "FCFSPolicy", "DeadlineCostPolicy",
           "AdmissionError", "DeadlineInfeasible", "CostBudgetExceeded"]
