"""Data pipeline: corpus registration, sharded deterministic loading, staging.

Mirrors the paper's data path: corpora live in the tiered object store under
``dataset/<name>/shard-NNN`` with RBAC on the ``dataset/...`` resource names;
jobs *stage* shards (via ``SecureStorage.get``, i.e. under the submitting
user's assumed role) before compute touches them; archived shards trigger the
restore queue.

Determinism contract: ``TokenLoader.batch_at(step)`` is a pure function of
(corpus bytes, seed, dp_rank, dp_size, step) — this is what makes
checkpoint-restart *bitwise* reproducible and elastic rescales well-defined
(tested in tests/test_trainer.py).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import numpy as np

from repro.core.lifecycle import ObjectStore, Tier


class SyntheticCorpus:
    """Deterministic synthetic token corpus registered in the object store."""

    @staticmethod
    def build(store: ObjectStore, name: str, *, num_shards: int = 4,
              tokens_per_shard: int = 65_536, vocab_size: int = 50_304,
              seed: int = 0, owner: str = "system",
              tier: Tier = Tier.STD) -> list[str]:
        keys = []
        for i in range(num_shards):
            rng = np.random.default_rng((seed, i))
            # Zipf-ish marginals so the loss has structure to learn.
            ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
            probs = 1.0 / ranks
            probs /= probs.sum()
            toks = rng.choice(vocab_size, size=tokens_per_shard,
                              p=probs).astype(np.int32)
            key = f"dataset/{name}/shard-{i:03d}"
            store.put(key, toks.tobytes(), owner=owner, tier=tier)
            keys.append(key)
        return keys


class TokenLoader:
    """Sharded, deterministic, step-indexed next-token-prediction loader.

    ``reader`` is any ``key -> bytes`` callable — typically
    ``lambda k: secure_storage.get(user_token, k)`` so every read is
    authorized + audited, or ``store.get`` for internal runs.
    """

    def __init__(self, reader: Callable[[str], bytes], keys: list[str],
                 *, batch_size: int, seq_len: int,
                 dp_rank: int = 0, dp_size: int = 1, seed: int = 0):
        if batch_size % dp_size:
            raise ValueError(f"global batch {batch_size} % dp {dp_size} != 0")
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.dp_rank = dp_rank
        self.dp_size = dp_size
        self.seed = seed
        # Stage all shards once (the worker has already assumed the user role).
        chunks = [np.frombuffer(reader(k), dtype=np.int32) for k in sorted(keys)]
        self._tokens = np.concatenate(chunks)
        self._window = seq_len + 1
        self.num_windows = len(self._tokens) // self._window
        if self.num_windows < batch_size:
            raise ValueError("corpus too small for one global batch")
        self.windows_per_epoch = (self.num_windows // batch_size) * batch_size
        self._perm_cache: dict[int, np.ndarray] = {}

    def _perm(self, epoch: int) -> np.ndarray:
        if epoch not in self._perm_cache:
            rng = np.random.default_rng((self.seed, epoch))
            self._perm_cache[epoch] = rng.permutation(self.num_windows)
            if len(self._perm_cache) > 4:
                self._perm_cache.pop(min(self._perm_cache))
        return self._perm_cache[epoch]

    def batch_at(self, step: int) -> dict:
        """This rank's slice of global batch ``step`` (pure function)."""
        steps_per_epoch = self.windows_per_epoch // self.batch_size
        epoch, within = divmod(step, steps_per_epoch)
        perm = self._perm(epoch)
        lo = within * self.batch_size
        idx = perm[lo:lo + self.batch_size]
        local = idx[self.dp_rank::self.dp_size]
        rows = np.stack([
            self._tokens[i * self._window:(i + 1) * self._window]
            for i in local])
        return {"tokens": rows[:, :-1].copy(), "labels": rows[:, 1:].copy()}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchLoader:
    """Background-thread prefetch wrapper (overlap host data with compute)."""

    def __init__(self, loader: TokenLoader, start_step: int = 0, depth: int = 2):
        self.loader = loader
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put(self.loader.batch_at(step), timeout=0.1)
                step += 1
            except queue.Full:
                continue

    def __next__(self):
        return self._q.get()

    def __iter__(self):
        return self

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
