from .pipeline import PrefetchLoader, SyntheticCorpus, TokenLoader

__all__ = ["PrefetchLoader", "SyntheticCorpus", "TokenLoader"]
