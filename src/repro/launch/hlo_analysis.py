"""Post-SPMD HLO analysis for the roofline report.

``compiled.cost_analysis()`` on the CPU backend does not scale while-loop
bodies by their trip counts, so a scanned 35-layer model would be accounted
as one layer. This module parses ``compiled.as_text()`` directly:

- builds the computation call graph (``body=``/``condition=``/``calls=``/
  ``to_apply=``) and recovers **while trip counts** from the loop-condition
  ``constant(N)`` compare;
- counts **matmul FLOPs** from ``dot`` instructions (2·|result|·|contract|),
  scaled by the enclosing computation's execution multiplier;
- counts **memory traffic** as result bytes of executed *HBM-resident* ops
  (dots, fusions, slices, copies, reduces, collectives — a fusion-optimistic
  convention: raw elementwise ops are assumed fused into their consumers, as
  the TPU backend does), write-once/read-once, documented in EXPERIMENTS.md;
- splits traffic into kernel-eligible regions (``flash_tile``/``ssd_tile``/
  ``mlstm_tile`` named_scopes) vs the rest, so the roofline can model the
  Pallas-fused variant where those tiles never leave VMEM;
- counts **collective wire bytes** per op with ring-algorithm conventions:
  all-gather/all-to-all: |result|·(g-1)/g; all-reduce: 2·|result|·(g-1)/g;
  reduce-scatter: |result|·(g-1); collective-permute: |result|.

All numbers are per-device (the SPMD module is the per-device program).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_CALL_ATTR_RE = re.compile(r"(?:body|condition|calls|to_apply)=%?([\w\.\-]+)")
_WHILE_RE = re.compile(r"while\(.*?\), condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")


def _shape_info(text: str):
    """First shape token in ``text`` -> (elements, bytes). Tuples: sum parts."""
    total_elems = total_bytes = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        elems = 1
        if dims:
            for d in dims.split(","):
                elems *= int(d)
        total_elems += elems
        total_bytes += elems * _DTYPE_BYTES[dtype]
    return total_elems, total_bytes


def _result_shape(rhs: str):
    """Shape of the instruction's result = first shape token(s) before op name."""
    # rhs looks like: "f32[16,64]{1,0} dot(%a, %b), ..." or a tuple
    m = re.match(r"^(\(?[a-z0-9]+\[[^\)]*?\)?)\s+[\w\-]+\(", rhs)
    if m:
        return _shape_info(m.group(1))
    # fall back: first shape token
    return _shape_info(rhs.split("(")[0])


@dataclass
class Computation:
    name: str
    lines: list[str] = field(default_factory=list)
    is_entry: bool = False
    is_fusion_like: bool = False  # reached via calls=/to_apply=


#: scopes whose intermediates a Pallas kernel keeps in VMEM
KERNEL_SCOPES = ("flash_tile", "ssd_tile", "mlstm_tile")

#: ops that necessarily touch HBM even on a well-fused backend
_HBM_OPS = frozenset({
    "dot", "fusion", "custom-call", "convolution", "copy",
    "dynamic-slice", "dynamic-update-slice", "transpose",
    "reduce", "reduce-window", "gather", "scatter",
    "concatenate", "pad", "sort", "cholesky", "rng",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
})

#: "<shape>{layout} opcode(" — the opcode position in an instruction rhs
_OPCODE_RE = re.compile(
    r"^\(?[a-z0-9]+\[[^\]]*\][^\s]*(?:, [a-z0-9]+\[[^\]]*\][^\s]*)*\)?\s+([\w\-]+)\(")


@dataclass
class HloReport:
    dot_flops: float = 0.0
    kernel_region_flops: float = 0.0
    bytes_written: float = 0.0
    kernel_region_bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_by_op: dict = field(default_factory=dict)
    collective_count: int = 0
    while_trips: dict = field(default_factory=dict)
    dot_count: int = 0

    @property
    def bytes_accessed(self) -> float:
        return 2.0 * self.bytes_written  # write-once / read-once convention

    @property
    def bytes_accessed_fused(self) -> float:
        """Traffic when kernel-eligible tile regions stay in VMEM."""
        return 2.0 * (self.bytes_written - self.kernel_region_bytes)


def _opcode(rhs: str) -> str:
    m = _OPCODE_RE.match(rhs)
    return m.group(1) if m else ""


def _is_kernel_tile_dot(rhs: str) -> bool:
    """Attention/SSD/mLSTM tile dot: batched, f32 accumulator, rank >= 3."""
    if _opcode(rhs) != "dot" or "lhs_batch_dims={}" in rhs:
        return False
    bm = re.search(r"lhs_batch_dims=\{([0-9,]*)\}", rhs)
    if not bm or not bm.group(1):
        return False
    sm = _SHAPE_RE.match(rhs)
    if not sm or sm.group(1) != "f32":
        return False
    dims = sm.group(2).split(",") if sm.group(2) else []
    elems = 1
    for d in dims:
        elems *= int(d)
    return len(dims) >= 3 and elems * 4 >= 1 << 20


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        header = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{", line)
        if header and not line.startswith(" "):
            cur = Computation(header.group(2), is_entry=bool(header.group(1)))
            comps[cur.name] = cur
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None and stripped:
            cur.lines.append(stripped)
    return comps


def _while_trip(cond: Computation) -> int:
    consts = [int(c) for ln in cond.lines for c in _CONST_RE.findall(ln)]
    consts = [c for c in consts if c > 0]
    return max(consts) if consts else 1


def _multipliers(comps: dict[str, Computation]) -> dict[str, float]:
    """Execution-count multiplier per computation via call-graph traversal."""
    mult: dict[str, float] = defaultdict(float)
    entries = [c for c in comps.values() if c.is_entry]
    stack = [(c.name, 1.0) for c in entries]
    seen_edges = set()
    while stack:
        name, m = stack.pop()
        mult[name] += m
        comp = comps.get(name)
        if comp is None:
            continue
        for ln in comp.lines:
            wm = _WHILE_RE.search(ln)
            if wm:
                cond_name, body_name = wm.group(1), wm.group(2)
                trips = _while_trip(comps[cond_name]) if cond_name in comps else 1
                edge = (name, body_name, ln[:60])
                if edge not in seen_edges:
                    seen_edges.add(edge)
                    stack.append((body_name, m * trips))
                continue
            for callee in _CALL_ATTR_RE.findall(ln):
                if callee in comps and "while(" not in ln:
                    edge = (name, callee, ln[:60])
                    if edge not in seen_edges:
                        seen_edges.add(edge)
                        stack.append((callee, m))
    return dict(mult)


_SKIP_BYTES_OPS = ("parameter(", "constant(", "get-tuple-element(", "tuple(",
                   "bitcast(", "after-all(", "iota(")


def analyze_hlo(hlo: str) -> HloReport:
    comps = parse_computations(hlo)
    mult = _multipliers(comps)
    rep = HloReport()
    rep.collective_by_op = {op: 0.0 for op in COLLECTIVE_OPS}

    # Which computations count for byte traffic: entry + while bodies/conds.
    body_like = set()
    for c in comps.values():
        for ln in c.lines:
            wm = _WHILE_RE.search(ln)
            if wm:
                body_like.add(wm.group(1))
                body_like.add(wm.group(2))
                rep.while_trips[wm.group(2)] = (
                    _while_trip(comps[wm.group(1)]) if wm.group(1) in comps else 1)

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m <= 0:
            continue
        count_bytes = comp.is_entry or comp.name in body_like
        # symbol table: instruction name -> dims of its result
        symbols: dict[str, list[int]] = {}
        for ln in comp.lines:
            dm = _DEF_RE.match(ln)
            if not dm:
                continue
            name, rhs = dm.group(1), dm.group(2)
            sm = _SHAPE_RE.search(rhs.split("(")[0] + "(")
            if sm and sm.group(2):
                symbols[name] = [int(d) for d in sm.group(2).split(",")]
            elif sm:
                symbols[name] = []
        for ln in comp.lines:
            dm = _DEF_RE.match(ln)
            if not dm:
                continue
            rhs = dm.group(2)
            opcode = _opcode(rhs)
            # ---- dot flops (anywhere, incl. fusion computations) ----------
            if opcode == "dot":
                res_elems, _res_b = _result_shape(rhs)
                contract = 1
                cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
                lhs_dims = None
                # operand shapes: inline in long format, else via symbol table
                operand_str = rhs.split("dot(", 1)[1].split(")")[0]
                inline = _SHAPE_RE.findall(operand_str)
                if inline and inline[0][1]:
                    lhs_dims = [int(d) for d in inline[0][1].split(",")]
                else:
                    names = re.findall(r"%([\w\.\-]+)", operand_str)
                    if names and names[0] in symbols:
                        lhs_dims = symbols[names[0]]
                if cm and lhs_dims:
                    for ci in cm.group(1).split(","):
                        if ci:
                            contract *= lhs_dims[int(ci)]
                rep.dot_flops += 2.0 * res_elems * contract * m
                if _is_kernel_tile_dot(rhs):
                    rep.kernel_region_flops += 2.0 * res_elems * contract * m
                rep.dot_count += 1
            # ---- collectives ------------------------------------------------
            for op in COLLECTIVE_OPS:
                if opcode == op:
                    res_elems, res_bytes = _result_shape(rhs)
                    gm = _GROUPS_RE.search(rhs)
                    g = int(gm.group(2)) if gm else 2
                    g = max(g, 2)
                    if op == "all-gather":
                        wire = res_bytes * (g - 1) / g
                    elif op == "all-reduce":
                        wire = 2.0 * res_bytes * (g - 1) / g
                    elif op == "reduce-scatter":
                        wire = res_bytes * (g - 1)
                    elif op == "all-to-all":
                        wire = res_bytes * (g - 1) / g
                    else:
                        wire = res_bytes
                    rep.collective_wire_bytes += wire * m
                    rep.collective_by_op[op] += wire * m
                    rep.collective_count += int(m) if m >= 1 else 1
                    break
            # ---- byte traffic (fusion-optimistic: HBM-resident ops only) ----
            if count_bytes and opcode in _HBM_OPS:
                _, res_bytes = _result_shape(rhs)
                eff_m = m
                # dynamic-update-slice (incl. DUS-rooted fusions) writes one
                # slice per invocation, aliasing the rest: inside a while body
                # of T trips, the full buffer is written once per *caller*
                # execution, not once per trip.
                if "dynamic-update-slice" in dm.group(1) \
                        or opcode == "dynamic-update-slice":
                    eff_m = m / max(rep.while_trips.get(comp.name, 1), 1)
                rep.bytes_written += res_bytes * eff_m
                # Tile intermediates stay in VMEM under the Pallas kernels;
                # streaming reads (dynamic-slice of K/V blocks) remain HBM
                # traffic. Two detectors: named_scope metadata (elementwise/
                # fusion ops keep it) and batch-dim f32 tile dots (XLA strips
                # their metadata, but the shape signature is unambiguous —
                # projection/expert GEMMs have no dot batch dims).
                in_scope = (any(s in rhs for s in KERNEL_SCOPES)
                            and opcode in ("dot", "fusion"))
                if in_scope or _is_kernel_tile_dot(rhs):
                    rep.kernel_region_bytes += res_bytes * m

    return rep
