"""Serving launcher: greedy decoding for a (reduced) architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --max-new 16

``--engine continuous`` (default for attention families) decodes over the
shared paged KV cache with continuous batching; ``--engine static`` uses the
legacy padded-batch engine (and is the only choice for recurrent-state
families, whose per-slot states are dense).

Gateway mode
------------
``--gateway`` routes every request through the :class:`KottaServeGateway`
instead of calling the engine directly — each prompt becomes a secured,
scheduled Kotta job:

- ``--tenants N`` registers N tenant principals (``tenant0..``), each with
  its own ``kotta-serve-*`` role; requests round-robin across them and the
  KV prefix cache is namespaced per (tenant, data-zone), so identical
  prompts from different tenants never share cached pages. Every
  authorize/deny lands in the audit log (a summary is printed).
- ``--deadline-s S`` gives each request a deadline; admission is
  earliest-deadline-first within priority class, and requests that cannot
  meet their deadline at current occupancy are shed with a typed rejection
  (reported, not hung).
- ``--replicas R`` sizes a static on-demand replica fleet (elastic spot
  autoscaling is exercised in ``benchmarks/gateway_bench.py``).
- ``--routing affinity|least-loaded|blind`` picks the fleet placement
  policy (prefix-affinity over replica radix fingerprints is the
  default); passing it explicitly also gives every tenant a hot shared
  prefix so the affinity/hit-rate numbers have something to show.
- ``--disaggregate N_PREFILL:N_DECODE`` splits the fleet into
  prefill-specialized and decode-specialized replicas: admission prefill
  runs on a prefill replica and the finished KV pages ship to a decode
  replica per request (the summary prints ships and bytes/ship).
- ``--interactive-burst`` (implies ``--gateway``) demos deadline-aware
  decode preemption: long batch-class jobs occupy every decode slot, then a
  burst of tight-deadline interactive requests arrives. Each infeasible
  interactive request pauses the latest-deadline batch slot (KV pages
  pinned, parked host-side), starts immediately, and the victim resumes
  with zero re-prefill — the summary prints preemptions/resumes, the added
  batch wait, and interactive p99 TTFT. Preemption follows the config knob
  ``enable_decode_preemption`` (pass ``--no-preempt`` to watch the same
  burst get shed instead).
- ``--saturation`` demos the observability plane: open-loop Poisson traffic
  with diurnal modulation from a Zipf-ranked user population drives the
  fleet while telemetry (audit records, terminal job states, periodic
  metric snapshots) streams into a write-capped ``StateStore``; the
  summary prints SLO burn, flush/throttle counters and store contents.
- ``--metrics-out PATH`` (any gateway mode) writes the run's final
  ``MetricsRegistry`` state as Prometheus text exposition to ``PATH``.
- ``--chaos-seed SEED`` (implies ``--gateway``) demos the failure plane: a
  seeded-random fault storm (crashes, revocation notices answered with
  notice-window KV evacuation, stragglers, heartbeat loss) plays out over
  the fleet while jobs run. Disturbed jobs either migrate losslessly or
  requeue with capped backoff; the summary prints fault/evacuation/retry
  counters and recovered TTFT, and every job ends DONE or typed-SHED.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --gateway \\
        --tenants 2 --deadline-s 120 --batch 6
    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b \\
        --interactive-burst
"""
import argparse

import jax

from repro.configs import ARCH_NAMES, get_reduced_config
from repro.models import get_family
from repro.models.params import init_params
from repro.serve import ContinuousBatchingEngine, ServeEngine


def _demo_prompts(cfg, batch: int) -> list[list[int]]:
    rng = jax.random.PRNGKey(1)
    return [[int(t) for t in jax.random.randint(
        jax.random.fold_in(rng, i), (3 + i % 4,), 0, cfg.vocab_size)]
        for i in range(batch)]


def _run_gateway(cfg, params, args):
    from repro.core.elastic import ScalingPolicy
    from repro.core.security import PolicyEngine, provision_tenant
    from repro.core.clock import VirtualClock
    from repro.serve import (DeadlineCostPolicy, JobState, KottaServeGateway,
                             ServiceModel)

    sec = PolicyEngine(clock=VirtualClock())
    tokens = [provision_tenant(sec, f"tenant{i}", f"pw-tenant{i}",
                               data_zones=("public",))
              for i in range(args.tenants)]

    # The policy estimates with the same model the gateway bills with; the
    # config knob decides whether infeasible interactive requests may pause
    # a batch-class slot instead of being shed.
    svc = ServiceModel()
    routing = (args.routing or "affinity").replace("-", "_")

    def factory(**kw):
        kw.setdefault("max_len", args.max_len)
        return lambda: ContinuousBatchingEngine(
            cfg, params, enable_spec_decode=args.spec,
            kv_cache_dtype=args.kv_dtype,
            spec_adaptive_k=args.adaptive_k or None, **kw)

    if args.disaggregate:
        n_prefill, n_decode = args.disaggregate
        gw = KottaServeGateway(
            factory(role="decode"), sec,
            scaling=ScalingPolicy.none(n_decode, market="on_demand"),
            service_model=svc, routing=routing,
            prefill_replicas=n_prefill,
            prefill_engine_factory=factory(role="prefill"),
            admission=DeadlineCostPolicy(
                model=svc, preempt=cfg.enable_decode_preemption))
        fleet_desc = f"{n_prefill} prefill + {n_decode} decode replica(s)"
    else:
        gw = KottaServeGateway(
            factory(), sec,
            scaling=ScalingPolicy.none(args.replicas, market="on_demand"),
            service_model=svc, routing=routing,
            admission=DeadlineCostPolicy(
                model=svc, preempt=cfg.enable_decode_preemption))
        fleet_desc = f"{args.replicas} static replica(s)"
    prompts = _demo_prompts(cfg, args.batch)
    if args.routing is not None or args.disaggregate:
        # Give each tenant a hot 2-page prefix so the routing/shipping
        # demo has cache residency to exploit (and to show in the stats).
        ps = cfg.page_size
        prompts = [[(17 + 31 * (i % len(tokens)) + j) % cfg.vocab_size
                    for j in range(2 * ps)] + p
                   for i, p in enumerate(prompts)]
    rids = []
    if args.routing is not None or args.disaggregate:
        # Two waves: the first warms each tenant's prefix onto a replica,
        # then the router places the rest against live fingerprints —
        # submitted all at once, nothing would have residency to hit.
        for wave in (prompts[:len(tokens)], prompts[len(tokens):]):
            rids += [gw.submit(tokens[(len(rids) + i) % len(tokens)], p,
                               max_new=args.max_new,
                               deadline_s=args.deadline_s,
                               data_zone="public")
                     for i, p in enumerate(wave)]
            gw.drain()
    else:
        rids = [gw.submit(tokens[i % len(tokens)], p, max_new=args.max_new,
                          deadline_s=args.deadline_s, data_zone="public")
                for i, p in enumerate(prompts)]
        gw.drain()
    print(f"engine: gateway ({fleet_desc}, "
          f"{args.tenants} tenant(s), routing={routing})")
    for i, (p, rid) in enumerate(zip(prompts, rids)):
        job = gw.jobs[rid]
        if job.status is JobState.DONE:
            print(f"[{job.tenant}] {p} -> {job.tokens}")
        else:
            print(f"[{job.tenant}] {p} -> SHED ({job.error.reason}: "
                  f"{job.error})")
    m = gw.metrics()
    audit = sec.audit
    print(f"deadline hit rate {m['deadline_hit_rate']:.2f}   shed "
          f"{m['shed']}   audit: {len(audit.records(decision='allow'))} "
          f"allows / {len(audit.records(decision='deny'))} denies")
    if args.routing is not None or args.disaggregate:
        print(f"routing decisions: {m['routing']}")
        if m["page_ships"]:
            print(f"page shipping: {m['page_ships']} ships, "
                  f"{m['page_ship_bytes_per_ship'] / 1e6:.2f} MB/ship")
        for e in m["per_replica"]:
            print(f"  replica {e['replica']} ({e['role']}): dispatched "
                  f"{e['dispatched']}, prefix hit rate "
                  f"{e['prefix_hit_rate']:.1%}")
    return gw


def _run_interactive_burst(cfg, params, args):
    """Demo: decode preemption under a tight-deadline interactive burst."""
    from repro.core.elastic import ScalingPolicy
    from repro.core.security import PolicyEngine, provision_tenant
    from repro.core.clock import VirtualClock
    from repro.serve import (ContinuousBatchingEngine, DeadlineCostPolicy,
                             JobState, KottaServeGateway, ServiceModel)

    preempt_on = cfg.enable_decode_preemption and not args.no_preempt
    sec = PolicyEngine(clock=VirtualClock())
    tok = provision_tenant(sec, "tenant0", "pw-tenant0",
                           data_zones=("public",))
    svc = ServiceModel()
    slots = 4
    gw = KottaServeGateway(
        lambda: ContinuousBatchingEngine(
            cfg, params, max_len=args.max_len, max_slots=slots,
            num_pages=2 * slots * (args.max_len // cfg.page_size),
            decode_chunk=2, kv_cache_dtype=args.kv_dtype),
        sec, scaling=ScalingPolicy.none(args.replicas, market="on_demand"),
        service_model=svc,
        admission=DeadlineCostPolicy(model=svc, preempt=preempt_on))
    rng = jax.random.PRNGKey(2)
    batch_rids = [gw.submit(
        tok, [int(t) for t in jax.random.randint(
            jax.random.fold_in(rng, i), (8,), 0, cfg.vocab_size)],
        max_new=32, deadline_s=3600.0, priority=1, data_zone="public")
        for i in range(slots)]
    # Let the batch occupy every slot, then fire the interactive burst.
    for _ in range(10_000):
        if any(l.emitted > 0 for r in gw.replicas()
               for l in r.engine._live.values()):
            break
        gw.step()
    else:
        raise SystemExit("interactive-burst demo: batch jobs never started "
                         "decoding (no live replica?)")
    inter_rids = [gw.submit(
        tok, [int(t) for t in jax.random.randint(
            jax.random.fold_in(rng, 100 + i), (6,), 0, cfg.vocab_size)],
        max_new=4, deadline_s=0.5, priority=0, data_zone="public")
        for i in range(3)]
    gw.drain()
    m = gw.metrics()
    print(f"engine: gateway interactive-burst demo (preemption "
          f"{'ON' if preempt_on else 'OFF'}; {slots} slots, "
          f"{len(batch_rids)} batch jobs, {len(inter_rids)} interactive)")
    for rid in inter_rids:
        job = gw.jobs[rid]
        if job.status is JobState.DONE:
            print(f"  interactive job {rid}: DONE ttft="
                  f"{job.started_at - job.submitted_at:.2f}s -> {job.tokens}")
        else:
            print(f"  interactive job {rid}: SHED ({job.error.reason})")
    print(f"preemptions {m['preemptions']}   resumes {m['resumes']}   "
          f"added batch wait {m['preempt_wait_s']:.2f}s   interactive p99 "
          f"TTFT {m['interactive_p99_ttft_s']:.2f}s   deadline hit rate "
          f"{m['deadline_hit_rate']:.2f}   shed {m['shed']}")
    audit = sec.audit.records()
    print(f"audit: {len([r for r in audit if r.action == 'serve:Preempt'])} "
          f"preempt / {len([r for r in audit if r.action == 'serve:Resume'])}"
          f" resume records")
    return gw


def _run_chaos(cfg, params, args):
    """Demo: a seeded fault storm over the fleet — crashes, revocation
    notices (KV evacuation), stragglers, heartbeat loss — with every job
    finishing or shedding with a typed error."""
    from collections import Counter

    from repro.core.clock import VirtualClock
    from repro.core.elastic import ProvisioningModel, ScalingPolicy
    from repro.core.security import PolicyEngine, provision_tenant
    from repro.serve import (ContinuousBatchingEngine, FaultInjector,
                             JobState, KottaServeGateway, ServiceModel)

    sec = PolicyEngine(clock=VirtualClock())
    tok = provision_tenant(sec, "tenant0", "pw-tenant0",
                           data_zones=("public",))
    horizon = 8.0
    inj = FaultInjector.random(
        args.chaos_seed, horizon, crash_rate_h=900.0, revoke_rate_h=1800.0,
        straggler_rate_h=1800.0, heartbeat_loss_rate_h=900.0,
        notice_s=0.6, duration_s=(0.5, 2.0), magnitude=(2.0, 6.0),
        max_targets=4)
    gw = KottaServeGateway(
        lambda: ContinuousBatchingEngine(cfg, params, max_len=args.max_len,
                                         decode_chunk=2,
                                         kv_cache_dtype=args.kv_dtype),
        sec,
        scaling=ScalingPolicy.none(max(2, args.replicas),
                                   market="on_demand"),
        provisioning=ProvisioningModel(base_delay_s=0.5, jitter_s=0.0,
                                       volatility_prob=0.0),
        service_model=ServiceModel(), retry_budget=8, backoff_base_s=0.5,
        fault_injector=inj)
    prompts = _demo_prompts(cfg, args.batch)
    rids = [gw.submit(tok, p, max_new=args.max_new, data_zone="public")
            for p in prompts]
    gw.drain(max_rounds=100_000)
    while gw.clock.now() < horizon + 1.0:   # let late-scheduled faults land
        gw.step()
    print(f"engine: gateway chaos demo (seed {args.chaos_seed}, "
          f"{inj.pending} pending / {len(inj.fired)} fired / "
          f"{len(inj.skipped)} skipped faults: "
          f"{dict(Counter(e.kind for e in inj.fired))})")
    for rid in rids:
        job = gw.jobs[rid]
        if job.status is JobState.DONE:
            note = (f" ({job.evacuations} evac, {job.retries} retries)"
                    if job.disturbed_at is not None else "")
            print(f"  job {rid}: DONE{note} -> {job.tokens}")
        else:
            print(f"  job {rid}: SHED ({job.error.reason})")
    m = gw.metrics()
    print(f"notices {m['notices']}   evacuations {m['evacuations']} "
          f"({m['evacuated_pages_bytes'] / 1e6:.2f} MB)   requeues "
          f"{m['requeues']}   retries {m['retries']}   wasted decode "
          f"tokens {m['wasted_decode_tokens']}")
    if m["recovered_jobs"]:
        print(f"recovered TTFT mean {m['recovered_ttft_mean_s']:.2f}s over "
              f"{m['recovered_jobs']} disturbed job(s)   replica health "
              f"{m['replica_health']}")
    return gw


def _run_saturation(cfg, params, args):
    """Demo: open-loop Poisson/diurnal traffic from a Zipf-ranked user
    population, telemetry (audit + job records + metric snapshots)
    streaming into a write-capped StateStore while the fleet serves."""
    from repro.core.clock import VirtualClock
    from repro.core.elastic import ScalingPolicy
    from repro.core.scheduler import StateStore
    from repro.core.security import PolicyEngine, provision_tenant
    from repro.serve import (ContinuousBatchingEngine, DeadlineCostPolicy,
                             KottaServeGateway, ServiceModel, TrafficConfig,
                             generate_trace, run_open_loop)
    from repro.serve.loadgen import offered_load

    sec = PolicyEngine(clock=VirtualClock())
    tokens = [provision_tenant(sec, f"tenant{i}", f"pw-tenant{i}",
                               data_zones=("public",))
              for i in range(args.tenants)]
    svc = ServiceModel()
    store = StateStore(clock=sec.clock, write_capacity=50.0)
    gw = KottaServeGateway(
        lambda: ContinuousBatchingEngine(cfg, params, max_len=args.max_len,
                                         kv_cache_dtype=args.kv_dtype),
        sec, scaling=ScalingPolicy.none(args.replicas, market="on_demand"),
        service_model=svc, admission=DeadlineCostPolicy(model=svc),
        idle_tick_s=0.05, telemetry_store=store, telemetry_flush_s=2.0)
    duration_s = 10.0
    tc = TrafficConfig(
        duration_s=duration_s, base_rate_rps=8.0, diurnal_amplitude=0.5,
        diurnal_period_s=duration_s, tenants=args.tenants,
        vocab_size=cfg.vocab_size,
        interactive_max_new=min(args.max_new, 8),
        batch_max_new=min(args.max_new, 8))
    trace = generate_trace(tc)
    rounds = run_open_loop(gw, tokens, trace)
    gw.flush_telemetry()
    m = gw.metrics()
    print(f"engine: gateway saturation demo ({args.replicas} replica(s), "
          f"{args.tenants} tenant(s), open loop "
          f"{offered_load(trace, tc):.1f} req/s offered x {duration_s:.0f}s,"
          f" {rounds} rounds)")
    print(f"arrivals {len(trace)}   completed {m['completed']}   shed "
          f"{m['shed']}   sla rate {m['sla_rate']:.3f}   p95 latency "
          f"{m['p95_latency_s']:.2f}s   SLO burn {m['slo_burn_rate']:.2f}")
    print(f"telemetry: {m['telemetry_flushes']} flushes, "
          f"{m['telemetry_writes']} StateStore writes "
          f"({m['statestore_throttled']} throttled, "
          f"{m['telemetry_dropped']} dropped), "
          f"{len(store.scan('servejob/'))} job records, "
          f"{len(store.scan('audit/'))} audit records, "
          f"{len(store.scan('metrics/'))} metric snapshots")
    print(f"registry: {len(gw.registry.families())} metric families")
    return gw


def _disaggregate_spec(spec: str) -> tuple[int, int]:
    try:
        n_prefill, n_decode = (int(x) for x in spec.split(":"))
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"want N_PREFILL:N_DECODE, got {spec!r}")
    if n_prefill < 1 or n_decode < 1:
        raise argparse.ArgumentTypeError(
            "need at least one prefill and one decode replica")
    return n_prefill, n_decode


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES), default="yi-6b")
    ap.add_argument("--engine", choices=("continuous", "static", "auto"),
                    default="auto")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--spec", action="store_true",
                    help="self-speculative decode (n-gram drafts verified "
                         "in one multi-query paged pass; greedy outputs "
                         "are unchanged)")
    ap.add_argument("--kv-dtype", choices=("f32", "int8"), default=None,
                    help="paged KV pool layout (default: config "
                         "kv_cache_dtype). int8 stores KV pages quantized "
                         "with per-row scales — ~4*hd/(hd+4)x the "
                         "slot-token capacity at a fixed pool budget; "
                         "greedy outputs are unchanged")
    ap.add_argument("--adaptive-k", action="store_true",
                    help="with --spec: per-slot adaptive speculative "
                         "window — each slot's accept-rate EMA shrinks/"
                         "grows its draft window within [1, spec_tokens]")
    ap.add_argument("--gateway", action="store_true",
                    help="serve through the KottaServeGateway: per-tenant "
                         "authorization + audit, tenant-scoped prefix "
                         "cache, deadline/cost-aware admission")
    ap.add_argument("--tenants", type=int, default=2,
                    help="gateway: tenant principals to register")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="gateway: per-request deadline (EDF admission; "
                         "infeasible requests are shed, typed)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="gateway: static on-demand replica count")
    ap.add_argument("--routing", default=None,
                    choices=("affinity", "least-loaded", "blind"),
                    help="gateway: fleet placement policy (default "
                         "affinity: requests land where their prefix is "
                         "already cached, least-loaded fallback, "
                         "load-imbalance capped). Passing the flag also "
                         "gives tenants hot shared prefixes so the demo "
                         "has residency to route on")
    ap.add_argument("--disaggregate", default=None,
                    metavar="N_PREFILL:N_DECODE", type=_disaggregate_spec,
                    help="gateway: split the fleet into prefill-specialized"
                         " and decode-specialized replicas (e.g. 1:2); "
                         "finished KV pages ship prefill -> decode per "
                         "request")
    ap.add_argument("--interactive-burst", action="store_true",
                    help="gateway demo: batch jobs hold every decode slot, "
                         "a tight-deadline interactive burst preempts them "
                         "(lossless pause/resume, pages pinned)")
    ap.add_argument("--no-preempt", action="store_true",
                    help="with --interactive-burst: disable preemption to "
                         "watch the burst shed instead")
    ap.add_argument("--chaos-seed", type=int, default=None, metavar="SEED",
                    help="gateway demo: seeded random fault storm (crashes, "
                         "revocation notices with KV evacuation, "
                         "stragglers, heartbeat loss) over the fleet; every "
                         "job must end DONE or typed-SHED")
    ap.add_argument("--saturation", action="store_true",
                    help="gateway demo: open-loop Poisson/diurnal traffic "
                         "from a Zipf user population with telemetry "
                         "streaming into a write-capped StateStore "
                         "(benchmarks/gateway_bench.py sweeps the full "
                         "offered-load range)")
    ap.add_argument("--metrics-out", type=str, default=None, metavar="PATH",
                    help="gateway modes: write the final Prometheus text "
                         "exposition of the run's MetricsRegistry to PATH")
    args = ap.parse_args()
    if args.adaptive_k and not args.spec:
        raise SystemExit("--adaptive-k requires --spec (it governs the "
                         "speculative draft window)")
    if (args.routing or args.disaggregate) and not args.gateway:
        args.gateway = True      # routing flags only make sense fleet-wide

    cfg = get_reduced_config(args.arch)
    if cfg.encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only (no decode step)")
    fam = get_family(cfg)
    params = init_params(fam.layout(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    gw = None
    if args.chaos_seed is not None:
        if not hasattr(fam, "decode_paged"):
            raise SystemExit("--chaos-seed requires a paged-decode family")
        gw = _run_chaos(cfg, params, args)
    elif args.saturation:
        if not hasattr(fam, "decode_paged"):
            raise SystemExit("--saturation requires a paged-decode family")
        if args.tenants < 1 or args.replicas < 1:
            raise SystemExit("--saturation needs --tenants >= 1 and "
                             "--replicas >= 1")
        gw = _run_saturation(cfg, params, args)
    elif args.interactive_burst:
        if not hasattr(fam, "decode_paged"):
            raise SystemExit("--interactive-burst requires a paged-decode "
                             "family")
        if args.replicas < 1:
            raise SystemExit("--interactive-burst needs --replicas >= 1")
        gw = _run_interactive_burst(cfg, params, args)
    elif args.gateway:
        if not hasattr(fam, "decode_paged"):
            raise SystemExit("--gateway requires a paged-decode family")
        if args.tenants < 1 or args.replicas < 1:
            raise SystemExit("--gateway needs --tenants >= 1 and "
                             "--replicas >= 1")
        gw = _run_gateway(cfg, params, args)
    if gw is not None:
        if args.metrics_out is not None:
            from pathlib import Path
            gw.registry.collect()
            Path(args.metrics_out).write_text(gw.registry.expose())
            print(f"wrote {len(gw.registry.families())} metric families "
                  f"(Prometheus text exposition) to {args.metrics_out}")
        return
    if args.metrics_out is not None:
        raise SystemExit("--metrics-out requires a gateway mode (--gateway,"
                         " --saturation, --interactive-burst or "
                         "--chaos-seed): the MetricsRegistry lives in the "
                         "gateway")
    engine_kind = args.engine
    if engine_kind == "auto":
        engine_kind = ("continuous" if hasattr(fam, "decode_paged")
                       else "static")
    if engine_kind == "continuous":
        engine = ContinuousBatchingEngine(cfg, params, max_len=args.max_len,
                                          enable_spec_decode=args.spec,
                                          kv_cache_dtype=args.kv_dtype,
                                          spec_adaptive_k=args.adaptive_k
                                          or None)
    elif args.spec:
        raise SystemExit("--spec requires the continuous engine")
    elif args.kv_dtype == "int8":
        raise SystemExit("--kv-dtype int8 requires the continuous engine "
                         "(the static engine keeps a dense unquantized "
                         "cache)")
    else:
        engine = ServeEngine(cfg, params, max_len=args.max_len)
    prompts = _demo_prompts(cfg, args.batch)
    out = engine.generate(prompts, max_new=args.max_new)
    print(f"engine: {engine_kind}")
    for p, toks in zip(prompts, out.tokens.tolist()):
        print(f"{p} -> {toks}")


if __name__ == "__main__":
    main()
