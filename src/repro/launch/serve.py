"""Serving launcher: batched greedy decoding for a (reduced) architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --max-new 16
"""
import argparse

import jax

from repro.configs import ARCH_NAMES, get_reduced_config
from repro.models import get_family
from repro.models.params import init_params
from repro.serve import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES), default="yi-6b")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    if cfg.encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only (no decode step)")
    fam = get_family(cfg)
    params = init_params(fam.layout(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    engine = ServeEngine(cfg, params, max_len=args.max_len)
    rng = jax.random.PRNGKey(1)
    prompts = [[int(t) for t in jax.random.randint(
        jax.random.fold_in(rng, i), (3 + i % 4,), 0, cfg.vocab_size)]
        for i in range(args.batch)]
    out = engine.generate(prompts, max_new=args.max_new)
    for p, toks in zip(prompts, out.tokens.tolist()):
        print(f"{p} -> {toks}")


if __name__ == "__main__":
    main()
