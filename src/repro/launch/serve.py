"""Serving launcher: greedy decoding for a (reduced) architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --max-new 16

``--engine continuous`` (default for attention families) decodes over the
shared paged KV cache with continuous batching; ``--engine static`` uses the
legacy padded-batch engine (and is the only choice for recurrent-state
families, whose per-slot states are dense).
"""
import argparse

import jax

from repro.configs import ARCH_NAMES, get_reduced_config
from repro.models import get_family
from repro.models.params import init_params
from repro.serve import ContinuousBatchingEngine, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES), default="yi-6b")
    ap.add_argument("--engine", choices=("continuous", "static", "auto"),
                    default="auto")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--spec", action="store_true",
                    help="self-speculative decode (n-gram drafts verified "
                         "in one multi-query paged pass; greedy outputs "
                         "are unchanged)")
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    if cfg.encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only (no decode step)")
    fam = get_family(cfg)
    params = init_params(fam.layout(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    engine_kind = args.engine
    if engine_kind == "auto":
        engine_kind = ("continuous" if hasattr(fam, "decode_paged")
                       else "static")
    if engine_kind == "continuous":
        engine = ContinuousBatchingEngine(cfg, params, max_len=args.max_len,
                                          enable_spec_decode=args.spec)
    elif args.spec:
        raise SystemExit("--spec requires the continuous engine")
    else:
        engine = ServeEngine(cfg, params, max_len=args.max_len)
    rng = jax.random.PRNGKey(1)
    prompts = [[int(t) for t in jax.random.randint(
        jax.random.fold_in(rng, i), (3 + i % 4,), 0, cfg.vocab_size)]
        for i in range(args.batch)]
    out = engine.generate(prompts, max_new=args.max_new)
    print(f"engine: {engine_kind}")
    for p, toks in zip(prompts, out.tokens.tolist()):
        print(f"{p} -> {toks}")


if __name__ == "__main__":
    main()
