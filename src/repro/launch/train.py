"""Training launcher: run an architecture end-to-end under the Kotta stack.

On this CPU container it trains reduced configs for real; with ``--dry``
it AOT-compiles the full config on the production mesh instead (see
``dryrun.py`` for the sweep form).

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --steps 20
"""
import argparse

from repro.checkpoint import Checkpointer
from repro.configs import ARCH_NAMES, get_reduced_config
from repro.core import ObjectStore, PolicyEngine, install_standard_roles
from repro.data import SyntheticCorpus, TokenLoader
from repro.models import count_params
from repro.train import AdamWConfig, ElasticTrainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES), default="yi-6b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-every", type=int, default=10)
    ap.add_argument("--run-name", default="train-cli")
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    if cfg.frontend:
        raise SystemExit(f"{args.arch}: modality-frontend archs train via "
                         "their smoke tests; use a text arch here")
    print(f"{cfg.name} (reduced): {count_params(cfg) / 1e6:.2f}M params")

    engine = PolicyEngine()
    install_standard_roles(engine)
    store = ObjectStore(clock=engine.clock)
    keys = SyntheticCorpus.build(
        store, "cli", num_shards=2,
        tokens_per_shard=max(65_536, args.batch * (args.seq + 1) * 8),
        vocab_size=cfg.vocab_size)
    loader = TokenLoader(store.get, keys, batch_size=args.batch,
                         seq_len=args.seq)
    opt = AdamWConfig(learning_rate=args.lr, warmup_steps=5,
                      decay_steps=max(args.steps, 10))
    trainer = ElasticTrainer(cfg, opt, Checkpointer(store, args.run_name),
                             microbatches=args.microbatches, seed=0)
    rep = trainer.train(loader, args.steps,
                        checkpoint_every=args.checkpoint_every)
    first, last = min(rep.losses), max(rep.losses)
    print(f"steps={rep.final_step} loss {rep.losses[first]:.4f} -> "
          f"{rep.losses[last]:.4f}; checkpoints {trainer.ckpt.steps()}")


if __name__ == "__main__":
    main()
