import os
os.environ["XLA_FLAGS"] = (os.environ.get("KOTTA_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")
# ^ MUST run before any other import: jax locks the device count on first init.

"""Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: abstract inputs,
AOT compile on 256/512 placeholder devices, then memory_analysis (fits?),
cost_analysis + while-aware HLO parsing (roofline terms).

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import (ARCH_NAMES, SHAPES, get_config, get_shape, runnable)
from repro.core.cost import TPU_V5E
from repro.distributed.sharding import ShardingRules, activate_rules
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.input_specs import build_cell, shape_rule_overrides
from repro.launch.mesh import make_production_mesh


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             overrides: dict | None = None, microbatches: int = 1,
             rule_overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = get_shape(shape_name)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "config_overrides": overrides or {}, "microbatches": microbatches,
              "rule_overrides": rule_overrides or {}}

    ok, why = runnable(cfg, shape)
    if not ok:
        result.update(status="skipped", reason=why)
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    rules = ShardingRules(mesh, {**cfg.sharding_overrides,
                                 **shape_rule_overrides(cfg, shape),
                                 **(rule_overrides or {})})
    step, args, shardings = build_cell(cfg, shape, rules,
                                       microbatches=microbatches)
    donate = (0, 1) if shape.kind == "train" else ()
    t0 = time.time()
    with jax.set_mesh(mesh), activate_rules(rules):
        lowered = jax.jit(step, in_shardings=shardings,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    rep = analyze_hlo(hlo)

    chip = TPU_V5E
    per_dev_bytes = (mem.temp_size_in_bytes + mem.argument_size_in_bytes
                     + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    model_flops = _model_flops(cfg, shape)
    t_compute = rep.dot_flops / chip.peak_bf16_flops
    t_memory = rep.bytes_accessed / chip.hbm_bandwidth
    t_memory_fused = rep.bytes_accessed_fused / chip.hbm_bandwidth
    t_collective = rep.collective_wire_bytes / chip.ici_link_bandwidth
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_collective}
    bottleneck = max(terms, key=terms.get)
    model_time = model_flops / (n_dev * chip.peak_bf16_flops)
    roofline_frac = model_time / max(max(terms.values()), 1e-30)

    result.update(
        status="ok",
        devices=n_dev,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory={"argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "per_device_total": per_dev_bytes,
                "fits_hbm": bool(per_dev_bytes <= chip.hbm_bytes)},
        cost_analysis={"flops": ca.get("flops", 0.0),
                       "bytes_accessed": ca.get("bytes accessed", 0.0)},
        hlo={"dot_flops": rep.dot_flops, "dot_count": rep.dot_count,
             "kernel_region_flops": rep.kernel_region_flops,
             "bytes_accessed": rep.bytes_accessed,
             "bytes_accessed_fused": rep.bytes_accessed_fused,
             "kernel_region_bytes": rep.kernel_region_bytes,
             "collective_wire_bytes": rep.collective_wire_bytes,
             "collective_by_op": rep.collective_by_op,
             "collective_count": rep.collective_count,
             "while_trips": rep.while_trips},
        roofline={**terms, "memory_fused_s": t_memory_fused,
                  "bottleneck": bottleneck,
                  "model_flops": model_flops,
                  "hlo_flops_global": rep.dot_flops * n_dev,
                  "useful_flops_ratio":
                      model_flops / max(rep.dot_flops * n_dev, 1e-30),
                  "model_time_s": model_time,
                  "roofline_fraction": roofline_frac},
    )
    return result


def _model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS per step: 6·N_active·D train, 2·N_active·D fwd."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def cell_path(out_dir: str, arch: str, shape: str, mesh: str,
              tag: str = "") -> str:
    suffix = f"__{tag}" if tag else ""
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh}{suffix}.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="", help="artifact suffix (perf variants)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--override", action="append", default=[],
                    help="cfg override key=value (repeatable)")
    ap.add_argument("--rule", action="append", default=[],
                    help="sharding rule logical=mesh_axis (repeatable)")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        overrides[k] = _coerce(v)
    rule_overrides = {}
    for kv in args.rule:
        k, v = kv.split("=", 1)
        rule_overrides[k] = None if v in ("none", "None") else (
            tuple(v.split(",")) if "," in v else v)

    cells = []
    if args.all:
        for arch in ARCH_NAMES:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch/--shape required unless --all")
        cells.append((args.arch, args.shape))
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        for mesh_kind in meshes:
            path = cell_path(args.out, arch, shape, mesh_kind, args.tag)
            if args.skip_existing and os.path.exists(path):
                continue
            try:
                res = run_cell(arch, shape, mesh_kind, overrides,
                               args.microbatches, rule_overrides)
            except Exception as e:  # noqa: BLE001 - record and continue
                res = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                       "status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()[-2000:]}
                failures += 1
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            _print_summary(res)
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


def _coerce(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    if v in ("true", "True"):
        return True
    if v in ("false", "False"):
        return False
    return v


def _print_summary(res: dict) -> None:
    if res["status"] == "ok":
        r = res["roofline"]
        m = res["memory"]
        print(f"[ok]   {res['arch']:<18} {res['shape']:<12} {res['mesh']:<6} "
              f"compile={res['compile_s']:6.1f}s "
              f"mem/dev={m['per_device_total']/2**30:6.2f}GiB "
              f"fits={m['fits_hbm']} "
              f"terms(c/m/x)={r['compute_s']:.2e}/{r['memory_s']:.2e}/"
              f"{r['collective_s']:.2e}s bottleneck={r['bottleneck']} "
              f"frac={r['roofline_fraction']:.3f}", flush=True)
    elif res["status"] == "skipped":
        print(f"[skip] {res['arch']:<18} {res['shape']:<12} {res['mesh']:<6} "
              f"{res['reason']}", flush=True)
    else:
        print(f"[ERR]  {res['arch']:<18} {res['shape']:<12} {res['mesh']:<6} "
              f"{res['error'][:140]}", flush=True)


if __name__ == "__main__":
    main()
