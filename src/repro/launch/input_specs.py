"""ShapeDtypeStruct input stand-ins + shardings for every (arch × shape) cell.

No device allocation happens here: abstract params, abstract optimizer state,
abstract batches and abstract decode caches feed ``jit(...).lower()`` for the
multi-pod dry-run.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import ShardingRules, param_sharding
from repro.models import get_family
from repro.models.params import abstract_params
from repro.train import adamw
from repro.train.train_step import (build_decode_step, build_encode_step,
                                    build_prefill_step, build_train_step)

BATCH_AXES = ("batch", None)


def _struct(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    """(structs, logical_axes) for the input batch of this cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.frontend == "frame":
            structs = {"frames": _struct((b, s, cfg.frontend_dim), "float32"),
                       "labels": _struct((b, s), "int32"),
                       "loss_mask": _struct((b, s), "float32")}
            axes = {"frames": ("batch", None, None), "labels": BATCH_AXES,
                    "loss_mask": BATCH_AXES}
        elif cfg.frontend == "patch":
            text = s - cfg.frontend_len
            structs = {"tokens": _struct((b, text), "int32"),
                       "labels": _struct((b, text), "int32"),
                       "patches": _struct((b, cfg.frontend_len, cfg.frontend_dim),
                                          "float32")}
            axes = {"tokens": BATCH_AXES, "labels": BATCH_AXES,
                    "patches": ("batch", None, None)}
        else:
            structs = {"tokens": _struct((b, s), "int32"),
                       "labels": _struct((b, s), "int32")}
            axes = {"tokens": BATCH_AXES, "labels": BATCH_AXES}
        return structs, axes

    if shape.kind == "prefill":
        if cfg.frontend == "frame":
            structs = {"frames": _struct((b, s, cfg.frontend_dim), "float32")}
            axes = {"frames": ("batch", None, None)}
        elif cfg.frontend == "patch":
            structs = {"tokens": _struct((b, s - cfg.frontend_len), "int32"),
                       "patches": _struct((b, cfg.frontend_len, cfg.frontend_dim),
                                          "float32")}
            axes = {"tokens": BATCH_AXES, "patches": ("batch", None, None)}
        else:
            structs = {"tokens": _struct((b, s), "int32")}
            axes = {"tokens": BATCH_AXES}
        return structs, axes

    if shape.kind == "decode":
        structs = {"tokens": _struct((b, 1), "int32"),
                   "pos": _struct((b,), "int32")}
        axes = {"tokens": BATCH_AXES, "pos": ("batch",)}
        return structs, axes

    raise ValueError(shape.kind)


def _shard_tree(rules: ShardingRules, structs, axes):
    return jax.tree.map(lambda st, ax: rules.named(st.shape, ax), structs, axes)


def optimizer_state_sharding(opt_cfg, abs_params, layout, rules: ShardingRules):
    """Shardings for AdamWState: fp32 moments mirror their parameter; int8
    QTensor moments shard their flat block dim across the whole mesh."""
    st = jax.eval_shape(partial(adamw.init, opt_cfg), abs_params)
    p_sh_tree = param_sharding(layout, rules)
    flat_sh = jax.tree.leaves(p_sh_tree)
    treedef = jax.tree.structure(abs_params)
    mesh = rules.mesh

    def map_moment(mtree):
        flat_m = treedef.flatten_up_to(mtree)
        out = []
        for sh, leaf in zip(flat_sh, flat_m):
            if isinstance(leaf, adamw.QTensor):
                # blocks tile the last axis: keep the parameter's leading-dim
                # sharding, leave (blocks, QBLOCK) unsharded.
                rank = len(leaf.shape)
                entries = tuple(sh.spec) + (None,) * (rank - len(tuple(sh.spec)))
                qspec = P(*entries[:-1], None, None)
                qsh = NamedSharding(mesh, qspec)
                out.append(adamw.QTensor(qsh, qsh, leaf.shape))
            else:
                out.append(sh)
        return treedef.unflatten(out)

    scalar = NamedSharding(mesh, P())
    return adamw.AdamWState(scalar, map_moment(st.m), map_moment(st.v)), st


def default_opt_cfg(cfg: ModelConfig) -> adamw.AdamWConfig:
    # bf16-param archs (Arctic) pair with int8 moments (DESIGN §3).
    state_dtype = "int8" if cfg.param_dtype == "bfloat16" else "float32"
    return adamw.AdamWConfig(state_dtype=state_dtype)


def build_cell(cfg: ModelConfig, shape: ShapeConfig, rules: ShardingRules,
               opt_cfg: adamw.AdamWConfig | None = None,
               microbatches: int = 1):
    """Returns (step_fn, abstract_args tuple, in_shardings tuple)."""
    family = get_family(cfg)
    layout = family.layout(cfg)
    abs_params = abstract_params(layout, cfg.param_dtype)
    p_sh = param_sharding(layout, rules)
    structs, axes = batch_specs(cfg, shape)
    b_sh = _shard_tree(rules, structs, axes)

    if shape.kind == "train":
        opt_cfg = opt_cfg or default_opt_cfg(cfg)
        o_sh, abs_opt = optimizer_state_sharding(opt_cfg, abs_params, layout,
                                                 rules)
        step = build_train_step(cfg, opt_cfg, microbatches)
        return step, (abs_params, abs_opt, structs), (p_sh, o_sh, b_sh)

    if shape.kind == "prefill":
        step = (build_encode_step(cfg) if cfg.encoder_only
                else build_prefill_step(cfg))
        return step, (abs_params, structs), (p_sh, b_sh)

    if shape.kind == "decode":
        cache_structs, cache_axes = family.cache_layout(
            cfg, shape.global_batch, shape.seq_len)
        c_sh = _shard_tree(rules, cache_structs, cache_axes)
        step = build_decode_step(cfg)
        return step, (abs_params, structs, cache_structs), (p_sh, b_sh, c_sh)

    raise ValueError(shape.kind)


def shape_rule_overrides(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Per-cell sharding-rule tweaks (e.g. sequence-shard huge KV caches)."""
    overrides: dict[str, Any] = {}
    if shape.kind == "decode":
        if shape.global_batch < 8:
            # batch=1 long-context decode: batch unshardable; shard the cache
            # sequence over data (flash-decoding style partial softmax).
            overrides["cache_seq"] = "data"
        else:
            # GQA KV heads rarely divide the 16-way model axis; shard the
            # cache sequence over "model" instead so the KV cache fits.
            overrides["cache_seq"] = "model"
    return overrides
