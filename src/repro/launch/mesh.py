"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16,16)=("data","model") = 256 chips.
    Multi-pod: (2,16,16)=("pod","data","model") = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Whatever devices exist locally, as a 1D 'data' mesh (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
