"""In-house AdamW with optional int8 block-quantized moments.

No optax in this environment — and the assignment asks for every substrate to
be built. Features:

- decoupled weight decay, bias-corrected moments, global-norm clipping;
- linear-warmup + cosine-decay schedule;
- ``state_dtype="int8"``: both moments stored as int8 with per-block (256)
  float32 scales — 4x less optimizer HBM, the adaptation that lets
  Arctic-480B train on 256 chips (DESIGN §3). Quantization error is bounded
  by scale/2 per element (property-tested).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

QBLOCK = 128


# ---------------------------------------------------------------------------
# Block quantization
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class QTensor:
    """int8 block-quantized tensor; ``shape`` is static aux data."""

    def __init__(self, q, scale, shape):
        self.q = q             # int8 (n_blocks, QBLOCK)
        self.scale = scale     # float32 (n_blocks, 1)
        self.shape = tuple(shape)

    def tree_flatten(self):
        return (self.q, self.scale), self.shape

    @classmethod
    def tree_unflatten(cls, shape, children):
        return cls(children[0], children[1], shape)

    def __repr__(self):
        return f"QTensor(shape={self.shape})"


def quantize_blockwise(x) -> QTensor:
    """Blocks tile the LAST axis (which must divide QBLOCK), preserving the
    leading axes — so GSPMD sharding propagates from the parameter to its
    quantized moments (flattening would force replication)."""
    shape = x.shape
    last = shape[-1] if shape else 1
    if last % QBLOCK:
        raise ValueError(f"last dim {last} % QBLOCK {QBLOCK} != 0")
    blocks = x.astype(jnp.float32).reshape(*shape[:-1], last // QBLOCK, QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return QTensor(q, scale, shape)


def dequantize_blockwise(qt: QTensor) -> jax.Array:
    return (qt.q.astype(jnp.float32) * qt.scale).reshape(qt.shape)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    state_dtype: str = "float32"     # "float32" | "int8"


def lr_at(cfg: AdamWConfig, step) -> jax.Array:
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, step / jnp.maximum(cfg.warmup_steps, 1))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(math.pi * prog))
    return cfg.learning_rate * warm * cos


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any   # pytree of arrays or QTensors
    v: Any


#: tensors smaller than this stay float32 even under int8 states
#: (norm gains, biases — negligible memory, high sensitivity).
QUANT_MIN_SIZE = 2048


def _encode(cfg: AdamWConfig, x, sqrt_domain: bool = False):
    if (cfg.state_dtype != "int8" or x.size < QUANT_MIN_SIZE
            or (x.ndim and x.shape[-1] % QBLOCK)):
        return x
    if sqrt_domain:  # v >= 0: quantize sqrt(v) — compresses the dynamic range
        return quantize_blockwise(jnp.sqrt(x))
    return quantize_blockwise(x)


def _decode(cfg: AdamWConfig, x, sqrt_domain: bool = False):
    if not isinstance(x, QTensor):
        return x
    d = dequantize_blockwise(x)
    return d * d if sqrt_domain else d


def init(cfg: AdamWConfig, params) -> AdamWState:
    def zero_m(p):
        return _encode(cfg, jnp.zeros(p.shape, jnp.float32))

    def zero_v(p):
        return _encode(cfg, jnp.zeros(p.shape, jnp.float32), sqrt_domain=True)

    return AdamWState(jnp.zeros((), jnp.int32),
                      jax.tree.map(zero_m, params), jax.tree.map(zero_v, params))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.asarray(leaves)))


def update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.max_grad_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def leaf(p, g, m_enc, v_enc):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * _decode(cfg, m_enc) + (1 - cfg.b1) * g
        v = cfg.b2 * _decode(cfg, v_enc, sqrt_domain=True) + (1 - cfg.b2) * g * g
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        return new_p, _encode(cfg, m), _encode(cfg, v, sqrt_domain=True)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    results = [leaf(*args) for args in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([r[0] for r in results])
    new_m = treedef.unflatten([r[1] for r in results])
    new_v = treedef.unflatten([r[2] for r in results])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step, new_m, new_v), metrics
