"""Elastic, fault-tolerant trainer.

Cloud Kotta's execution model applied to training: the trainer is the *job*,
revocations kill it mid-step, the queue-watcher resubmits it, and it resumes
from the latest tiered checkpoint. Because ``TokenLoader.batch_at(step)`` is
pure, a restart replays the exact data order — restart equality is bitwise
(tested). Elastic rescale = restore the topology-independent checkpoint with
a different dp_size and keep the same global batch.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import Checkpointer
from repro.models import get_family
from repro.models.params import init_params
from . import adamw
from .train_step import build_train_step


class Revoked(Exception):
    """Raised by a revocation signal mid-training (spot reclaim)."""


@dataclass
class TrainerReport:
    steps_run: int
    final_step: int
    losses: dict[int, float] = field(default_factory=dict)
    restarts: int = 0


class ElasticTrainer:
    def __init__(self, cfg, opt_cfg: adamw.AdamWConfig,
                 checkpointer: Checkpointer, *,
                 microbatches: int = 1, seed: int = 0,
                 async_checkpoint: bool = False):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.ckpt = checkpointer
        self.family = get_family(cfg)
        self.microbatches = microbatches
        self.seed = seed
        self.async_checkpoint = async_checkpoint
        self._step_fn = jax.jit(build_train_step(cfg, opt_cfg, microbatches),
                                donate_argnums=(0, 1))

    # -- state -----------------------------------------------------------------
    def init_state(self):
        params = init_params(self.family.layout(self.cfg),
                             jax.random.PRNGKey(self.seed),
                             self.cfg.param_dtype)
        opt_state = adamw.init(self.opt_cfg, params)
        return params, opt_state

    def restore_or_init(self):
        params, opt_state = self.init_state()
        step = self.ckpt.latest_step()
        if step is None:
            return 0, params, opt_state
        step, (params, opt_state) = self.ckpt.restore((params, opt_state))
        return step, params, opt_state

    # -- loop ---------------------------------------------------------------------
    def train(self, loader, num_steps: int, *, checkpoint_every: int = 50,
              revoke_at: Optional[Callable[[int], bool]] = None,
              max_restarts: int = 10) -> TrainerReport:
        """Run to ``num_steps`` global steps, surviving revocations."""
        report = TrainerReport(0, 0)
        restarts = 0
        while True:
            start, params, opt_state = self.restore_or_init()
            try:
                step = start
                while step < num_steps:
                    if revoke_at is not None and revoke_at(step):
                        raise Revoked(f"revoked at step {step}")
                    batch = loader.batch_at(step)
                    batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                    params, opt_state, metrics = self._step_fn(
                        params, opt_state, batch)
                    step += 1
                    report.steps_run += 1
                    report.losses[step] = float(metrics["total_loss"])
                    if step % checkpoint_every == 0 or step == num_steps:
                        self.ckpt.save(step, (params, opt_state),
                                       blocking=not self.async_checkpoint)
                self.ckpt.wait()
                report.final_step = step
                report.restarts = restarts
                self._final = (params, opt_state)
                return report
            except Revoked:
                restarts += 1
                if restarts > max_restarts:
                    raise
                # params/opt_state lost with the instance; loop restores.
                continue

    @property
    def final_state(self):
        return self._final
