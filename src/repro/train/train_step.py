"""Train / serve step construction.

``build_train_step`` produces the jit-able step function: microbatch gradient
accumulation (``lax.scan`` over microbatches, float32 accumulators), AdamW
update, metrics. ``build_prefill_step`` / ``build_decode_step`` produce the
serving steps. All of them run under the active sharding-rules context, so
the same functions lower for 1 CPU device and for the production meshes.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import get_family
from . import adamw


def _microbatches(batch, n: int):
    """Split the leading batch dim into n microbatches: (n, B/n, ...)."""
    def split(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} % microbatches {n} != 0"
        return x.reshape(n, b // n, *x.shape[1:])
    return jax.tree.map(split, batch)


def build_train_step(cfg, opt_cfg: adamw.AdamWConfig,
                     microbatches: int = 1) -> Callable:
    family = get_family(cfg)

    def loss_fn(params, mb):
        return family.train_loss(cfg, params, mb)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            mbs = _microbatches(batch, microbatches)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), metrics = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = jax.tree.map(lambda m: jnp.mean(m), metrics)

        params, opt_state, opt_metrics = adamw.update(
            opt_cfg, grads, opt_state, params)
        return params, opt_state, {"total_loss": loss, **metrics, **opt_metrics}

    return train_step


def build_prefill_step(cfg) -> Callable:
    family = get_family(cfg)

    def prefill_step(params, batch):
        return family.prefill(cfg, params, batch)

    return prefill_step


def build_decode_step(cfg) -> Callable:
    family = get_family(cfg)

    def decode_step(params, batch, cache):
        logits, cache = family.decode(cfg, params, batch, cache)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, cache

    return decode_step


def build_paged_decode_step(cfg) -> Callable:
    """Decode step over the shared paged KV pool (continuous batching).

    The returned function is pure and donation-friendly: the serve engine
    jits it with the pool donated so XLA updates pages in place, and wraps it
    in a ``lax.fori_loop`` so a whole decode chunk runs without host syncs.
    """
    family = get_family(cfg)
    if not hasattr(family, "decode_paged"):
        raise ValueError(f"{cfg.name}: family {family.name!r} has no paged "
                         "decode path (recurrent-state families keep their "
                         "per-slot states dense)")

    def paged_decode_step(params, batch, pool):
        logits, pool = family.decode_paged(cfg, params, batch, pool)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, pool

    return paged_decode_step


def build_paged_prefill_step(cfg) -> Callable:
    """Chunked-prefill step over the shared paged KV pool.

    One call prefills a fixed-width chunk of C prompt tokens per request,
    scattering KV directly into pool pages (no dense intermediate cache).
    The serve engine jits it with the pool donated and loops it over a
    wave's suffix chunks; the fixed (B, C) shape means one compile per batch
    bucket instead of one per prompt-length pad bucket.
    """
    family = get_family(cfg)
    if not hasattr(family, "prefill_paged"):
        raise ValueError(f"{cfg.name}: family {family.name!r} has no paged "
                         "prefill path (recurrent-state families keep their "
                         "per-slot states dense)")

    def paged_prefill_step(params, batch, pool):
        logits, pool = family.prefill_paged(cfg, params, batch, pool)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, pool

    return paged_prefill_step


def build_paged_verify_step(cfg) -> Callable:
    """Speculative-verify step over the shared paged KV pool.

    One call scores a T-token draft window per slot (the verified current
    token + T-1 drafts) through the multi-query paged verify path and
    returns the greedy next token for EVERY window position, (B, T): column
    i is the model's token following window prefix [:, :i+1] — comparing it
    against the drafts gives the accepted length, and entry [b, a] is the
    corrected token that replaces the first rejected draft. The serve
    engine jits this inside its on-device decode chunk with the pool
    donated.
    """
    family = get_family(cfg)
    if not hasattr(family, "decode_verify"):
        raise ValueError(f"{cfg.name}: family {family.name!r} has no paged "
                         "verify path (recurrent-state families keep their "
                         "per-slot states dense)")

    def paged_verify_step(params, batch, pool):
        logits, pool = family.decode_verify(cfg, params, batch, pool)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, logits, pool

    return paged_verify_step


def build_fused_spec_step(cfg, draft_fn) -> Callable:
    """Fused draft + multi-query verify step (speculative decode).

    ``draft_fn(hist, cur, pos) -> (S, K)`` is an injected pure draft
    proposal (e.g. :func:`repro.serve.drafting.build_ngram_draft`); fusing
    it with the verify pass here means the serve engine's decode chunk
    issues ONE step call per ``fori_loop`` iteration — draft lookup, window
    assembly, KV scatter and verify all land in the same traced dispatch.

    batch: cur (S,), pos (S,), hist (S, hlen), page_table (S, npages),
    write_limit (S,). Returns ``(window, drafts, next_tokens, pool)`` where
    window = [cur | drafts] is what was scored, and next_tokens[:, i] is the
    model's greedy token after window prefix [:, :i+1] — acceptance and
    history bookkeeping stay with the caller, which owns the chunk carry.
    """
    family = get_family(cfg)
    if not hasattr(family, "decode_verify"):
        raise ValueError(f"{cfg.name}: family {family.name!r} has no paged "
                         "verify path (recurrent-state families keep their "
                         "per-slot states dense)")

    def fused_spec_step(params, batch, pool):
        drafts = draft_fn(batch["hist"], batch["cur"], batch["pos"])
        window = jnp.concatenate([batch["cur"][:, None], drafts], axis=1)
        vbatch = {"tokens": window, "pos": batch["pos"],
                  "page_table": batch["page_table"],
                  "write_limit": batch["write_limit"]}
        logits, pool = family.decode_verify(cfg, params, vbatch, pool)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return window, drafts, next_tokens, pool

    return fused_spec_step


def build_encode_step(cfg) -> Callable:
    """Encoder-only serve step (HuBERT): frames -> per-frame logits."""
    family = get_family(cfg)

    def encode_step(params, batch):
        logits, _ = family.prefill(cfg, params, batch)
        return logits

    return encode_step
