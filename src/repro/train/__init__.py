from . import adamw, grad_compress
from .adamw import AdamWConfig
from .train_step import (build_decode_step, build_encode_step,
                         build_prefill_step, build_train_step)
from .trainer import ElasticTrainer, Revoked, TrainerReport

__all__ = ["adamw", "grad_compress", "AdamWConfig", "build_train_step",
           "build_prefill_step", "build_decode_step", "build_encode_step",
           "ElasticTrainer", "Revoked", "TrainerReport"]
