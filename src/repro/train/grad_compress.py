"""Gradient compression with error feedback (beyond-paper distributed trick).

Int8 uniform quantization of per-pod partial gradients before the cross-pod
all-reduce, with local error-feedback residuals (Seide et al. 2014 / EF-SGD,
Karimireddy et al. 2019): the quantization error is carried to the next step,
so compressed SGD converges at the uncompressed rate. Cross-pod traffic drops
4x (int8 vs float32).

``compressed_psum`` is the shard_map building block; ``CompressorState``
holds residuals in the optimizer pytree.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .adamw import QBLOCK, dequantize_blockwise, quantize_blockwise


class CompressorState(NamedTuple):
    residual: Any  # pytree matching grads (float32)


def init_compressor(grads_like) -> CompressorState:
    return CompressorState(jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def compress_decompress(x):
    """Round-trip int8 block quantization: returns (x_hat, error)."""
    xq = quantize_blockwise(x.astype(jnp.float32))
    x_hat = dequantize_blockwise(xq)
    return x_hat, x.astype(jnp.float32) - x_hat


def ef_step(grads, state: CompressorState):
    """Error-feedback compression of a gradient pytree (local part).

    Returns (compressed grads to be reduced, new state).
    """
    def leaf(g, r):
        corrected = g.astype(jnp.float32) + r
        g_hat, err = compress_decompress(corrected)
        return g_hat, err

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(state.residual)
    outs = [leaf(g, r) for g, r in zip(flat_g, flat_r)]
    g_hat = treedef.unflatten([o[0] for o in outs])
    resid = treedef.unflatten([o[1] for o in outs])
    return g_hat, CompressorState(resid)


def compressed_psum(grads, axis_name: str, state: CompressorState):
    """Inside shard_map: error-feedback int8 quantize, then psum over
    ``axis_name`` (the cross-pod axis). Intra-pod reductions stay full
    precision (they ride fast ICI; the pod axis rides slower DCN links)."""
    g_hat, new_state = ef_step(grads, state)
    reduced = jax.tree.map(
        lambda g: jax.lax.psum(g, axis_name) / jax.lax.axis_size(axis_name),
        g_hat)
    return reduced, new_state
