"""Ring attention over a mesh axis (Liu et al., arXiv:2310.01889 — blockwise
ring attention), implemented with ``jax.shard_map`` + ``lax.ppermute``.

Motivation (EXPERIMENTS §Perf A4): architectures whose head count does not
divide the model axis (Arctic 56H, StarCoder2 36H, PaliGemma 8H) leave
attention *replicated* across that axis — 16x redundant FLOPs and tile
traffic. Plain sequence sharding fixes the redundancy but GSPMD reshards the
residual stream at every layer boundary. Ring attention instead:

- shards Q, K, V by *sequence* over the ring axis (inputs arrive already
  batch/seq-sharded, no resharding of the residual stream);
- each of the R devices loops R times over its local Q shard, combining with
  the KV shard currently resident, then ``ppermute``s the KV block to its
  ring neighbour — online-softmax accumulators merge the partial results
  exactly (same recurrence as the flash kernel);
- per-device wire traffic is (R-1)/R · |KV shard| · R = |KV| — the same bytes
  a single all-gather moves, but in R pipelined hops that overlap with the
  per-block attention compute on real hardware, and the full KV never
  materializes on any device.

Causality is handled by absolute positions carried with each KV block.
Oracle-tested against dense attention (tests/test_ring_attention.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models.layers import MASK_VALUE


def _local_attention(q, k, v, q_pos, kv_pos, causal):
    """Partial attention of local q against one KV block; returns
    (m, l, acc) online-softmax accumulators (fp32)."""
    b, sq, nkv, g, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        mask = q_pos[:, None] >= kv_pos[None, :]
        s = jnp.where(mask[None, None, None], s, MASK_VALUE)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    acc = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return m, l, acc


def _merge(m1, l1, a1, m2, l2, a2):
    """Combine two online-softmax partials."""
    m = jnp.maximum(m1, m2)
    w1 = jnp.exp(m1 - m)
    w2 = jnp.exp(m2 - m)
    return m, l1 * w1 + l2 * w2, a1 * w1[..., None] + a2 * w2[..., None]


def ring_attention(q, k, v, *, axis_name: str, causal: bool = True,
                   q_offset=None):
    """Inside shard_map: q,k,v are the LOCAL sequence shards
    (B, S_local, H|KV, hd); the global sequence is the ring-axis
    concatenation. Returns the local output shard (B, S_local, H, hd)."""
    b, sq, h, hd = q.shape
    nkv = k.shape[2]
    qg = q.reshape(b, sq, nkv, h // nkv, hd)
    r = lax.axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    if q_offset is None:
        q_pos = idx * sq + jnp.arange(sq)
    else:
        q_pos = q_offset + jnp.arange(sq)

    m0 = jnp.full((b, nkv, h // nkv, sq), MASK_VALUE, jnp.float32)
    l0 = jnp.zeros((b, nkv, h // nkv, sq), jnp.float32)
    a0 = jnp.zeros((b, nkv, h // nkv, sq, hd), jnp.float32)
    perm = [(i, (i + 1) % r) for i in range(r)]

    @jax.checkpoint  # flash semantics: recompute ring blocks in backward
    def body(carry, step):
        m, l, acc, k_blk, v_blk, kv_owner = carry
        kv_pos = kv_owner * k_blk.shape[1] + jnp.arange(k_blk.shape[1])
        m2, l2, a2 = _local_attention(qg, k_blk, v_blk, q_pos, kv_pos, causal)
        m, l, acc = _merge(m, l, acc, m2, l2, a2)
        # stream the KV block to the next ring neighbour
        k_blk = lax.ppermute(k_blk, axis_name, perm)
        v_blk = lax.ppermute(v_blk, axis_name, perm)
        kv_owner = lax.ppermute(kv_owner, axis_name, perm)
        return (m, l, acc, k_blk, v_blk, kv_owner), None

    init = (m0, l0, a0, k, v, idx)
    (m, l, acc, _, _, _), _ = lax.scan(body, init, jnp.arange(r))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def ring_attention_sharded(q, k, v, mesh, *, axis_name: str = "model",
                           causal: bool = True, batch_axes=("pod", "data")):
    """jit-level wrapper: shard (B, S, H, hd) inputs by (batch, seq) and run
    the ring. Usable directly inside a pjit'd step function."""
    baxes = tuple(a for a in batch_axes if a in mesh.shape)
    spec_q = P(baxes if baxes else None, axis_name, None, None)
    fn = functools.partial(ring_attention, axis_name=axis_name, causal=causal)
    return jax.shard_map(
        fn, mesh=mesh,
        in_specs=(spec_q, spec_q, spec_q),
        out_specs=spec_q,
        check_vma=False,
    )(q, k, v)
