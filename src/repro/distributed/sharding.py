"""Logical-axis sharding rule engine.

Model code annotates tensors with *logical* axis names (``"batch"``,
``"heads"``, ``"experts"`` …). A ``ShardingRules`` context maps those names to
mesh axes, with automatic divisibility fallback (an axis whose size does not
divide the mesh extent is left unsharded — e.g. Arctic's 56 query heads on a
16-way model axis). The same model code therefore runs unmodified on a single
CPU device, a (data, model) pod, or a (pod, data, model) multi-pod mesh.

Per-architecture overrides come from ``ModelConfig.sharding_overrides``;
per-shape overrides (e.g. sequence-sharding the 500k KV cache when
global_batch=1) from the launch layer.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Optional, Union

import jax
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisRule = Union[None, str, tuple[str, ...]]

#: logical axis -> mesh axis (or tuple of mesh axes). Axes absent from the
#: active mesh are dropped, so one rule set serves 1-pod and 2-pod meshes.
DEFAULT_RULES: dict[str, AxisRule] = {
    "batch": ("pod", "data"),
    "moe_groups": ("pod", "data"),
    "vocab": "model",
    "embed": "data",          # FSDP over parameter rows
    "heads": "model",
    "kv_heads": "model",
    # context parallelism for archs whose head count doesn't divide the model
    # axis (arctic 56H, starcoder2 36H, paligemma 8H): override to "model" so
    # attention work shards by sequence instead of being 16x replicated.
    "attn_seq": None,
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "moe_mlp": None,
    "layers": None,
    "seq": None,
    "cache_seq": None,        # long-context decode overrides this to "data"
    "ssm_heads": "model",
    "ssm_state": None,
    "conv": None,
    "frontend": None,
}

_tls = threading.local()


@dataclass
class ShardingRules:
    mesh: Mesh
    rules: dict[str, AxisRule] = field(default_factory=dict)

    def __post_init__(self):
        merged = dict(DEFAULT_RULES)
        merged.update(self.rules)
        self.rules = merged

    # -- resolution ------------------------------------------------------------
    def mesh_axes_for(self, logical: Optional[str]) -> tuple[str, ...]:
        rule = self.rules.get(logical) if logical else None
        if rule is None:
            return ()
        axes = (rule,) if isinstance(rule, str) else tuple(rule)
        return tuple(a for a in axes if a in self.mesh.shape)

    def _extent(self, axes: tuple[str, ...]) -> int:
        return int(np.prod([self.mesh.shape[a] for a in axes])) if axes else 1

    def spec_for(self, shape: tuple[int, ...],
                 axes: tuple[Optional[str], ...]) -> P:
        """PartitionSpec with divisibility fallback; mesh axes used once."""
        used: set[str] = set()
        entries = []
        for dim, logical in zip(shape, axes):
            mesh_axes = tuple(a for a in self.mesh_axes_for(logical)
                              if a not in used)
            if mesh_axes and dim % self._extent(mesh_axes) == 0:
                entries.append(mesh_axes[0] if len(mesh_axes) == 1 else mesh_axes)
                used.update(mesh_axes)
            else:
                entries.append(None)
        return P(*entries)

    def named(self, shape: tuple[int, ...],
              axes: tuple[Optional[str], ...]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(shape, axes))


def current_rules() -> Optional[ShardingRules]:
    return getattr(_tls, "rules", None)


@contextmanager
def activate_rules(rules: Optional[ShardingRules]):
    prev = current_rules()
    _tls.rules = rules
    try:
        yield rules
    finally:
        _tls.rules = prev


def shard(x, axes: tuple[Optional[str], ...]):
    """Annotate ``x`` with logical axes; no-op outside a rules context."""
    rules = current_rules()
    if rules is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"axes {axes} vs rank-{x.ndim} tensor")
    spec = rules.spec_for(x.shape, axes)
    return lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


def param_sharding(layout, rules: ShardingRules):
    """NamedSharding tree for a parameter layout (for jit in_shardings)."""
    from repro.models.params import tree_map_specs  # lazy: avoids import cycle
    return tree_map_specs(lambda s: rules.named(s.shape, s.axes), layout)


def input_sharding(rules: ShardingRules, shape: tuple[int, ...],
                   axes: tuple[Optional[str], ...]) -> NamedSharding:
    return rules.named(shape, axes)
