from .sharding import (DEFAULT_RULES, ShardingRules, activate_rules,
                       current_rules, input_sharding, param_sharding, shard)

__all__ = ["DEFAULT_RULES", "ShardingRules", "activate_rules", "current_rules",
           "input_sharding", "param_sharding", "shard"]
