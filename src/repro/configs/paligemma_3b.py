"""PaliGemma-3B [arXiv:2407.07726]: SigLIP patch frontend (stub) + Gemma LM.

MQA (kv=1), head_dim 256, tied embeddings over the 257k vocab. The SigLIP
tower is a STUB per the assignment: ``input_specs`` provides 256 precomputed
1152-d patch embeddings which are linearly projected and prepended to the
text sequence.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257216,
    mlp_act="gelu",
    tie_embeddings=True,
    frontend="patch",
    frontend_dim=1152,
    frontend_len=256,
    remat="full",
    logit_chunk=640,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
                          head_dim=16, d_ff=128, vocab_size=512,
                          frontend_dim=32, frontend_len=8)
