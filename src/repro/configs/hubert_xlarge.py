"""HuBERT-XLarge [arXiv:2106.07447]: encoder-only audio transformer.

The conv waveform frontend is a STUB per the assignment: ``input_specs``
provides precomputed 512-d frame features; the model projects them to
d_model. vocab=504 is the masked-prediction cluster codebook. Bidirectional
attention; RoPE substitutes for the original conv positional embedding
(hardware-adaptation note in DESIGN.md).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    encoder_only=True,
    causal=False,
    mlp_gated=False,
    mlp_act="gelu",
    frontend="frame",
    frontend_dim=512,
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
                          head_dim=16, d_ff=128, vocab_size=64, frontend_dim=32)
