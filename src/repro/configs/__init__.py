"""Architecture registry: the 10 assigned configs + shapes + skip rules."""
from . import (arctic_480b, hubert_xlarge, internlm2_1_8b, mistral_nemo_12b,
               olmoe_1b_7b, paligemma_3b, starcoder2_7b, xlstm_350m, yi_6b,
               zamba2_1_2b)
from .base import (DECODE_32K, LONG_500K, PREFILL_32K, SHAPES, TRAIN_4K,
                   ModelConfig, ShapeConfig, runnable)

_MODULES = {
    "arctic-480b": arctic_480b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "mistral-nemo-12b": mistral_nemo_12b,
    "starcoder2-7b": starcoder2_7b,
    "yi-6b": yi_6b,
    "internlm2-1.8b": internlm2_1_8b,
    "hubert-xlarge": hubert_xlarge,
    "xlstm-350m": xlstm_350m,
    "paligemma-3b": paligemma_3b,
    "zamba2-1.2b": zamba2_1_2b,
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_NAMES}")
    return _MODULES[name].CONFIG


def get_reduced_config(name: str) -> ModelConfig:
    return _MODULES[name].reduced()


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def all_cells():
    """All 40 (arch, shape) cells with runnability verdicts."""
    out = []
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = runnable(cfg, shape)
            out.append((arch, shape.name, ok, why))
    return out
