"""OLMoE-1B-7B [arXiv:2409.02060]: 64 experts, top-8."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    num_experts=64,
    experts_per_token=8,
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=64, num_experts=8, experts_per_token=4, moe_group_size=64,
        vocab_size=256, remat="none")
