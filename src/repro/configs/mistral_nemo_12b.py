"""Mistral-Nemo-Base-2407 (12B dense, 128k ctx) [hf:mistralai].

head_dim is 128 (explicit: 32 heads x 128 = 4096 != d_model 5120).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                          head_dim=16, d_ff=128, vocab_size=256, remat="none")
