"""StarCoder2-7B [arXiv:2402.19173]: GQA kv=4, RoPE, plain-GELU 4x FFN."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    mlp_gated=False,
    mlp_act="gelu",
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                          head_dim=16, d_ff=256, vocab_size=256, remat="none")
