"""Snowflake Arctic-base (480B MoE): 128 experts top-2 + dense residual branch.

[hf:Snowflake/snowflake-arctic-base]. 35L, d_model 7168, 56 heads (GQA kv=8),
expert d_ff 4864, vocab 32000. The dense residual FFN (Arctic's
"dense-MoE hybrid") uses 2*d_model = 14336, bringing the total to ~484B.
56 heads do not divide the 16-way model axis, so attention runs DP/FSDP and
tensor parallelism comes from expert parallelism (see DESIGN §4).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    num_experts=128,
    experts_per_token=2,
    moe_dense_ff=14336,
    # 480B training state cannot hold fp32 Adam on 256 chips x 16 GB:
    # bf16 params + int8 quantized moments (DESIGN §3).
    param_dtype="bfloat16",
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
        d_ff=96, moe_dense_ff=128, num_experts=8, experts_per_token=2,
        moe_group_size=64, vocab_size=256, param_dtype="float32", remat="none")
