"""Yi-6B [arXiv:2403.04652]: llama-architecture GQA."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                          head_dim=16, d_ff=128, vocab_size=256, remat="none")
