"""InternLM2-1.8B [arXiv:2403.17297]: GQA kv=8."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92544,
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                          head_dim=16, d_ff=128, vocab_size=256)
