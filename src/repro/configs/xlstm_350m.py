"""xLSTM-350M-class [arXiv:2405.04517]: alternating sLSTM + mLSTM blocks.

24 blocks = 12 (mLSTM, sLSTM) pairs, d_model 1024, 4 heads. Recurrent state
is O(1) in sequence length, so this arch runs the long_500k cell.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    ssm_variant="xlstm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    tie_embeddings=True,
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
                          head_dim=16, vocab_size=256)
