"""Zamba2-1.2B [arXiv:2411.15242]: Mamba2 backbone + shared attention block.

38 Mamba2 layers (d_state 64, expand 2, headdim 64 -> 64 SSM heads) with one
weight-shared attention+FFN block applied every 6 layers (6 applications).
SSM state is O(1) in sequence length, so this arch runs the long_500k cell
(the shared block's KV cache is sequence-sharded over the data axis there).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    ssm_variant="mamba2",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    shared_attn_every=6,
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
                          head_dim=16, d_ff=128, vocab_size=256, ssm_state=16,
                          ssm_headdim=16, shared_attn_every=2)
