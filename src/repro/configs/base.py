"""Model + shape configuration schema.

Every assigned architecture is a ``ModelConfig``; every assigned input shape a
``ShapeConfig``. ``(ModelConfig, ShapeConfig)`` cells drive smoke tests, the
multi-pod dry-run and the roofline table.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | audio | ssm | vlm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # --- MoE ---------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 512        # GShard dispatch group size (tokens)
    moe_impl: str = "einsum"         # "einsum" (GShard baseline) | "sort"
    moe_dense_ff: int = 0            # Arctic: parallel dense-residual FFN width
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2

    # --- SSM (Mamba2 / xLSTM) -----------------------------------------------
    ssm_variant: str = ""            # "mamba2" | "xlstm"
    ssm_state: int = 0               # N (d_state)
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 128             # SSD chunk length
    ssm_conv: int = 4                # short conv window

    # --- hybrid (Zamba2) -----------------------------------------------------
    shared_attn_every: int = 0       # apply the shared attention block every k layers

    # --- FFN ------------------------------------------------------------------
    mlp_gated: bool = True           # SwiGLU/GeGLU vs plain 2-matrix FFN
    mlp_act: str = "silu"            # "silu" | "gelu"

    # --- attention / positions -----------------------------------------------
    causal: bool = True
    encoder_only: bool = False
    rope_theta: float = 10_000.0
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    attn_impl: str = "chunked"       # "chunked" | "dense" | "pallas"
    attn_block_triangular: bool = False  # skip fully-masked KV chunks (perf opt)

    # --- serving (paged KV cache / continuous batching) ------------------------
    page_size: int = 16              # KV rows per physical cache page
    max_decode_slots: int = 8        # concurrent requests the serve engine admits
    prefill_chunk: int = 32          # query tokens per paged-prefill step
    enable_prefix_cache: bool = True # share prompt-prefix pages copy-on-write
    # Gateway decode preemption: an otherwise-infeasible interactive request
    # may pause the latest-deadline batch-class slot (KV pages pinned,
    # lossless zero-re-prefill resume) when the feasibility walk says the
    # pause meets BOTH deadlines. Consumed by the serving launcher when it
    # builds the gateway's DeadlineCostPolicy; pools should keep page
    # headroom, since a paused request's pages stay allocated while parked.
    enable_decode_preemption: bool = True
    # Self-speculative decode: each engine step drafts spec_tokens candidates
    # per slot by n-gram lookup over the slot's own token history and scores
    # all spec_tokens+1 positions in one paged multi-query verify pass.
    # Greedy outputs are token-identical to the non-speculative path; the win
    # is fewer engine steps per token on repetitive/structured output.
    enable_spec_decode: bool = False
    spec_tokens: int = 4             # drafted tokens per verify step (K)
    # Draft-key order: 2 = trailing bigram (hist[pos-1], cur); 3 = trailing
    # trigram, falling back to the bigram match when the trigram has no
    # earlier occurrence (sharper drafts on structured output, same greedy
    # tokens either way — verification restores exactness).
    spec_ngram: int = 2
    # Batch-adaptive decode tuning (the BENCH_serve batch-32 droop):
    # split-KV fills cores that idle when the decode batch is narrow, so the
    # split count is chosen as ~decode_split_budget / slot_width, where
    # slot_width is the dispatch's static batch dimension (max_slots — NOT
    # the live request count, which would retrace per occupancy level),
    # clamped to a divisor of the page-table width; the decode chunk length
    # targets ~decode_chunk_tokens tokens per on-device chunk dispatch,
    # clamped to
    # [decode_chunk_min, decode_chunk_max] — wide batches amortize the host
    # sync across slots and take shorter chunks, which also re-admits queued
    # requests sooner (lower p95). Under spec decode a step emits up to
    # spec_tokens+1 tokens, so the engine divides both the token target and
    # decode_chunk_min by that window (floor 2): chunks are sized in emitted
    # tokens, not steps.
    decode_split_budget: int = 32    # target batch * num_splits product
    decode_chunk_tokens: int = 256   # target slots * decode_chunk product
    decode_chunk_min: int = 8
    decode_chunk_max: int = 32
    # Paged KV pool storage dtype: "f32" keeps pages in the compute dtype
    # (the exact baseline path); "int8" stores pages as symmetric per-row
    # int8 with an f32 scale per (layer, kv-head, page, row) — the paged
    # attention kernels dequantize inside their K/V tile loads with f32
    # accumulation, so the pool holds ~3.9x the tokens per HBM byte at
    # hd=128 while greedy decode stays token-identical on the parity suite
    # (tests/test_kv_parity.py). Opt-in: "f32" is byte-identical to the
    # pre-quantization engine.
    kv_cache_dtype: str = "f32"
    # Per-slot adaptive speculation: each slot carries an accept-rate EMA
    # and shrinks/grows its draft window within 1..spec_tokens so verify
    # FLOPs track acceptance instead of paying K+1 query rows for slots
    # that accept nothing. Greedy outputs stay token-identical for ANY
    # window schedule (accepted prefixes are exact greedy matches).
    spec_adaptive_k: bool = False

    # --- modality frontend stub (audio / vlm) ---------------------------------
    frontend: str = ""               # "" | "frame" | "patch"
    frontend_dim: int = 0            # 512 (HuBERT features) / 1152 (SigLIP)
    frontend_len: int = 0            # image patches per example (PaliGemma: 256)

    # --- numerics / execution ---------------------------------------------------
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"          # activation/compute dtype
    param_dtype: str = "float32"
    logit_dtype: str = "float32"
    remat: str = "none"              # "none" | "full" | "dots"
    scan_layers: bool = True
    logit_chunk: int = 0             # chunk the loss over seq (0 = off)
    tie_embeddings: bool = False

    # --- per-arch sharding rule overrides (logical axis -> mesh axis name) ------
    sharding_overrides: dict = field(default_factory=dict, hash=False, compare=False)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_heads % max(self.num_kv_heads, 1):
            raise ValueError(f"{self.name}: heads {self.num_heads} not a multiple "
                             f"of kv heads {self.num_kv_heads}")
        if self.kv_cache_dtype not in ("f32", "int8"):
            raise ValueError(f"{self.name}: kv_cache_dtype must be 'f32' or "
                             f"'int8', got {self.kv_cache_dtype!r}")

    # -- dtypes -------------------------------------------------------------
    @property
    def cdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- derived sizes ----------------------------------------------------------
    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def param_count(self) -> int:
        """Total parameters (analytic; cross-checked against init in tests)."""
        from repro.models.registry import count_params  # lazy, avoids cycle
        return count_params(self)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed experts only)."""
        from repro.models.registry import count_params
        return count_params(self, active_only=True)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int         # train/prefill: sequence length; decode: KV-cache length
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


# The assigned LM-family shape set (same four for every architecture).
TRAIN_4K = ShapeConfig("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)

SHAPES: dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


def runnable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Assignment skip rules. Returns (runnable, reason_if_not)."""
    if cfg.encoder_only and shape.is_decode:
        return False, "encoder-only arch has no autoregressive decode step"
    full_attention = cfg.family in ("dense", "moe", "vlm") or (
        cfg.family == "audio")
    if shape.name == "long_500k" and full_attention:
        return False, "pure full-attention arch; long_500k requires sub-quadratic"
    return True, ""
