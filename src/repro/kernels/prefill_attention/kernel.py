"""Paged chunked-prefill GQA flash-attention Pallas TPU kernel.

The decode kernel streams pool pages for ONE query row per KV head; prefill
admission needs the same dataflow for a *chunk* of C prompt rows so admission
cost is O(new tokens) regardless of how long the already-cached context is.
One grid step attends the whole (C*G, hd) query block of a request against
one physical page:

- Grid = (B, KV, npages) with the page axis innermost (sequential on TPU), so
  the online-softmax accumulators for the chunk live in VMEM scratch across
  pages. No split-KV here: a chunk already exposes C*G rows of parallelism
  per KV head, and prefill normalizes in-kernel at the last page.
- Page indirection is resolved by the BlockSpec index map reading the
  scalar-prefetched page table, exactly as in ``kernels/decode_attention``:
  physical page ``pt[b, pi]`` is DMA'd HBM->VMEM while the previous page
  computes. Pages entirely beyond the chunk's last position (``q_start + C``)
  are skipped with ``pl.when`` (their DMA target is a clamped valid page, so
  no OOB traffic).
- Causality is positional: query row r (chunk offset r // G) at global
  position ``q_start[b] + r // G`` masks keys at positions greater than its
  own — that single rule covers both the history pages and the in-chunk
  lower-triangular block, because the chunk's own KV rows are scattered into
  the pool *before* the kernel runs.

This container is CPU-only: validated against ``ref.py`` in interpret mode
(tests/test_prefill_attention.py); on TPU silicon
``ops.paged_prefill_attention`` dispatches here for ``attn_impl="pallas"``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_prefill_kernel(pt_ref, qs_ref, q_ref, k_ref, v_ref, *rest,
                          scale: float, page_size: int, group: int,
                          chunk: int, quantized: bool = False):
    # ``quantized`` prepends per-row scale-page refs (see kernels/kv_quant):
    # K/V tiles arrive int8 and are dequantized in-register at load, so the
    # online-softmax body below is shared verbatim between both layouts.
    if quantized:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    pi = pl.program_id(2)          # logical page (innermost, sequential)
    start = pi * page_size
    qs = qs_ref[b]

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Skip pages wholly beyond the chunk's last query position.
    @pl.when(start <= qs + chunk - 1)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (C*G, hd)
        k = k_ref[0, 0].astype(jnp.float32)                # (ps, hd)
        v = v_ref[0, 0].astype(jnp.float32)                # (ps, hd)
        if quantized:
            k = k * ks_ref[0, 0][:, None]                  # f32 dequant
            v = v * vs_ref[0, 0][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        rows = q.shape[0]
        q_pos = qs + jax.lax.broadcasted_iota(
            jnp.int32, (rows, page_size), 0) // group
        kv_pos = start + jax.lax.broadcasted_iota(
            jnp.int32, (rows, page_size), 1)
        s = jnp.where(kv_pos <= q_pos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(pi == pl.num_programs(2) - 1)
    def _emit():
        o_ref[0, 0] = acc_scr[...] / jnp.maximum(l_scr[...], 1e-20)[:, None]


def flash_prefill_fwd(q, k_pages, v_pages, page_table, q_start, *,
                      k_scale=None, v_scale=None, interpret: bool = False):
    """q: (B,C,H,hd); k/v_pages: (KV,P,ps,hd); page_table: (B,npages) int32;
    q_start: (B,) int32 -> (B,C,H,hd). ``k_scale``/``v_scale``: optional
    (KV,P,ps) f32 per-row scale pages for an int8 pool — the kernel then
    dequantizes each K/V tile at load (f32 accumulation throughout)."""
    b, c, h, hd = q.shape
    nkv, _, page_size, _ = k_pages.shape
    g = h // nkv
    npages = page_table.shape[1]
    scale = 1.0 / math.sqrt(hd)
    quantized = k_scale is not None

    # Clamp table entries so skipped pages still DMA a valid physical page.
    pt = jnp.clip(page_table.astype(jnp.int32), 0, k_pages.shape[1] - 1)
    qr = q.reshape(b, c, nkv, g, hd).transpose(0, 2, 1, 3, 4) \
          .reshape(b, nkv, c * g, hd)

    grid = (b, nkv, npages)
    kernel = functools.partial(_flash_prefill_kernel, scale=scale,
                               page_size=page_size, group=g, chunk=c,
                               quantized=quantized)

    def page_index(bi, kv, pi, pt_ref, qs_ref):
        return (kv, pt_ref[bi, pi], 0, 0)

    def scale_index(bi, kv, pi, pt_ref, qs_ref):
        # Scale pages drop the trailing hd axis but share the page map.
        return (kv, pt_ref[bi, pi], 0)

    in_specs = [
        pl.BlockSpec((1, 1, c * g, hd),
                     lambda bi, kv, pi, pt, qs: (bi, kv, 0, 0)),
        pl.BlockSpec((1, 1, page_size, hd), page_index),
        pl.BlockSpec((1, 1, page_size, hd), page_index),
    ]
    inputs = [qr, k_pages, v_pages]
    if quantized:
        in_specs += [pl.BlockSpec((1, 1, page_size), scale_index)] * 2
        inputs += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, c * g, hd),
                               lambda bi, kv, pi, pt, qs: (bi, kv, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((c * g,), jnp.float32),      # running max m
            pltpu.VMEM((c * g,), jnp.float32),      # running denom l
            pltpu.VMEM((c * g, hd), jnp.float32),   # accumulator
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nkv, c * g, hd), jnp.float32),
        interpret=interpret,
    )(pt, q_start.astype(jnp.int32), *inputs)

    out = out.reshape(b, nkv, c, g, hd).transpose(0, 2, 1, 3, 4)
    return out.reshape(b, c, h, hd).astype(q.dtype)
