from .kernel import flash_prefill_fwd
from .ops import flash_prefill, paged_prefill_attention
from .ref import paged_prefill_reference

__all__ = ["flash_prefill", "flash_prefill_fwd", "paged_prefill_attention",
           "paged_prefill_reference"]
