"""jit'd public wrappers for the paged chunked-prefill kernel.

``flash_prefill`` is the raw kernel entry point (interpret-capable for CPU
validation). ``paged_prefill_attention`` is what the model prefill path
calls: it dispatches to the Pallas kernel on TPU silicon
(``attn_impl="pallas"``) and to the fused-gather jnp reference everywhere
else, mirroring ``kernels/decode_attention.paged_decode_attention``.
"""
from __future__ import annotations

from functools import partial

import jax

from .kernel import flash_prefill_fwd
from .ref import paged_prefill_reference


@partial(jax.jit, static_argnames=("interpret",))
def flash_prefill(q, k_pages, v_pages, page_table, q_start, *,
                  interpret: bool = False):
    return flash_prefill_fwd(q, k_pages, v_pages, page_table, q_start,
                             interpret=interpret)


def paged_prefill_attention(q, k_pages, v_pages, page_table, q_start, *,
                            impl: str = "pallas"):
    """Paged chunked-prefill GQA attention with backend dispatch."""
    if impl == "pallas" and jax.default_backend() == "tpu":
        return flash_prefill_fwd(q, k_pages, v_pages, page_table, q_start)
    return paged_prefill_reference(q, k_pages, v_pages, page_table, q_start)
