"""jit'd public wrappers for the paged chunked-prefill kernel.

``flash_prefill`` is the raw kernel entry point (interpret-capable for CPU
validation). ``paged_prefill_attention`` is what the model prefill path
calls: it dispatches to the Pallas kernel on TPU silicon
(``attn_impl="pallas"``) and to the fused-gather jnp reference everywhere
else, mirroring ``kernels/decode_attention.paged_decode_attention``.
"""
from __future__ import annotations

from functools import partial

import jax

from .kernel import flash_prefill_fwd
from .ref import paged_prefill_reference


@partial(jax.jit, static_argnames=("interpret",))
def flash_prefill(q, k_pages, v_pages, page_table, q_start, *,
                  k_scale=None, v_scale=None, interpret: bool = False):
    return flash_prefill_fwd(q, k_pages, v_pages, page_table, q_start,
                             k_scale=k_scale, v_scale=v_scale,
                             interpret=interpret)


def paged_prefill_attention(q, k_pages, v_pages, page_table, q_start, *,
                            k_scale=None, v_scale=None,
                            impl: str = "pallas"):
    """Paged chunked-prefill GQA attention with backend dispatch.

    ``k_scale``/``v_scale``: per-row scale pages for an int8 pool; both
    backends dequantize with identical f32 arithmetic.
    """
    if impl == "pallas" and jax.default_backend() == "tpu":
        return flash_prefill_fwd(q, k_pages, v_pages, page_table, q_start,
                                 k_scale=k_scale, v_scale=v_scale)
    return paged_prefill_reference(q, k_pages, v_pages, page_table, q_start,
                                   k_scale=k_scale, v_scale=v_scale)
