"""Pure-jnp oracle for paged chunked-prefill GQA attention.

A prefill *chunk* is ``C`` consecutive prompt tokens whose KV rows have
already been scattered into the block-paged pool (the same pool the decode
kernel reads). Each chunk query at global position ``q_start[b] + i`` attends
every pooled KV row at a position ``<= `` its own — history pages written by
earlier chunks (or by a shared prefix) plus the causal lower triangle of its
own in-chunk block. The oracle gathers the logical KV stream dense and runs
masked fp32 attention — the semantics the Pallas kernel must reproduce.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.ref import dequant_pages, gather_pages

MASK_VALUE = -1e30


def paged_prefill_reference(q, k_pages, v_pages, page_table, q_start,
                            k_scale=None, v_scale=None):
    """Chunked-prefill GQA attention over a paged KV cache.

    q: (B, C, H, hd) — RoPE'd queries for one chunk of C prompt tokens.
    k_pages/v_pages: (KV, P, page_size, hd) — the shared physical pool, with
        this chunk's own KV rows already written.
    page_table: (B, npages) int32 — per-request logical->physical page map.
    q_start: (B,) int32 — global position of ``q[:, 0]`` per request.
    k_scale/v_scale: optional (KV, P, page_size) f32 per-row scales for an
        int8 pool (see :mod:`repro.kernels.kv_quant`).
    Returns (B, C, H, hd). Rows past a request's real prompt length produce
    garbage (their keys were routed to the sink page); callers discard them.
    """
    b, c, h, hd = q.shape
    nkv = k_pages.shape[0]
    g = h // nkv
    if k_scale is not None:
        k_pages = dequant_pages(k_pages, k_scale)
        v_pages = dequant_pages(v_pages, v_scale)
    k = gather_pages(k_pages, page_table)            # (B, T, KV, hd)
    v = gather_pages(v_pages, page_table)
    t = k.shape[1]
    qg = q.reshape(b, c, nkv, g, hd)
    s = jnp.einsum("bqkgh,btkh->bkgqt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    q_pos = q_start[:, None] + jnp.arange(c)[None, :]              # (B, C)
    mask = jnp.arange(t)[None, None, :] <= q_pos[:, :, None]       # (B, C, T)
    s = jnp.where(mask[:, None, None, :, :], s, MASK_VALUE)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkh->bqkgh", p, v.astype(jnp.float32))
    return out.reshape(b, c, h, hd).astype(q.dtype)
