"""Pure-jnp oracle for the flash-attention kernel (GQA, causal optional)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

MASK_VALUE = -1e30


def attention_reference(q, k, v, *, causal: bool = True):
    """q: (B,Sq,H,hd); k/v: (B,Skv,KV,hd) -> (B,Sq,H,hd). fp32 softmax."""
    b, sq, h, hd = q.shape
    skv, nkv = k.shape[1], k.shape[2]
    g = h // nkv
    qg = q.reshape(b, sq, nkv, g, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :]
        s = jnp.where(mask[None, None, None], s, MASK_VALUE)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)
