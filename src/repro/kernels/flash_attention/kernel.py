"""Flash-attention forward Pallas TPU kernel (GQA, causal).

TPU-native adaptation of the FlashAttention dataflow (Dao et al. 2022,
arXiv:2205.14135), blocked for the MXU and the HBM->VMEM hierarchy:

- Grid = (batch·kv_head·q_group, Sq/BQ, Skv/BK); the KV axis is the innermost
  (sequential on TPU) grid dimension, so the online-softmax accumulators live
  in VMEM scratch across KV steps. The MXU sees (BQ,hd)x(hd,BK) and
  (BQ,BK)x(BK,hd) matmuls — both 128-aligned for BQ,BK multiples of 128.
- Q/K/V tiles are staged HBM->VMEM by ``pl.BlockSpec``; the (BQ,BK) score
  tile, running max/denominator and the fp32 output accumulator never touch
  HBM — the traffic the XLA fallback pays per tile (see
  launch/hlo_analysis.KERNEL_SCOPES) disappears here.
- VMEM budget @ BQ=BK=512, hd=128 fp32 accum:
    q 256KiB + k,v 256KiB ea + s-tile 1MiB + acc 256KiB + m/l 4KiB
    ≈ 2.1MiB << ~16MiB/core, leaving headroom for double-buffered K/V
  streaming (the Mosaic pipeliner overlaps the ki+1 DMA with ki compute).
- Causal masking by absolute block positions; KV blocks strictly above the
  diagonal are skipped with ``pl.when`` (block-triangular schedule: ~2x
  fewer tiles for causal self-attention).

This container is CPU-only: the kernel is validated against ``ref.py`` in
``interpret=True`` mode over shape/dtype sweeps (tests/test_kernels.py);
on TPU silicon ``ops.flash_attention`` is what ``attn_impl="pallas"``
dispatches to.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                      scale: float, block_q: int, block_k: int, causal: bool):
    qi = pl.program_id(1)          # Q-block index
    ki = pl.program_id(2)          # KV-block index (innermost, sequential)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Block-triangular schedule: skip KV blocks strictly above the diagonal.
    run = True
    if causal:
        run = ki * block_k <= qi * block_q + block_q - 1

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (BQ, hd)
        k = k_ref[0].astype(jnp.float32)                  # (BK, hd)
        v = v_ref[0].astype(jnp.float32)                  # (BK, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            k_pos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        block_q: int = 512, block_k: int = 512,
                        interpret: bool = False):
    """q: (B,Sq,H,hd); k/v: (B,Skv,KV,hd) -> (B,Sq,H,hd)."""
    b, sq, h, hd = q.shape
    skv, nkv = k.shape[1], k.shape[2]
    g = h // nkv
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    if sq % block_q or skv % block_k:
        raise ValueError(f"seq ({sq},{skv}) % blocks ({block_q},{block_k})")
    scale = 1.0 / math.sqrt(hd)

    # layout: (B*KV*G, S, hd) per stream; each KV stream feeds its G q-heads.
    qr = q.reshape(b, sq, nkv, g, hd).transpose(0, 2, 3, 1, 4) \
          .reshape(b * nkv * g, sq, hd)
    kr = jnp.repeat(k.transpose(0, 2, 1, 3).reshape(b * nkv, skv, hd), g,
                    axis=0)
    vr = jnp.repeat(v.transpose(0, 2, 1, 3).reshape(b * nkv, skv, hd), g,
                    axis=0)

    grid = (b * nkv * g, sq // block_q, skv // block_k)
    kernel = functools.partial(_flash_fwd_kernel, scale=scale,
                               block_q=block_q, block_k=block_k, causal=causal)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * nkv * g, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),        # running max m
            pltpu.VMEM((block_q,), jnp.float32),        # running denom l
            pltpu.VMEM((block_q, hd), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, nkv, g, sq, hd).transpose(0, 3, 1, 2, 4) \
              .reshape(b, sq, h, hd)
