"""Pallas TPU kernels for the compute hot spots.

Cloud Kotta itself has no kernel-level contribution (it is a scheduling /
storage / security paper), but the training & serving substrate it schedules
does: attention, the Mamba2 SSD scan and RMSNorm dominate step time. Each
kernel ships with ``ops.py`` (jit wrapper) and ``ref.py`` (pure-jnp oracle)
and is validated in interpret mode on CPU (tests/test_kernels.py); real-TPU
dispatch is selected by ``ModelConfig.attn_impl="pallas"``.
"""
from .decode_attention import (flash_decode, paged_decode_attention,
                               paged_decode_reference)
from .flash_attention import attention_reference, flash_attention
from .kv_quant import dequantize_rows, quantize_pool, quantize_rows
from .mamba_scan import mamba_chunk_scan, ssd_reference
from .prefill_attention import (flash_prefill, paged_prefill_attention,
                                paged_prefill_reference)
from .rmsnorm import rmsnorm, rmsnorm_reference
from .verify_attention import (flash_verify, paged_verify_attention,
                               paged_verify_reference)

__all__ = ["flash_attention", "attention_reference", "mamba_chunk_scan",
           "ssd_reference", "rmsnorm", "rmsnorm_reference", "flash_decode",
           "paged_decode_attention", "paged_decode_reference",
           "flash_prefill", "paged_prefill_attention",
           "paged_prefill_reference", "flash_verify",
           "paged_verify_attention", "paged_verify_reference",
           "quantize_rows", "dequantize_rows", "quantize_pool"]
