"""Pure-jnp oracle for fused RMSNorm."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def rmsnorm_reference(x, weight, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * lax.rsqrt(var + eps) * weight.astype(jnp.float32)) \
        .astype(x.dtype)
