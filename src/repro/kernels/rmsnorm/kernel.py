"""Fused RMSNorm Pallas TPU kernel.

Row-blocked: grid over (rows/BR); each step loads a (BR, D) tile to VMEM,
computes fp32 mean-square + rsqrt + scale in one pass, writes the tile back —
one HBM read + one write per element (XLA emits separate reduce + scale
passes plus an f32 upcast round-trip when not fused).

VMEM @ BR=256, D=8192: tile 4 MiB bf16 read + fp32 stats (BR,1) — fits
comfortably; D up to ~16k stays under budget at BR=128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                  # (BR, D)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * w_ref[...].astype(jnp.float32)[None, :]).astype(o_ref.dtype)


def rmsnorm_fused(x, weight, eps: float = 1e-6, block_rows: int = 256,
                  interpret: bool = False):
    """x: (..., D) -> same shape; stats in fp32."""
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    xr = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    if rows % block_rows:
        raise ValueError(f"rows {rows} % block {block_rows}")
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(xr, weight)
    return out.reshape(shape)
