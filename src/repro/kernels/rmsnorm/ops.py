"""jit'd public wrapper for the fused RMSNorm kernel."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import rmsnorm_fused


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, weight, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = False):
    return rmsnorm_fused(x, weight, eps, block_rows, interpret)
