"""jit'd public wrappers for the paged flash-decode kernel.

``flash_decode`` is the raw kernel entry point (interpret-capable for CPU
validation). ``paged_decode_attention`` is what the model decode path calls:
it dispatches to the Pallas kernel on TPU silicon (``attn_impl="pallas"``)
and to the fused-gather jnp reference everywhere else, so the same serving
engine runs on a laptop CPU and a TPU pod slice.
"""
from __future__ import annotations

from functools import partial

import jax

from .kernel import flash_decode_fwd
from .ref import paged_decode_reference


@partial(jax.jit, static_argnames=("num_splits", "interpret"))
def flash_decode(q, k_pages, v_pages, page_table, lengths, *,
                 k_scale=None, v_scale=None,
                 num_splits: int = 1, interpret: bool = False):
    return flash_decode_fwd(q, k_pages, v_pages, page_table, lengths,
                            k_scale=k_scale, v_scale=v_scale,
                            num_splits=num_splits, interpret=interpret)


def default_num_splits(npages: int, target: int = 4, *, batch: int = 0,
                       split_budget: int = 0) -> int:
    """Largest split count <= target that divides the page-table width.

    When ``batch`` and ``split_budget`` are given, the target adapts to
    occupancy: split-KV exists to fill cores that idle when few slots are
    active, but at high occupancy the (B, KV) grid axes already cover the
    chip and extra splits only add partial-combine overhead (the batch-32
    droop in BENCH_serve.json). Holding ``batch * splits`` near the budget
    gives split counts of 32/8/1 at batch 1/4/32 for the default budget —
    see ``ModelConfig.decode_split_budget``.
    """
    if split_budget and batch:
        target = max(1, split_budget // batch)
    for s in range(min(target, npages), 0, -1):
        if npages % s == 0:
            return s
    return 1


def paged_decode_attention(q, k_pages, v_pages, page_table, lengths, *,
                           k_scale=None, v_scale=None,
                           impl: str = "pallas", split_budget: int = 32):
    """Paged GQA decode attention with backend dispatch (see module doc).

    ``k_scale``/``v_scale``: per-row scale pages for an int8 pool; both
    backends dequantize with identical f32 arithmetic (kernel: per tile
    load; reference: whole pool up front).
    """
    if impl == "pallas" and jax.default_backend() == "tpu":
        splits = default_num_splits(page_table.shape[1],
                                    batch=page_table.shape[0],
                                    split_budget=split_budget)
        return flash_decode_fwd(q, k_pages, v_pages, page_table, lengths,
                                k_scale=k_scale, v_scale=v_scale,
                                num_splits=splits)
    return paged_decode_reference(q, k_pages, v_pages, page_table, lengths,
                                  k_scale=k_scale, v_scale=v_scale)
