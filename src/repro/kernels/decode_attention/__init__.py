from .ops import (default_num_splits, flash_decode, paged_decode_attention)
from .ref import gather_pages, paged_decode_reference

__all__ = ["flash_decode", "paged_decode_attention", "paged_decode_reference",
           "gather_pages", "default_num_splits"]
