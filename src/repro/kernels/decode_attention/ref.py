"""Pure-jnp oracle for paged GQA flash-decode.

The cache is a *block-paged* pool: physical pages of ``page_size`` KV rows,
addressed per request through a page table. The oracle gathers each request's
logical KV stream back into a dense (B, T, KV, hd) view and runs masked
attention in fp32 — the semantics the Pallas kernel must reproduce.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

MASK_VALUE = -1e30


def gather_pages(pages, page_table):
    """(KV, P, ps, hd), (B, npages) -> dense (B, T, KV, hd), T = npages*ps."""
    nkv, _, ps, hd = pages.shape
    b, npages = page_table.shape
    seq = pages[:, page_table]                       # (KV, B, npages, ps, hd)
    seq = seq.transpose(1, 2, 3, 0, 4)               # (B, npages, ps, KV, hd)
    return seq.reshape(b, npages * ps, nkv, hd)


def dequant_pages(pages, scale):
    """Dequantize an int8 pool up front: (KV,P,ps,hd) int8 * (KV,P,ps) f32.

    This defines the int8 semantics the Pallas kernels must reproduce
    tightly (they dequantize per K/V tile load instead, with identical
    arithmetic); the looser int8-vs-f32 output error is governed by the
    tiered bounds in tests/test_kv_parity.py.
    """
    return pages.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]


def paged_decode_reference(q, k_pages, v_pages, page_table, lengths,
                           k_scale=None, v_scale=None):
    """Single-step GQA attention over a paged KV cache.

    q: (B, H, hd) — the new token's queries.
    k_pages/v_pages: (KV, P, page_size, hd) — the shared physical pool.
    page_table: (B, npages) int32 — logical page i of request b lives in
        physical page ``page_table[b, i]``.
    lengths: (B,) int32 — valid KV rows per request (cache slots >= length
        are masked; ragged batches need no host-side padding).
    k_scale/v_scale: optional (KV, P, page_size) f32 per-row scales for an
        int8 pool (see :mod:`repro.kernels.kv_quant`).
    Returns (B, H, hd).
    """
    b, h, hd = q.shape
    nkv = k_pages.shape[0]
    g = h // nkv
    if k_scale is not None:
        k_pages = dequant_pages(k_pages, k_scale)
        v_pages = dequant_pages(v_pages, v_scale)
    k = gather_pages(k_pages, page_table)            # (B, T, KV, hd)
    v = gather_pages(v_pages, page_table)
    t = k.shape[1]
    qg = q.reshape(b, nkv, g, hd)
    s = jnp.einsum("bkgh,btkh->bkgt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    mask = jnp.arange(t)[None, :] < lengths[:, None]               # (B, T)
    s = jnp.where(mask[:, None, None, :], s, MASK_VALUE)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkh->bkgh", p, v.astype(jnp.float32))
    return out.reshape(b, h, hd).astype(q.dtype)
