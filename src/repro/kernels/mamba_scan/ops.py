"""jit'd public wrapper for the SSD chunk-scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import ssd_scan


@partial(jax.jit, static_argnames=("chunk", "head_block", "interpret"))
def mamba_chunk_scan(x, dt, a_log, bmat, cmat, *, chunk: int = 128,
                     head_block: int = 8, interpret: bool = False):
    return ssd_scan(x, dt, a_log, bmat, cmat, chunk=chunk,
                    head_block=head_block, interpret=interpret)
