from .ops import mamba_chunk_scan
from .ref import ssd_reference

__all__ = ["mamba_chunk_scan", "ssd_reference"]
