"""Pure-jnp oracle for the Mamba2/SSD chunk kernel: sequential recurrence."""
from __future__ import annotations

import jax.numpy as jnp


def ssd_reference(x, dt, a_log, bmat, cmat):
    """Sequential SSD recurrence.

    x: (B,S,H,P); dt: (B,S,H); a_log: (H,); bmat/cmat: (B,S,N).
    Returns (y: (B,S,H,P), h_final: (B,H,N,P)). fp32 throughout.
    """
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))
    hstate = jnp.zeros((b, h, n, p), jnp.float32)
    ys = []
    for t in range(s):
        dt_t = dt[:, t].astype(jnp.float32)                   # (B,H)
        decay = jnp.exp(dt_t * a)
        upd = jnp.einsum("bh,bn,bhp->bhnp", dt_t,
                         bmat[:, t].astype(jnp.float32),
                         x[:, t].astype(jnp.float32))
        hstate = decay[:, :, None, None] * hstate + upd
        ys.append(jnp.einsum("bn,bhnp->bhp",
                             cmat[:, t].astype(jnp.float32), hstate))
    return jnp.stack(ys, axis=1).astype(x.dtype), hstate
