"""Mamba2 / SSD chunk-scan Pallas TPU kernel.

TPU-native blocking of the state-space-duality algorithm (Dao & Gu,
arXiv:2405.21060 §6). One grid step processes one (batch, head-block) chunk
of L timesteps entirely in VMEM:

- Grid = (B·H/BH, S/L); the chunk axis is innermost (sequential), the SSM
  state (BH, N, P) persists in VMEM scratch across chunks — the recurrence
  never round-trips HBM.
- Per chunk the kernel computes, all on the MXU:
    CB^T (L,L) ⊙ segsum-decay, masked lower-triangular -> intra-chunk Y
    C · h_state (L,P) -> inter-chunk Y
    decay-weighted B^T X (N,P) -> state update.
- VMEM @ L=128, N=64, P=64, BH=8:
    x,dt,B,C tiles ~ (128·64·4)·3 + s-tile 128²·4 + state 8·64·64·4
    ≈ 0.5 MiB — small; BH (heads per block) is the occupancy lever.
- The (L,L) decay matrix is built from a cumulative log-sum (segsum) with
  broadcasted iota, not a gather — MXU/VPU friendly.

Layout note: heads are blocked on the leading grid axis so one kernel
instance owns BH heads; B/C are shared across heads (n_groups=1) and staged
once per chunk.

Validated against ``ref.py`` (sequential recurrence) in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, y_ref, h_scr, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)          # (L, BH, P)
    dt = dt_ref[0].astype(jnp.float32)        # (L, BH)
    a = -jnp.exp(alog_ref[0].astype(jnp.float32))  # (BH,)
    bmat = b_ref[0].astype(jnp.float32)       # (L, N)
    cmat = c_ref[0].astype(jnp.float32)       # (L, N)

    la = jnp.cumsum(dt * a[None, :], axis=0)  # (L, BH) log-decay prefix
    # intra-chunk: Y[l] += sum_{m<=l} (C_l.B_m) exp(la_l - la_m) dt_m x_m
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    mi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    seg = la[:, None, :] - la[None, :, :]                     # (L, M, BH)
    decay = jnp.where((li >= mi)[:, :, None], jnp.exp(seg), 0.0)
    w = cb[:, :, None] * decay                                # (L, M, BH)
    wx = dt[:, :, None] * x                                   # (L, BH, P)
    y = jnp.einsum("lmh,mhp->lhp", w, wx)
    # inter-chunk: Y[l] += C_l · (exp(la_l) ⊙ h_prev)
    y = y + jnp.einsum("ln,lh,hnp->lhp", cmat, jnp.exp(la), h_scr[...])
    y_ref[0] = y.astype(y_ref.dtype)
    # state update: h = exp(la_last) h + sum_m exp(la_last - la_m) dt_m B_m x_m^T
    w_state = jnp.exp(la[-1:, :] - la) * dt                   # (L, BH)
    st = jnp.einsum("ln,lh,lhp->hnp", bmat, w_state, x)
    h_scr[...] = jnp.exp(la[-1, :])[:, None, None] * h_scr[...] + st


def ssd_scan(x, dt, a_log, bmat, cmat, *, chunk: int = 128,
             head_block: int = 8, interpret: bool = False):
    """x: (B,S,H,P); dt: (B,S,H); a_log: (H,); bmat/cmat: (B,S,N).

    Returns y: (B,S,H,P) (no D-skip/gating — those fuse outside).
    """
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    chunk = min(chunk, s)
    head_block = min(head_block, h)
    if s % chunk or h % head_block:
        raise ValueError(f"S {s} % chunk {chunk} or H {h} % block {head_block}")
    nb = h // head_block

    # (B·nb, S, BH, ...) streams
    xr = x.reshape(b, s, nb, head_block, p).transpose(0, 2, 1, 3, 4) \
          .reshape(b * nb, s, head_block, p)
    dtr = dt.reshape(b, s, nb, head_block).transpose(0, 2, 1, 3) \
            .reshape(b * nb, s, head_block)
    ar = jnp.tile(a_log.reshape(nb, head_block), (b, 1))      # (B·nb, BH)
    br = jnp.broadcast_to(bmat[:, None], (b, nb, s, n)).reshape(b * nb, s, n)
    cr = jnp.broadcast_to(cmat[:, None], (b, nb, s, n)).reshape(b * nb, s, n)

    grid = (b * nb, s // chunk)
    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, head_block, p), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((1, chunk, head_block), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, head_block), lambda i, c: (i, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, c: (i, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, head_block, p),
                               lambda i, c: (i, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * nb, s, head_block, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((head_block, n, p), jnp.float32)],
        interpret=interpret,
    )(xr, dtr, ar, br, cr)
    return out.reshape(b, nb, s, head_block, p).transpose(0, 2, 1, 3, 4) \
              .reshape(b, s, h, p)
