"""Split-KV paged multi-query (speculative-verify) GQA Pallas TPU kernel.

``kernels/decode_attention`` streams pool pages for ONE query row per KV
head; speculative decode needs the same dataflow for the T = K+1 rows of a
draft window so all candidates are verified in a single pass over the cache.
This kernel is the T>1 generalization of the flash-decode kernel — it keeps
the FlashDecoding split-KV dataflow (long caches still use the full chip)
and adds the prefill kernel's positional causal mask inside the window:

- Grid = (B, KV, splits, pages_per_split). The page axis is innermost
  (sequential on TPU), so the online-softmax accumulators for one split live
  in VMEM scratch across its pages. Each split emits an *unnormalized*
  partial (acc, m, l); the cheap associative combine over splits happens in
  jnp outside the kernel.
- The query block is the whole (T*G, hd) window per KV head. Query row r
  (draft offset r // G) sits at global position ``pos[b] + r // G`` and
  masks keys at positions greater than its own — one rule covers both the
  verified history pages and the in-window lower triangle, because the
  window's own KV rows are scattered into the pool *before* the kernel runs
  (exactly as in ``kernels/prefill_attention``).
- Page indirection is resolved by the BlockSpec index map reading the
  scalar-prefetched page table; pages entirely past the window's last
  position (``pos + T``) are skipped with ``pl.when`` (their DMA target is a
  clamped valid page, so no OOB traffic).
- T=1 reproduces the decode kernel exactly (lengths = pos + 1).

This container is CPU-only: validated against ``ref.py`` in interpret mode
(tests/test_verify_attention.py); on TPU silicon
``ops.paged_verify_attention`` dispatches here for ``attn_impl="pallas"``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_verify_kernel(pt_ref, pos_ref, q_ref, k_ref, v_ref, *rest,
                         scale: float, page_size: int, pages_per_split: int,
                         group: int, window: int, quantized: bool = False):
    # ``quantized`` prepends per-row scale-page refs (see kernels/kv_quant):
    # K/V tiles arrive int8 and are dequantized in-register at load, so the
    # online-softmax body below is shared verbatim between both layouts.
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_ref, l_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    sp = pl.program_id(2)          # split index
    pi = pl.program_id(3)          # page-within-split (innermost, sequential)
    page_global = sp * pages_per_split + pi
    start = page_global * page_size
    pos = pos_ref[b]

    @pl.when(pi == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Skip pages entirely past the window's last query position.
    @pl.when(start <= pos + window - 1)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (T*G, hd)
        k = k_ref[0, 0].astype(jnp.float32)                # (ps, hd)
        v = v_ref[0, 0].astype(jnp.float32)                # (ps, hd)
        if quantized:
            k = k * ks_ref[0, 0][:, None]                  # f32 dequant
            v = v * vs_ref[0, 0][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        rows = q.shape[0]
        q_pos = pos + jax.lax.broadcasted_iota(
            jnp.int32, (rows, page_size), 0) // group
        kv_pos = start + jax.lax.broadcasted_iota(
            jnp.int32, (rows, page_size), 1)
        s = jnp.where(kv_pos <= q_pos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(pi == pages_per_split - 1)
    def _emit_partial():
        # Unnormalized: the split combine normalizes once, globally.
        o_ref[0, 0, 0] = acc_scr[...]
        m_ref[0, 0, 0] = m_scr[...]
        l_ref[0, 0, 0] = l_scr[...]


def flash_verify_fwd(q, k_pages, v_pages, page_table, pos, *,
                     k_scale=None, v_scale=None,
                     num_splits: int = 1, interpret: bool = False):
    """q: (B,T,H,hd); k/v_pages: (KV,P,ps,hd); page_table: (B,npages) int32;
    pos: (B,) int32 global position of q[:,0] -> (B,T,H,hd).
    ``k_scale``/``v_scale``: optional (KV,P,ps) f32 per-row scale pages for
    an int8 pool — the kernel then dequantizes each K/V tile at load."""
    b, t, h, hd = q.shape
    nkv, _, page_size, _ = k_pages.shape
    g = h // nkv
    npages = page_table.shape[1]
    if npages % num_splits:
        raise ValueError(f"npages {npages} % num_splits {num_splits}")
    pps = npages // num_splits
    scale = 1.0 / math.sqrt(hd)
    quantized = k_scale is not None

    # Clamp table entries so skipped pages still DMA a valid physical page.
    pt = jnp.clip(page_table.astype(jnp.int32), 0, k_pages.shape[1] - 1)
    qr = q.reshape(b, t, nkv, g, hd).transpose(0, 2, 1, 3, 4) \
          .reshape(b, nkv, t * g, hd)

    grid = (b, nkv, num_splits, pps)
    kernel = functools.partial(_flash_verify_kernel, scale=scale,
                               page_size=page_size, pages_per_split=pps,
                               group=g, window=t, quantized=quantized)

    def page_index(bi, kv, sp, pi, pt_ref, pos_ref):
        return (kv, pt_ref[bi, sp * pps + pi], 0, 0)

    def scale_index(bi, kv, sp, pi, pt_ref, pos_ref):
        # Scale pages drop the trailing hd axis but share the page map.
        return (kv, pt_ref[bi, sp * pps + pi], 0)

    in_specs = [
        pl.BlockSpec((1, 1, t * g, hd),
                     lambda bi, kv, sp, pi, pt, ps_: (bi, kv, 0, 0)),
        pl.BlockSpec((1, 1, page_size, hd), page_index),
        pl.BlockSpec((1, 1, page_size, hd), page_index),
    ]
    inputs = [qr, k_pages, v_pages]
    if quantized:
        in_specs += [pl.BlockSpec((1, 1, page_size), scale_index)] * 2
        inputs += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, 1, t * g, hd),
                         lambda bi, kv, sp, pi, pt, ps_: (bi, kv, sp, 0, 0)),
            pl.BlockSpec((1, 1, 1, t * g),
                         lambda bi, kv, sp, pi, pt, ps_: (bi, kv, sp, 0)),
            pl.BlockSpec((1, 1, 1, t * g),
                         lambda bi, kv, sp, pi, pt, ps_: (bi, kv, sp, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((t * g,), jnp.float32),      # running max m
            pltpu.VMEM((t * g,), jnp.float32),      # running denom l
            pltpu.VMEM((t * g, hd), jnp.float32),   # unnormalized accumulator
        ],
    )
    o_part, m_part, l_part = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, nkv, num_splits, t * g, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, nkv, num_splits, t * g), jnp.float32),
            jax.ShapeDtypeStruct((b, nkv, num_splits, t * g), jnp.float32),
        ],
        interpret=interpret,
    )(pt, pos.astype(jnp.int32), *inputs)

    # Associative split combine (FlashDecoding reduction), fp32.
    m_star = jnp.max(m_part, axis=2, keepdims=True)          # (B,KV,1,T*G)
    w = jnp.exp(m_part - m_star)                             # (B,KV,S,T*G)
    l_tot = jnp.sum(w * l_part, axis=2)                      # (B,KV,T*G)
    acc = jnp.sum(w[..., None] * o_part, axis=2)             # (B,KV,T*G,hd)
    out = acc / jnp.maximum(l_tot, 1e-20)[..., None]
    out = out.reshape(b, nkv, t, g, hd).transpose(0, 2, 1, 3, 4)
    return out.reshape(b, t, h, hd).astype(q.dtype)
