from .ops import flash_verify, paged_verify_attention
from .ref import paged_verify_reference

__all__ = ["flash_verify", "paged_verify_attention", "paged_verify_reference"]
