"""Pure-jnp oracle for paged multi-query (speculative-verify) GQA attention.

A verify step scores T = K+1 candidate tokens per decode slot in one forward
pass: the already-verified current token plus K drafted tokens, occupying
global positions ``pos[b] .. pos[b] + T - 1``. Their KV rows have already
been scattered into the block-paged pool (the same pool the decode and
prefill kernels read), so query i of request b attends every pooled KV row
at a position ``<= pos[b] + i`` — the whole verified history plus the causal
lower triangle of the draft window itself. The oracle gathers the logical
KV stream dense and runs masked fp32 attention — the semantics the Pallas
kernel must reproduce.

T=1 degenerates to single-token decode attention with ``lengths = pos + 1``.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.ref import dequant_pages, gather_pages

MASK_VALUE = -1e30


def paged_verify_reference(q, k_pages, v_pages, page_table, pos,
                           k_scale=None, v_scale=None):
    """Multi-query GQA attention over a paged KV cache (speculative verify).

    q: (B, T, H, hd) — RoPE'd queries for the draft window.
    k_pages/v_pages: (KV, P, page_size, hd) — the shared physical pool, with
        the draft window's own KV rows already written.
    page_table: (B, npages) int32 — per-request logical->physical page map.
    pos: (B,) int32 — global position of ``q[:, 0]`` per request (the cache
        holds [0, pos) verified rows plus the freshly written draft rows).
    k_scale/v_scale: optional (KV, P, page_size) f32 per-row scales for an
        int8 pool (see :mod:`repro.kernels.kv_quant`).
    Returns (B, T, H, hd). Rows whose KV writes were routed to the sink page
    (past a slot's budget) produce garbage; callers discard them.
    """
    b, t, h, hd = q.shape
    nkv = k_pages.shape[0]
    g = h // nkv
    if k_scale is not None:
        k_pages = dequant_pages(k_pages, k_scale)
        v_pages = dequant_pages(v_pages, v_scale)
    k = gather_pages(k_pages, page_table)            # (B, S, KV, hd)
    v = gather_pages(v_pages, page_table)
    s_len = k.shape[1]
    qg = q.reshape(b, t, nkv, g, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    q_pos = pos[:, None] + jnp.arange(t)[None, :]                  # (B, T)
    mask = jnp.arange(s_len)[None, None, :] <= q_pos[:, :, None]   # (B, T, S)
    s = jnp.where(mask[:, None, None, :, :], s, MASK_VALUE)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return out.reshape(b, t, h, hd).astype(q.dtype)
