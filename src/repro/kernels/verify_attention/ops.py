"""jit'd public wrappers for the paged speculative-verify kernel.

``flash_verify`` is the raw kernel entry point (interpret-capable for CPU
validation). ``paged_verify_attention`` is what the model verify path calls:
it dispatches to the Pallas kernel on TPU silicon (``attn_impl="pallas"``)
and to the fused-gather jnp reference everywhere else, mirroring
``kernels/decode_attention.paged_decode_attention``.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.decode_attention.ops import default_num_splits

from .kernel import flash_verify_fwd
from .ref import paged_verify_reference


@partial(jax.jit, static_argnames=("num_splits", "interpret"))
def flash_verify(q, k_pages, v_pages, page_table, pos, *,
                 k_scale=None, v_scale=None,
                 num_splits: int = 1, interpret: bool = False):
    return flash_verify_fwd(q, k_pages, v_pages, page_table, pos,
                            k_scale=k_scale, v_scale=v_scale,
                            num_splits=num_splits, interpret=interpret)


def paged_verify_attention(q, k_pages, v_pages, page_table, pos, *,
                           k_scale=None, v_scale=None,
                           impl: str = "pallas", split_budget: int = 32):
    """Paged multi-query verify GQA attention with backend dispatch.

    ``k_scale``/``v_scale``: per-row scale pages for an int8 pool; both
    backends dequantize with identical f32 arithmetic.
    """
    if impl == "pallas" and jax.default_backend() == "tpu":
        splits = default_num_splits(page_table.shape[1],
                                    batch=page_table.shape[0],
                                    split_budget=split_budget)
        return flash_verify_fwd(q, k_pages, v_pages, page_table, pos,
                                k_scale=k_scale, v_scale=v_scale,
                                num_splits=splits)
    return paged_verify_reference(q, k_pages, v_pages, page_table, pos,
                                  k_scale=k_scale, v_scale=v_scale)
