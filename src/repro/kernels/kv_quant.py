"""Symmetric per-row int8 quantization for the paged KV pool.

The pool stores each KV row (one token's keys or values for one layer/head,
``head_dim`` wide) as int8 with one f32 scale per row, organized as "scale
pages" mirroring the data pages: pool ``k``/``v`` are
(L, KV, P, page_size, head_dim) int8 and ``k_scale``/``v_scale`` are
(L, KV, P, page_size) f32. Per-ROW scales — not one scale per page — are
what make incremental decode writes possible: a new token scatters one row
into a partially-filled page, and a per-page scale would force requantizing
every earlier row whenever a louder row arrives. Per-row symmetric
quantization keeps the write O(1) and bounds the absolute error of every
element by ``amax(row) / 254`` (round-to-nearest over [-127, 127]).

The paged attention kernels dequantize inside their K/V tile loads
(``int8_row.astype(f32) * scale[:, None]``) and accumulate in f32, so the
numerics policy is: quantize once on scatter, dequantize per tile read,
never accumulate in int8. At hd=128 a token's KV row costs hd + 4 bytes
instead of 4*hd — ~3.9x more tokens per pool byte.
"""
from __future__ import annotations

import jax.numpy as jnp

# Scale floor: an all-zero row (e.g. the untouched sink page) quantizes to
# zeros with this scale instead of dividing by zero; dequantized values stay
# exactly zero either way.
SCALE_EPS = 1e-12
QMAX = 127.0


def quantize_rows(x):
    """(..., hd) f32-like -> ((..., hd) int8, (...,) f32 per-row scales).

    Symmetric: scale = amax / 127, values round-to-nearest into [-127, 127].
    """
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1), SCALE_EPS) / QMAX
    q = jnp.clip(jnp.round(xf / scale[..., None]), -QMAX, QMAX)
    return q.astype(jnp.int8), scale


def dequantize_rows(q, scale):
    """((..., hd) int8, (...,) f32) -> (..., hd) f32."""
    return q.astype(jnp.float32) * scale[..., None]


def quantize_pool(pool_f32):
    """Quantize a whole f32 page pool {"k","v"} into the int8+scales layout.

    Test/bench helper (the serving path quantizes row-by-row on scatter):
    returns {"k", "v", "k_scale", "v_scale"} with the shapes documented in
    the module docstring.
    """
    out = {}
    for name in ("k", "v"):
        q, s = quantize_rows(pool_f32[name])
        out[name] = q
        out[name + "_scale"] = s
    return out
