"""Benchmark: Table VII-C — scaling strategies (makespan / cost / wait)."""
from __future__ import annotations

import time

from repro.core import run_table7c

PAPER = {  # policy row -> (makespan, spot$, od$, max wait, avg wait)
    ("none", 40): ("07:43:00", 10.26, 74.57, "00:00:00", "00:00:00"),
    ("none", 20): ("08:33:00", 5.98, 40.87, "01:27:00", "00:11:30"),
    ("unlimited", None): ("07:43:00", 3.95, 28.92, "00:30:00", "00:07:39"),
    ("limited", 20): ("08:22:00", 4.52, 26.77, "01:46:00", "00:15:10"),
    ("limited", 10): ("12:50:00", 3.62, 23.18, "05:41:00", "02:08:06"),
}


def hms(s: float) -> str:
    s = int(s)
    return f"{s // 3600:02d}:{s % 3600 // 60:02d}:{s % 60:02d}"


def run(verbose: bool = True, seed: int = 7):
    t0 = time.perf_counter()
    reports = run_table7c(seed=seed)
    elapsed_us = (time.perf_counter() - t0) * 1e6 / len(reports)
    base = reports[0]
    rows = []
    if verbose:
        print("\n== Table VII-C: elastic scaling strategies ==")
        print(f"{'policy':<11}{'nodes':<9}{'makespan':<10}{'spot$':>7}"
              f"{'od$':>8}{'maxwait':>9}{'avgwait':>9}{'sav%':>6}   paper row")
    for r in reports:
        sav = 100 * (1 - r.on_demand_cost / base.on_demand_cost)
        key = (r.policy, r.max_nodes)
        paper = PAPER.get(key, ("-",) * 5)
        rows.append((r, sav))
        if verbose:
            nodes = f"{r.min_nodes},{r.max_nodes if r.max_nodes else '-'}"
            print(f"{r.policy:<11}{nodes:<9}{hms(r.makespan_s):<10}"
                  f"{r.spot_cost:>7.2f}{r.on_demand_cost:>8.2f}"
                  f"{hms(r.max_wait_s):>9}{hms(r.avg_wait_s):>9}{sav:>6.1f}"
                  f"   {paper[0]} / ${paper[1]} / ${paper[2]}")
    unlimited = next(r for r, _ in rows if r.policy == "unlimited")
    headline = base.on_demand_cost / unlimited.spot_cost
    if verbose:
        print(f"headline: static-OD / elastic-spot = {headline:.1f}x "
              f"(paper: 'up to 16x')")
    return [("elastic_scaling.table7c", elapsed_us,
             f"headline_savings={headline:.1f}x")]


if __name__ == "__main__":
    run()
