"""Benchmark: Fig 6 — task-throughput strong scaling with the DB bottleneck.

The paper pushes 10,000 ``sleep(0)`` tasks through worker pools of
{1,2,4,8,16,32} m4.xlarge nodes and observes linear scaling up to 16 nodes
(~4.9 tasks/s/node) before the DynamoDB provisioned capacity saturates the
system at ~80 tasks/s. We reproduce the same dynamics with the live threaded
runtime: the ``StateStore`` token buckets ARE the provisioned capacity; the
per-worker service time models the paper's per-task overhead.

Scaled for a 1-core CI container: a fixed measurement window instead of 10k
tasks (the steady-state rate is the quantity of interest). Our workers spend
2 reads + 3 writes per task, so a 160 reads/s budget caps the system at
~80 tasks/s — the paper's saturation point — putting the knee at 16 workers
exactly as in Fig 6.
"""
from __future__ import annotations

import time

from repro.core import (ExecutableRegistry, JobSpec, KottaService, ObjectStore,
                        PolicyEngine, Principal, Role, StateStore, allow,
                        install_standard_roles)

WORKERS = (1, 2, 4, 8, 16, 24)
PER_WORKER_RATE = 5.0        # paper: 4.90 tasks/s/node
DB_READ_CAP = 160.0          # 2 reads/task -> 80 tasks/s ceiling (Fig 6)
DB_WRITE_CAP = 640.0
WINDOW_S = 6.0


def _service(n_workers: int) -> KottaService:
    engine = PolicyEngine()
    install_standard_roles(engine)
    store = ObjectStore(clock=engine.clock)
    registry = ExecutableRegistry()
    exec_time = 1.0 / PER_WORKER_RATE

    @registry.register("sleep0")
    def sleep0(ctx):
        time.sleep(exec_time)  # paper's sleep(0) + per-task node overhead
        return 0

    svc = KottaService(engine, store, registry,
                       db=StateStore(engine.clock, DB_READ_CAP, DB_WRITE_CAP),
                       watcher_kwargs={"heartbeat_timeout_s": 60.0,
                                       "interval_s": 1.0,
                                       "speculation": False})
    role = Role("bench", policies=[allow(["jobs:*"], ["*"])])
    engine.register_role(role)
    p = Principal("bench")
    engine.authenticator.register_identity(p, "pw")
    engine.bind(p, "bench")
    svc._bench_token = engine.login("bench", "pw")
    svc.start(dev_workers=0, prod_workers=n_workers)
    return svc


def run(verbose: bool = True):
    rows = []
    if verbose:
        print("\n== Fig 6: throughput strong scaling (scaled 1/5) ==")
        print(f"{'workers':>8}{'tasks/s':>9}{'per-node':>9}{'ideal':>7}")
    results = []
    for n in WORKERS:
        svc = _service(n)
        try:
            tok = svc._bench_token
            # enough backlog to keep every worker busy through the window
            backlog = int(2 * WINDOW_S * PER_WORKER_RATE * n + 20)
            jobs = [svc.submit(tok, JobSpec("sleep0", queue="prod"))
                    for _ in range(backlog)]
            t0 = time.perf_counter()
            done0 = sum(w.jobs_done for w in svc.workers())
            time.sleep(WINDOW_S)
            done1 = sum(w.jobs_done for w in svc.workers())
            rate = (done1 - done0) / (time.perf_counter() - t0)
        finally:
            svc.shutdown()
        ideal = n * PER_WORKER_RATE
        results.append((n, rate))
        if verbose:
            print(f"{n:>8}{rate:>9.2f}{rate / n:>9.2f}{ideal:>7.1f}")
        rows.append((f"throughput.workers_{n}", WINDOW_S * 1e6 / max(rate * WINDOW_S, 1),
                     f"tasks_per_s={rate:.2f}"))
    # Fig 6 shape: near-linear to 16 workers, flat 16 -> 24 (DB-bound).
    d = dict(results)
    lin = d.get(16, 0.0) / max(d.get(1, 1e-9) * 16, 1e-9)
    flat = d.get(24, 0.0) / max(d.get(16, 1e-9), 1e-9)
    rows.append(("throughput.linearity_to_16", 0.0, f"{lin:.2f} (paper ~1.0)"))
    rows.append(("throughput.saturation_16_24", 0.0,
                 f"{flat:.2f} (flat => DB-bound, paper-like)"))
    if verbose:
        print(f"linearity to 16 workers: {lin:.2f} (1.0 = ideal); "
              f"r24/r16 = {flat:.2f} (paper flattens past 16 nodes)")
    return rows


if __name__ == "__main__":
    run()
