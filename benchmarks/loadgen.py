"""Open-loop traffic trace generator CLI.

The generator itself lives in :mod:`repro.serve.loadgen` (importable by the
bench AND by ``repro.launch.serve``); this CLI materializes traces for
inspection or replay:

    python benchmarks/loadgen.py --rate 8 --duration 30 --tenants 4 \
        --diurnal-amplitude 0.5 --out /tmp/trace.json
    python benchmarks/loadgen.py --rate 8 --duration 30 --describe

``--describe`` prints the trace's empirical shape — offered load,
per-tenant Zipf skew, class mix, rate-over-time buckets — which is how you
sanity-check a config before spending a saturation sweep on it. The JSON
rows are plain dicts (one per arrival) so any driver can replay them.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import Counter
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.serve.loadgen import TrafficConfig, generate_trace, offered_load


def _describe(trace, cfg: TrafficConfig) -> None:
    print(f"arrivals: {len(trace)} over {cfg.duration_s:.0f}s "
          f"(offered {offered_load(trace, cfg):.2f} req/s, "
          f"configured base {cfg.base_rate_rps:.2f})")
    by_tenant = Counter(a.tenant_idx for a in trace)
    total = max(len(trace), 1)
    print("tenant share (Zipf skew):")
    for t, n in by_tenant.most_common():
        print(f"  tenant {t}: {n:4d} ({100.0 * n / total:.1f}%)")
    inter = sum(1 for a in trace if a.priority == 0)
    print(f"class mix: {inter} interactive / {len(trace) - inter} batch")
    users = len({a.user for a in trace})
    print(f"distinct users: {users}")
    buckets = Counter(int(a.at_s // max(cfg.duration_s / 10, 1e-9))
                      for a in trace)
    print("arrivals per decile (diurnal shape):")
    print("  " + " ".join(f"{buckets.get(i, 0):3d}" for i in range(10)))


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--rate", type=float, default=4.0,
                   help="base arrival rate, req/s")
    p.add_argument("--duration", type=float, default=30.0,
                   help="trace length, virtual seconds")
    p.add_argument("--tenants", type=int, default=4)
    p.add_argument("--users", type=int, default=1_000_000,
                   help="user population behind the tenants (Zipf-ranked)")
    p.add_argument("--zipf-alpha", type=float, default=1.3)
    p.add_argument("--diurnal-amplitude", type=float, default=0.0)
    p.add_argument("--diurnal-period", type=float, default=60.0)
    p.add_argument("--interactive-fraction", type=float, default=0.5)
    p.add_argument("--prefix-tokens", type=int, default=16)
    p.add_argument("--vocab-size", type=int, default=256)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--out", type=Path, default=None,
                   help="write arrival rows as JSON")
    p.add_argument("--describe", action="store_true",
                   help="print the trace's empirical shape")
    args = p.parse_args(argv)

    cfg = TrafficConfig(
        duration_s=args.duration, base_rate_rps=args.rate,
        diurnal_amplitude=args.diurnal_amplitude,
        diurnal_period_s=args.diurnal_period, tenants=args.tenants,
        users=args.users, zipf_alpha=args.zipf_alpha,
        interactive_fraction=args.interactive_fraction,
        prefix_tokens=args.prefix_tokens, vocab_size=args.vocab_size,
        seed=args.seed)
    trace = generate_trace(cfg)
    if args.out is not None:
        rows = [{"at_s": a.at_s, "tenant_idx": a.tenant_idx, "user": a.user,
                 "prompt": list(a.prompt), "max_new": a.max_new,
                 "deadline_s": a.deadline_s, "priority": a.priority}
                for a in trace]
        args.out.write_text(json.dumps(rows))
        print(f"wrote {len(rows)} arrivals to {args.out}")
    if args.describe or args.out is None:
        _describe(trace, cfg)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
