"""CI perf-regression gate: fresh smoke-run metrics vs committed baselines.

The smoke benches (``serve_bench.py --smoke``, ``gateway_bench.py --smoke``)
write machine-readable JSON. This script compares a fresh run against the
``BENCH_*.smoke.json`` baselines committed in the repo and exits nonzero on
any regression, so a perf-path slip fails the PR instead of waiting for a
human to read the artifacts.

Design rules:

- **Gate on ratios and simulated metrics, never on absolute wall-clock.**
  A GitHub runner is not the machine the baseline was recorded on, so raw
  tok/s is meaningless across hosts — but continuous/static *speedup*,
  spec-decode *speedup* and accepted-draft length are normalized within one
  run, and every gateway metric runs on a virtual clock (host-independent).
- **Derived ratios are recomputed from the raw fields**, not read from the
  stored convenience fields: a candidate whose ``continuous_tok_s`` dropped
  20% fails the gate even if its stored ``speedup`` field were stale.
- **A missing metric is a failure**, not a skip: the benches exit nonzero
  on scenario errors, and a JSON that lacks a gated metric is exactly the
  half-run the gate exists to catch.

Usage (CI runs the smokes into a scratch dir first)::

    python benchmarks/serve_bench.py   --smoke --json /tmp/serve.json
    python benchmarks/gateway_bench.py --smoke --json /tmp/gateway.json
    python benchmarks/check_regression.py \
        --serve /tmp/serve.json --gateway /tmp/gateway.json
"""
from __future__ import annotations

import argparse
import json
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import Callable

REPO = Path(__file__).resolve().parent.parent
SERVE_BASELINE = REPO / "BENCH_serve.smoke.json"
GATEWAY_BASELINE = REPO / "BENCH_gateway.smoke.json"


class MetricMissing(Exception):
    pass


def _decode_speedup(r: dict) -> float:
    d = r["decode"][0]
    return d["continuous_tok_s"] / d["static_tok_s"]


def _spec_speedup(r: dict) -> float:
    s = r["spec_decode"]
    return s["spec_decode_tok_s"] / s["base_decode_tok_s"]


def _adaptive_vs_spec(r: dict) -> float:
    s = r["spec_decode"]
    return s["adaptive_decode_tok_s"] / s["spec_decode_tok_s"]


def _low_accept_adaptive_vs_spec(r: dict) -> float:
    s = r["spec_low_accept"]
    return s["adaptive_decode_tok_s"] / s["spec_decode_tok_s"]


def _fleet_tok_ratio(r: dict) -> float:
    f = r["fleet_routing"]
    return f["affinity"]["tok_per_sim_s"] / f["blind"]["tok_per_sim_s"]


def _fleet_ttft_ratio(r: dict) -> float:
    f = r["fleet_routing"]
    return (f["blind"]["interactive_p99_ttft_s"]
            / f["affinity"]["interactive_p99_ttft_s"])


def _kv_tok_s_ratio(r: dict) -> float:
    q = r["quantized_kv"]
    return q["int8_decode_tok_s"] / q["f32_decode_tok_s"]


def _kv_capacity_ratio(r: dict) -> float:
    q = r["quantized_kv"]
    return q["f32_bytes_per_slot_token"] / q["int8_bytes_per_slot_token"]


def _fault_ttft_ratio(r: dict) -> float:
    f = r["fault_recovery"]
    return (f["requeue"]["recovered_ttft_mean_s"]
            / f["evacuate"]["recovered_ttft_mean_s"])


def _fault_goodput_ratio(r: dict) -> float:
    f = r["fault_recovery"]
    return (f["evacuate"]["tok_per_sim_s"]
            / f["requeue"]["tok_per_sim_s"])


def _resume_ttft_ratio(r: dict) -> float:
    s = r["session_resume"]
    return (s["reprefill"]["resumed_ttft_mean_s"]
            / s["tiered"]["resumed_ttft_mean_s"])


def _resume_usd_per_1k(r: dict) -> float:
    t = r["session_resume"]["tiered"]
    return ((t["cost_usd"] + t["storage_cost_usd"]) * 1e3
            / max(t["resumed_tokens_out"], 1))


def _resume_restores(r: dict) -> float:
    return r["session_resume"]["tiered"]["kv_restores"]


@dataclass(frozen=True)
class Metric:
    """One gated metric.

    ``direction`` is what a HEALTHY candidate does: ``higher`` means the
    candidate must stay >= baseline * (1 - rel_tol); ``lower`` means it must
    stay <= baseline * (1 + rel_tol). ``rel_tol`` absorbs run-to-run noise —
    0.0 for metrics that are deterministic on the virtual clock.
    """

    bench: str                      # "serve" | "gateway"
    name: str
    extract: Callable[[dict], float]
    direction: str                  # "higher" | "lower"
    rel_tol: float
    # Additive slack on top of the relative band — for metrics whose
    # baseline sits at/near zero (a pure relative tolerance degenerates to
    # an exact-match check there).
    abs_tol: float = 0.0


METRICS = [
    # -- serve smoke: same-host normalized ratios ---------------------------
    Metric("serve", "decode.continuous_vs_static_speedup", _decode_speedup,
           "higher", 0.15),        # a 20% decode-tok/s drop MUST fail
    Metric("serve", "spec_decode.speedup", _spec_speedup, "higher", 0.35),
    Metric("serve", "spec_decode.mean_accepted_len",
           lambda r: r["spec_decode"]["mean_accepted_len"], "higher", 0.35),
    Metric("serve", "shared_prefix.hit_rate",
           lambda r: r["shared_prefix"]["prefix_hit_rate"], "higher", 0.05),
    # Adaptive speculation must track fixed-K on the high-acceptance
    # workload and hold its recovery on the adversarial one.
    Metric("serve", "spec_decode.adaptive_vs_spec", _adaptive_vs_spec,
           "higher", 0.35),
    Metric("serve", "spec_low_accept.adaptive_vs_spec",
           _low_accept_adaptive_vs_spec, "higher", 0.25),
    # int8 KV: decode-rate ratio is host-noisy (0.35 band); the capacity
    # ratio is a pure layout property — any drift (dropped scale page,
    # widened dtype) is a bug, so it gates exactly.
    Metric("serve", "quantized_kv.tok_s_ratio", _kv_tok_s_ratio,
           "higher", 0.35),
    Metric("serve", "quantized_kv.capacity_ratio", _kv_capacity_ratio,
           "higher", 0.0),
    # -- gateway smoke: virtual-clock, host-independent ---------------------
    Metric("gateway", "trace.cost_ratio_static_over_elastic",
           lambda r: r["trace"]["cost_ratio_static_over_elastic"],
           "higher", 0.10),
    Metric("gateway", "trace.elastic.deadline_hit_rate",
           lambda r: r["trace"]["elastic"]["deadline_hit_rate"],
           "higher", 0.0),
    Metric("gateway", "interactive_burst.ttft_reduction_s",
           lambda r: r["interactive_burst"]["ttft_reduction_s"],
           "higher", 0.20),
    Metric("gateway", "interactive_burst.preempt.p99_ttft_s",
           lambda r: r["interactive_burst"]["preempt"]
           ["interactive_p99_ttft_s"], "lower", 0.20,
           abs_tol=0.1),    # baseline ~0: allow one round of virtual time
    Metric("gateway", "interactive_burst.preempt.interactive_sla_rate",
           lambda r: r["interactive_burst"]["preempt"]
           ["interactive_sla_rate"], "higher", 0.0),
    # Fleet routing: affinity must keep beating blind on the same trace.
    # Both ratios recomputed from the raw per-mode fields (virtual clock,
    # host-independent); page-ship bytes are a pure KV-layout constant —
    # any drift (dropped scale page, widened dtype, extra pages shipped)
    # is a bug, so they gate exactly.
    Metric("gateway", "fleet_routing.tok_ratio_affinity_over_blind",
           _fleet_tok_ratio, "higher", 0.10),
    Metric("gateway", "fleet_routing.ttft_p99_ratio_blind_over_affinity",
           _fleet_ttft_ratio, "higher", 0.25),
    Metric("gateway", "fleet_routing.page_ship_bytes_per_request",
           lambda r: r["fleet_routing"]["page_ship_bytes_per_request"],
           "lower", 0.0),
    # Fault recovery: evacuation must keep beating abort-and-requeue on the
    # same scripted fault schedule. Both ratios recomputed from the raw
    # per-mode fields (virtual clock, host-independent). Token identity
    # across recovery modes is binary — any divergence is a correctness
    # bug — and an evacuation count of zero means the graceful path never
    # ran, so both gate exactly.
    Metric("gateway",
           "fault_recovery.recovered_ttft_ratio_requeue_over_evacuate",
           _fault_ttft_ratio, "higher", 0.30),
    Metric("gateway", "fault_recovery.goodput_ratio_evacuate_over_requeue",
           _fault_goodput_ratio, "higher", 0.10),
    Metric("gateway", "fault_recovery.token_identity",
           lambda r: 1.0 if r["fault_recovery"]["token_identity"] else 0.0,
           "higher", 0.0),
    Metric("gateway", "fault_recovery.evacuate.evacuations",
           lambda r: r["fault_recovery"]["evacuate"]["evacuations"],
           "higher", 0.0),
    # Session resume: tier restores must keep beating re-prefill on the
    # same trace. Ratio and $/1k recomputed from the raw per-mode fields
    # (virtual clock, host-independent). Token identity across
    # demote/restore — f32 AND the int8 scale-page leg — is binary: any
    # divergence means a tier round-trip corrupted a page. The restore
    # count is structural (trace + demotion state, no numerics), so it
    # gates EXACTLY in both directions: a drop means resumes stopped
    # coming back through the store, a rise means the device radix or the
    # affinity skip quietly broke.
    Metric("gateway", "session_resume.resumed_ttft_ratio",
           _resume_ttft_ratio, "higher", 0.25),
    Metric("gateway", "session_resume.tiered.usd_per_1k_resumed_tokens",
           _resume_usd_per_1k, "lower", 0.15),
    Metric("gateway", "session_resume.tiered.kv_restores",
           _resume_restores, "higher", 0.0),
    Metric("gateway", "session_resume.tiered.kv_restores(upper)",
           _resume_restores, "lower", 0.0),
    Metric("gateway", "session_resume.token_identity",
           lambda r: 1.0 if r["session_resume"]["token_identity"] else 0.0,
           "higher", 0.0),
    Metric("gateway", "session_resume.int8_token_identity",
           lambda r: (1.0 if r["session_resume"]["int8_token_identity"]
                      else 0.0), "higher", 0.0),
    # Saturation: open-loop offered-load sweep on the virtual clock. The
    # max sustained rate at the 99% bar is deterministic, so it gates
    # exactly — an admission/scheduling slip that drops the wall a whole
    # load point MUST fail. The sharding win is binary: throttles at the
    # top offered load must still drop when the telemetry table is
    # sharded, else the write wall silently came back.
    Metric("gateway", "saturation.max_sustained_req_s",
           lambda r: r["saturation"]["max_sustained_req_s"],
           "higher", 0.0),
    Metric("gateway", "saturation.sharding_cuts_throttles",
           lambda r: 1.0 if (r["saturation"]["statestore"]
                             ["throttled_sharded"]
                             < r["saturation"]["statestore"]
                             ["throttled_single"]) else 0.0,
           "higher", 0.0),
]

# Metric families the unified registry must expose after a saturation run.
# This is a schema gate, not a perf gate: an instrumentation refactor that
# silently drops a family (renames it, forgets to bind it) breaks every
# dashboard scraping it, so a missing name fails the gate by itself.
REQUIRED_METRIC_FAMILIES = (
    "kotta_requests_total",
    "kotta_requests_completed_total",
    "kotta_requests_shed_total",
    "kotta_request_ttft_seconds",
    "kotta_request_tpot_seconds",
    "kotta_request_queue_wait_seconds",
    "kotta_tenant_tokens_total",
    "kotta_tenant_cost_usd_total",
    "kotta_replica_occupancy",
    "kotta_replica_queue_depth",
    "kotta_replica_prefix_hit_rate",
    "kotta_replica_health_transitions_total",
    "kotta_gateway_queue_depth",
    "kotta_gateway_live_replicas",
    "kotta_slo_burn_rate",
    "kotta_slo_target",
    "kotta_routing_decisions_total",
    "kotta_engine_admitted_total",
)


def check_metric_schema(gateway: dict, out=sys.stdout) -> list[str]:
    """Required metric families must appear in the saturation results."""
    fams = set((gateway.get("saturation") or {}).get("metric_families")
               or [])
    missing = sorted(f for f in REQUIRED_METRIC_FAMILIES if f not in fams)
    label = "gateway:saturation.metric_schema"
    if missing:
        print(f"{label:<48}{'MISSING':>39}", file=out)
        return [f"gateway:saturation.metric_families lacks required "
                f"families: {', '.join(missing)}"]
    print(f"{label:<48}{len(fams):>10d}{'present':>11}{'':>10}{'ok':>8}",
          file=out)
    return []


def _get(metric: Metric, results: dict, which: str) -> float:
    try:
        return float(metric.extract(results))
    except (KeyError, IndexError, TypeError, ZeroDivisionError) as e:
        raise MetricMissing(
            f"{metric.bench}:{metric.name} unreadable in {which} results "
            f"({type(e).__name__}: {e})") from e


def check(serve: dict | None, gateway: dict | None,
          serve_base: dict | None, gateway_base: dict | None,
          out=sys.stdout) -> list[str]:
    """Compare candidates against baselines; returns failure strings."""
    results = {"serve": serve, "gateway": gateway}
    baselines = {"serve": serve_base, "gateway": gateway_base}
    failures: list[str] = []
    print(f"{'metric':<48}{'baseline':>10}{'candidate':>11}{'limit':>10}"
          f"{'status':>8}", file=out)
    for m in METRICS:
        cand_res, base_res = results[m.bench], baselines[m.bench]
        if cand_res is None or base_res is None:
            continue                        # bench not under test this call
        for res, which in ((cand_res, "candidate"), (base_res, "baseline")):
            if res.get("failures"):
                failures.append(f"{m.bench} {which} JSON records scenario "
                                f"failures: {res['failures']}")
        try:
            base = _get(m, base_res, "baseline")
            cand = _get(m, cand_res, "candidate")
        except MetricMissing as e:
            failures.append(str(e))
            print(f"{m.bench + ':' + m.name:<48}{'MISSING':>39}", file=out)
            continue
        if m.direction == "higher":
            limit = base * (1.0 - m.rel_tol) - m.abs_tol
            ok = cand >= limit
        else:
            limit = base * (1.0 + m.rel_tol) + m.abs_tol
            ok = cand <= limit
        status = "ok" if ok else "FAIL"
        print(f"{m.bench + ':' + m.name:<48}{base:>10.3f}{cand:>11.3f}"
              f"{limit:>10.3f}{status:>8}", file=out)
        if not ok:
            failures.append(
                f"{m.bench}:{m.name} regressed: {cand:.4f} vs baseline "
                f"{base:.4f} (limit {limit:.4f}, direction {m.direction})")
    if gateway is not None:
        failures.extend(check_metric_schema(gateway, out=out))
    # Deduplicate the scenario-failure complaints (added once per metric).
    seen, uniq = set(), []
    for f in failures:
        if f not in seen:
            seen.add(f)
            uniq.append(f)
    return uniq


def _load(path: str | Path | None) -> dict | None:
    if path is None:
        return None
    return json.loads(Path(path).read_text())


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--serve", default=None,
                    help="fresh serve smoke JSON (candidate)")
    ap.add_argument("--gateway", default=None,
                    help="fresh gateway smoke JSON (candidate)")
    ap.add_argument("--serve-baseline", default=SERVE_BASELINE,
                    help=f"baseline (default: {SERVE_BASELINE})")
    ap.add_argument("--gateway-baseline", default=GATEWAY_BASELINE,
                    help=f"baseline (default: {GATEWAY_BASELINE})")
    args = ap.parse_args()
    if args.serve is None and args.gateway is None:
        ap.error("nothing to check: pass --serve and/or --gateway")
    failures = check(
        _load(args.serve), _load(args.gateway),
        _load(args.serve_baseline if args.serve else None),
        _load(args.gateway_baseline if args.gateway else None))
    if failures:
        print(f"\nREGRESSION GATE FAILED ({len(failures)}):")
        for f in failures:
            print(f"  {f}")
        return 1
    print("\nregression gate: all metrics within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
