"""Microbenchmark: wall-clock per train/serve step on a reduced model (CPU).

Not a TPU number — a regression canary for the step-construction path
(jit cache, microbatching, optimizer)."""
from __future__ import annotations

import time

import jax

from repro.configs import get_reduced_config
from repro.models import get_family
from repro.models.params import init_params
from repro.train import AdamWConfig, adamw
from repro.train.train_step import build_train_step


def run(verbose: bool = True):
    cfg = get_reduced_config("internlm2-1.8b")
    fam = get_family(cfg)
    params = init_params(fam.layout(cfg), jax.random.PRNGKey(0),
                         cfg.param_dtype)
    opt_cfg = AdamWConfig(warmup_steps=1, decay_steps=100)
    opt_state = adamw.init(opt_cfg, params)
    step = jax.jit(build_train_step(cfg, opt_cfg))
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (4, 64), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (4, 64), 0, cfg.vocab_size)}
    params, opt_state, m = step(params, opt_state, batch)  # compile
    jax.block_until_ready(m["total_loss"])
    n = 10
    t0 = time.perf_counter()
    for _ in range(n):
        params, opt_state, m = step(params, opt_state, batch)
    jax.block_until_ready(m["total_loss"])
    us = (time.perf_counter() - t0) * 1e6 / n
    if verbose:
        print(f"\n== train-step microbench (reduced internlm2, CPU) ==")
        print(f"per-step: {us:.0f} us, loss={float(m['total_loss']):.4f}")
    return [("train_microbench.step", us,
             f"loss={float(m['total_loss']):.4f}")]


if __name__ == "__main__":
    run()
